"""Paper Tab. II / Eq. 1-2 reproduction: analytic communication volumes per
DLRM config, cross-checked against the collective bytes parsed out of the
compiled dry-run HLO.

    Eq. 1:  SZ_allreduce  = sum_l (f_i^l * f_o^l + f_o^l)   (per rank,
            rank-count independent -> the strong-scaling wall)
    Eq. 2:  SZ_alltoall   = S * N * E                        (global; per-rank
            share shrinks as ranks grow)
"""

import json
from pathlib import Path

from repro.configs.dlrm_paper import dlrm_large, dlrm_mlperf, dlrm_small
from repro.models.mlp import allreduce_bytes

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def analytic(cfg):
    sz_allreduce = allreduce_bytes(cfg.bottom_sizes) + \
        allreduce_bytes(cfg.top_sizes)
    S, N, E = len(cfg.table_rows), cfg.batch, cfg.emb_dim
    sz_alltoall = S * N * E * 4
    emb_gib = cfg.spec.bytes(4) / 2**30
    return sz_allreduce, sz_alltoall, emb_gib


def rows():
    out = []
    for mk, name in ((dlrm_small, "dlrm-small"), (dlrm_large, "dlrm-large"),
                     (dlrm_mlperf, "dlrm-mlperf")):
        cfg = mk()
        ar, a2a, emb = analytic(cfg)
        out.append((f"{name}_eq1_allreduce_MB", ar / 2**20, "paper Eq.1"))
        out.append((f"{name}_eq2_alltoall_MB", a2a / 2**20, "paper Eq.2"))
        out.append((f"{name}_emb_capacity_GiB", emb, "paper Tab.II row 1"))
        f = RESULTS / f"{name}__train_tablewise__pod1x16x16.json"
        if f.exists():
            rec = json.loads(f.read_text())
            if rec.get("status") == "ok":
                coll = rec["collectives"]["bytes_by_op"]
                out.append((f"{name}_measured_a2a_MB_per_dev",
                            coll.get("all-to-all", 0) / 2**20,
                            "compiled HLO (table mode)"))
    return out


def main():
    for name, val, derived in rows():
        print(f"{name},{val:.2f},{derived}")


if __name__ == "__main__":
    main()
