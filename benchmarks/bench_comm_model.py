"""Paper Tab. II / Eq. 1-2 reproduction + staged-pipeline overlap model.

Analytic communication volumes per DLRM config, cross-checked against the
collective bytes parsed out of compiled HLO:

    Eq. 1:  SZ_allreduce  = sum_l (f_i^l * f_o^l + f_o^l)   (per rank,
            rank-count independent -> the strong-scaling wall)
    Eq. 2:  SZ_alltoall   = S * N * E                        (global; per-rank
            share shrinks as ranks grow)

``--microbatches M0,M1,...`` additionally evaluates the staged microbatch
pipeline (repro/core/pipeline.py) at each M: the analytic step-time model
applies the paper's Sect. VI comm/compute OVERLAP term — with M
microbatches, microbatch i+1's index exchange + all-to-all runs under
microbatch i's dense compute, so

    t_serial(M)  = M * (t_ex/M + t_comp/M) + t_tail          (no overlap)
    t_overlap(M) = t_ex/M + (M-1) * max(t_comp/M, t_ex/M)
                   + t_comp/M + t_tail                        (pipelined)

and the overlap efficiency is the fraction of exchange time hidden under
compute.  Each M is also lowered+compiled on a forced-multi-device CPU
subprocess (the pipeline's regression surface) and, without ``--dry-run``,
timed end-to-end (CPU wall-clock: schedule-shape only, NOT
hardware-representative — the modeled numbers target TPU_V5E).  Results
land in ``BENCH_pipeline.json``.
"""

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

from repro.configs.dlrm_paper import dlrm_large, dlrm_mlperf, dlrm_small
from repro.hw import TPU_V5E
from repro.models.mlp import allreduce_bytes

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"
SRC = ROOT / "src"


def analytic(cfg):
    sz_allreduce = allreduce_bytes(cfg.bottom_sizes) + \
        allreduce_bytes(cfg.top_sizes)
    S, N, E = len(cfg.table_rows), cfg.batch, cfg.emb_dim
    sz_alltoall = S * N * E * 4
    emb_gib = cfg.spec.bytes(4) / 2**30
    return sz_allreduce, sz_alltoall, emb_gib


def dense_flops(cfg) -> float:
    """fwd+bwd MLP FLOPs per GLOBAL batch (3x fwd: fwd + dgrad + wgrad)."""
    total = 0
    for sizes in (cfg.bottom_sizes, cfg.top_sizes):
        for cin, cout in zip(sizes[:-1], sizes[1:]):
            total += 2 * cin * cout * cfg.batch
    return 3.0 * total


def pipeline_model(cfg, ranks: int, M: int, chip=TPU_V5E) -> dict:
    """Modeled per-rank step time with and without the overlap term."""
    S, N, E, P = len(cfg.table_rows), cfg.batch, cfg.emb_dim, cfg.pooling
    ici_bw = chip.ici_bw_per_link * chip.ici_links
    # per-rank exchange volume per STEP: index stream (int32) + the
    # fwd/bwd layout-switch share of Eq. 2 (both directions)
    idx_bytes = S * N * P * 4 / ranks
    a2a_bytes = 2 * (S * N * E * 4) / ranks
    t_ex = (idx_bytes + a2a_bytes) / ici_bw
    t_comp = dense_flops(cfg) / ranks / chip.peak_flops_bf16
    # tail (not pipelined): sparse touched-row update + dense RS+AG
    sz_ar = allreduce_bytes(cfg.bottom_sizes) + allreduce_bytes(cfg.top_sizes)
    t_tail = (sz_ar / ici_bw
              + (2 * N * S * E * 4 / ranks) / chip.hbm_bw)
    ex_mb, comp_mb = t_ex / M, t_comp / M
    t_serial = M * (ex_mb + comp_mb) + t_tail
    t_overlap = ex_mb + (M - 1) * max(comp_mb, ex_mb) + comp_mb + t_tail
    hidden = (M - 1) * min(comp_mb, ex_mb)
    return {
        "microbatches": M,
        "exchange_ms_per_microbatch": ex_mb * 1e3,
        "compute_ms_per_microbatch": comp_mb * 1e3,
        "tail_ms": t_tail * 1e3,
        "modeled_serial_ms": t_serial * 1e3,
        "modeled_overlap_ms": t_overlap * 1e3,
        "overlap_efficiency": (hidden / t_ex) if t_ex else 0.0,
    }


# ---------------------------------------------------------------------------
# Measured leg: lower/compile (and optionally time) the pipelined step on a
# forced-multi-device CPU subprocess.
# ---------------------------------------------------------------------------

SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ranks}"
import json, time, jax, jax.numpy as jnp, numpy as np
from repro.core.dlrm import DLRMConfig, make_train_step, init_state
from repro.core import sharded_embedding as se
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import parse_collective_bytes

mesh = make_mesh((1, {ranks}), ("data", "model"))
cfg = DLRMConfig(name="bench", num_dense=32, bottom=(64, 16), top=(64,),
                 table_rows=(2000,) * 8, emb_dim=16, pooling=5,
                 batch={batch}, emb_mode="table", microbatches={mb})
step, shardings, bspecs, layout = make_train_step(cfg, mesh)
state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
rng = np.random.default_rng(0)
idx = np.stack([rng.integers(0, m, ({batch}, 5))
                for m in cfg.table_rows], 1).astype(np.int32)
idx = np.asarray(se.permute_indices(layout, jnp.asarray(idx)))
batch = {{"idx": jnp.asarray(idx),
         "dense_x": jnp.asarray(rng.standard_normal(({batch}, 32)),
                                jnp.bfloat16),
         "labels": jnp.asarray(rng.integers(0, 2, {batch}), jnp.float32)}}
lowered = step.lower(state, batch)
compiled = lowered.compile()
coll = parse_collective_bytes(compiled.as_text())
measured_ms = None
if not {dry_run}:
    state, loss = step(state, batch)     # warm donation-compatible call
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(5):
        state, loss = step(state, batch)
    jax.block_until_ready(loss)
    measured_ms = (time.perf_counter() - t0) / 5 * 1e3
print(json.dumps(dict(microbatches={mb}, measured_ms=measured_ms,
                      collective_bytes=coll["bytes_by_op"],
                      collective_counts=coll["counts"])))
"""


def run_measured(ranks: int, batch: int, mb: int, dry_run: bool) -> dict:
    env = dict(os.environ, PYTHONPATH=str(SRC))
    code = textwrap.dedent(SUB.format(ranks=ranks, batch=batch, mb=mb,
                                      dry_run=dry_run))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def rows():
    out = []
    for mk, name in ((dlrm_small, "dlrm-small"), (dlrm_large, "dlrm-large"),
                     (dlrm_mlperf, "dlrm-mlperf")):
        cfg = mk()
        ar, a2a, emb = analytic(cfg)
        out.append((f"{name}_eq1_allreduce_MB", ar / 2**20, "paper Eq.1"))
        out.append((f"{name}_eq2_alltoall_MB", a2a / 2**20, "paper Eq.2"))
        out.append((f"{name}_emb_capacity_GiB", emb, "paper Tab.II row 1"))
        f = RESULTS / f"{name}__train_tablewise__pod1x16x16.json"
        if f.exists():
            rec = json.loads(f.read_text())
            if rec.get("status") == "ok":
                coll = rec["collectives"]["bytes_by_op"]
                out.append((f"{name}_measured_a2a_MB_per_dev",
                            coll.get("all-to-all", 0) / 2**20,
                            "compiled HLO (table mode)"))
    return out


def pipeline_rows(microbatches, ranks: int, batch: int, dry_run: bool,
                  json_path: Path):
    cfg_model = dlrm_small(mode="table")
    points = []
    out = []
    for M in microbatches:
        rec = pipeline_model(cfg_model, ranks=64, M=M)
        measured = run_measured(ranks, batch, M, dry_run)
        rec.update(measured)
        points.append(rec)
        out.append((f"pipeline_M{M}_modeled_serial_ms",
                    rec["modeled_serial_ms"], "no-overlap model @64r"))
        out.append((f"pipeline_M{M}_modeled_overlap_ms",
                    rec["modeled_overlap_ms"], "Sect.VI overlap model @64r"))
        out.append((f"pipeline_M{M}_overlap_efficiency",
                    rec["overlap_efficiency"], "hidden/total exchange"))
        if rec.get("measured_ms") is not None:
            out.append((f"pipeline_M{M}_measured_ms", rec["measured_ms"],
                        f"CPU wall-clock {ranks}r (schedule shape only)"))
    json_path.write_text(json.dumps({
        "model_config": cfg_model.name,
        "modeled_chip": TPU_V5E.name,
        "modeled_ranks": 64,
        "measured_ranks": ranks,
        "measured_batch": batch,
        "measured_backend": "cpu-forced-devices"
                            + (" (dry-run, compile only)" if dry_run else ""),
        "points": points,
    }, indent=2))
    out.append(("pipeline_json", 1.0, str(json_path)))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--microbatches", default=None,
                    help="comma list, e.g. 1,2,4: evaluate the staged "
                         "pipeline at each M (model + compile + measure)")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile each M but skip wall-clock timing")
    ap.add_argument("--ranks", type=int, default=8,
                    help="forced device count for the measured leg")
    ap.add_argument("--batch", type=int, default=64,
                    help="global batch for the measured leg")
    ap.add_argument("--json", default=str(ROOT / "BENCH_pipeline.json"))
    args = ap.parse_args(argv)

    for name, val, derived in rows():
        print(f"{name},{val:.2f},{derived}")
    if args.microbatches:
        ms = [int(x) for x in args.microbatches.split(",") if x]
        for name, val, derived in pipeline_rows(
                ms, args.ranks, args.batch, args.dry_run, Path(args.json)):
            print(f"{name},{val:.4f},{derived}")


if __name__ == "__main__":
    main()
