"""Paper Tab. II / Eq. 1-2 reproduction + staged-pipeline overlap model.

Analytic communication volumes per DLRM config, cross-checked against the
collective bytes parsed out of compiled HLO:

    Eq. 1:  SZ_allreduce  = sum_l (f_i^l * f_o^l + f_o^l)   (per rank,
            rank-count independent -> the strong-scaling wall)
    Eq. 2:  SZ_alltoall   = S * N * E                        (global; per-rank
            share shrinks as ranks grow)

``--microbatches M0,M1,...`` additionally evaluates the staged microbatch
pipeline (repro/core/pipeline.py) at each M: the analytic step-time model
applies the paper's Sect. VI comm/compute OVERLAP term — with M
microbatches, microbatch i+1's index exchange + all-to-all runs under
microbatch i's dense compute, so

    t_serial(M)  = M * (t_ex/M + t_comp/M) + t_tail          (no overlap)
    t_overlap(M) = t_ex/M + (M-1) * max(t_comp/M, t_ex/M)
                   + t_comp/M + t_tail                        (pipelined)

and the overlap efficiency is the fraction of exchange time hidden under
compute.  Each M is also lowered+compiled on a forced-multi-device CPU
subprocess (the pipeline's regression surface) and, without ``--dry-run``,
timed end-to-end (CPU wall-clock: schedule-shape only, NOT
hardware-representative — the modeled numbers target TPU_V5E).  Results
land in ``BENCH_pipeline.json``.

``--exchange-dtype D0,D1,...`` (e.g. ``fp32,bf16``) additionally evaluates
the compressed exchange wire formats (repro/dist/exchange.py) at each
dtype: an analytic per-rank wire-volume model at the 64 modeled ranks
(the bwd dY all_to_all share of Eq. 2 + the dense-gradient
reduce-scatter share of Eq. 1 scale with the wire itemsize; the index
stream, the fwd layout switch, and the always-bf16 weight all-gather do
not), the Sect. VI overlap model re-run with the compressed exchange,
and a compiled-HLO leg (``exchange_dtype`` threaded into the measured
subprocess) whose collective bytes shrink accordingly.  Paired rows land
in the ``wire`` section next to ``wire_reduction_x`` — the modeled
compressible-byte reduction vs the fp32 wire, an EXACT gate key
(benchmarks/check_bench.py): it is a pure ratio of itemsizes, 2.0 for
bf16 — and ``wire_reduction_ok`` (>= 1.9, the acceptance floor).

``--cache-rows K0,K1,...`` additionally measures the frequency-tiered
hot-row cache (repro/core/cache.py, docs/cache.md) at each hot_rows=K on
a zipf(1.05) stream: the subprocess trains the table-mode pipelined step
for a few steps so the touch counters promote a real hot set, then reads
the measured all-hot-bag hit rate.  A bag served from the replicated hot
slab ships no all-to-all payload, so the paired rows report the payload-
effective exchange volume ``a2a * (1 - hit_rate)`` next to the K=0
baseline — the index stream (promotion is counter-local) and the HLO
collective set are unchanged.  The JSON write is a KEY-STABLE MERGE (same
contract as bench_split_sgd.py): a cache-only or pipeline-only run
updates exactly the sections it computed.
"""

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

from repro.configs.dlrm_paper import dlrm_large, dlrm_mlperf, dlrm_small
from repro.hw import TPU_V5E
from repro.models.mlp import allreduce_bytes

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"
SRC = ROOT / "src"


def analytic(cfg):
    sz_allreduce = allreduce_bytes(cfg.bottom_sizes) + \
        allreduce_bytes(cfg.top_sizes)
    S, N, E = len(cfg.table_rows), cfg.batch, cfg.emb_dim
    sz_alltoall = S * N * E * 4
    emb_gib = cfg.spec.bytes(4) / 2**30
    return sz_allreduce, sz_alltoall, emb_gib


def dense_flops(cfg) -> float:
    """fwd+bwd MLP FLOPs per GLOBAL batch (3x fwd: fwd + dgrad + wgrad)."""
    total = 0
    for sizes in (cfg.bottom_sizes, cfg.top_sizes):
        for cin, cout in zip(sizes[:-1], sizes[1:]):
            total += 2 * cin * cout * cfg.batch
    return 3.0 * total


def pipeline_model(cfg, ranks: int, M: int, chip=TPU_V5E) -> dict:
    """Modeled per-rank step time with and without the overlap term."""
    S, N, E, P = len(cfg.table_rows), cfg.batch, cfg.emb_dim, cfg.pooling
    ici_bw = chip.ici_bw_per_link * chip.ici_links
    # per-rank exchange volume per STEP: index stream (int32) + the
    # fwd/bwd layout-switch share of Eq. 2 (both directions)
    idx_bytes = S * N * P * 4 / ranks
    a2a_bytes = 2 * (S * N * E * 4) / ranks
    t_ex = (idx_bytes + a2a_bytes) / ici_bw
    t_comp = dense_flops(cfg) / ranks / chip.peak_flops_bf16
    # tail (not pipelined): sparse touched-row update + dense RS+AG
    sz_ar = allreduce_bytes(cfg.bottom_sizes) + allreduce_bytes(cfg.top_sizes)
    t_tail = (sz_ar / ici_bw
              + (2 * N * S * E * 4 / ranks) / chip.hbm_bw)
    ex_mb, comp_mb = t_ex / M, t_comp / M
    t_serial = M * (ex_mb + comp_mb) + t_tail
    t_overlap = ex_mb + (M - 1) * max(comp_mb, ex_mb) + comp_mb + t_tail
    hidden = (M - 1) * min(comp_mb, ex_mb)
    return {
        "microbatches": M,
        "exchange_ms_per_microbatch": ex_mb * 1e3,
        "compute_ms_per_microbatch": comp_mb * 1e3,
        "tail_ms": t_tail * 1e3,
        "modeled_serial_ms": t_serial * 1e3,
        "modeled_overlap_ms": t_overlap * 1e3,
        "overlap_efficiency": (hidden / t_ex) if t_ex else 0.0,
    }


# ---------------------------------------------------------------------------
# Measured leg: lower/compile (and optionally time) the pipelined step on a
# forced-multi-device CPU subprocess.
# ---------------------------------------------------------------------------

SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ranks}"
import json, time, jax, jax.numpy as jnp, numpy as np
from repro.core.dlrm import DLRMConfig, make_train_step, init_state
from repro.core import sharded_embedding as se
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import parse_collective_bytes

mesh = make_mesh((1, {ranks}), ("data", "model"))
cfg = DLRMConfig(name="bench", num_dense=32, bottom=(64, 16), top=(64,),
                 table_rows=(2000,) * 8, emb_dim=16, pooling=5,
                 batch={batch}, emb_mode="table", microbatches={mb},
                 exchange_dtype={exdt})
step, shardings, bspecs, layout = make_train_step(cfg, mesh)
state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
rng = np.random.default_rng(0)
idx = np.stack([rng.integers(0, m, ({batch}, 5))
                for m in cfg.table_rows], 1).astype(np.int32)
idx = np.asarray(se.permute_indices(layout, jnp.asarray(idx)))
batch = {{"idx": jnp.asarray(idx),
         "dense_x": jnp.asarray(rng.standard_normal(({batch}, 32)),
                                jnp.bfloat16),
         "labels": jnp.asarray(rng.integers(0, 2, {batch}), jnp.float32)}}
lowered = step.lower(state, batch)
compiled = lowered.compile()
coll = parse_collective_bytes(compiled.as_text())
measured_ms = None
if not {dry_run}:
    state, loss = step(state, batch)     # warm donation-compatible call
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(5):
        state, loss = step(state, batch)
    jax.block_until_ready(loss)
    measured_ms = (time.perf_counter() - t0) / 5 * 1e3
print(json.dumps(dict(microbatches={mb}, measured_ms=measured_ms,
                      collective_bytes=coll["bytes_by_op"],
                      collective_counts=coll["counts"])))
"""


def run_measured(ranks: int, batch: int, mb: int, dry_run: bool,
                 exchange_dtype: str | None = None) -> dict:
    return _run_sub(SUB.format(ranks=ranks, batch=batch, mb=mb,
                               dry_run=dry_run,
                               exdt=repr(exchange_dtype)))


def _run_sub(code: str) -> dict:
    env = dict(os.environ, PYTHONPATH=str(SRC))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


# Hot-row cache leg: train the REAL table-mode pipelined step on a
# zipf(1.05) stream so the counter-driven promotion picks an actual hot
# set, then measure the all-hot-bag hit rate on a held-out batch.  The
# batch stream is seed-deterministic and promotion is integer-exact, so
# hit_rate is an EXACT gate key (benchmarks/check_bench.py), not a
# tolerance-band one.
SUB_CACHE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ranks}"
import json, jax, jax.numpy as jnp, numpy as np
from repro.core.dlrm import DLRMConfig, make_train_step, init_state
from repro.core import cache as hot_cache
from repro.data.synthetic import zipf_indices
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import parse_collective_bytes

mesh = make_mesh((1, {ranks}), ("data", "model"))
cfg = DLRMConfig(name="bench", num_dense=32, bottom=(64, 16), top=(64,),
                 table_rows=(2000,) * 8, emb_dim=16, pooling=5,
                 batch={batch}, emb_mode="table", idx_input="sharded",
                 hot_rows={hot}, promote_every=2)
step, shardings, bspecs, layout = make_train_step(cfg, mesh)
state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
rng = np.random.default_rng(0)

def batch(i):
    idx = np.stack([zipf_indices(rng, m, ({batch}, 5), {zipf})
                    for m in cfg.table_rows], 1).astype(np.int32)
    return {{"idx": jnp.asarray(idx),
             "dense_x": jnp.asarray(rng.standard_normal(({batch}, 32)),
                                    jnp.bfloat16),
             "labels": jnp.asarray(rng.integers(0, 2, {batch}),
                                   jnp.float32)}}

b0 = batch(0)
coll = parse_collective_bytes(step.lower(state, b0).compile().as_text())
for i in range({steps}):
    state, loss = step(state, b0 if i == 0 else batch(i))
jax.block_until_ready(loss)
hit_rate = 0.0
if {hot} > 0:
    hit, _ = hot_cache.hot_bag_local(layout, state["cache"]["hot_w"],
                                     state["cache"]["hot_pos"],
                                     batch({steps})["idx"])
    hit_rate = float(jnp.mean(hit))
print(json.dumps(dict(hot_rows={hot}, hit_rate=hit_rate,
                      trained_steps={steps},
                      collective_bytes=coll["bytes_by_op"],
                      collective_counts=coll["counts"])))
"""


def rows():
    out = []
    for mk, name in ((dlrm_small, "dlrm-small"), (dlrm_large, "dlrm-large"),
                     (dlrm_mlperf, "dlrm-mlperf")):
        cfg = mk()
        ar, a2a, emb = analytic(cfg)
        out.append((f"{name}_eq1_allreduce_MB", ar / 2**20, "paper Eq.1"))
        out.append((f"{name}_eq2_alltoall_MB", a2a / 2**20, "paper Eq.2"))
        out.append((f"{name}_emb_capacity_GiB", emb, "paper Tab.II row 1"))
        f = RESULTS / f"{name}__train_tablewise__pod1x16x16.json"
        if f.exists():
            rec = json.loads(f.read_text())
            if rec.get("status") == "ok":
                coll = rec["collectives"]["bytes_by_op"]
                out.append((f"{name}_measured_a2a_MB_per_dev",
                            coll.get("all-to-all", 0) / 2**20,
                            "compiled HLO (table mode)"))
    return out


def pipeline_rows(microbatches, ranks: int, batch: int, dry_run: bool,
                  json_path: Path):
    cfg_model = dlrm_small(mode="table")
    points = []
    out = []
    for M in microbatches:
        rec = pipeline_model(cfg_model, ranks=64, M=M)
        measured = run_measured(ranks, batch, M, dry_run)
        rec.update(measured)
        points.append(rec)
        out.append((f"pipeline_M{M}_modeled_serial_ms",
                    rec["modeled_serial_ms"], "no-overlap model @64r"))
        out.append((f"pipeline_M{M}_modeled_overlap_ms",
                    rec["modeled_overlap_ms"], "Sect.VI overlap model @64r"))
        out.append((f"pipeline_M{M}_overlap_efficiency",
                    rec["overlap_efficiency"], "hidden/total exchange"))
        if rec.get("measured_ms") is not None:
            out.append((f"pipeline_M{M}_measured_ms", rec["measured_ms"],
                        f"CPU wall-clock {ranks}r (schedule shape only)"))
    _write_merged(json_path, {
        "model_config": cfg_model.name,
        "modeled_chip": TPU_V5E.name,
        "modeled_ranks": 64,
        "measured_ranks": ranks,
        "measured_batch": batch,
        "measured_backend": "cpu-forced-devices"
                            + (" (dry-run, compile only)" if dry_run else ""),
        "points": points,
    })
    out.append(("pipeline_json", 1.0, str(json_path)))
    return out


def wire_rows(dtypes, ranks: int, batch: int, dry_run: bool,
              json_path: Path, chip=TPU_V5E):
    """Compressed exchange wire formats (repro/dist/exchange.py): analytic
    per-rank wire volume + the Sect. VI overlap model at each dtype, and a
    compiled-HLO leg with ``exchange_dtype`` threaded into the subprocess.

    The compressible volume is the bwd dY all_to_all share of Eq. 2 plus
    the dense-gradient reduce-scatter share of Eq. 1; the index stream,
    the fwd layout switch (fp32) and the weight all-gather (always bf16 —
    the Split-SGD hi half) are wire-dtype-independent."""
    from repro.dist.exchange import wire_itemsize

    cfg = dlrm_small(mode="table")
    S, N, E, P = len(cfg.table_rows), cfg.batch, cfg.emb_dim, cfg.pooling
    RM, M = 64, 4                      # modeled ranks / microbatches
    ici_bw = chip.ici_bw_per_link * chip.ici_links
    ag_B = (allreduce_bytes(cfg.bottom_sizes, bytes_per_elem=2)
            + allreduce_bytes(cfg.top_sizes, bytes_per_elem=2))

    def model(isz: int) -> dict:
        dY_B = S * N * E * isz / RM
        rs_B = (allreduce_bytes(cfg.bottom_sizes, bytes_per_elem=isz)
                + allreduce_bytes(cfg.top_sizes, bytes_per_elem=isz))
        idx_bytes = S * N * P * 4 / RM
        a2a_bytes = (S * N * E * 4) / RM + dY_B      # fwd fp32 + bwd wire
        t_ex = (idx_bytes + a2a_bytes) / ici_bw
        t_comp = dense_flops(cfg) / RM / chip.peak_flops_bf16
        t_tail = ((rs_B + ag_B) / ici_bw
                  + (2 * N * S * E * 4 / RM) / chip.hbm_bw)
        ex_mb, comp_mb = t_ex / M, t_comp / M
        t_overlap = ex_mb + (M - 1) * max(comp_mb, ex_mb) + comp_mb + t_tail
        return {"wire_itemsize_B": isz,
                "modeled_dY_a2a_B_per_rank": dY_B,
                "modeled_dense_rs_B": rs_B,
                "modeled_dense_ag_B": ag_B,
                "modeled_compressible_B": dY_B + rs_B,
                "modeled_overlap_s": t_overlap}

    fp32_ref = model(4)
    section, out = {}, []
    for dt in dtypes:
        rec = model(wire_itemsize(dt))
        rec["modeled_overlap_speedup_x"] = (fp32_ref["modeled_overlap_s"]
                                            / rec["modeled_overlap_s"])
        measured = run_measured(ranks, batch, 1, dry_run, exchange_dtype=dt)
        rec["collective_bytes"] = measured["collective_bytes"]
        rec["collective_counts"] = measured["collective_counts"]
        section[dt] = rec
        out.append((f"wire_{dt}_compressible_B_per_rank",
                    rec["modeled_compressible_B"],
                    "bwd dY a2a (Eq.2 share) + dense RS (Eq.1) @64r"))
        out.append((f"wire_{dt}_overlap_speedup_x",
                    rec["modeled_overlap_speedup_x"],
                    "Sect.VI overlap model vs fp32 wire @64r M=4"))
        out.append((f"wire_{dt}_measured_a2a_B",
                    measured["collective_bytes"].get("all-to-all", 0),
                    f"compiled HLO, {ranks}r table mode"))
    if "fp32" in section:
        base_B = section["fp32"]["modeled_compressible_B"]
        for dt in dtypes:
            red = base_B / section[dt]["modeled_compressible_B"]
            section[dt]["wire_reduction_x"] = red
            if dt != "fp32":
                section[dt]["wire_reduction_ok"] = bool(red >= 1.9)
                out.append((f"wire_{dt}_reduction_x", red,
                            "modeled compressible bytes vs fp32 wire"))
    _write_merged(json_path, {"wire": dict(
        section, modeled_ranks=RM, modeled_microbatches=M,
        measured_ranks=ranks, measured_batch=batch)})
    return out


def merge_sections(old, new):
    # local copy of bench_split_sgd.merge_sections (same dual-path import
    # caveat as bench_split_sgd._timeit): key-stable deep merge, so a
    # cache-only run never drops the pipeline points and vice versa
    for k, v in new.items():
        if isinstance(v, dict) and isinstance(old.get(k), dict):
            merge_sections(old[k], v)
        else:
            old[k] = v
    return old


def _write_merged(json_path: Path, new: dict) -> None:
    old = {}
    if json_path.exists():
        try:
            old = json.loads(json_path.read_text())
        except json.JSONDecodeError:
            pass          # corrupt previous file: write fresh
    json_path.write_text(json.dumps(merge_sections(old, new), indent=2))


def cache_rows(ks, ranks: int, batch: int, json_path: Path,
               steps: int = 6, zipf: float = 1.05):
    """Paired hot_rows=K rows: measured hit rate + payload-effective
    all-to-all volume on the zipf stream, vs the K=0 baseline."""
    section = {}
    out = []
    # Eq.2 share of the measured bench config (S=8 tables, E=16, fwd+bwd)
    raw_a2a = 2 * (8 * batch * 16 * 4) / ranks
    for K in ks:
        rec = _run_sub(SUB_CACHE.format(ranks=ranks, batch=batch, hot=K,
                                        steps=steps, zipf=zipf))
        hit = rec["hit_rate"]
        rec["a2a_payload_per_rank"] = raw_a2a
        rec["a2a_payload_effective_per_rank"] = raw_a2a * (1.0 - hit)
        rec["exchange_bytes_saved"] = raw_a2a * hit
        rec["a2a_reduction_x"] = (1.0 / (1.0 - hit)) if hit < 1.0 else \
            float("inf")
        section[f"hot{K}"] = rec
        out.append((f"cache_hot{K}_hit_rate", hit,
                    f"all-hot-bag fraction, zipf({zipf}) after "
                    f"{steps} steps"))
        out.append((f"cache_hot{K}_a2a_effective_B_per_rank",
                    rec["a2a_payload_effective_per_rank"],
                    "a2a payload x (1 - hit_rate)"))
        out.append((f"cache_hot{K}_a2a_reduction_x",
                    rec["a2a_reduction_x"], "vs own raw a2a payload"))
    _write_merged(json_path, {"cache": dict(
        section, measured_ranks=ranks, measured_batch=batch, zipf=zipf)})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--microbatches", default=None,
                    help="comma list, e.g. 1,2,4: evaluate the staged "
                         "pipeline at each M (model + compile + measure)")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile each M but skip wall-clock timing")
    ap.add_argument("--ranks", type=int, default=8,
                    help="forced device count for the measured leg")
    ap.add_argument("--batch", type=int, default=64,
                    help="global batch for the measured leg")
    ap.add_argument("--exchange-dtype", default=None,
                    help="comma list of wire formats, e.g. fp32,bf16: "
                         "model + compile the compressed exchange "
                         "collectives at each dtype "
                         "(repro/dist/exchange.py)")
    ap.add_argument("--cache-rows", default=None,
                    help="comma list of hot_rows K values, e.g. 0,64: "
                         "measure the hot-row cache's bag hit rate and "
                         "payload-effective all-to-all volume at each K "
                         "on a zipf(1.05) stream (docs/cache.md)")
    ap.add_argument("--json", default=str(ROOT / "BENCH_pipeline.json"))
    args = ap.parse_args(argv)

    for name, val, derived in rows():
        print(f"{name},{val:.2f},{derived}")
    if args.microbatches:
        ms = [int(x) for x in args.microbatches.split(",") if x]
        for name, val, derived in pipeline_rows(
                ms, args.ranks, args.batch, args.dry_run, Path(args.json)):
            print(f"{name},{val:.4f},{derived}")
    if args.exchange_dtype:
        dts = [x for x in args.exchange_dtype.split(",") if x]
        for name, val, derived in wire_rows(dts, args.ranks, args.batch,
                                            args.dry_run, Path(args.json)):
            print(f"{name},{val:.4f},{derived}")
    if args.cache_rows:
        ks = [int(x) for x in args.cache_rows.split(",") if x]
        for name, val, derived in cache_rows(ks, args.ranks, args.batch,
                                             Path(args.json)):
            print(f"{name},{val:.4f},{derived}")


if __name__ == "__main__":
    main()
