"""Ingestion benchmark: shard write/read throughput + host-prep overlap.

Sections (one BENCH_ingest.json, CI runs --smoke and uploads it):

  write     pack a seeded synthetic stream into shards
            -> samples/s, shards/s, MB/s
  read      ShardedReader sequential + shuffled epochs (mmap decode)
            -> batches/s, samples/s, MB/s
  pipeline  HostPipeline (threaded decode + per-batch pre-sort) driven by
            a consumer that simulates device compute
            -> host-prep overlap fraction (how much of the worker's prep
               time is hidden behind "compute"), prep ms/batch, wait
               ms/batch

The overlap fraction is the loader-off-critical-path claim of the
ingestion subsystem in one number: 1 - wait/elapsed ~= 1 means the
consumer never starves (prep fully hidden); ~0 means the loader is the
bottleneck.
"""

from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def section_write(out_dir, tables, pooling, num_dense, n_samples, per_shard,
                  seed=0):
    from repro.data.format import pack_synthetic
    t0 = time.perf_counter()
    manifest = pack_synthetic(out_dir, tables, pooling, n_samples,
                              num_dense=num_dense, alpha=0.8, seed=seed,
                              samples_per_shard=per_shard)
    dt = time.perf_counter() - t0
    nbytes = sum((Path(out_dir) / s["file"]).stat().st_size
                 for s in manifest["shards"])
    return {"num_samples": n_samples, "num_shards": len(manifest["shards"]),
            "bytes": nbytes, "seconds": dt,
            "samples_per_s": n_samples / dt,
            "shards_per_s": len(manifest["shards"]) / dt,
            "MB_per_s": nbytes / dt / 2**20}


def section_read(out_dir, batch, epochs, shuffle):
    from repro.data.reader import ShardedReader
    r = ShardedReader(out_dir, batch=batch, shuffle=shuffle, seed=0)
    nb = 0
    t0 = time.perf_counter()
    for b in r.batches(epochs=epochs):
        nb += 1
    dt = time.perf_counter() - t0
    nbytes = nb * r.nbytes_per_batch()
    return {"shuffle": shuffle, "batches": nb, "seconds": dt,
            "batches_per_s": nb / dt,
            "samples_per_s": nb * batch / dt,
            "MB_per_s": nbytes / dt / 2**20}


def section_pipeline(out_dir, batch, epochs, table_rows, emb_dim,
                     compute_ms):
    """Drive HostPipeline (decode + pre-sort for a row-mode layout over 8
    shards) while the consumer sleeps ``compute_ms`` per batch — a stand-in
    for device compute; on hardware the step itself plays this role."""
    from repro.core import sharded_embedding as se
    from repro.core.embedding import EmbeddingSpec
    from repro.data.pipeline import HostPipeline
    from repro.data.reader import ShardedReader
    layout = se.make_layout(EmbeddingSpec(tuple(table_rows), emb_dim), 8,
                            "row")
    r = ShardedReader(out_dir, batch=batch, shuffle=True, seed=0)
    hp = HostPipeline(r.batches(epochs=epochs), layout=layout, presort=True)
    nb = 0
    t0 = time.perf_counter()
    for b in hp:
        nb += 1
        time.sleep(compute_ms / 1e3)
    elapsed = time.perf_counter() - t0
    prep, wait = hp.stats["prep_s"], hp.stats["wait_s"]
    return {"batches": nb, "seconds": elapsed, "compute_ms": compute_ms,
            "prep_ms_per_batch": prep / nb * 1e3,
            "wait_ms_per_batch": wait / nb * 1e3,
            # fraction of wall-clock the consumer was NOT starved: the
            # host-prep overlap claim in one number
            "overlap_fraction": max(0.0, 1.0 - wait / elapsed)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes (CI)")
    ap.add_argument("--out", default=None,
                    help="dataset dir (default: temp, deleted after)")
    ap.add_argument("--json", default=str(ROOT / "BENCH_ingest.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        tables, pooling, num_dense = (2000,) * 8, 5, 16
        n_samples, per_shard, batch, epochs = 8192, 1024, 256, 2
        compute_ms = 5.0
    else:
        tables, pooling, num_dense = (100_000,) * 8, 20, 64
        n_samples, per_shard, batch, epochs = 131072, 8192, 1024, 3
        compute_ms = 20.0

    tmp = None
    out_dir = args.out
    if out_dir is None:
        tmp = tempfile.mkdtemp(prefix="bench_ingest_")
        out_dir = tmp
    try:
        res = {
            "config": {"tables": list(tables), "pooling": pooling,
                       "num_dense": num_dense, "num_samples": n_samples,
                       "samples_per_shard": per_shard, "batch": batch,
                       "smoke": args.smoke},
            "write": section_write(out_dir, tables, pooling, num_dense,
                                   n_samples, per_shard),
            "read_seq": section_read(out_dir, batch, epochs, shuffle=False),
            "read_shuffled": section_read(out_dir, batch, epochs,
                                          shuffle=True),
            "pipeline": section_pipeline(out_dir, batch, epochs, tables,
                                         32, compute_ms),
        }
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)

    Path(args.json).write_text(json.dumps(res, indent=1))
    w, rs, rsh, p = (res["write"], res["read_seq"], res["read_shuffled"],
                     res["pipeline"])
    print(f"write, {w['samples_per_s']:.0f} samples/s, "
          f"{w['MB_per_s']:.1f} MB/s, {w['shards_per_s']:.2f} shards/s")
    print(f"read_seq, {rs['batches_per_s']:.1f} batches/s, "
          f"{rs['MB_per_s']:.1f} MB/s")
    print(f"read_shuffled, {rsh['batches_per_s']:.1f} batches/s, "
          f"{rsh['MB_per_s']:.1f} MB/s")
    print(f"pipeline, overlap_fraction={p['overlap_fraction']:.3f}, "
          f"prep {p['prep_ms_per_batch']:.2f} ms/batch, "
          f"wait {p['wait_ms_per_batch']:.2f} ms/batch")
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
