"""Single-socket operator breakdown (paper Fig. 7/8 analogue).

CPU wall-times of the DLRM hot operators, including the paper's Fig. 8
experiment: embedding UPDATE strategies under uniform vs skewed (zipf)
indices.  The 'sorted-dedup' strategy is the TPU-native analogue of the
paper's race-free Alg. 4 (pre-reduce duplicates, then disjoint writes);
'scatter-add' is Alg. 3 with XLA supplying the atomicity.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import EmbeddingSpec, bag_lookup, bag_update, \
    globalize
from repro.data.synthetic import zipf_indices
from repro.optim.row import apply_rows_split_sgd
from repro.optim.split_sgd import split_fp32


def timeit(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def rows() -> list[tuple[str, float, str]]:
    out = []
    spec = EmbeddingSpec((100_000,) * 8, 64)
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.standard_normal((spec.total_rows, 64)), jnp.float32)
    B, P = 2048, 20

    for alpha, tag in ((0.0, "uniform"), (1.05, "zipf")):
        idx = np.stack([zipf_indices(rng, 100_000, (B, P), alpha)
                        for _ in range(8)], 1).astype(np.int32)
        g = globalize(spec, jnp.asarray(idx))
        dY = jnp.asarray(rng.standard_normal((B, 8, 64)), jnp.float32)

        us = timeit(jax.jit(bag_lookup), W, g)
        out.append((f"embed_fwd_{tag}", us, f"B{B}xS8xP{P}xE64"))

        us = timeit(jax.jit(lambda W, g, dY: bag_update(W, g, dY, 0.1)),
                    W, g, dY)
        out.append((f"embed_update_scatter_{tag}", us, "alg3-scatter-add"))

        hi, lo = split_fp32(W)
        flat_g = g.reshape(-1)
        grad = jnp.broadcast_to(dY[:, :, None, :], (B, 8, P, 64)
                                ).reshape(-1, 64)
        us = timeit(jax.jit(
            lambda h, l, t, gr: apply_rows_split_sgd(h, l, t, gr, 0.1)),
            hi, lo, flat_g, grad)
        out.append((f"embed_update_dedup_split_{tag}", us,
                    "alg4-dedup+split-sgd"))

        # fused Pallas kernel (kernels/embedding_update), interpret-mode
        # emulation on CPU: the while-loop grid round-trips every carried
        # buffer per step (O(shard) per touched row), so time a tiny
        # sub-shard only — bench_split_sgd.py --fused has the full-size
        # bytes/step roofline that transfers to hardware.
        from repro.kernels import ops as kops
        Mm = 5_000
        Lm = (256 // P) * P          # keep L a multiple of P: bag ids of
        us = timeit(jax.jit(          # lookups [0, Lm) must index dY[:Lm//P]
            lambda h, l, t, d: kops.fused_row_update(
                "split_sgd", {"hi": h, "lo": l}, t, d, 0.1, pooling=P,
                interpret=True)),
            hi[:Mm], lo[:Mm], jnp.minimum(flat_g[:Lm], Mm - 1),
            dY.reshape(-1, 64)[:Lm // P], iters=1)
        out.append((f"embed_update_fused_split_{tag}", us,
                    f"pallas-fused-interpret-M{Mm}-L{Lm}"))

    # MLP + interaction
    from repro.models.mlp import init_mlp, mlp_forward
    from repro.core.interaction import dot_interaction
    mlp = init_mlp(jax.random.PRNGKey(0), [512, 1024, 1024, 256])
    x = jnp.asarray(rng.standard_normal((2048, 512)), jnp.bfloat16)
    us = timeit(jax.jit(lambda p, x: mlp_forward(p, x)), mlp, x)
    gflops = 2 * 2048 * (512 * 1024 + 1024 * 1024 + 1024 * 256) / us / 1e3
    out.append(("mlp_fwd_2048x512-1024-1024-256", us, f"{gflops:.1f}GFLOP/s"))

    dense = jnp.asarray(rng.standard_normal((2048, 64)), jnp.float32)
    emb = jnp.asarray(rng.standard_normal((2048, 8, 64)), jnp.float32)
    us = timeit(jax.jit(dot_interaction), dense, emb)
    out.append(("interaction_dot_2048xF9xE64", us, "batched-self-dot"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
