"""Resilience benchmark: verified-checkpoint IO cost + recovery drills.

Sections (one BENCH_resilience.json, CI runs --smoke and gates it via
check_bench.py):

  checkpoint_io   save/restore throughput with per-array checksums and
                  restore-time verification ON vs OFF -> MB/s each way,
                  plus the standalone verify cost.  The delta IS the
                  price of the integrity guarantee.
  recovery        K committed checkpoints with the newest corrupted:
                  time for the newest-first verified scan to fall back
                  and restore from the newest GOOD one (counts exact).
  drills          the kill matrix end-to-end on a deterministic toy
                  loop: crash at every checkpoint phase, torn commit,
                  corrupted latest, loader death, SIGTERM preemption —
                  each must resume BITWISE vs an uninterrupted run.
                  drills_run / drills_passed are exact model keys: a
                  drill that stops passing fails the CI gate.
  steps_lost      analytic preemption-loss model per checkpoint cadence
                  (uniform failure time): expected/worst steps lost.

Run:  python benchmarks/bench_resilience.py [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import itertools
import json
import os
import shutil
import signal
import tempfile
import time
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]


def make_state(rows: int, dim: int = 64):
    rng = np.random.default_rng(0)
    return {
        "emb": rng.standard_normal((rows, dim)).astype(np.float32),
        "sr": np.int32(0),
    }


def state_nbytes(state) -> int:
    return sum(np.asarray(v).nbytes for v in state.values())


def _timed_save(mgr, step, state):
    t0 = time.perf_counter()
    mgr.save(step, state, blocking=True)
    return time.perf_counter() - t0


def section_checkpoint_io(state, workdir: Path, repeats: int) -> dict:
    import jax

    from repro.checkpoint import CheckpointManager

    mb = state_nbytes(state) / 2**20
    structs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype), state
    )
    out = {"state_rows": int(state["emb"].shape[0]), "repeats": repeats}
    for label, checksums in (("checksums", True), ("plain", False)):
        d = workdir / f"io_{label}"
        mgr = CheckpointManager(d, checksums=checksums)
        dt = min(_timed_save(mgr, s + 1, state) for s in range(repeats))
        out[f"save_{label}_mb_s"] = mb / dt
        verify = checksums  # plain checkpoints have nothing to verify against
        t0 = time.perf_counter()
        mgr.restore(structs, verify=verify)
        dt = time.perf_counter() - t0
        out[f"restore_{'verified' if verify else 'unverified'}_mb_s"] = mb / dt
    mgr = CheckpointManager(workdir / "io_checksums")
    t0 = time.perf_counter()
    mgr.verify(repeats)
    out["verify_ms"] = (time.perf_counter() - t0) * 1e3
    return out


def section_recovery(state, workdir: Path, n_ckpts: int) -> dict:
    from repro.checkpoint import CheckpointManager
    from repro.faults import FailureLog, corrupt_checkpoint

    import jax

    d = workdir / "recovery"
    log = FailureLog()
    mgr = CheckpointManager(d, keep=n_ckpts, event_log=log)
    for s in range(1, n_ckpts + 1):
        mgr.save(s, state, blocking=True)
    corrupt_checkpoint(d, n_ckpts, "flip")
    t0 = time.perf_counter()
    good = mgr.latest_valid_step()
    scan_ms = (time.perf_counter() - t0) * 1e3
    # count from the timed scan only (restore below re-scans internally)
    corrupt_skipped = log.counts().get("ckpt_corrupt_skipped", 0)
    structs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype), state
    )
    t0 = time.perf_counter()
    step, _ = mgr.restore(structs)
    restore_ms = (time.perf_counter() - t0) * 1e3
    assert step == good == n_ckpts - 1
    return {
        "checkpoints": n_ckpts,
        "corrupt_skipped": corrupt_skipped,
        "fallback_step": int(step),
        "fallback_scan_ms": scan_ms,
        "restore_after_corruption_ms": restore_ms,
    }


# --------------------------------------------------------------------------
# Kill-matrix drills on a deterministic toy loop (mirrors tests/test_faults)
# --------------------------------------------------------------------------


def _toy_step(state, batch):
    new = {
        "w": state["w"] * np.float32(0.999) + batch["x"],
        "sr": state["sr"] + np.int32(1),
    }
    return new, float(np.sum(new["w"]))


def _toy_init():
    return {"w": np.arange(64, dtype=np.float32), "sr": np.int32(0)}


def _toy_stream(start=0):
    def batch(i):
        rng = np.random.default_rng(1000 + i)
        return {"x": rng.standard_normal(64).astype(np.float32)}

    return (batch(i) for i in itertools.count(start))


def _toy_reference(steps):
    state, stream = _toy_init(), _toy_stream()
    for _ in range(steps):
        state, _ = _toy_step(state, next(stream))
    return state


def _run_drill(name, faults, ckpt_dir, steps=12) -> bool:
    """Inject, die (or stop), restart from disk, require bitwise equality
    with the uninterrupted run.  Returns pass/fail."""
    from repro.data.pipeline import ThreadedIterator
    from repro.faults import FaultPlan, corrupt_checkpoint
    from repro.train import TrainLoop, TrainLoopConfig

    want = _toy_reference(steps)
    plan = FaultPlan(faults)
    batches = (
        ThreadedIterator(_toy_stream(), faults=plan)
        if name == "loader_death"
        else _toy_stream()
    )
    cfg = TrainLoopConfig(steps=steps, ckpt_dir=str(ckpt_dir), ckpt_every=3, log_every=10_000)
    loop = TrainLoop(cfg, _toy_step, _toy_init(), batches, faults=plan)
    try:
        loop.run()
    except BaseException:  # noqa: BLE001 — drills die in many ways
        pass
    if name == "corrupt_latest":
        from repro.checkpoint import CheckpointManager

        latest = CheckpointManager(ckpt_dir).latest_step()
        if latest:
            corrupt_checkpoint(ckpt_dir, latest, "flip")
    loop2 = TrainLoop(cfg, _toy_step, _toy_init(), iter(()))
    loop2.batches = _toy_stream(loop2.start_step)
    got = loop2.run()
    return bool(
        np.array_equal(got["w"], want["w"]) and int(got["sr"]) == int(want["sr"])
    )


def section_drills(workdir: Path) -> dict:
    from repro.faults import Fault

    matrix = [
        ("arrays_crash", [Fault("ckpt.write.arrays", action="crash")]),
        ("arrays_torn_commit", [Fault("ckpt.write.arrays", action="partial")]),
        ("meta_crash", [Fault("ckpt.write.meta", action="crash")]),
        ("commit_crash", [Fault("ckpt.commit", action="crash")]),
        ("enospc", [Fault("ckpt.write.arrays", times=10,
                          exc=lambda: OSError(28, "No space left"))]),
        ("loader_death", [Fault("loader.next", step=7)]),
        ("sigterm", [Fault("train.step", action="sigterm", step=7)]),
        ("preempt", [Fault("train.step", action="preempt", step=5)]),
        ("corrupt_latest", []),
    ]
    old = signal.getsignal(signal.SIGTERM)
    t0 = time.perf_counter()
    passed = []
    try:
        for name, faults in matrix:
            d = workdir / f"drill_{name}"
            ok = _run_drill(name, faults, d)
            passed.append((name, ok))
    finally:
        signal.signal(signal.SIGTERM, old)
    elapsed = time.perf_counter() - t0
    return {
        "drills_run": len(matrix),
        "drills_passed": sum(ok for _, ok in passed),
        "failed": [name for name, ok in passed if not ok],
        "drills_s": elapsed,
    }


def section_steps_lost(cadences) -> dict:
    """Analytic preemption-loss model: with failures uniform in time, a
    run checkpointing every K steps loses K/2 steps in expectation and
    K - 1 worst-case (plus the in-flight step) — the knob the
    ``--ckpt-every`` flag trades against checkpoint write cost."""
    out = {}
    for k in cadences:
        out[f"ckpt_every_{k}"] = {
            "expected_steps_lost": (k - 1) / 2,
            "worst_steps_lost": k - 1,
        }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced sizes (CI)")
    ap.add_argument("--json", default=str(ROOT / "BENCH_resilience.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        rows, repeats, n_ckpts = 8192, 2, 3
    else:
        rows, repeats, n_ckpts = 262_144, 3, 4

    state = make_state(rows)
    tmp = tempfile.mkdtemp(prefix="bench_resilience_")
    try:
        workdir = Path(tmp)
        res = {
            "config": {
                "rows": rows,
                "state_bytes": state_nbytes(state),
                "smoke": args.smoke,
            },
            "checkpoint_io": section_checkpoint_io(state, workdir, repeats),
            "recovery": section_recovery(state, workdir, n_ckpts),
            "drills": section_drills(workdir),
            "steps_lost": section_steps_lost((10, 50, 100)),
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    Path(args.json).write_text(json.dumps(res, indent=1))
    io, rec, dr = res["checkpoint_io"], res["recovery"], res["drills"]
    print(
        f"checkpoint_io, save {io['save_checksums_mb_s']:.1f} MB/s "
        f"(checksums) vs {io['save_plain_mb_s']:.1f} MB/s (plain), "
        f"restore {io['restore_verified_mb_s']:.1f} MB/s verified, "
        f"verify {io['verify_ms']:.2f} ms"
    )
    print(
        f"recovery, fell back to step {rec['fallback_step']} past "
        f"{rec['corrupt_skipped']} corrupt in {rec['fallback_scan_ms']:.2f} ms "
        f"(restore {rec['restore_after_corruption_ms']:.2f} ms)"
    )
    print(
        f"drills, {dr['drills_passed']}/{dr['drills_run']} passed in "
        f"{dr['drills_s']:.1f} s"
        + (f", FAILED: {dr['failed']}" if dr["failed"] else "")
    )
    print(f"wrote {args.json}")
    if dr["failed"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
