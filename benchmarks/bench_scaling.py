"""Strong/weak scaling experiment (paper Fig. 9-14 analogue, on compiled
artifacts).

For rank counts 2..32 we lower the paper-faithful TABLE-mode DLRM on a 1D
mesh (one rank = one paper socket) in a SUBPROCESS (the device-count flag
must precede jax init) and record per-rank compute FLOPs and collective
bytes.  Expectations from the paper:

  strong scaling: alltoall bytes/rank shrink ~1/R (Eq. 2 at fixed GN);
                  allreduce bytes/rank stay CONSTANT (Eq. 1) -> efficiency
                  decays exactly the way Fig. 9 shows.
  weak scaling:   alltoall bytes/rank stay ~constant (volume grows with R).

``--microbatches M`` lowers the staged microbatch pipeline
(repro/core/pipeline.py) instead of the monolithic step — the collective
bytes must match the M=1 step (same exchange volume, chunked), which is
the pipeline's lowering regression check.  ``--smoke`` runs a reduced,
cache-less sweep (CI); results also land in ``BENCH_scaling.json``.
"""

import argparse
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results"
SRC = ROOT / "src"

SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ranks}"
import json, jax
from repro.configs.dlrm_paper import dlrm_small
from repro.core.dlrm import make_train_step, state_struct, batch_struct
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import parse_collective_bytes
import dataclasses

mesh = make_mesh((1, {ranks}), ("data", "model"))
cfg = dataclasses.replace(dlrm_small(mode="table", batch={batch}),
                          microbatches={mb})
step, shardings, bspecs, layout = make_train_step(cfg, mesh)
sstructs, _, _, _ = state_struct(cfg, mesh)
bstructs, _ = batch_struct(cfg, mesh, layout)
# no jax.set_mesh here: the shard_mapped step carries its mesh explicitly
# (and set_mesh does not exist on pre-0.5 jax)
compiled = step.lower(sstructs, bstructs).compile()
ca = compiled.cost_analysis() or {{}}
if isinstance(ca, (list, tuple)):      # pre-0.5 jax: one dict per device
    ca = ca[0] if ca else {{}}
coll = parse_collective_bytes(compiled.as_text())
print(json.dumps(dict(ranks={ranks}, batch={batch}, microbatches={mb},
                      flops=float(ca.get("flops", 0)),
                      coll=coll["bytes_by_op"])))
"""


def run_point(ranks: int, batch: int, microbatches: int = 1) -> dict:
    env = dict(os.environ, PYTHONPATH=str(SRC))
    code = textwrap.dedent(SUB.format(ranks=ranks, batch=batch,
                                      mb=microbatches))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def rows(ranks=(2, 4, 8), gn=8192, ln=1024, cache=True, microbatches=1,
         json_path: Path | None = None, tag: str = ""):
    mb_tag = (f"_mb{microbatches}" if microbatches != 1 else "") + tag
    out_path = RESULTS / f"scaling{mb_tag}.json"
    if cache and out_path.exists():
        data = json.loads(out_path.read_text())
    else:
        data = {"strong": [run_point(r, gn, microbatches) for r in ranks],
                "weak": [run_point(r, ln * r, microbatches) for r in ranks]}
        out_path.parent.mkdir(exist_ok=True)
        out_path.write_text(json.dumps(data, indent=2))
    if json_path is not None:
        json_path.write_text(json.dumps(
            {"microbatches": microbatches, "gn": gn, "ln": ln, **data},
            indent=2))
    out = []
    for kind in ("strong", "weak"):
        for rec in data[kind]:
            a2a = rec["coll"].get("all-to-all", 0) / 2**20
            ar = (rec["coll"].get("all-reduce", 0)
                  + rec["coll"].get("reduce-scatter", 0)
                  + rec["coll"].get("all-gather", 0)) / 2**20
            out.append((f"scaling_{kind}_{rec['ranks']}r{mb_tag}"
                        f"_a2a_MBperdev", a2a, f"GN={rec['batch']}"))
            out.append((f"scaling_{kind}_{rec['ranks']}r{mb_tag}"
                        f"_dense_MBperdev", ar,
                        "Eq.1 term (const under strong scaling)"))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced cache-less sweep (CI): 2 rank points, "
                         "small batches")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="lower the staged pipeline at this M")
    ap.add_argument("--json", default=str(ROOT / "BENCH_scaling.json"))
    args = ap.parse_args(argv)
    kw = dict(microbatches=args.microbatches, json_path=Path(args.json))
    if args.smoke:
        # own cache filename so the reduced sweep never shadows the full
        # sweep's results/scaling.json
        kw.update(ranks=(2, 4), gn=256, ln=64, cache=False, tag="_smoke")
    for name, val, derived in rows(**kw):
        print(f"{name},{val:.3f},{derived}")


if __name__ == "__main__":
    main()
