"""Serving bench: bucketed continuous batching over published snapshots.

Four sections, landing in ``BENCH_serve.json`` (gated by
benchmarks/check_bench.py):

* ``model`` — the reduced serving config (tables, dim, bucket ladder);
  every key is exact.
* ``bytes`` — the bf16-hi serving-table claim: a snapshot of the
  Split-SGD store serves the ``hi`` slab directly, so its table bytes
  must be <= 0.55x the fp32 table an ``sgd`` store serves
  (``bf16_hi_vs_fp32_ok`` is the exact-gated bool; the byte counts are
  shape-derived and exact).
* ``latency`` — two phases.  The CLOSED-LOOP ladder drives each compiled
  bucket synchronously (pad + score + host read per batch), so the
  per-bucket batch counts are deterministic exact keys and p50/p99 ride
  the cost band.  The OPEN-LOOP sweep offers paced request streams to the
  real worker-thread :class:`~repro.serve.server.ContinuousBatchingServer`
  and reports client-observed global percentiles + achieved rate; only
  the configured request counts are exact (which buckets the racy
  coalescing picks is NOT a stable key and is deliberately not emitted).
* ``freshness`` — a LIVE train-to-serve run: a real hybrid train loop
  with a :class:`~repro.serve.publish.SnapshotPublisher` step hook, then
  scoring from the newest snapshot.  Publish counts / versions /
  steps-behind are cadence arithmetic (exact); seconds-behind is a
  measured cost key.

Run:  PYTHONPATH=src python benchmarks/bench_serve.py [--smoke]
"""

import argparse
import dataclasses
import itertools
import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
sys.path.insert(0, str(SRC))

import numpy as np  # noqa: E402

BYTES_BUDGET = 0.55  # bf16-hi serving table must be <= 0.55x fp32


def make_def(optimizer: str, rows: int, tables: int, batch: int):
    from repro.models import recsys as R

    return dataclasses.replace(R.make_fm((rows,) * tables, batch=batch),
                               sparse_optimizer=optimizer)


def make_payloads(mdef, layout, n: int, seed: int = 0) -> list:
    """n single-sample request payloads (deterministic)."""
    rng = np.random.default_rng(seed)
    rows = [mdef.spec.table_rows[t] for t in layout.slot_to_table]
    idx = np.stack([rng.integers(0, m, (n, 1)) for m in rows], axis=1)
    labels = rng.integers(0, 2, (n,)).astype(np.float32)
    return [{"idx": idx[i].astype(np.int32), "labels": labels[i]}
            for i in range(n)]


# ---------------------------------------------------------------------------
# Section: bytes (bf16-hi vs fp32 serving tables)
# ---------------------------------------------------------------------------


def bytes_section(rows: int, tables: int, batch: int) -> dict:
    import jax

    from repro.core import hybrid as H
    from repro.launch.mesh import make_mesh
    from repro.serve import snapshot_from_state

    mesh = make_mesh((1, 1), ("data", "model"))
    out = {}
    for opt in ("split_sgd", "sgd"):
        mdef = make_def(opt, rows, tables, batch)
        state, _ = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
        snap = snapshot_from_state(mdef, state)
        out[opt] = {
            "serving_table_bytes": snap.emb_bytes,
            "fp32_table_bytes": snap.fp32_emb_bytes,
            "snapshot_total_bytes": snap.total_bytes,
            "fp32_fraction": snap.emb_bytes / snap.fp32_emb_bytes,
        }
    out["bf16_hi_vs_fp32_ok"] = (
        out["split_sgd"]["serving_table_bytes"]
        <= BYTES_BUDGET * out["sgd"]["serving_table_bytes"])
    return out


# ---------------------------------------------------------------------------
# Section: latency (closed-loop ladder + open-loop QPS sweep)
# ---------------------------------------------------------------------------


def _pct(lat_ms: list, n_requests: int) -> dict:
    a = np.asarray(lat_ms)
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()),
            "n_requests": n_requests}


def latency_section(mdef, buckets, closed_batches: int, open_points,
                    open_requests: int) -> dict:
    import jax

    from repro.core import hybrid as H
    from repro.launch.mesh import make_mesh
    from repro.serve import (ContinuousBatchingServer, SnapshotRegistry,
                             make_bucket_scorers, snapshot_state)

    mesh = make_mesh((1, 1), ("data", "model"))
    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    reg = SnapshotRegistry()
    reg.publish(snapshot_state(mdef, state), step=0)
    fns, pad = make_bucket_scorers(mdef, mesh, buckets,
                                   lambda: reg.current().state)
    payloads = make_payloads(mdef, layout, max(buckets))
    for b in buckets:                       # compile outside the clock
        np.asarray(fns[b](pad(payloads[:b], b)))

    # closed loop: one synchronous full batch at a time per bucket — the
    # per-batch service time of each compiled shape, no queueing
    closed = {}
    for b in buckets:
        lat = []
        for _ in range(closed_batches):
            t0 = time.perf_counter()
            np.asarray(fns[b](pad(payloads[:b], b)))
            lat.append((time.perf_counter() - t0) * 1e3)
        closed[str(b)] = {"batches": closed_batches,
                          **_pct(lat, closed_batches * b)}
        closed[str(b)]["n_requests"] = closed_batches * b

    # open loop: paced offered load through the worker-thread server;
    # latency is client-observed (queue wait + pad + score)
    open_rows = []
    for offered in open_points:
        with ContinuousBatchingServer(fns, pad, max_wait_ms=2.0) as srv:
            gap = 1.0 / offered
            handles = []
            t_next = time.perf_counter()
            for i in range(open_requests):
                now = time.perf_counter()
                if now < t_next:
                    time.sleep(t_next - now)
                handles.append(srv.submit(payloads[i % len(payloads)]))
                t_next += gap
            for h in handles:
                h.result(timeout=120.0)
            lat = [(h.t_done - h.t_submit) * 1e3 for h in handles]
            wall = (max(h.t_done for h in handles)
                    - min(h.t_submit for h in handles))
        open_rows.append({"offered_per_s": float(offered),
                          "achieved_per_s": open_requests / wall,
                          **_pct(lat, open_requests)})
    return {"closed_loop": closed, "open_loop": open_rows}


# ---------------------------------------------------------------------------
# Section: freshness (live train loop -> publish -> serve)
# ---------------------------------------------------------------------------


def freshness_section(mdef, steps: int, publish_every: int) -> dict:
    import jax

    from repro.core import hybrid as H
    from repro.launch.mesh import make_mesh
    from repro.serve import (SnapshotPublisher, combined_serve_stats,
                             make_snapshot_score_step)
    from repro.train import TrainLoop, TrainLoopConfig

    mesh = make_mesh((1, 1), ("data", "model"))
    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    step_fn, _, _, _ = H.make_train_step(mdef, mesh)
    payloads = make_payloads(mdef, layout, mdef.batch, seed=1)
    batch = {k: np.stack([p[k] for p in payloads])
             for k in payloads[0]}
    pub = SnapshotPublisher(mdef, publish_every=publish_every)
    pub.publish(0, state)
    loop = TrainLoop(TrainLoopConfig(steps=steps, log_every=10_000,
                                     prefetch=0),
                     step_fn, state, itertools.repeat(batch),
                     step_hook=pub,
                     serve_stats=combined_serve_stats(pub))
    loop.run()
    f = pub.freshness()
    # prove the published tables actually serve: score a batch from the
    # newest snapshot, synchronously
    fn, _, _, _ = make_snapshot_score_step(mdef, mesh, donate_batch=False)
    scores = np.asarray(fn(pub.registry.current().state, batch))
    return {"steps": steps,
            "publish_every": publish_every,
            "publishes": pub.publishes,
            "snapshot_version": f["version"],
            "steps_behind": f["steps_behind"],
            "seconds_behind": f["seconds_behind"],
            "served_ok": bool(np.isfinite(scores).all()
                              and scores.shape == (mdef.batch,))}


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI (the committed baseline is "
                         "the smoke run — exact keys must reproduce)")
    ap.add_argument("--json", default=str(ROOT / "BENCH_serve.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        rows, tables, batch = 200, 6, 32
        buckets, closed_batches = (4, 16), 30
        open_points, open_requests = (200.0, 1000.0), 200
        steps, publish_every = 10, 4
    else:
        rows, tables, batch = 2000, 8, 64
        buckets, closed_batches = (8, 32, 128), 100
        open_points, open_requests = (500.0, 2000.0, 8000.0), 2000
        steps, publish_every = 50, 10

    doc = {"model": {"tables": tables, "rows_per_table": rows,
                     "batch": batch, "buckets": list(buckets),
                     "closed_loop_batches": closed_batches,
                     "open_loop_requests": open_requests}}

    doc["bytes"] = bytes_section(rows, tables, batch)
    b = doc["bytes"]
    print(f"serving_bytes_bf16_hi,{b['split_sgd']['serving_table_bytes']}")
    print(f"serving_bytes_fp32,{b['sgd']['serving_table_bytes']}")
    print(f"bytes_fraction,{b['split_sgd']['fp32_fraction']:.3f},budget "
          f"{BYTES_BUDGET} -> {'OK' if b['bf16_hi_vs_fp32_ok'] else 'FAIL'}")

    mdef = make_def("split_sgd", rows, tables, batch)
    doc["latency"] = latency_section(mdef, buckets, closed_batches,
                                     open_points, open_requests)
    for bk, row in doc["latency"]["closed_loop"].items():
        print(f"closed_bucket_{bk},p50 {row['p50_ms']:.3f} ms,"
              f"p99 {row['p99_ms']:.3f} ms,{row['n_requests']} reqs")
    for row in doc["latency"]["open_loop"]:
        print(f"open_offered_{row['offered_per_s']:.0f},"
              f"achieved {row['achieved_per_s']:.1f}/s,"
              f"p50 {row['p50_ms']:.3f} ms,p99 {row['p99_ms']:.3f} ms")

    doc["freshness"] = freshness_section(mdef, steps, publish_every)
    f = doc["freshness"]
    print(f"freshness,v{f['snapshot_version']},{f['steps_behind']} steps,"
          f"{f['seconds_behind']:.3f}s behind,"
          f"{'OK' if f['served_ok'] else 'FAIL'}")

    Path(args.json).write_text(json.dumps(doc, indent=2))
    print(f"serve_json,1.0,{args.json}")
    if not doc["bytes"]["bf16_hi_vs_fp32_ok"]:
        return 1
    if not doc["freshness"]["served_ok"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
