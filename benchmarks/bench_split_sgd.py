"""Paper Fig. 16 + Sect. VII accounting: Split-SGD-BF16 convergence parity
and capacity/bandwidth table."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))


def rows():
    from split_sgd_convergence import run
    import numpy as np
    out = []
    finals = {}
    for mode in ("fp32", "split", "split8", "bf16"):
        losses = run(mode, steps=120)
        finals[mode] = float(np.mean(losses[-20:]))
        out.append((f"split_sgd_{mode}_final_loss", finals[mode] * 1e6,
                    "x1e-6 (Fig.16 final-20 mean)"))
    out.append(("split_vs_fp32_gap", abs(finals["split"] - finals["fp32"])
                * 1e6, "x1e-6 — paper: ~0"))
    out.append(("bf16_vs_fp32_gap", abs(finals["bf16"] - finals["fp32"])
                * 1e6, "x1e-6 — naive bf16 drifts"))
    # capacity table (paper Sect. VII): bytes/param
    out.append(("bytes_per_param_fp32", 4.0, "fp32 weights"))
    out.append(("bytes_per_param_split", 4.0, "hi+lo: zero overhead"))
    out.append(("bytes_per_param_fp16_master", 6.0, "fp16 + fp32 master"))
    out.append(("fwd_bwd_bytes_per_param_split", 2.0,
                "2x bandwidth saving on 2 of 3 passes"))
    return out


def main():
    for name, val, derived in rows():
        print(f"{name},{val:.2f},{derived}")


if __name__ == "__main__":
    main()
