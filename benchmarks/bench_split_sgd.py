"""Paper Fig. 16 + Sect. VII accounting: Split-SGD-BF16 convergence parity,
capacity/bandwidth table, and the fused-vs-reference embedding update
roofline (kernels/embedding_update.py) — now swept over every registered
sparse RowOptimizer (repro/optim/row.py).

    PYTHONPATH=src python benchmarks/bench_split_sgd.py [--fused|--reference]
        [--optimizer sgd|split_sgd|momentum|adagrad_rowwise|adagrad|all]
        [--smoke] [--json BENCH_embedding_update.json]

The update section reports THEORETICAL bytes/step for both paths (the
acceptance metric: the fused path touches O(unique_rows) data — weights
AND per-row optimizer state — while the reference path touches
O(shard_rows)) plus measured wall-clock.  ``--optimizer`` adds the named
optimizer's state-slab traffic to the roofline and times its fused
interpret-mode kernel on a tiny shard; ``all`` sweeps the registry.
``--smoke`` skips the 120-step convergence study (the CI sweep).  The
fused kernel runs in Pallas interpret mode on CPU — its wall-clock is an
emulation artifact; the bytes model is the TPU-relevant number.

The JSON write is a KEY-STABLE MERGE into any existing file
(:func:`merge_sections`): partial runs update only the sections they
computed, so `benchmarks/check_bench.py` can diff the artifact against
the committed baseline without one sweep clobbering another's rows.
"""

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))


def rows():
    from split_sgd_convergence import run
    import numpy as np
    out = []
    finals = {}
    for mode in ("fp32", "split", "split8", "bf16"):
        losses = run(mode, steps=120)
        finals[mode] = float(np.mean(losses[-20:]))
        out.append((f"split_sgd_{mode}_final_loss", finals[mode] * 1e6,
                    "x1e-6 (Fig.16 final-20 mean)"))
    out.append(("split_vs_fp32_gap", abs(finals["split"] - finals["fp32"])
                * 1e6, "x1e-6 — paper: ~0"))
    out.append(("bf16_vs_fp32_gap", abs(finals["bf16"] - finals["fp32"])
                * 1e6, "x1e-6 — naive bf16 drifts"))
    # capacity table (paper Sect. VII): bytes/param
    out.append(("bytes_per_param_fp32", 4.0, "fp32 weights"))
    out.append(("bytes_per_param_split", 4.0, "hi+lo: zero overhead"))
    out.append(("bytes_per_param_fp16_master", 6.0, "fp16 + fp32 master"))
    out.append(("fwd_bwd_bytes_per_param_split", 2.0,
                "2x bandwidth saving on 2 of 3 passes"))
    return out


def _timeit(fn, *args, iters=5):
    # local copy of bench_ops.timeit: these files run both as scripts and
    # as benchmarks.* modules, so a cross-file import would need dual-path
    # resolution for a three-line helper
    import jax
    jax.block_until_ready(fn(*args))          # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def optimizer_bytes_row(name: str, U: int, E: int, NB: int, L: int) -> dict:
    """Roofline bytes/step of one registered RowOptimizer's FUSED update:
    touched weight rows in+out, per-row state slab in+out (the second
    row-addressed operand of kernels/embedding_update.py), dY once, and
    the int32 index sort.  State traffic per touched row follows each
    slab's WIDTH and DTYPE: momentum / elementwise adagrad E lanes (fp32,
    or 2-byte bf16-hi for the compressed ``*_bf16`` kinds — half the
    state bytes), row-wise adagrad ONE fp32 scalar, the stateless kinds
    zero."""
    from repro.optim import row as row_optim
    opt = row_optim.get(name)
    state_bytes = sum((w or E) * dt.itemsize
                      for _, w, dt in opt.state_slabs())
    b = {
        "touched_rows_rw": 2 * U * E * 4,
        "state_rows_rw": 2 * U * state_bytes,
        "dY_read": NB * E * 4,
        "index_sort": 3 * L * 4,
    }
    return {"bytes_per_step": sum(b.values()), "bytes_breakdown": b,
            "state_bytes_per_row": state_bytes,
            "touches": "O(unique_rows)"}


def embedding_update_bench(modes=("reference", "fused"),
                           M=200_000, E=64, B=512, S=8, P=4, zipf=1.05,
                           measure_fused=False, optimizers=()):
    """Fused vs reference sparse Split-SGD update on one shard, plus the
    per-RowOptimizer bytes/step roofline rows (``optimizers``).

    Returns a JSON-able dict with the bytes/step roofline model and
    measured wall-clock per requested mode."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.synthetic import zipf_indices
    from repro.kernels import ops
    from repro.optim import row as row_optim
    from repro.optim.row import apply_rows_split_sgd
    from repro.optim.split_sgd import split_fp32

    rng = np.random.default_rng(0)
    L, NB = B * S * P, B * S
    W = jnp.asarray(rng.standard_normal((M, E)), jnp.float32)
    hi, lo = split_fp32(W)
    tgt = jnp.asarray(
        zipf_indices(rng, M, (L,), zipf).astype(np.int32))
    dY = jnp.asarray(rng.standard_normal((NB, E)), jnp.float32)
    grad_rows = jnp.take(dY, jnp.arange(L) // P, axis=0)
    U = int(len(np.unique(np.asarray(tgt))))

    # --- bytes/step roofline model --------------------------------------
    # reference: materialize the [L, E] per-lookup gradient (write+read),
    # segment-sum it (write), gather+combine the L candidate rows, then the
    # functional scatter COPIES the whole (hi, lo) shard (read+write of
    # M rows x 4 B/elem).
    ref_bytes = {
        "grad_expand_rw": 2 * L * E * 4,
        "segment_sum_out": L * E * 4,
        "row_gather": L * E * 4,
        "shard_copy_rw": 2 * M * E * 4,
    }
    # fused: touched rows in+out (2+2 B/elem each way), dY once, and the
    # int32 sort of the L flat row ids. No dense dW, no shard copy.
    fused_bytes = {
        "touched_rows_rw": 2 * U * E * 4,
        "dY_read": NB * E * 4,
        "index_sort": 3 * L * 4,
    }
    result = {
        "config": {"shard_rows": M, "dim": E, "batch": B, "slots": S,
                   "pooling": P, "flat_lookups": L, "unique_rows": U,
                   "zipf": zipf},
        "reference": {"bytes_per_step": sum(ref_bytes.values()),
                      "bytes_breakdown": ref_bytes,
                      "touches": "O(shard_rows)"},
        "fused": {"bytes_per_step": sum(fused_bytes.values()),
                  "bytes_breakdown": fused_bytes,
                  "touches": "O(unique_rows)"},
    }
    result["model_speedup"] = (result["reference"]["bytes_per_step"]
                               / result["fused"]["bytes_per_step"])

    # --- per-RowOptimizer roofline rows --------------------------------
    if optimizers:
        result["optimizers"] = {}
        for name in optimizers:
            r = optimizer_bytes_row(name, U, E, NB, L)
            if measure_fused:
                # tiny shard, one iteration: interpret-mode emulation is
                # O(shard) per grid step (see the note below); the bytes
                # model is the hardware-relevant number
                Mm, Lm = 5_000, 256
                opt = row_optim.get(name)
                store = opt.init_store(W[:Mm])
                f = jax.jit(lambda s, t, d: opt.apply_sparse(
                    s, row_optim.SparseStream(
                        idx=t.reshape(-1, 1, P),
                        dY=d.reshape(-1, 1, E)), 0.05,
                    fused=True, interpret=True))
                r["us_measured_interpret"] = _timeit(
                    f, store, jnp.minimum(tgt[:Lm], Mm - 1),
                    dY[:Lm // P], iters=1)
            result["optimizers"][name] = r

    # --- hot-row cache rows (repro/core/cache.py, docs/cache.md) -------
    # counter-driven promotion on this shard's OWN zipf stream: the first
    # half of the flat lookups trains the touch counters, the real
    # ``select_hot`` promotion picks the top-K, and the second half
    # measures the all-hot-bag hit rate.  A hot bag ships no exchange
    # payload, so ``exchange_bytes_saved`` is hit_bags * E * 4 per step.
    # Counters and promotion are integer-exact on the seeded stream, so
    # both keys are EXACT gate keys in benchmarks/check_bench.py.
    from repro.core import cache as hot_cache
    from repro.core import sharded_embedding as se
    from repro.core.embedding import EmbeddingSpec

    layout1 = se.make_layout(EmbeddingSpec((M,), E), 1, "row")
    warm, ev = np.asarray(tgt[:L // 2]), np.asarray(tgt[L // 2:])
    cnt = np.bincount(warm, minlength=layout1.total_rows).astype(np.int32)
    result["cache"] = {"warmup_lookups": len(warm),
                       "eval_bags": len(ev) // P}
    for K in (0, 64):
        hot = np.zeros(layout1.total_rows, bool)
        if K:
            ids = np.asarray(hot_cache.select_hot(
                layout1, jnp.asarray(cnt), K, seed=0))
            hot[ids[ids >= 0]] = True
        bag_hit = hot[ev].reshape(-1, P).all(axis=1)
        hit = float(bag_hit.mean())
        result["cache"][f"hot{K}"] = {
            "hot_rows": K,
            "hit_rate": hit,
            "exchange_bytes_saved": int(bag_hit.sum()) * E * 4,
        }

    # --- measured wall-clock -------------------------------------------
    if "reference" in modes:
        f = jax.jit(apply_rows_split_sgd)
        result["reference"]["us_measured"] = _timeit(f, hi, lo, tgt,
                                                     grad_rows, 0.05)
    if measure_fused and "fused" in modes:
        # CPU interpret emulation runs the grid as an XLA while-loop that
        # round-trips EVERY carried buffer per step — O(shard_rows) per
        # touched row, the exact inverse of the kernel's on-TPU profile.
        # So: opt-in (--fused), tiny shard, one iteration.  The bytes model
        # above is the hardware-relevant number.
        Mm, Lm = 5_000, 256
        f = jax.jit(lambda h, l, t, d: ops.fused_row_update(
            "split_sgd", {"hi": h, "lo": l}, t, d, 0.05, pooling=P,
            interpret=True))
        us = _timeit(f, hi[:Mm], lo[:Mm],
                     jnp.minimum(tgt[:Lm], Mm - 1), dY[:Lm // P], iters=1)
        result["fused"]["us_measured_interpret"] = us
        result["fused"]["measured_lookups"] = Lm
        result["fused"]["measured_shard_rows"] = Mm
    return result


def merge_sections(old, new):
    """KEY-STABLE deep merge of a fresh bench result into the existing
    JSON: every dict level merges per key (``optimizers`` per optimizer
    name, ``reference``/``fused`` per metric), so a partial run — a
    ``--smoke`` sweep, a single ``--optimizer`` row, a ``--fused``-only
    timing — updates exactly the keys it computed and never drops the
    sections it didn't.  This is what lets the CI bench-regression gate
    (benchmarks/check_bench.py) diff the file against the committed
    baseline without spurious section-loss failures."""
    for k, v in new.items():
        if isinstance(v, dict) and isinstance(old.get(k), dict):
            merge_sections(old[k], v)
        else:
            old[k] = v
    return old


def main(argv=None):
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--fused", action="store_true",
                   help="measure only the fused Pallas path")
    g.add_argument("--reference", action="store_true",
                   help="measure only the segment_sum reference path")
    ap.add_argument("--optimizer", default="all",
                    help="RowOptimizer(s) for the per-optimizer roofline "
                         "rows: a registry name, or 'all' (default) for "
                         "the full registered sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="skip the 120-step convergence study; emit only "
                         "the bytes/step roofline rows (the CI sweep)")
    ap.add_argument("--json", default="BENCH_embedding_update.json",
                    help="where to write the update-bench JSON")
    ap.add_argument("--fresh", action="store_true",
                    help="write the JSON from scratch instead of the "
                         "key-stable merge — use when REFRESHING a "
                         "committed baseline, so sections a removed/"
                         "renamed optimizer no longer emits actually "
                         "disappear (the merge would carry them forever "
                         "and the CI gate would flag them as lost)")
    args, _ = ap.parse_known_args(argv)

    if not args.smoke:
        for name, val, derived in rows():
            print(f"{name},{val:.2f},{derived}")

    from repro.optim import row as row_optim
    optimizers = (row_optim.names() if args.optimizer == "all"
                  else (args.optimizer,))
    modes = (("fused",) if args.fused else
             ("reference",) if args.reference else ("reference", "fused"))
    res = embedding_update_bench(modes, measure_fused=args.fused,
                                 optimizers=optimizers)
    for path in ("reference", "fused"):
        b = res[path]["bytes_per_step"]
        print(f"embed_update_{path}_bytes_per_step,{b:.0f},"
              f"{res[path]['touches']}")
    print(f"embed_update_model_speedup,{res['model_speedup']:.1f},"
          f"bytes(ref)/bytes(fused) at U={res['config']['unique_rows']}")
    for name, r in res.get("optimizers", {}).items():
        print(f"embed_update_opt_{name}_bytes_per_step,"
              f"{r['bytes_per_step']:.0f},"
              f"state {r['state_bytes_per_row']}B/row, {r['touches']}")
    for k, r in res["cache"].items():
        if isinstance(r, dict):
            print(f"embed_update_cache_{k}_hit_rate,{r['hit_rate']:.4f},"
                  f"saves {r['exchange_bytes_saved']} B/step exchange")
    for path in ("reference", "fused"):
        for k in ("us_measured", "us_measured_interpret"):
            if k in res[path]:
                print(f"embed_update_{path}_{k},{res[path][k]:.1f},us")
    out_path = Path(args.json)
    if out_path.exists() and not args.fresh:
        try:
            res = merge_sections(json.loads(out_path.read_text()), res)
        except json.JSONDecodeError:
            pass          # corrupt/absent previous file: write fresh
    out_path.write_text(json.dumps(res, indent=2))
    print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
