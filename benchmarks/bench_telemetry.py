"""Telemetry bench: tracer overhead + in-graph metrics reproduction.

Two sections, landing in ``BENCH_telemetry.json`` (gated by
benchmarks/check_bench.py):

* ``tracer`` — the cost of the observability layer itself.  A disabled
  tracer must be compiled-in-permanently cheap (one attribute check, a
  shared no-op context manager), and an ENABLED tracer wrapping a
  realistic ~1 ms step workload must cost < 3% wall-clock
  (``overhead_ok`` is an exact-gated bool; ``overhead_ratio`` rides the
  two-sided band for visibility).  Per-span costs are measured bare
  (span around ``pass``), the overhead ratio around a deterministic
  numpy workload sized like a small train step.
* ``metrics`` — the in-graph step-metrics vector
  (repro/telemetry/metrics.py) must REPRODUCE the cache bench: train the
  BENCH_pipeline.json cache config (zipf(1.05), hot_rows=64,
  promote_every=2, 8 forced devices) for 6 steps, run ONE more step on
  the held-out measurement batch, and the drained per-window
  ``skipped_bags / bags`` must equal the ``hot64.hit_rate`` the cache
  bench measured via ``hot_bag_local`` — exactly (both are an exact
  small-integer f32 sum and one f32 divide).  The window is also emitted
  as tracer counters and read back through ``repro.telemetry
  summarize``, pinning the whole trace -> summary path.  Every key in
  this section is deterministic, so the gate is EXACT.

Run:  PYTHONPATH=src python benchmarks/bench_telemetry.py
"""

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
sys.path.insert(0, str(SRC))

from repro.telemetry import Tracer  # noqa: E402

OVERHEAD_BUDGET = 1.03  # enabled tracer must cost < 3% on a ~1 ms step


# ---------------------------------------------------------------------------
# Section 1: tracer overhead (in-process, no jax)
# ---------------------------------------------------------------------------


def _span_cost_us(tracer: Tracer, n: int = 50_000, rounds: int = 5) -> float:
    """Per-span cost of ``with tracer.span(...): pass`` (min of rounds)."""
    best = float("inf")
    for _ in range(rounds):
        tracer.reset()
        t0 = time.perf_counter()
        for _ in range(n):
            with tracer.span("bench/span", step=0):
                pass
        best = min(best, time.perf_counter() - t0)
    return best / n * 1e6


def _workload_ms(tracer: Tracer, iters: int = 200, rounds: int = 5) -> float:
    """Mean wall per iteration of a deterministic ~1 ms numpy workload
    wrapped in one span, min over rounds (min rejects scheduler noise
    without hiding a systematic per-span cost)."""
    import numpy as np

    a = np.random.default_rng(0).standard_normal((256, 256))
    best = float("inf")
    for _ in range(rounds):
        tracer.reset()
        t0 = time.perf_counter()
        for i in range(iters):
            with tracer.span("bench/step", step=i):
                (a @ a).sum()
        best = min(best, time.perf_counter() - t0)
    return best / iters * 1e3


def tracer_section() -> dict:
    off = Tracer(enabled=False)
    on = Tracer(enabled=True)
    cost_off = _span_cost_us(off)
    cost_on = _span_cost_us(on)
    wl_off = _workload_ms(off)
    wl_on = _workload_ms(on)
    ratio = wl_on / wl_off
    return {
        "span_cost_disabled_us": cost_off,
        "span_cost_enabled_us": cost_on,
        "workload_disabled_ms": wl_off,
        "workload_enabled_ms": wl_on,
        "overhead_ratio": ratio,
        "overhead_budget": OVERHEAD_BUDGET,
        "overhead_ok": bool(ratio < OVERHEAD_BUDGET),
    }


# ---------------------------------------------------------------------------
# Section 2: in-graph metrics vs the cache bench (forced-device subprocess)
# ---------------------------------------------------------------------------

SUB_METRICS = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ranks}"
import json, tempfile, jax, jax.numpy as jnp, numpy as np
from repro.core.dlrm import DLRMConfig, make_train_step, init_state
from repro.core import cache as hot_cache
from repro.data.synthetic import zipf_indices
from repro.launch.mesh import make_mesh
from repro.telemetry import Tracer
from repro.telemetry import metrics as step_mx
from repro.telemetry import summarize as tsum

mesh = make_mesh((1, {ranks}), ("data", "model"))
cfg = DLRMConfig(name="bench", num_dense=32, bottom=(64, 16), top=(64,),
                 table_rows=(2000,) * 8, emb_dim=16, pooling=5,
                 batch={batch}, emb_mode="table", idx_input="sharded",
                 hot_rows={hot}, promote_every=2, step_metrics=True)
step, shardings, bspecs, layout = make_train_step(cfg, mesh)
state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
rng = np.random.default_rng(0)

def batch(i):
    idx = np.stack([zipf_indices(rng, m, ({batch}, 5), {zipf})
                    for m in cfg.table_rows], 1).astype(np.int32)
    return {{"idx": jnp.asarray(idx),
             "dense_x": jnp.asarray(rng.standard_normal(({batch}, 32)),
                                    jnp.bfloat16),
             "labels": jnp.asarray(rng.integers(0, 2, {batch}),
                                   jnp.float32)}}

for i in range({steps}):
    state, loss = step(state, batch(i))
jax.block_until_ready(loss)
# the cache bench's measurement: all-hot-bag fraction on the held-out
# batch, read straight off the post-training hot set
mb = batch({steps})
hit, _ = hot_cache.hot_bag_local(layout, state["cache"]["hot_w"],
                                 state["cache"]["hot_pos"], mb["idx"])
bench_hit_rate = float(jnp.mean(hit))
# the metrics path: one more step ON that batch — its epilogue reads the
# same pre-step hot set — and the drain window is that step alone
tdir = tempfile.mkdtemp()
tr = Tracer(enabled=True, trace_dir=tdir)
before = step_mx.drain(state)
step_mx.emit(tr, before)
state, loss = step(state, mb)
jax.block_until_ready(loss)
after = step_mx.drain(state)
step_mx.emit(tr, after)
win = step_mx.window(after, before)
trace = tr.export()
summ = tsum.summarize(trace)["metrics"]
print(json.dumps(dict(
    trained_steps={steps}, hot_rows={hot},
    bench_hit_rate=bench_hit_rate,
    window_hit_rate=step_mx.hit_rate(win),
    summarize_hit_rate=summ["last_window_hit_rate"],
    window={{k: win[k] for k in ("steps", "hit_lookups", "skipped_bags",
                                 "bags", "rows_touched",
                                 "exchange_payload_bytes")}},
    cumulative_steps=after["steps"],
)))
"""


def _run_sub(code: str) -> dict:
    env = dict(os.environ, PYTHONPATH=str(SRC))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def metrics_section(ranks: int, batch: int, hot: int, steps: int,
                    zipf: float) -> dict:
    rec = _run_sub(SUB_METRICS.format(ranks=ranks, batch=batch, hot=hot,
                                      steps=steps, zipf=zipf))
    rec["measured_ranks"] = ranks
    rec["measured_batch"] = batch
    rec["zipf"] = zipf
    rec["reproduces_cache_bench"] = bool(
        rec["window_hit_rate"] == rec["bench_hit_rate"]
        == rec["summarize_hit_rate"])
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=8)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--hot-rows", type=int, default=64)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--zipf", type=float, default=1.05)
    ap.add_argument("--skip-metrics", action="store_true",
                    help="tracer-overhead section only (no subprocess)")
    ap.add_argument("--json", default=str(ROOT / "BENCH_telemetry.json"))
    args = ap.parse_args(argv)

    doc = {"tracer": tracer_section()}
    t = doc["tracer"]
    print(f"span_cost_disabled_us,{t['span_cost_disabled_us']:.4f}")
    print(f"span_cost_enabled_us,{t['span_cost_enabled_us']:.4f}")
    print(f"overhead_ratio,{t['overhead_ratio']:.5f},budget "
          f"{OVERHEAD_BUDGET} -> {'OK' if t['overhead_ok'] else 'FAIL'}")
    if not args.skip_metrics:
        doc["metrics"] = metrics_section(args.ranks, args.batch,
                                         args.hot_rows, args.steps,
                                         args.zipf)
        m = doc["metrics"]
        print(f"metrics_window,{json.dumps(m['window'])}")
        print(f"metrics_hit_rate,{m['window_hit_rate']:.9f},"
              f"bench {m['bench_hit_rate']:.9f},"
              f"summarize {m['summarize_hit_rate']:.9f},"
              f"{'EXACT' if m['reproduces_cache_bench'] else 'MISMATCH'}")
    Path(args.json).write_text(json.dumps(doc, indent=2))
    print(f"telemetry_json,1.0,{args.json}")
    if not doc["tracer"]["overhead_ok"]:
        return 1
    if not args.skip_metrics and not doc["metrics"]["reproduces_cache_bench"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
