"""CI bench-regression gate: diff fresh smoke-run BENCH_*.json artifacts
against the committed baselines and FAIL on regression, instead of only
uploading artifacts for a human to eyeball.

    python benchmarks/check_bench.py --baseline-dir ci-baselines \
        [--candidate-dir .] [--files "BENCH_*.json"] [--tol 8.0]

Rules, per leaf key (recursive walk over each JSON pair):

* **model keys are EXACT** — anything structural or analytically derived
  (``*bytes*``, counts, dims, config, strings, ints, booleans) must match
  bit-for-bit: the bytes/step roofline is the acceptance metric of the
  fused-update work and must never drift silently.  Floats that are pure
  functions of model keys (``model_speedup``) are compared to 1e-9
  relative.
* **measured keys get a tolerance band** — wall-clock / throughput /
  overlap numbers (``us_*``, ``*_mb_s``, ``*_s``, ``overlap*``, ...)
  vary with the runner; a COST key (time) fails only when the candidate
  is more than ``--tol`` x the baseline, a RATE key (MB/s, samples/s,
  overlap fraction) only when it is less than baseline / ``--tol``.  The
  default band is deliberately wide (8x): the gate is after order-of-
  magnitude regressions and lost sections, not scheduler noise.
* **compiler-derived volumes get a two-sided band** — ``flops`` /
  ``collective_bytes`` / ``collective_counts`` come out of the compiled
  HLO: stable on one jax/XLA version, allowed to drift across versions
  (CI installs latest), but a band escape catches a collective that
  disappears or explodes.
* **derived slack metrics are informational** — ``wait_ms_per_batch`` /
  ``tail_ms`` are differences of measured times (``max(0, prep -
  compute)``-shaped): a slowdown well inside the inputs' own band
  amplifies into an unbounded ratio on a near-zero baseline, so they are
  reported in the artifacts but not ratio-gated (the underlying
  prep/compute keys still are).
* **missing keys fail** — a section present in the baseline but absent
  from the candidate means a bench stopped emitting it (exactly the
  section-clobbering bug the key-stable merge in bench_split_sgd.py
  fixed); extra candidate keys are fine (new rows land before the
  baseline is refreshed).

Exit code 0 = gate passed; 1 = regressions (all of them are listed).
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# key classification, on the FULL path (lowercased).  Order matters: rate
# before cost (``mb_per_s`` must not fall into the trailing ``_s`` cost
# pattern), band before both (compiler-derived volumes carry time-free
# names).  Rate patterns are suffix-anchored so time-valued keys that
# merely CONTAIN a rate word (``modeled_overlap_ms``) still classify as
# cost via their time-unit suffix.
RATE_RE = re.compile(
    r"(mb_s$|_mbs$|per_s$|throughput|overlap_fraction$|efficiency$|speedup_measured$)"
)
COST_RE = re.compile(r"(^|_)(us|ms|s|sec|seconds|wall|time)(_|$)|us_measured")
# compiler/runtime-derived volumes: stable on one jax/XLA version but
# allowed to drift across versions (CI installs latest) — two-sided band.
# ``overhead_ratio`` (bench_telemetry.py) is a ratio of two measured
# walls: banded for visibility, with the real gate on the exact-class
# ``overhead_ok`` bool next to it.
BAND_RE = re.compile(r"collective_bytes|collective_counts|/coll/|flops"
                     r"|overhead_ratio|overlap_speedup")
# analytically derived from model keys: exact up to float repr
# (modeled_*_ms values are functions of MEASURED times — the cost class
# catches them via their _ms suffix)
DERIVED_RE = re.compile(r"model_speedup")
# derived SLACK metrics (wait ~= max(0, prep - compute), pipeline tail):
# a small slowdown of their inputs — well inside those inputs' own band —
# amplifies into an unbounded ratio on a near-zero baseline, so gating
# them by ratio flakes on contended runners.  Informational only; the
# underlying prep/compute keys are still gated.
SKIP_RE = re.compile(r"(^|/)(wait_ms_per_batch|tail_ms)$")


def classify(path: str) -> str:
    p = path.lower()
    if SKIP_RE.search(p):
        return "skip"
    if BAND_RE.search(p):
        return "band"
    key = p.rsplit("/", 1)[-1]
    if DERIVED_RE.search(key):
        return "derived"
    if RATE_RE.search(key):
        return "rate"
    if COST_RE.search(key):
        return "cost"
    return "exact"


def compare(base, cand, tol: float, path: str, problems: list) -> None:
    if isinstance(base, dict):
        if not isinstance(cand, dict):
            problems.append(f"{path}: section became {type(cand).__name__}")
            return
        for k, v in base.items():
            if k not in cand:
                problems.append(f"{path}/{k}: missing from candidate (section lost)")
                continue
            compare(v, cand[k], tol, f"{path}/{k}", problems)
        return
    if isinstance(base, list):
        if not isinstance(cand, list) or len(base) != len(cand):
            problems.append(f"{path}: list shape changed")
            return
        for i, (b, c) in enumerate(zip(base, cand)):
            compare(b, c, tol, f"{path}[{i}]", problems)
        return
    kind = classify(path)
    if kind == "skip":
        return
    if base is None:
        # a null baseline (dry-run placeholders like measured_ms) gates
        # nothing: a candidate that starts measuring is MORE data, and
        # extra data never fails the gate
        return
    if isinstance(base, bool) or isinstance(base, str):
        if base != cand:
            problems.append(f"{path}: {base!r} -> {cand!r}")
        return
    # numeric baseline: a null/str candidate is itself a regression (a
    # bench stopped measuring) — report it, don't crash the walk
    if isinstance(cand, bool) or not isinstance(cand, (int, float)):
        problems.append(f"{path}: {base!r} -> {cand!r} (type changed)")
        return
    b, c = float(base), float(cand)
    if kind == "exact":
        if b != c:
            problems.append(f"{path}: {base} -> {cand} (exact model key)")
    elif kind == "derived":
        if abs(c - b) > 1e-9 * max(abs(b), 1.0):
            problems.append(f"{path}: {b} -> {c} (model-derived key)")
    elif kind == "band":
        if b > 0 and not (b / tol <= c <= b * tol):
            problems.append(f"{path}: {b:g} -> {c:g} (outside {tol:.0f}x band)")
    elif kind == "cost":
        if b > 0 and c > b * tol:
            problems.append(f"{path}: {b:.1f} -> {c:.1f} (> {tol:.0f}x slower)")
    elif kind == "rate":
        if b > 0 and c < b / tol:
            problems.append(f"{path}: {b:.3f} -> {c:.3f} (> {tol:.0f}x lower)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--baseline-dir",
        required=True,
        help="directory holding the committed BENCH_*.json baselines (CI "
        "copies them aside before the smoke benches overwrite the working "
        "tree)",
    )
    ap.add_argument(
        "--candidate-dir",
        default=".",
        help="directory holding the freshly generated artifacts (default: repo root)",
    )
    ap.add_argument(
        "--files",
        default="BENCH_*.json",
        help="glob of bench artifacts to gate",
    )
    ap.add_argument(
        "--tol",
        type=float,
        default=8.0,
        help="tolerance band factor for measured keys (cost keys fail above "
        "baseline*tol, rate keys below baseline/tol); bytes/model keys are "
        "always exact",
    )
    args = ap.parse_args(argv)

    base_dir = Path(args.baseline_dir)
    cand_dir = Path(args.candidate_dir)
    baselines = sorted(base_dir.glob(args.files))
    if not baselines:
        print(
            f"check_bench: no baselines matching {args.files!r} in "
            f"{base_dir} — nothing to gate",
            file=sys.stderr,
        )
        return 1

    problems: list[str] = []
    checked = 0
    for bp in baselines:
        cp = cand_dir / bp.name
        if not cp.exists():
            problems.append(
                f"{bp.name}: candidate artifact missing (bench did not run or did not write it)"
            )
            continue
        base = json.loads(bp.read_text())
        cand = json.loads(cp.read_text())
        before = len(problems)
        compare(base, cand, args.tol, bp.name, problems)
        checked += 1
        status = "OK" if len(problems) == before else "FAIL"
        print(f"check_bench: {bp.name}: {status}")

    if problems:
        print(
            f"\ncheck_bench: {len(problems)} regression(s) across {checked} artifact(s):",
            file=sys.stderr,
        )
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(
        f"check_bench: all {checked} artifact(s) within gate "
        f"(bytes exact, measured within {args.tol:.0f}x)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
