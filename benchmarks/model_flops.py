"""Analytic MODEL_FLOPS per (arch x shape) — the 'useful compute' numerator
of the roofline ratio (EXPERIMENTS.md section Roofline).

LM: 6*N_active*D for training, 2*N_active*D for inference (standard).
DLRM/recsys: per-op accounting (embedding adds + dense matmuls +
interaction), x3 for training (fwd + bwd-data + bwd-weights).
GNN: per-layer edge/node MLP matmul counts, x3 for training.
"""

from __future__ import annotations


def _mlp_flops(sizes, batch):
    return sum(2 * a * b for a, b in zip(sizes[:-1], sizes[1:])) * batch


def lm_flops(meta: dict) -> float:
    n = meta["active_params"]
    toks = meta["tokens"]
    if meta["kind"] == "train":
        return 6.0 * n * toks
    return 2.0 * n * toks


def dlrm_flops(meta: dict, cfg=None) -> float:
    """meta carries batch/slots/pooling/emb_dim(+bottom/top for dlrm)."""
    B = meta["batch"]
    S, P, E = meta["slots"], meta["pooling"], meta["emb_dim"]
    emb = 2.0 * B * S * P * E            # gather-add fwd
    train = meta["kind"] == "train"
    dense = 0.0
    if "bottom" in meta:
        dense += _mlp_flops(meta["bottom"], B)
        dense += _mlp_flops(meta["top"], B)
        F = S + 1
        dense += 2.0 * B * F * F * E     # dot interaction
    if train:
        return 3.0 * dense + 2.0 * emb   # emb bwd+update ~= fwd cost
    return dense + emb


def recsys_flops(meta: dict) -> float:
    B = meta["batch"]
    S, E = meta["slots"], meta["emb_dim"]
    emb = 2.0 * B * S * meta["pooling"] * E
    arch = meta["arch"]
    if arch == "fm":
        dense = 2.0 * B * S * E * 2
    elif arch == "bst":
        L, d, H = 21, 32, 8
        attn = 2 * B * (4 * L * d * d + 2 * L * L * d)
        ffn = 2 * B * L * (d * 4 * d * 2)
        mlp = _mlp_flops([29 * d if False else L * d + 8 * d, 1024, 512,
                          256, 1], B)
        dense = attn + ffn + mlp
    elif arch == "sasrec":
        L, d = 50, 50
        dense = 2 * B * 2 * (4 * L * d * d + 2 * L * L * d + L * d * d * 2)
    else:  # din
        T, E_, = 100, 18
        attn_mlp = _mlp_flops([4 * E_, 80, 40, 1], B * T)
        mlp = _mlp_flops([6 * E_, 200, 80, 1], B)
        dense = attn_mlp + mlp
    if meta["kind"] == "train":
        return 3.0 * dense + 2.0 * emb
    if meta["kind"] == "retrieval":
        nc = meta.get("n_candidates", 1)
        return dense / max(B, 1) * nc + emb
    return dense + emb


def egnn_flops(meta: dict) -> float:
    h = 64
    E_edges, N = meta["n_edges"], meta["n_nodes"]
    nl = meta["n_layers"]
    per_layer = (E_edges * (2 * (2 * h + 1) * h + 2 * h * h)      # phi_e
                 + E_edges * (2 * h * h + 2 * h)                  # phi_x
                 + N * (2 * 2 * h * h + 2 * h * h))               # phi_h
    total = nl * per_layer
    return 3.0 * total  # training


def model_flops(meta: dict) -> float:
    fam = meta["family"]
    if fam == "lm":
        return lm_flops(meta)
    if fam == "gnn":
        return egnn_flops(meta)
    if fam == "dlrm":
        return dlrm_flops(meta)
    return recsys_flops(meta)
