"""Render results/dryrun + results/roofline into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.report [--section dryrun|roofline]
"""

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"


def _fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table() -> str:
    rows = []
    for f in sorted((RESULTS / "dryrun").glob("*.json")):
        r = json.loads(f.read_text())
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], r["mesh"], "skipped", "",
                         "", "", ""))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], "ERROR", "", "",
                         "", ""))
            continue
        mem = r["memory"]
        rows.append((
            r["arch"], r["shape"], r["mesh"], "ok",
            _fmt_bytes(mem["peak_estimate_bytes"]),
            f"{r['cost']['flops']:.3g}",
            f"{r['collectives']['total_bytes']:.3g}",
            str(r.get("compile_s", "")),
        ))
    out = ["| arch | shape | mesh | status | peak GiB/dev | HLO flops/dev "
           "(scan-once) | coll B/dev | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for row in rows:
        out.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(out)


def roofline_table(dirname="roofline") -> str:
    rows = []
    for f in sorted((RESULTS / dirname).glob("*.json")):
        r = json.loads(f.read_text())
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], "skipped", "", "", "", "",
                         "", ""))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], "ERROR", "", "", "", "", "",
                         ""))
            continue
        t = r["terms"]
        rows.append((
            r["arch"], r["shape"], r["kind"],
            f"{t['compute_s']*1e3:.2f}", f"{t['memory_s']*1e3:.2f}",
            f"{t['collective_s']*1e3:.2f}",
            r["bottleneck"].replace("_s", ""),
            f"{r['useful_flops_ratio']*100:.0f}%",
            f"{r['roofline_fraction']*100:.2f}%",
        ))
    out = ["| arch | shape | kind | compute ms | memory ms | collective ms "
           "| bottleneck | useful/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for row in rows:
        out.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=("dryrun", "roofline", "baseline"),
                    default=None)
    args = ap.parse_args()
    if args.section in (None, "dryrun"):
        print("## Dry-run\n")
        print(dryrun_table())
    if args.section in (None, "roofline"):
        print("\n## Roofline\n")
        print(roofline_table())
    if args.section == "baseline":
        print(roofline_table("roofline_baseline"))


if __name__ == "__main__":
    main()
