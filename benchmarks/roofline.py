import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis (EXPERIMENTS.md section Roofline).

Per (arch x shape) on the single-pod 16x16 mesh, derive the three terms:

    compute_s    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = collective_operand_bytes_per_device / ICI_link_bw

cost_analysis counts a ``lax.scan`` body ONCE regardless of trip count, so
scanned programs (LM layer stacks, EGNN layers, chunked embedding updates)
are extrapolated linearly from two lowerings with different layer counts /
batch sizes:  c(N) = c(n1) + (c(n2)-c(n1)) * (N-n1)/(n2-n1).

The roofline fraction reported in section Perf is
    MODEL_FLOPS_per_device / (peak * max(compute_s, memory_s, collective_s))
i.e. model-flops utilization at the roofline-limited step time.

Caveat (documented): the CPU dry-run backend normalizes bf16 loop carries to
f32, inflating 'bytes accessed' and some temp buffers ~2x vs real TPU; the
relative term comparison and the iteration log are unaffected.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--arch A] [--shape S]
Results: results/roofline/<arch>__<shape>.json + stdout table.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from benchmarks.model_flops import model_flops
from repro.configs import base as cfgbase
from repro.hw import TPU_V5E
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[1] / "results" / "roofline"


def measure(build, mesh) -> dict:
    with jax.set_mesh(mesh):
        lowered = build.fn.lower(*build.args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = parse_collective_bytes(compiled.as_text())
    ma = compiled.memory_analysis()
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
            "coll_by_op": coll["bytes_by_op"],
            "peak_gib": (ma.argument_size_in_bytes
                         + ma.output_size_in_bytes + ma.temp_size_in_bytes
                         - ma.alias_size_in_bytes) / 2**30}


def extrapolate(ad, shape, mesh, meta) -> dict:
    """Layer- or batch-extrapolated per-device cost."""
    fam = meta["family"]
    if fam in ("lm", "gnn"):
        unit = meta.get("scan_unit", 1)
        pre = meta.get("scan_outside", 0)
        n_full = meta["n_layers"]
        n1, n2 = pre + unit, pre + 2 * unit
        if n_full <= n2:                       # tiny configs: measure direct
            return measure(ad.build(shape, mesh, cost_mode=True), mesh)
        c1 = measure(ad.build(shape, mesh, n_layers=n1, cost_mode=True),
                     mesh)
        c2 = measure(ad.build(shape, mesh, n_layers=n2, cost_mode=True),
                     mesh)
        out = {}
        for k in ("flops", "bytes", "coll"):
            slope = (c2[k] - c1[k]) / (n2 - n1)
            out[k] = max(0.0, c1[k] + slope * (n_full - n1))
        out["coll_by_op"] = {
            op: c1["coll_by_op"].get(op, 0)
            + (c2["coll_by_op"].get(op, 0) - c1["coll_by_op"].get(op, 0))
            / (n2 - n1) * (n_full - n1)
            for op in set(c1["coll_by_op"]) | set(c2["coll_by_op"])}
        full = measure(ad.build(shape, mesh), mesh)   # real peak memory
        out["peak_gib"] = full["peak_gib"]
        out["extrapolated"] = f"layers {n1},{n2} -> {n_full}"
        return out
    # recsys/dlrm: batch extrapolation (chunk scans disabled via env so the
    # reduced-batch cost builds are scan-free; linear in B with the RS+AG
    # parameter traffic captured by the intercept)
    B = meta["batch"]
    ns = int(np.prod(list(mesh.shape.values())))
    b1 = max(ns, B // 16)
    b2 = 2 * b1
    os.environ["REPRO_EMB_CHUNK_BUDGET"] = str(1 << 62)
    try:
        if b2 >= B:
            return measure(ad.build(shape, mesh), mesh)
        c1 = measure(ad.build(shape, mesh, batch=b1), mesh)
        c2 = measure(ad.build(shape, mesh, batch=b2), mesh)
    finally:
        os.environ.pop("REPRO_EMB_CHUNK_BUDGET", None)
    out = {}
    for k in ("flops", "bytes", "coll"):
        slope = (c2[k] - c1[k]) / (b2 - b1)
        out[k] = c1[k] + slope * (B - b1)
    out["coll_by_op"] = {
        op: c1["coll_by_op"].get(op, 0)
        + (c2["coll_by_op"].get(op, 0) - c1["coll_by_op"].get(op, 0))
        / (b2 - b1) * (B - b1)
        for op in set(c1["coll_by_op"]) | set(c2["coll_by_op"])}
    full = measure(ad.build(shape, mesh), mesh)
    out["peak_gib"] = full["peak_gib"]
    out["extrapolated"] = f"batch {b1},{b2} -> {B}"
    return out


def analyze(arch: str, shape: str, mesh) -> dict:
    ad = cfgbase.get(arch)
    cell = next(c for c in ad.cells if c.shape == shape)
    if cell.skip:
        return {"arch": arch, "shape": shape, "status": "skipped",
                "skip_reason": cell.skip}
    build = ad.build(shape, mesh)
    meta = build.meta
    cost = extrapolate(ad, shape, mesh, meta)
    chips = int(np.prod(list(mesh.shape.values())))
    hw = TPU_V5E
    compute_s = cost["flops"] / hw.peak_flops_bf16
    memory_s = cost["bytes"] / hw.hbm_bw
    coll_s = cost["coll"] / hw.ici_bw_per_link
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    bottleneck = max(terms, key=terms.get)
    step_lb = max(terms.values())
    mf = model_flops(meta)
    mf_dev = mf / chips
    rec = {
        "arch": arch, "shape": shape, "kind": meta["kind"],
        "status": "ok", "chips": chips,
        "terms": terms, "bottleneck": bottleneck,
        "step_time_lower_bound_s": step_lb,
        "model_flops_total": mf,
        "hlo_flops_per_device": cost["flops"],
        "hlo_bytes_per_device": cost["bytes"],
        "collective_bytes_per_device": cost["coll"],
        "coll_by_op": cost["coll_by_op"],
        "useful_flops_ratio": mf_dev / cost["flops"] if cost["flops"] else 0,
        "roofline_fraction": (mf_dev / (hw.peak_flops_bf16 * step_lb)
                              if step_lb else 0.0),
        "peak_gib": cost["peak_gib"],
        "extrapolated": cost.get("extrapolated", "direct"),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else cfgbase.list_archs()
    rows = []
    for arch in archs:
        ad = cfgbase.get(arch)
        for cell in ad.cells:
            if args.shape and cell.shape != args.shape:
                continue
            out = RESULTS / f"{arch}__{cell.shape}.json"
            if out.exists() and not args.force:
                rec = json.loads(out.read_text())
            else:
                print(f"[roofline] {arch} {cell.shape} ...", flush=True)
                try:
                    rec = analyze(arch, cell.shape, mesh)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": cell.shape,
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                out.write_text(json.dumps(rec, indent=2))
            rows.append(rec)
            if rec["status"] == "ok":
                t = rec["terms"]
                print(f"  {arch:22s} {cell.shape:16s} "
                      f"comp={t['compute_s']*1e3:8.2f}ms "
                      f"mem={t['memory_s']*1e3:8.2f}ms "
                      f"coll={t['collective_s']*1e3:8.2f}ms "
                      f"-> {rec['bottleneck'][:-2]:10s} "
                      f"roofline={rec['roofline_fraction']*100:5.1f}%",
                      flush=True)
            elif rec["status"] == "skipped":
                print(f"  {arch:22s} {cell.shape:16s} skipped")
            else:
                print(f"  {arch:22s} {cell.shape:16s} ERROR "
                      f"{rec['error']}")
    n_err = sum(r["status"] == "error" for r in rows)
    print(f"\nroofline cells: {len(rows)}, errors: {n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
