"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--section ops|comm|scaling|split]

Prints ``name,us_per_call_or_value,derived`` CSV lines per section.  The
roofline (section Roofline of EXPERIMENTS.md) and the multi-pod dry-run have
their own entry points (benchmarks.roofline, repro.launch.dryrun) because
they need the 512-device flag before jax initializes.
"""

import argparse
import inspect
import sys
import traceback


SECTIONS = ("ops", "comm", "scaling", "split", "ingest", "resilience")


def _call_main(m) -> None:
    """Benchmark mains that take an ``argv`` parameter get an empty list so
    run.py's own --section flag never leaks into their parsers."""
    if inspect.signature(m.main).parameters:
        m.main([])
    else:
        m.main()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=SECTIONS, default=None)
    args = ap.parse_args()
    sections = [args.section] if args.section else list(SECTIONS)
    failed = []
    for sec in sections:
        print(f"# --- {sec} ---")
        try:
            if sec == "ops":
                from benchmarks import bench_ops as m
            elif sec == "comm":
                from benchmarks import bench_comm_model as m
            elif sec == "scaling":
                from benchmarks import bench_scaling as m
            elif sec == "ingest":
                from benchmarks import bench_ingest as m
            elif sec == "resilience":
                from benchmarks import bench_resilience as m
            else:
                from benchmarks import bench_split_sgd as m
            _call_main(m)
        except Exception:  # noqa: BLE001
            failed.append(sec)
            traceback.print_exc()
    if failed:
        print(f"# FAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
