"""Elastic restart scenario: train on one mesh, lose devices, restore the
SAME logical state onto a smaller mesh and keep training.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart.py

Exercises the global-array checkpoint format + ``reshard_embedding`` (the
embedding row space is re-laid-out when the shard count changes).
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import reshard_store
from repro.core import dlrm as D
from repro.core import sharded_embedding as se
from repro.data.synthetic import dlrm_stream
from repro.launch.mesh import make_mesh


def make(cfg, mesh):
    state, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    step, shardings, _, _ = D.make_train_step(cfg, mesh)
    return state, layout, step, shardings


def main():
    n = len(jax.devices())
    assert n >= 8, "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
    big = make_mesh((2, 4), ("data", "model"))       # healthy cluster
    small = make_mesh((1, 4), ("data", "model"))     # after losing a host

    cfg = D.DLRMConfig(name="elastic", num_dense=32, bottom=(64, 16),
                       top=(64,), table_rows=(5000, 3000, 1000, 500),
                       emb_dim=16, pooling=4, batch=64, lr=0.05)
    stream = ({k: jnp.asarray(v) for k, v in b.items()}
              for b in dlrm_stream(0, cfg))

    state, layout_big, step, _ = make(cfg, big)
    for i in range(10):
        state, loss = step(state, next(stream))
    print(f"big mesh (8 dev): 10 steps, loss {float(loss):.4f}")

    with tempfile.TemporaryDirectory() as ck:
        mgr = CheckpointManager(ck)
        mgr.save(10, state, blocking=True)

        # ---- "failure": rebuild everything on the 4-device mesh ----------
        state2, layout_small, step2, shardings2 = make(cfg, small)
        _, restored = mgr.restore(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state))
        # embedding row space re-layout (shard count 8 -> 4): every slab
        # of the EmbeddingStore — weights AND per-row optimizer state —
        # reshards the same way (repro/optim/row.py store contract)
        restored["emb"] = {k: jnp.asarray(v) for k, v in reshard_store(
            layout_big, layout_small, restored["emb"]).items()}
        # dense lo shard layout is bucket-major per shard count: rebuild it
        from repro.optim import data_parallel as dp
        from repro.optim.split_sgd import combine_split, split_fp32
        hi_tree = restored["dense"]["hi"]
        # reconstruct fp32 dense params from hi + old lo layout
        old_lo = np.asarray(restored["dense"]["lo"])
        flat_hi, _ = jax.flatten_util.ravel_pytree(hi_tree)
        n_real = flat_hi.size
        old_lo_nat = dp.to_bucketed_layout  # noqa: F841 (layout docs)
        # simplest correct path: checkpoint stores lo in bucket layout for
        # the OLD shard count; reconstruct fp32 via the old layout inverse
        from repro.dist.exchange import resolve_exchange
        ns_old, nb = 8, resolve_exchange(cfg).num_buckets
        padded = old_lo.size
        bchunk = padded // (ns_old * nb)
        lo_nat = old_lo.reshape(ns_old, nb, bchunk).transpose(1, 0, 2
                                                             ).reshape(-1)
        w32 = combine_split(
            jax.lax.bitcast_convert_type(
                jnp.pad(jax.lax.bitcast_convert_type(flat_hi, jnp.uint16),
                        (0, padded - n_real)), jnp.bfloat16),
            jnp.asarray(lo_nat))
        dense_fp32 = dp.unravel_like(w32[:n_real], hi_tree)
        arrays = dp.dp_global_arrays(dense_fp32, 4, num_buckets=nb)
        restored["dense"]["hi"] = arrays["hi"]
        restored["dense"]["lo"] = arrays["lo"]
        state2 = jax.device_put(restored, shardings2)

        for i in range(10):
            state2, loss2 = step2(state2, next(stream))
        print(f"small mesh (4 dev): resumed, 10 more steps, "
              f"loss {float(loss2):.4f}")
        assert np.isfinite(float(loss2))
        print("elastic restart OK: same logical state, half the devices")


if __name__ == "__main__":
    main()
