"""Quickstart: hybrid-parallel DLRM training end-to-end in ~30 seconds.

Run with a simulated 8-device mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py

What this shows:
  * the paper's hybrid parallelism (model-parallel unified embedding +
    data-parallel MLPs, reduce-scatter layout switch) on a (2, 4) mesh,
  * Split-SGD-BF16 (C5) as the optimizer for both sparse and dense params,
  * checkpoint -> crash -> restore.
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import dlrm as D
from repro.data.synthetic import dlrm_stream
from repro.launch.mesh import make_mesh
from repro.train import TrainLoop, TrainLoopConfig


def main():
    n = len(jax.devices())
    mesh = make_mesh((max(1, n // 4), min(4, n)), ("data", "model"))
    print(f"devices={n}, mesh={dict(mesh.shape)}")

    cfg = D.DLRMConfig(
        name="quickstart", num_dense=64, bottom=(128, 32), top=(128, 64),
        table_rows=(40_000, 10_000, 5_000, 2_000, 1_000, 500, 200, 100),
        emb_dim=32, pooling=8, batch=512, lr=0.05)
    state, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    step, shardings, bspecs, _ = D.make_train_step(cfg, mesh)
    stream = ({k: jnp.asarray(v) for k, v in b.items()}
              for b in dlrm_stream(0, cfg, alpha=0.6))

    with tempfile.TemporaryDirectory() as ckdir:
        loop = TrainLoop(TrainLoopConfig(steps=60, ckpt_dir=ckdir,
                                         ckpt_every=20, log_every=20),
                         step, state, stream, state_shardings=shardings)
        state = loop.run()
        print(f"loss: {loop.losses[0]:.4f} -> {loop.losses[-1]:.4f}")

        # simulate a restart: a fresh loop restores from the checkpoint
        loop2 = TrainLoop(TrainLoopConfig(steps=80, ckpt_dir=ckdir,
                                          ckpt_every=20, log_every=20),
                          step, state, stream, state_shardings=shardings)
        assert loop2.start_step >= 60, loop2.start_step
        loop2.run()
        print(f"restored at step {loop2.start_step}, continued to 80 OK")

    ev, _, _, _ = D.make_eval_step(cfg, mesh)
    batch = next(stream)
    scores = ev(state, batch)
    print(f"eval scores: shape {scores.shape}, "
          f"mean {float(scores.mean()):.4f}")


if __name__ == "__main__":
    main()
