"""Serving scenario: DIN online scoring with dynamic batching (serve_p99)
plus a retrieval pass (retrieval_cand) with a distributed top-k merge.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_recsys.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid as H
from repro.data.synthetic import hybrid_stream
from repro.launch.mesh import make_mesh
from repro.models import recsys as R
from repro.serve import BatchingServer


def main():
    n = len(jax.devices())
    mesh = make_mesh((max(1, n // 4), min(4, n)), ("data", "model"))
    BATCH = 64
    mdef = R.make_din(50_000, (1000,) * 4, batch=BATCH)
    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    score, _, _, _ = H.make_score_step(mdef, mesh, batch=BATCH)
    gen = hybrid_stream(0, mdef, alpha=0.7)

    def pad_batch(reqs):
        base = next(gen)
        for i, r in enumerate(reqs):
            base["idx"][i] = r["idx"]
        return {k: jnp.asarray(v) for k, v in base.items()}

    server = BatchingServer(lambda b: score(state, b), BATCH, pad_batch)
    # warmup compile
    list(server.drain())
    rng = np.random.default_rng(1)
    template = next(gen)
    for _ in range(400):
        server.submit({"idx": template["idx"][rng.integers(0, BATCH)]})
        if rng.random() < 0.3:
            for _ in server.drain():
                pass
    for _ in server.drain():
        pass
    print("online scoring latency:", server.percentiles())

    # ---- retrieval: one query vs sharded candidate index + global top-k ---
    ns = int(np.prod(list(mesh.shape.values())))
    n_cand = 4096
    retr, arg_structs, arg_shardings, _ = H.make_retrieval_step(
        mdef, mesh, n_cand, target_slot=100, topk=16)
    batch1 = {k: jnp.asarray(v[:1]) for k, v in next(gen).items()}
    cand = jnp.asarray(
        np.random.default_rng(2).standard_normal((n_cand, mdef.spec.dim)),
        jnp.bfloat16)
    vals, ids = retr(state, batch1, cand)
    print(f"retrieval top-16 of {n_cand} candidates: "
          f"ids {np.asarray(ids)[:5]}... scores {np.asarray(vals)[:3]}")
    assert len(set(np.asarray(ids).tolist())) == 16


if __name__ == "__main__":
    main()
