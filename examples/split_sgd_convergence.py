"""Reproduces the paper's Fig. 16 claim: Split-SGD-BF16 trains to the same
loss as fp32 SGD, while bf16-weights-WITHOUT-the-lo-bits (the naive
mixed-precision baseline) degrades.

    PYTHONPATH=src python examples/split_sgd_convergence.py

The paper also reports that 8 LSBs are not enough; we emulate that by
zeroing the low byte of ``lo`` each step (keeping 8 extra mantissa bits).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dlrm import DLRMConfig, forward_local, bce_with_logits, \
    init_dense_params
from repro.core.embedding import bag_lookup, globalize
from repro.data.synthetic import dlrm_stream
from repro.optim import split_sgd as S


def run(mode: str, steps: int = 200, lr: float = 0.05) -> list:
    cfg = DLRMConfig(name="fig16", num_dense=32, bottom=(64, 16),
                     top=(64, 32), table_rows=(2000,) * 4, emb_dim=16,
                     pooling=4, batch=512, lr=lr)
    key = jax.random.PRNGKey(0)
    ke, kd = jax.random.split(key)
    W = jax.random.uniform(ke, (cfg.spec.total_rows, cfg.emb_dim),
                           jnp.float32, -0.02, 0.02)
    dense = init_dense_params(kd, cfg)
    params = {"emb": W, "dense": dense}

    if mode == "fp32":
        state = params
    elif mode in ("split", "split8"):
        state = S.init(params)
    else:  # bf16: no master bits at all
        state = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)

    def loss_fn(fwd_params, batch):
        g = globalize(cfg.spec, batch["idx"])
        emb_out = bag_lookup(fwd_params["emb"], g)
        logits = forward_local(fwd_params["dense"], emb_out,
                               batch["dense_x"].astype(jnp.bfloat16))
        return bce_with_logits(logits, batch["labels"]).mean()

    @jax.jit
    def step(state, batch):
        if mode == "fp32":
            loss, g = jax.value_and_grad(loss_fn)(state, batch)
            return jax.tree.map(lambda p, gg: p - lr * gg, state, g), loss
        if mode == "bf16":
            loss, g = jax.value_and_grad(loss_fn)(state, batch)
            return jax.tree.map(
                lambda p, gg: (p.astype(jnp.float32)
                               - lr * gg.astype(jnp.float32)
                               ).astype(jnp.bfloat16), state, g), loss
        loss, g = jax.value_and_grad(loss_fn)(state.params.hi, batch)
        new = S.apply_updates(state, g, lr)
        if mode == "split8":   # keep only 8 extra mantissa bits
            new = S.SplitSGDState(
                S.SplitParams(new.params.hi, jax.tree.map(
                    lambda l: l & jnp.uint16(0xFF00), new.params.lo)),
                new.momentum)
        return new, loss

    stream = dlrm_stream(7, cfg)
    losses = []
    for i, b in zip(range(steps), stream):
        # learnable teacher: label depends on a sparse id parity AND a dense
        # feature — both the embedding and MLP paths must train to fit it
        y = ((b["idx"][:, 0, 0] % 2).astype(np.float32)
             + (b["dense_x"][:, 0] > 0).astype(np.float32)) >= 1.5
        b["labels"] = y.astype(np.float32)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, loss = step(state, batch)
        losses.append(float(loss))
    return losses


def main():
    out = {}
    for mode in ("fp32", "split", "split8", "bf16"):
        losses = run(mode)
        out[mode] = np.mean(losses[-20:])
        print(f"{mode:7s}: final-20 mean loss {out[mode]:.5f}")
    gap_split = abs(out["split"] - out["fp32"])
    gap_bf16 = abs(out["bf16"] - out["fp32"])
    print(f"\nsplit-vs-fp32 gap {gap_split:.5f}  |  "
          f"bf16-vs-fp32 gap {gap_bf16:.5f}")
    assert gap_split < 5e-3, "Split-SGD should match fp32 (paper Fig. 16)"
    print("paper claim holds: Split-SGD-BF16 ~ fp32; naive bf16 drifts")


if __name__ == "__main__":
    main()
