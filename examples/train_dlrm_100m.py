"""End-to-end driver: train a ~103M-parameter DLRM for a few hundred steps
(the deliverable-(b) "train ~100M model" scenario).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_dlrm_100m.py [--steps 300]

Uses the skewed (zipf) index stream — the regime where the paper's race-free
ownership update matters (Fig. 8's contention analysis).

With ``--data-dir DIR`` the same stream is PACKED into shard files on
first run and training streams from disk through the full ingestion
chain (docs/data.md): mmap reader -> threaded HostPipeline ->
prefetch_to_device.  ``--host-presort`` additionally moves the
sparse-update index sort onto the loader thread (row mode; the
compiled-kernel win — on this CPU container it runs the interpret-mode
kernel, which is validation-speed only).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dlrm as D
from repro.data.synthetic import dlrm_stream
from repro.launch.mesh import make_mesh
from repro.train import TrainLoop, TrainLoopConfig


def packed_stream(cfg, data_dir, steps, host_presort, layout):
    """Pack (first run) + stream the packed dataset (docs/data.md)."""
    from repro.data.format import DatasetSpec, write_shards
    from repro.data.pipeline import HostPipeline
    from repro.data.reader import ShardedReader
    if not os.path.exists(os.path.join(data_dir, "dataset.json")):
        n = max(steps * cfg.batch // 4, cfg.batch)   # ~4 epochs of reuse
        print(f"packing {n} synthetic samples into {data_dir} ...")
        spec = DatasetSpec(table_rows=cfg.table_rows, pooling=cfg.pooling,
                           num_dense=cfg.num_dense)
        write_shards(dlrm_stream(0, cfg, alpha=0.8), data_dir, spec, n,
                     samples_per_shard=8192)
    reader = ShardedReader(data_dir, batch=cfg.batch, seed=0, shuffle=True)
    reader.spec.check(cfg.table_rows, cfg.pooling, num_dense=cfg.num_dense)
    return HostPipeline(reader, layout=layout, presort=host_presort)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--data-dir", default=None,
                    help="train from packed shards (packed on first run)")
    ap.add_argument("--host-presort", action="store_true",
                    help="pre-sort the update index stream on the loader "
                         "thread (requires --data-dir)")
    ap.add_argument("--optimizer", default="adagrad_rowwise",
                    help="sparse RowOptimizer for the embedding path "
                         "(docs/optim.md); production DLRM default is "
                         "row-wise Adagrad — O(rows) optimizer state")
    ap.add_argument("--eps", type=float, default=None,
                    help="adagrad denominator floor override")
    args = ap.parse_args()
    if args.host_presort and not args.data_dir:
        ap.error("--host-presort requires --data-dir")

    n = len(jax.devices())
    mesh = make_mesh((max(1, n // 4), min(4, n)), ("data", "model"))
    cfg = D.DLRMConfig(
        name="dlrm-100m", num_dense=64, bottom=(128, 64), top=(256, 128),
        table_rows=(200_000,) * 8, emb_dim=64, pooling=20, batch=256,
        lr=0.03, sparse_optimizer=args.optimizer, opt_eps=args.eps,
        host_presort=args.host_presort)
    print(f"sparse optimizer: {args.optimizer}")
    emb_params = cfg.spec.total_rows * cfg.emb_dim
    dense_params = sum(a * b for a, b in zip(cfg.bottom_sizes[:-1],
                                             cfg.bottom_sizes[1:]))
    dense_params += sum(a * b for a, b in zip(cfg.top_sizes[:-1],
                                              cfg.top_sizes[1:]))
    print(f"~{(emb_params + dense_params)/1e6:.1f}M params "
          f"({emb_params/1e6:.1f}M embedding) on mesh {dict(mesh.shape)}")

    state, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    step, shardings, bspecs, _ = D.make_train_step(cfg, mesh)
    if args.data_dir:
        from repro.dist import sharding
        stream = packed_stream(cfg, args.data_dir, args.steps,
                               args.host_presort, layout)
        loop = TrainLoop(TrainLoopConfig(steps=args.steps, log_every=25,
                                         prefetch=2),
                         step, state, stream,
                         batch_shardings=sharding.named(mesh, bspecs))
    else:
        stream = ({k: jnp.asarray(v) for k, v in b.items()}
                  for b in dlrm_stream(0, cfg, alpha=0.8))
        loop = TrainLoop(TrainLoopConfig(steps=args.steps, log_every=25),
                         step, state, stream)
    try:
        loop.run()
    finally:
        if hasattr(stream, "close"):
            stream.close()        # release the HostPipeline worker
    first = np.mean(loop.losses[:10])
    last = np.mean(loop.losses[-10:])
    print(f"mean loss first-10 {first:.4f} -> last-10 {last:.4f}")
    assert last < first, "training should reduce loss"


if __name__ == "__main__":
    main()
