"""End-to-end driver: train a ~103M-parameter DLRM for a few hundred steps
(the deliverable-(b) "train ~100M model" scenario).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_dlrm_100m.py [--steps 300]

Uses the skewed (zipf) index stream — the regime where the paper's race-free
ownership update matters (Fig. 8's contention analysis).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dlrm as D
from repro.data.synthetic import dlrm_stream
from repro.launch.mesh import make_mesh
from repro.train import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    n = len(jax.devices())
    mesh = make_mesh((max(1, n // 4), min(4, n)), ("data", "model"))
    cfg = D.DLRMConfig(
        name="dlrm-100m", num_dense=64, bottom=(128, 64), top=(256, 128),
        table_rows=(200_000,) * 8, emb_dim=64, pooling=20, batch=256,
        lr=0.03)
    emb_params = cfg.spec.total_rows * cfg.emb_dim
    dense_params = sum(a * b for a, b in zip(cfg.bottom_sizes[:-1],
                                             cfg.bottom_sizes[1:]))
    dense_params += sum(a * b for a, b in zip(cfg.top_sizes[:-1],
                                              cfg.top_sizes[1:]))
    print(f"~{(emb_params + dense_params)/1e6:.1f}M params "
          f"({emb_params/1e6:.1f}M embedding) on mesh {dict(mesh.shape)}")

    state, _ = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    step, shardings, _, _ = D.make_train_step(cfg, mesh)
    stream = ({k: jnp.asarray(v) for k, v in b.items()}
              for b in dlrm_stream(0, cfg, alpha=0.8))
    loop = TrainLoop(TrainLoopConfig(steps=args.steps, log_every=25),
                     step, state, stream)
    loop.run()
    first = np.mean(loop.losses[:10])
    last = np.mean(loop.losses[-10:])
    print(f"mean loss first-10 {first:.4f} -> last-10 {last:.4f}")
    assert last < first, "training should reduce loss"


if __name__ == "__main__":
    main()
