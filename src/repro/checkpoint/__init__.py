from repro.checkpoint.manager import (CheckpointManager,  # noqa: F401
                                      reshard_embedding, reshard_store)
