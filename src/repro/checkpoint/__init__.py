from repro.checkpoint.manager import (  # noqa: F401
    FORMAT_VERSION,
    CheckpointCorruptError,
    CheckpointError,
    CheckpointManager,
    reshard_embedding,
    reshard_store,
)
