"""Fault-tolerant checkpointing.

Properties needed at 1000+ nodes, implemented here:

* atomic commit — writes land in ``step_<n>.tmp/`` and are ``os.replace``d
  into place only when complete; a crash mid-save never corrupts the latest
  checkpoint;
* async save — serialization happens on a background thread so the train
  loop isn't blocked (the device->host copy is synchronous and cheap
  relative to the write);
* retention — keep the newest K checkpoints;
* elastic restore — arrays are stored in GLOBAL logical form with the pytree
  structure, so restoring onto a DIFFERENT mesh (changed device count after
  a failure) is just a re-``device_put`` with the new shardings; the
  embedding row space is re-laid-out with
  :func:`reshard_embedding` when the shard count changes.

On a real multi-host deployment each host writes only its addressable
shards (the file format already keys arrays by tree path, so per-host
sharded writes are an IO-layer change, not a format change).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(state: Any) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """(flat arrays keyed by tree path, dtype tag per key).  npz cannot
    hold bf16, so bf16 leaves (split-weight ``hi`` halves, compressed
    bf16-hi optimizer-state slabs) are stored as their raw uint16 bits;
    the dtype TAG records the logical dtype so restore can view the bits
    back even when the target leaf doesn't pin a dtype — a genuinely
    uint16 slab (the split ``lo`` half) tags as uint16 and is never
    reinterpreted."""
    flat = {}
    dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat, dtypes


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------------------- save
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        flat, dtypes = _flatten(state)  # device->host copy happens here
        treedef = jax.tree_util.tree_structure(state)
        if self._thread is not None:
            self._thread.join()         # one in-flight save at a time

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            np.savez(tmp / "arrays.npz", **flat)
            (tmp / "meta.json").write_text(json.dumps(
                {"step": step, "treedef": str(treedef),
                 "time": time.time(),
                 "keys": sorted(flat),
                 "dtypes": dtypes}))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ----------------------------------------------------------- restore
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                      if p.is_dir() and p.name.startswith("step_")
                      and not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (same structure) re-places the
        arrays — pass the NEW mesh's shardings for an elastic restart."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        cdir = self.dir / f"step_{step}"
        data = np.load(cdir / "arrays.npz")
        # dtype tags (see _flatten): older checkpoints lack them and fall
        # back to the target leaf's dtype alone
        tags = json.loads((cdir / "meta.json").read_text()).get("dtypes", {})
        paths = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        import ml_dtypes
        for path, leaf in paths[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = data[key]
            tag = tags.get(key)
            want = str(getattr(leaf, "dtype", "")) or tag or ""
            if want and tag and want != tag:
                # the tag records the dtype the slab was SAVED as; a
                # restore target asking for anything else (fp32 momentum
                # under a bf16-state optimizer or vice versa, uint16 lo
                # bits as bf16, ...) would silently reinterpret or
                # mis-type the state — refuse both directions.  Untagged
                # (pre-tag) checkpoints trust the target struct.
                raise ValueError(
                    f"checkpoint leaf {key!r} dtype mismatch: saved as "
                    f"{tag}, restore target {want} — convert the state "
                    "explicitly instead of reinterpreting it")
            if arr.dtype == np.uint16 and want == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(paths[1], leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return step, state


def reshard_embedding(old_layout, new_layout, W_old: np.ndarray
                      ) -> np.ndarray:
    """Re-lay-out a unified embedding array when the shard count (and hence
    row padding / bin packing) changes across an elastic restart."""
    spec = old_layout.spec
    E = W_old.shape[1]
    W_new = np.zeros((new_layout.total_rows, E), W_old.dtype)

    def table_base(layout, t):
        if layout.mode == "row":
            return int(spec.row_offsets[t])
        # table mode: find the slot whose table is t (first match)
        for pos, s in enumerate(layout.padded_slots):
            if s >= 0 and layout.slot_to_table[s] == t:
                shard = pos // layout.slots_per_shard
                return shard * layout.rows_per_shard + \
                    int(layout.slot_local_offsets[pos])
        raise KeyError(t)

    for t, rows in enumerate(spec.table_rows):
        src = table_base(old_layout, t)
        dst = table_base(new_layout, t)
        W_new[dst:dst + rows] = W_old[src:src + rows]
    return W_new


def reshard_store(old_layout, new_layout, store: dict) -> dict:
    """Re-lay-out a full EmbeddingStore (repro/optim/row.py) across an
    elastic restart: every slab — weight halves AND per-row optimizer
    state (momentum rows, Adagrad accumulators) — is row-aligned on the
    same layout, so each one reshards exactly like the weights.  Slabs
    keep their dtypes (bf16 hi / uint16 lo / fp32 state / compressed
    bf16-hi state: ``np.asarray`` of a bf16 jax array yields an
    ``ml_dtypes.bfloat16`` view and the new slab inherits it)."""
    return {k: reshard_embedding(old_layout, new_layout, np.asarray(v))
            for k, v in store.items()}
