"""Fault-tolerant, VERIFIED checkpointing.

Properties needed at 1000+ nodes, implemented here:

* atomic commit — writes land in ``step_<n>.tmp/`` and are ``os.replace``d
  into place only when complete; a crash mid-save never corrupts the latest
  checkpoint;
* verified restore — ``meta.json`` carries a format version and a per-array
  CRC32; restore checks structure (treedef, key set) and content
  (checksums), so a torn write or bit-rot that slipped past the atomic
  commit is DETECTED instead of silently loaded.  ``latest_valid_step``
  scans newest-first and falls back to the newest checkpoint that
  verifies — training resumes from a good state, never a corrupt one;
* bounded retry — transient write failures (``OSError``: ENOSPC, a flaky
  mount) are retried with exponential backoff before the save is declared
  lost; an :class:`repro.faults.InjectedCrash` is never retried (a dead
  process does not get a second attempt);
* async save with surfaced failures — serialization happens on a background
  thread so the train loop isn't blocked; an exception on that thread is
  captured and re-raised at the next ``save()`` / ``wait()`` instead of
  dying silently with the daemon thread;
* retention — keep the newest K checkpoints;
* elastic restore — arrays are stored in GLOBAL logical form with the pytree
  structure, so restoring onto a DIFFERENT mesh (changed device count after
  a failure) is just a re-``device_put`` with the new shardings; the
  embedding row space is re-laid-out with :func:`reshard_embedding` /
  :func:`reshard_store` when the shard count changes.

Fault-injection hook points (``repro/faults/plan.py``; no-ops unless a
drill arms them): ``ckpt.write.arrays``, ``ckpt.write.meta``,
``ckpt.commit``.  Recovery actions record structured events on the
optional :class:`repro.faults.FailureLog`.

On a real multi-host deployment each host writes only its addressable
shards (the file format already keys arrays by tree path, so per-host
sharded writes are an IO-layer change, not a format change).
"""

from __future__ import annotations

import io
import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro import telemetry
from repro.faults.plan import NO_FAULTS, InjectedCrash

#: meta.json schema version.  1 = pre-verification (no checksums — verified
#: structurally only); 2 = per-array crc32 + format_version fields.
FORMAT_VERSION = 2


class CheckpointError(RuntimeError):
    """A checkpoint could not be written or restored."""


class CheckpointCorruptError(CheckpointError):
    """A checkpoint directory exists but fails verification."""


def _flatten(state: Any) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """(flat arrays keyed by tree path, dtype tag per key).  npz cannot
    hold bf16, so bf16 leaves (split-weight ``hi`` halves, compressed
    bf16-hi optimizer-state slabs) are stored as their raw uint16 bits;
    the dtype TAG records the logical dtype so restore can view the bits
    back even when the target leaf doesn't pin a dtype — a genuinely
    uint16 slab (the split ``lo`` half) tags as uint16 and is never
    reinterpreted."""
    flat = {}
    dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat, dtypes


def _tree_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


class CheckpointManager:
    def __init__(
        self,
        directory,
        keep: int = 3,
        retries: int = 2,
        backoff_s: float = 0.05,
        checksums: bool = True,
        verify_on_restore: bool = True,
        faults=None,
        event_log=None,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.retries = retries
        self.backoff_s = backoff_s
        self.checksums = checksums
        self.verify_on_restore = verify_on_restore
        self.faults = faults if faults is not None else NO_FAULTS
        self.events = event_log
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        #: wall seconds of each completed save attempt (async or blocking),
        #: newest last — the train-loop heartbeat reports these
        self.save_durations: list[float] = []

    def _record(self, kind: str, **fields) -> None:
        if self.events is not None:
            self.events.record(kind, **fields)

    # -------------------------------------------------------------- save
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Write checkpoint ``step``.  Re-raises any failure of a PREVIOUS
        background save first — an async save never fails silently."""
        self._raise_pending()
        with telemetry.span("ckpt/flatten", cat="ckpt", step=step):
            flat, dtypes = _flatten(state)  # device->host copy happens here
        treedef = jax.tree_util.tree_structure(state)
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
            self._thread = None
            self._raise_pending()

        def write():
            t0 = time.perf_counter()
            # explicit track: the blocking path runs on the caller's
            # thread, the async path on a fresh writer thread — both land
            # on one 'ckpt_writer' timeline
            with telemetry.span("ckpt/write", cat="ckpt",
                                track="ckpt_writer", step=step):
                self._write_with_retry(step, flat, dtypes, str(treedef))
            self.save_durations.append(time.perf_counter() - t0)

        if blocking:
            write()
        else:

            def guarded():
                try:
                    write()
                except BaseException as e:  # noqa: BLE001 — surfaced at next save/wait
                    self._error = e
                    self._record("ckpt_async_save_failed", step=step, error=repr(e))

            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()

    def _raise_pending(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            if isinstance(e, InjectedCrash):
                raise e  # simulated process death keeps its semantics
            raise CheckpointError(f"background checkpoint save failed: {e!r}") from e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _write_with_retry(self, step, flat, dtypes, treedef_str) -> None:
        """Bounded retry with exponential backoff around one atomic write
        attempt.  Only ``OSError`` (transient IO: ENOSPC, flaky mounts) is
        retried; ``InjectedCrash`` models process death and propagates."""
        last: Optional[OSError] = None
        for attempt in range(self.retries + 1):
            try:
                self._write_once(step, flat, dtypes, treedef_str)
                return
            except OSError as e:
                last = e
                self._record("ckpt_write_retry", step=step, attempt=attempt, error=repr(e))
                if attempt < self.retries:
                    time.sleep(self.backoff_s * (2**attempt))
        self._record("ckpt_write_failed", step=step, error=repr(last))
        raise CheckpointError(
            f"checkpoint save at step {step} failed after {self.retries + 1} attempts"
        ) from last

    def _write_once(self, step, flat, dtypes, treedef_str) -> None:
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        fault = self.faults.fire("ckpt.write.arrays", step=step)
        torn = fault is not None and fault.action == "partial"
        if torn:
            # commit a TORN arrays.npz behind a valid-looking directory —
            # the case that slips past atomic rename and only per-array
            # checksums catch (simulated fs lie / post-commit bit rot)
            buf = io.BytesIO()
            np.savez(buf, **flat)
            raw = buf.getvalue()
            (tmp / "arrays.npz").write_bytes(raw[: max(1, len(raw) // 3)])
        else:
            np.savez(tmp / "arrays.npz", **flat)
        meta = {
            "format_version": FORMAT_VERSION,
            "step": step,
            "treedef": treedef_str,
            "time": time.time(),
            "keys": sorted(flat),
            "dtypes": dtypes,
        }
        if self.checksums:
            meta["checksums"] = {
                k: zlib.crc32(np.ascontiguousarray(v).tobytes()) for k, v in flat.items()
            }
        self.faults.fire("ckpt.write.meta", step=step)
        (tmp / "meta.json").write_text(json.dumps(meta))
        self.faults.fire("ckpt.commit", step=step)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        if torn:
            raise InjectedCrash(f"injected torn-commit crash at step {step}")
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------ verify
    def verify(self, step: int) -> None:
        """Raise :class:`CheckpointCorruptError` unless checkpoint ``step``
        is structurally complete and (format >= 2) every array's CRC32
        matches ``meta.json``."""
        cdir = self.dir / f"step_{step}"
        meta_p = cdir / "meta.json"
        arrays_p = cdir / "arrays.npz"
        if not meta_p.exists() or not arrays_p.exists():
            raise CheckpointCorruptError(f"step {step}: incomplete checkpoint directory")
        try:
            meta = json.loads(meta_p.read_text())
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
            raise CheckpointCorruptError(f"step {step}: unreadable meta.json: {e!r}") from e
        version = meta.get("format_version", 1)
        if version > FORMAT_VERSION:
            raise CheckpointCorruptError(
                f"step {step}: format_version {version} is newer than this reader ({FORMAT_VERSION})"
            )
        if meta.get("step") != step:
            raise CheckpointCorruptError(
                f"step {step}: meta.json records step {meta.get('step')!r}"
            )
        sums = meta.get("checksums")
        try:
            with np.load(arrays_p) as data:
                keys = sorted(data.files)
                want = sorted(meta.get("keys", keys))
                if keys != want:
                    raise CheckpointCorruptError(
                        f"step {step}: array keys do not match meta.json"
                    )
                if sums is not None:
                    for k in keys:
                        crc = zlib.crc32(np.ascontiguousarray(data[k]).tobytes())
                        if crc != sums.get(k):
                            raise CheckpointCorruptError(
                                f"step {step}: checksum mismatch on {k!r} "
                                f"(stored {sums.get(k)}, computed {crc})"
                            )
        except CheckpointCorruptError:
            raise
        except Exception as e:  # noqa: BLE001 — any load failure IS corruption
            raise CheckpointCorruptError(f"step {step}: unreadable arrays.npz: {e!r}") from e

    def is_valid(self, step: int) -> bool:
        try:
            self.verify(step)
            return True
        except CheckpointCorruptError:
            return False

    # ----------------------------------------------------------- restore
    def steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.iterdir()
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        )

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def latest_valid_step(self) -> Optional[int]:
        """Newest step that passes :meth:`verify`.  Corrupt or incomplete
        checkpoints are skipped (and logged) — the fallback scan that keeps
        a torn latest checkpoint from wedging a restart."""
        for step in sorted(self.steps(), reverse=True):
            try:
                self.verify(step)
                return step
            except CheckpointCorruptError as e:
                self._record("ckpt_corrupt_skipped", step=step, error=str(e))
                print(f"[ckpt] skipping corrupt checkpoint step {step}: {e}")
        return None

    def restore(
        self,
        like: Any,
        step: Optional[int] = None,
        shardings: Any = None,
        verify: Optional[bool] = None,
    ) -> tuple[int, Any]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (same structure) re-places the
        arrays — pass the NEW mesh's shardings for an elastic restart.

        With verification on (the default), ``step=None`` resolves to
        :meth:`latest_valid_step` — corrupt checkpoints are skipped, and an
        explicitly requested ``step`` must verify or the restore refuses.
        """
        verify = self.verify_on_restore if verify is None else verify
        if step is None:
            step = self.latest_valid_step() if verify else self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no {'valid ' if verify else ''}checkpoints in {self.dir}")
        elif verify:
            self.verify(step)
        cdir = self.dir / f"step_{step}"
        data = np.load(cdir / "arrays.npz")
        meta = json.loads((cdir / "meta.json").read_text())
        # dtype tags (see _flatten): older checkpoints lack them and fall
        # back to the target leaf's dtype alone
        tags = meta.get("dtypes", {})
        paths = jax.tree_util.tree_flatten_with_path(like)
        if verify and meta.get("treedef") is not None:
            want_tree = str(jax.tree_util.tree_structure(like))
            if meta["treedef"] != want_tree:
                raise CheckpointError(
                    f"step {step}: checkpoint tree structure does not match the "
                    f"restore target (saved {meta['treedef']}, want {want_tree})"
                )
        leaves = []
        import ml_dtypes

        for path, leaf in paths[0]:
            key = _tree_key(path)
            arr = data[key]
            tag = tags.get(key)
            want = str(getattr(leaf, "dtype", "")) or tag or ""
            if want and tag and want != tag:
                # the tag records the dtype the slab was SAVED as; a
                # restore target asking for anything else (fp32 momentum
                # under a bf16-state optimizer or vice versa, uint16 lo
                # bits as bf16, ...) would silently reinterpret or
                # mis-type the state — refuse both directions.  Untagged
                # (pre-tag) checkpoints trust the target struct.
                raise ValueError(
                    f"checkpoint leaf {key!r} dtype mismatch: saved as "
                    f"{tag}, restore target {want} — convert the state "
                    "explicitly instead of reinterpreting it"
                )
            if arr.dtype == np.uint16 and want == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(paths[1], leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return step, state


def reshard_embedding(old_layout, new_layout, W_old: np.ndarray) -> np.ndarray:
    """Re-lay-out a unified embedding array when the shard count (and hence
    row padding / bin packing) changes across an elastic restart."""
    spec = old_layout.spec
    E = W_old.shape[1]
    W_new = np.zeros((new_layout.total_rows, E), W_old.dtype)

    def table_base(layout, t):
        if layout.mode == "row":
            return int(spec.row_offsets[t])
        # table mode: find the slot whose table is t (first match)
        for pos, s in enumerate(layout.padded_slots):
            if s >= 0 and layout.slot_to_table[s] == t:
                shard = pos // layout.slots_per_shard
                return shard * layout.rows_per_shard + int(layout.slot_local_offsets[pos])
        raise KeyError(t)

    for t, rows in enumerate(spec.table_rows):
        src = table_base(old_layout, t)
        dst = table_base(new_layout, t)
        W_new[dst : dst + rows] = W_old[src : src + rows]
    return W_new


def reshard_store(old_layout, new_layout, store: dict) -> dict:
    """Re-lay-out a full EmbeddingStore (repro/optim/row.py) across an
    elastic restart: every slab — weight halves AND per-row optimizer
    state (momentum rows, Adagrad accumulators) — is row-aligned on the
    same layout, so each one reshards exactly like the weights.  Slabs
    keep their dtypes (bf16 hi / uint16 lo / fp32 state / compressed
    bf16-hi state: ``np.asarray`` of a bf16 jax array yields an
    ``ml_dtypes.bfloat16`` view and the new slab inherits it)."""
    return {k: reshard_embedding(old_layout, new_layout, np.asarray(v)) for k, v in store.items()}
