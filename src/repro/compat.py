"""JAX version-portability shims.

The codebase targets the current jax API (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.get_abstract_mesh``).  Older runtimes (<= 0.4.x) spell these
``jax.experimental.shard_map.shard_map(check_rep=...)``, ``jax.make_mesh``
without axis types, and the thread-resources physical mesh.  Every internal
module routes through here so the repo runs unmodified on both.
"""

from __future__ import annotations

import jax

_P = jax.sharding.PartitionSpec


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map with the pre-0.5 fallback (check_vma ~ check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape, axes):
    """jax.make_mesh with explicit-Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    import math
    import numpy as np
    devices = np.asarray(jax.devices()[:math.prod(shape)]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def axis_size(axis_name) -> int:
    """Size of a named mesh axis (or product over a tuple of axes) inside
    shard_map.  Pre-0.5 jax has no jax.lax.axis_size; psum of a literal 1
    constant-folds to the size there."""
    if isinstance(axis_name, (tuple, list)):
        s = 1
        for a in axis_name:
            s *= axis_size(a)
        return s
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def get_abstract_mesh():
    """Mesh of the current tracing context (abstract on new jax, the
    physical thread-resources mesh on old)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh
