from repro.configs.base import get, list_archs  # noqa: F401
