"""Config/registry plumbing: each architecture module registers an ArchDef
exposing (arch x shape) cells that the dry-run lowers and the roofline
analyzes.  ``build(shape, mesh, **overrides)`` returns a CellBuild whose
``fn.lower(*args)`` must compile — that IS the multi-pod dry-run contract.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np


@dataclasses.dataclass
class CellBuild:
    fn: Any                  # jitted callable (has .lower)
    args: tuple              # ShapeDtypeStruct pytrees
    meta: dict               # roofline metadata (tokens, params, kind, ...)


@dataclasses.dataclass
class Cell:
    shape: str
    kind: str                # train|prefill|decode|score|retrieval
    skip: Optional[str] = None   # reason, if this cell is skipped


@dataclasses.dataclass
class ArchDef:
    name: str
    family: str              # lm|gnn|recsys|dlrm
    cells: list
    build: Callable          # (shape, mesh, **overrides) -> CellBuild
    # overrides supported for roofline extrapolation:
    #   lm/gnn: n_layers=...   recsys/dlrm: batch=...
    notes: str = ""


_REGISTRY: dict[str, ArchDef] = {}


def register(arch: ArchDef) -> ArchDef:
    _REGISTRY[arch.name] = arch
    return arch


def get(name: str) -> ArchDef:
    _ensure_loaded()
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    import repro.configs.qwen3_moe_30b_a3b      # noqa: F401
    import repro.configs.deepseek_v2_236b       # noqa: F401
    import repro.configs.internlm2_1_8b         # noqa: F401
    import repro.configs.gemma2_27b             # noqa: F401
    import repro.configs.phi3_medium_14b        # noqa: F401
    import repro.configs.egnn_arch              # noqa: F401
    import repro.configs.fm_arch                # noqa: F401
    import repro.configs.bst_arch               # noqa: F401
    import repro.configs.sasrec_arch            # noqa: F401
    import repro.configs.din_arch               # noqa: F401
    import repro.configs.dlrm_paper             # noqa: F401


# ---------------------------------------------------------------------------
# LM family shared shapes/builder
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k":    dict(kind="train",   L=4096,   B=256),
    "prefill_32k": dict(kind="prefill", L=32768,  B=32),
    "decode_32k":  dict(kind="decode",  L=32768,  B=128),
    "long_500k":   dict(kind="decode",  L=524288, B=1),
}


def lm_archdef(name: str, cfg_fn: Callable, sub_quadratic: bool,
               momentum: bool = True, notes: str = "",
               pure_dp: bool = False) -> ArchDef:
    import dataclasses as dc

    import jax

    from repro.models import lm_steps

    skip_long = (None if sub_quadratic else
                 "pure full-attention arch: long_500k requires sub-quadratic "
                 "attention (DESIGN.md section 5)")
    cells = [Cell("train_4k", "train"), Cell("prefill_32k", "prefill"),
             Cell("decode_32k", "decode"),
             Cell("long_500k", "decode", skip=skip_long)]

    def build(shape: str, mesh, n_layers: int | None = None,
              batch: int | None = None, cost_mode: bool = False) -> CellBuild:
        sh = LM_SHAPES[shape]
        bdp = tuple(mesh.axis_names)[:-1]
        cfg = cfg_fn()
        if n_layers is not None:
            cfg = dc.replace(cfg, n_layers=n_layers)
        cfg = dc.replace(cfg, dp_axes=bdp, tp_size=mesh.shape["model"])
        # pure-DP mapping (HC1): small models treat BOTH mesh axes as data
        # parallel when the batch covers the mesh — kills the TP activation
        # allreduce entirely (train shapes only; decode/prefill keep TP for
        # the KV-cache placement)
        import numpy as _np0
        all_ax = tuple(mesh.axis_names)
        if (pure_dp and sh["kind"] == "train"
                and (batch or sh["B"]) % int(_np0.prod(
                    [mesh.shape[a] for a in all_ax])) == 0):
            cfg = dc.replace(cfg, dp_axes=all_ax, tp_size=1,
                             seq_shard=False)
            bdp = all_ax
        if cost_mode:
            # fully-unrolled reduced-depth cost build: inner scans
            # neutralized so cost_analysis counts everything exactly once
            # attention q-chunk scan stays (it is UNROLLED in cost mode,
            # so the windowed-KV slicing of local layers is costed)
            cfg = dc.replace(cfg, cost_mode=True, microbatch=1,
                             prefill_microbatch=1, loss_chunk=sh["L"])
        B = batch or sh["B"]
        L = sh["L"]
        # each microbatch must still shard over the DP axes; wider meshes
        # need proportionally fewer accumulation steps for the same
        # per-device footprint
        import numpy as _np
        ndp = int(_np.prod([mesh.shape[a] for a in bdp]))
        if cfg.microbatch > 1 and sh["kind"] == "train":
            mb = min(cfg.microbatch, max(1, B // ndp))
            while mb > 1 and (B % mb or (B // mb) % ndp):
                mb -= 1
            cfg = dc.replace(cfg, microbatch=mb)
        meta = dict(arch=name, shape=shape, kind=sh["kind"], family="lm",
                    tokens=B * L, batch=B, seq=L,
                    params=cfg.param_count(),
                    active_params=cfg.active_param_count(),
                    n_layers=cfg.n_layers,
                    scan_unit=2 if cfg.local_global else 1,
                    scan_outside=cfg.first_dense_layers)
        if sh["kind"] == "train":
            fn, structs, _ = lm_steps.make_lm_train_step(
                cfg, mesh, B, L, momentum=momentum)
            return CellBuild(fn, structs, meta)
        if sh["kind"] == "prefill":
            fn, structs, _ = lm_steps.make_prefill_step(cfg, mesh, B, L)
            return CellBuild(fn, structs, meta)
        fn, structs, _ = lm_steps.make_decode_step(cfg, mesh, B, L)
        meta["tokens"] = B   # one token per sequence per step
        return CellBuild(fn, structs, meta)

    return register(ArchDef(name, "lm", cells, build, notes=notes))
