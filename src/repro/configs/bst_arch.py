"""bst [arXiv:1905.06874]: embed_dim=32, behavior seq 20 + target, 1
transformer block (8 heads), MLP 1024-512-256.  Item vocab 10M (shared
across all sequence slots), 8 context fields of 100k."""

from repro.configs.recsys_common import recsys_archdef
from repro.models.recsys import make_bst

ITEM_VOCAB = 10_000_000
CTX = (100_000,) * 8


def make_mdef(batch):
    return make_bst(ITEM_VOCAB, CTX, batch=batch)


# slot 20 is the target item (seq_len=20 -> slots 0..19 history, 20 target)
ARCH = recsys_archdef("bst", make_mdef, target_slot=20)
