"""deepseek-v2-236b [arXiv:2405.04434]: 60L d_model=5120 128H, MLA
kv_lora=512 q_lora=1536 qk_nope=128 qk_rope=64 v_head=128; 2 shared + 160
routed experts top-6 (moe intermediate 1536), first layer dense (ff 12288),
vocab 102400."""

from repro.configs.base import lm_archdef
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
        n_kv_heads=128, d_head=128, d_ff=12288, vocab=102400,
        n_experts=160, top_k=6, moe_d_ff=1536, n_shared_experts=2,
        first_dense_layers=1, capacity_factor=1.0, microbatch=16, prefill_microbatch=2,
        mla=True, q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
        v_head=128, tie_embeddings=False)


# momentum off: 236B params at hi+lo (4 B/param) already uses ~40% of HBM
# under EPxTP; plain SGD is the paper's default optimizer anyway.
ARCH = lm_archdef("deepseek-v2-236b", config, sub_quadratic=False,
                  momentum=False,
                  notes="MLA latent cache (absorbed decode); EP x TP; "
                        "momentum-free Split-SGD for capacity")
