"""din [arXiv:1706.06978]: embed_dim=18, history 100, attention MLP 80-40,
main MLP 200-80.  Item vocab 10M shared across history+target slots, 4
context fields."""

from repro.configs.recsys_common import recsys_archdef
from repro.models.recsys import make_din

ITEM_VOCAB = 10_000_000
CTX = (100_000, 10_000, 1_000, 100)


def make_mdef(batch):
    return make_din(ITEM_VOCAB, CTX, batch=batch)


# slot 100 is the target item (history slots 0..99)
ARCH = recsys_archdef("din", make_mdef, target_slot=100)
