"""The paper's own three DLRM configs (Tab. I), as first-class archs.

Each gets TWO train cells: row mode (beyond-paper production placement) and
table mode (the paper's table-wise hybrid parallelism) — the A/B the perf
log builds on.  Batch sizes are the paper's strong-scaling global
minibatches (GN).
"""

from repro.configs.base import ArchDef, Cell, CellBuild, register
from repro.core.dlrm import DLRMConfig, make_train_step, batch_struct, \
    state_struct
from repro.configs.fm_arch import CRITEO_TB


def dlrm_small(mode="row", batch=8192):
    return DLRMConfig(
        name="dlrm-small", num_dense=512, bottom=(512, 512, 64),
        top=(1024, 1024, 1024, 1024), table_rows=(1_000_000,) * 8,
        emb_dim=64, pooling=50, batch=batch, emb_mode=mode)


def dlrm_large(mode="row", batch=16384):
    return DLRMConfig(
        name="dlrm-large", num_dense=2048,
        bottom=(2048,) * 7 + (256,), top=(4096,) * 16,
        table_rows=(6_000_000,) * 64, emb_dim=256, pooling=100,
        batch=batch, emb_mode=mode)


def dlrm_mlperf(mode="row", batch=16384):
    return DLRMConfig(
        name="dlrm-mlperf", num_dense=13, bottom=(512, 256, 128),
        top=(512, 512, 256), table_rows=CRITEO_TB, emb_dim=128,
        pooling=1, batch=batch, emb_mode=mode)


def _archdef(name, cfg_fn, default_batch):
    cells = [Cell("train", "train"), Cell("train_tablewise", "train")]

    def build(shape: str, mesh, batch: int | None = None,
              n_layers: int | None = None,
              cost_mode: bool = False) -> CellBuild:
        mode = "table" if shape == "train_tablewise" else "row"
        cfg = cfg_fn(mode=mode, batch=batch or default_batch)
        fn, shardings, bspecs, layout = make_train_step(cfg, mesh)
        sstructs, _, _, _ = state_struct(cfg, mesh)
        bstructs, _ = batch_struct(cfg, mesh, layout)
        meta = dict(arch=name, shape=shape, kind="train", family="dlrm",
                    batch=cfg.batch, slots=len(cfg.table_rows),
                    pooling=cfg.pooling, emb_dim=cfg.emb_dim,
                    emb_rows=cfg.spec.total_rows,
                    bottom=cfg.bottom_sizes, top=cfg.top_sizes,
                    scan_unit=1, scan_outside=0, n_layers=1)
        return CellBuild(fn, (sstructs, bstructs), meta)

    return register(ArchDef(name, "dlrm", cells, build,
                            notes="paper Tab. I config"))


ARCH_SMALL = _archdef("dlrm-small", dlrm_small, 8192)
ARCH_LARGE = _archdef("dlrm-large", dlrm_large, 16384)
ARCH_MLPERF = _archdef("dlrm-mlperf", dlrm_mlperf, 16384)
