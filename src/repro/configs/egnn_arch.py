"""egnn [arXiv:2102.09844]: 4 layers, hidden 64, E(n)-equivariant.

Four shape cells:
    full_graph_sm   cora-like      N=2708      E=10556      d_feat=1433
    minibatch_lg    reddit-like    fanout 15-10, 1024 target nodes
    ogb_products    full-batch     N=2449029   E=61859140   d_feat=100
    molecule        128 graphs x (30 nodes, 64 edges), graph-level target

Citation/product graphs carry synthesized 3D coordinates (EGNN needs
geometry; noted in DESIGN.md).
"""

import dataclasses as dc

from repro.configs.base import ArchDef, Cell, CellBuild, register
from repro.models.egnn import EGNNConfig


SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg": dict(kind="train", n_graphs=1024, fanout=(15, 10),
                         d_feat=602, n_classes=41,
                         n_pad=192, e_pad=192),
    "ogb_products": dict(kind="train", n_nodes=2449029, n_edges=61859140,
                         d_feat=100, n_classes=47),
    "molecule": dict(kind="train", n_graphs=128, nodes_per=30, edges_per=64,
                     d_feat=11, n_classes=1),
}


def build(shape: str, mesh, n_layers: int | None = None,
          batch: int | None = None, cost_mode: bool = False) -> CellBuild:
    from repro.models import egnn_steps

    sh = SHAPES[shape]
    cfg = EGNNConfig("egnn", n_layers=n_layers or 4, d_hidden=64,
                     d_feat=sh["d_feat"], n_classes=sh["n_classes"],
                     graph_level=(shape == "molecule"))
    meta = dict(arch="egnn", shape=shape, kind="train", family="gnn",
                n_layers=cfg.n_layers, scan_unit=1, scan_outside=0)
    if shape == "minibatch_lg":
        g = batch or sh["n_graphs"]
        fn, structs, _ = egnn_steps.make_minibatch_train_step(
            cfg, mesh, g, sh["n_pad"], sh["e_pad"], unroll=cost_mode)
        meta.update(n_edges=g * sh["e_pad"], n_nodes=g * sh["n_pad"],
                    batch=g)
        return CellBuild(fn, structs, meta)
    if shape == "molecule":
        g = batch or sh["n_graphs"]
        n_nodes = g * sh["nodes_per"]
        n_edges = g * sh["edges_per"]
        fn, structs, _ = egnn_steps.make_fullgraph_train_step(
            cfg, mesh, n_nodes, n_edges, graph_level_graphs=g,
            unroll=cost_mode)
        meta.update(n_edges=n_edges, n_nodes=n_nodes, batch=g)
        return CellBuild(fn, structs, meta)
    fn, structs, _ = egnn_steps.make_fullgraph_train_step(
        cfg, mesh, sh["n_nodes"], sh["n_edges"], unroll=cost_mode)
    meta.update(n_edges=sh["n_edges"], n_nodes=sh["n_nodes"], batch=1)
    return CellBuild(fn, structs, meta)


ARCH = register(ArchDef(
    "egnn", "gnn",
    [Cell(s, "train") for s in SHAPES], build,
    notes="edge-sharded message passing; segment_sum scatter; "
          "minibatch_lg uses the fanout neighbor sampler in repro/data"))
