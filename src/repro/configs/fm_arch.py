"""fm [Rendle ICDM'10]: 39 sparse fields, embed_dim 10, 2-way FM via the
O(nk) sum-square trick.  Tables: the 26 Criteo-TB categorical sizes + 13
bucketized-dense fields of 1000 rows (criteo has 13 numeric features)."""

from repro.configs.recsys_common import recsys_archdef
from repro.models.recsys import make_fm

CRITEO_TB = (39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63,
             38532951, 2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14,
             39979771, 25641295, 39664984, 585935, 12972, 108, 36)
TABLES = CRITEO_TB + (1000,) * 13          # 39 fields


def make_mdef(batch):
    return make_fm(TABLES, batch=batch)


ARCH = recsys_archdef("fm", make_mdef, target_slot=0,
                      notes="unified E=11 rows: dims 0..9 factor vector, "
                            "dim 10 linear weight")
