"""gemma2-27b [arXiv:2408.00118]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; alternating local(4096)/global attention, attn softcap 50,
final softcap 30, tied + scaled embeddings.

long_500k RUNS for this arch: the local layers are sub-quadratic and the
decode step is O(N) — the only assigned LM that qualifies (DESIGN.md §5).
"""

from repro.configs.base import lm_archdef
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-27b", n_layers=46, d_model=4608, n_heads=32,
        n_kv_heads=16, d_head=128, d_ff=36864, vocab=256000,
        local_global=True, window=4096, attn_softcap=50.0,
        final_softcap=30.0, microbatch=4, loss_chunk=256, embed_scale=True, tie_embeddings=True)


# momentum off: 27B x 8B/param on a 16-wide TP axis leaves too little HBM
# headroom next to the 36864-wide FFN activations.
ARCH = lm_archdef("gemma2-27b", config, sub_quadratic=True, momentum=False,
                  notes="hybrid local/global -> long_500k runs")
