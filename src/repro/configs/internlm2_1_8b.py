"""internlm2-1.8b [arXiv:2403.17297]: 24L d_model=2048 16H (GQA kv=8)
d_ff=8192 vocab=92544."""

from repro.configs.base import lm_archdef
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=8, d_head=128, d_ff=8192, vocab=92544,
        tie_embeddings=False, rope_theta=1e6)


ARCH = lm_archdef("internlm2-1.8b", config, sub_quadratic=False,
                  momentum=False, pure_dp=True,
                  notes="pure-DP on the train shape (HC1)")
