"""phi3-medium-14b [arXiv:2404.14219]: 40L d_model=5120 40H (GQA kv=10)
d_ff=17920 vocab=100352, RoPE + SwiGLU."""

from repro.configs.base import lm_archdef
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="phi3-medium-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=10, d_head=128, d_ff=17920, vocab=100352, microbatch=2,
        tie_embeddings=False)


ARCH = lm_archdef("phi3-medium-14b", config, sub_quadratic=False,
                  momentum=False)
