"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d_model=2048 32H (GQA kv=4)
moe intermediate 768, vocab 151936, 128 experts top-8."""

from repro.configs.base import lm_archdef
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, d_head=128, d_ff=768, vocab=151936,
        n_experts=128, top_k=8, moe_d_ff=768, capacity_factor=1.0, microbatch=4,
        tie_embeddings=False, rope_theta=1e6)


ARCH = lm_archdef("qwen3-moe-30b-a3b", config, sub_quadratic=False,
                  momentum=True,
                  notes="MoE EP over 'data' x TP over 'model'; the MoE "
                        "dispatch reshard is the paper's hybrid-parallel "
                        "all-to-all pattern")
