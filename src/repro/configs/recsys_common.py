"""Shared builder for the four recsys ArchDefs (paper-pattern hybrid
parallel)."""

from __future__ import annotations

from repro.configs.base import ArchDef, Cell, CellBuild, register

RECSYS_SHAPES = {
    "train_batch":    dict(kind="train", batch=65536),
    "serve_p99":      dict(kind="score", batch=512),
    "serve_bulk":     dict(kind="score", batch=262144),
    # 2^20 candidates: divisible by the 512-device mesh (brief says 1e6;
    # padded up, noted in EXPERIMENTS.md)
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1 << 20),
}


def recsys_archdef(name: str, make_mdef, target_slot: int,
                   notes: str = "") -> ArchDef:
    from repro.core import hybrid

    cells = [Cell(s, RECSYS_SHAPES[s]["kind"]) for s in RECSYS_SHAPES]

    def build(shape: str, mesh, batch: int | None = None,
              n_layers: int | None = None,
              cost_mode: bool = False) -> CellBuild:
        sh = RECSYS_SHAPES[shape]
        B = batch or sh["batch"]
        mdef = make_mdef(B)
        layout_slots = (len(mdef.slot_to_table) if mdef.slot_to_table
                        else mdef.spec.num_tables)
        meta = dict(arch=name, shape=shape, kind=sh["kind"], family="recsys",
                    batch=B, slots=layout_slots, pooling=mdef.pooling,
                    emb_dim=mdef.spec.dim,
                    emb_rows=mdef.spec.total_rows,
                    scan_unit=1, scan_outside=0, n_layers=1)
        if sh["kind"] == "train":
            fn, shardings, bspecs, layout = hybrid.make_train_step(mdef, mesh)
            bstructs, _ = hybrid.batch_struct(mdef, mesh, layout)
            sstructs, _, _, _ = hybrid.state_struct(mdef, mesh)
            return CellBuild(fn, (sstructs, bstructs), meta)
        if sh["kind"] == "score":
            fn, shardings, bspecs, layout = hybrid.make_score_step(
                mdef, mesh, batch=B)
            bstructs, _ = hybrid.batch_struct(mdef, mesh, layout, batch=B)
            sstructs, _, _, _ = hybrid.state_struct(mdef, mesh)
            return CellBuild(fn, (sstructs, bstructs), meta)
        nc = sh["n_candidates"]
        meta["n_candidates"] = nc
        fn, arg_structs, _, layout = hybrid.make_retrieval_step(
            mdef, mesh, nc, target_slot)
        return CellBuild(fn, arg_structs, meta)

    return register(ArchDef(name, "recsys", cells, build, notes=notes))
