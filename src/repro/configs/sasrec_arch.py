"""sasrec [arXiv:1808.09781]: embed_dim=50, 2 blocks, 1 head, seq_len=50.
Item vocab 4M shared across seq/pos/neg slots."""

from repro.configs.recsys_common import recsys_archdef
from repro.models.recsys import make_sasrec

ITEM_VOCAB = 4_000_000


def make_mdef(batch):
    return make_sasrec(ITEM_VOCAB, batch=batch)


# slot 50 = first "positive" slot doubles as the scoring target at serve time
ARCH = recsys_archdef("sasrec", make_mdef, target_slot=50)
