# The paper's primary contribution: the unified embedding engine (C1), the
# hybrid-parallel embedding placement + all-to-all layout switch (C3), the
# dot interaction, and the DLRM model assembled from them.
from repro.core import embedding, interaction, sharded_embedding  # noqa: F401
