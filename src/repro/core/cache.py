"""Frequency-tiered hot-row embedding cache (replicated hot slab).

Zipf-distributed lookup streams concentrate most bag traffic on a tiny set
of rows: on the paper's terabyte-scale configs the all-to-all that moves
bag partials between sockets is the dominant non-compute cost, yet the
bulk of its payload is the same few hundred hot rows every step.  This
module puts a small REPLICATED mirror of the top-``hot_rows`` rows per
table (ranked by the reserved ``cnt`` touch-counter slab of
:mod:`repro.optim.row`) in front of the sharded cold store:

* the cold store stays AUTHORITATIVE — every update is applied there by
  the normal fused sparse path (write-through; the cache never absorbs
  gradients);
* the forward substitutes a locally-computed bag for every bag whose
  lookups ALL hit the hot set (table mode + ``idx_input='sharded'``), so
  those bags never depend on the all-to-all payload;
* a deterministic, seeded promotion policy re-ranks the hot set from the
  counters every ``promote_every`` steps, identically on every rank.

Sync modes (``hot_sync``):

* ``"allreduce"`` — the slab is refreshed from the post-update cold store
  every step via a masked integer-bitcast psum (exactly one owner
  contributes each row, so the integer sum is the owner's bits verbatim).
  The mirror therefore always equals the store and the step is BITWISE
  identical to ``hot_rows=0`` for every registered optimizer.
* ``"deferred:N"`` — refresh only every N steps (and on promotion).  Hot
  bags read up-to-N-step-stale weights; cold-store updates are unchanged,
  so the drift is bounded by N steps of hot-row movement (see
  docs/cache.md for when this is safe).

Membership is keyed on SPEC-GLOBAL row ids (``sharded_embedding.
layout_gid_maps``), never layout positions, so counters and the hot set
survive checkpoint/restore and elastic N->N+-k reshards bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import sharded_embedding as se


def parse_hot_sync(mode: str) -> int:
    """Refresh cadence in steps: ``"allreduce"`` -> 1, ``"deferred:N"`` ->
    N (N >= 1).  Raises ValueError on anything else."""
    if mode == "allreduce":
        return 1
    if isinstance(mode, str) and mode.startswith("deferred:"):
        try:
            n = int(mode.split(":", 1)[1])
        except ValueError:
            n = 0
        if n >= 1:
            return n
    raise ValueError(
        f"unknown hot_sync {mode!r}; expected 'allreduce' or 'deferred:N' with N >= 1"
    )


def hash32(x: jax.Array, seed: int) -> jax.Array:
    """32-bit avalanche hash (uint32) — the layout-independent tiebreaker
    of the promotion sort.  Rows with equal counts are ordered by
    ``hash32(gid ^ seed)``, so the selected hot set depends only on
    (count, gid, seed) — never on shard position or mesh shape."""
    x = x.astype(jnp.uint32) ^ jnp.uint32(seed & 0xFFFFFFFF)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def spec_gid_to_table(spec) -> np.ndarray:
    """Static map gid -> table id ([spec.total_rows] int32, -1 inside the
    per-table ``row_pad`` gaps of the unified row space)."""
    out = np.full(spec.total_rows, -1, np.int32)
    for t, rows_t in enumerate(spec.table_rows):
        base = int(spec.row_offsets[t])
        out[base : base + rows_t] = t
    return out


# ---------------------------------------------------------------------------
# Cache state subtree
# ---------------------------------------------------------------------------

def cache_struct(mdef, layout, opt) -> dict:
    """ShapeDtypeStructs of the replicated cache subtree.

    ``hot_w`` mirrors the FORWARD slab (``opt.fwd_weights``: bf16 hi for
    split optimizers, fp32 w otherwise) so a hit reads exactly the bits
    the owner's gather would have produced.  ``hot_ids`` are spec-global
    gids (-1 = empty); ``hot_pos`` inverts them over the unified row
    space; ``tick`` drives the promotion / refresh cadence."""
    K_tot = int(mdef.hot_rows) * layout.spec.num_tables
    dt = jnp.bfloat16 if opt.split else jnp.float32
    return {
        "hot_w": jax.ShapeDtypeStruct((K_tot, layout.spec.dim), dt),
        "hot_ids": jax.ShapeDtypeStruct((K_tot,), jnp.int32),
        "hot_pos": jax.ShapeDtypeStruct((layout.spec.total_rows,), jnp.int32),
        "tick": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_specs(structs: dict) -> dict:
    """Everything in the cache subtree is replicated."""
    return jax.tree.map(lambda _: P(), structs)


def init_cache(mdef, layout, opt) -> dict:
    """Empty cache: no hot rows, first promotion fills it.  An empty hot
    set misses every bag, so step 1 is trivially identical to cache-off."""
    s = cache_struct(mdef, layout, opt)
    return {
        "hot_w": jnp.zeros(s["hot_w"].shape, s["hot_w"].dtype),
        "hot_ids": jnp.full(s["hot_ids"].shape, -1, jnp.int32),
        "hot_pos": jnp.full(s["hot_pos"].shape, -1, jnp.int32),
        "tick": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Promotion / demotion (deterministic, seeded, layout-independent)
# ---------------------------------------------------------------------------

def select_hot(layout, cnt_full: jax.Array, hot_rows: int, seed: int) -> jax.Array:
    """Top-``hot_rows`` rows per table by touch count -> hot_ids
    [num_tables * hot_rows] int32 (spec-global gids, -1 where a table has
    fewer than ``hot_rows`` touched rows).

    ``cnt_full`` is the [layout.total_rows] counter vector in LAYOUT row
    order, identical on every rank (all_gather over the embedding axes).
    Ranking uses a two-pass stable argsort — by hash first, then stably
    by descending count — i.e. the total order (count desc, hash asc).
    No ``top_k``: its index-position tiebreak would make the selection
    depend on shard layout under count ties; this order is a pure
    function of (count, gid, seed), so every rank and every layout of
    the same store picks the identical set, which is what keeps elastic
    reshards and multi-rank promotion bitwise consistent."""
    spec = layout.spec
    l2g, _ = se.layout_gid_maps(layout)
    gid_table = spec_gid_to_table(spec)
    row_table = np.where(l2g >= 0, gid_table[np.clip(l2g, 0, None)], -1)
    l2g_c = jnp.asarray(l2g)
    o1 = jnp.argsort(hash32(l2g_c, seed))
    cnt_full = cnt_full.reshape(-1).astype(jnp.int32)
    parts = []
    for t in range(spec.num_tables):
        elig = jnp.asarray(row_table == t) & (cnt_full > 0)
        score = jnp.where(elig, cnt_full, -1)
        order = o1[jnp.argsort(-score[o1])]  # jnp.argsort is stable
        top = order[:hot_rows]
        parts.append(jnp.where(score[top] > 0, l2g_c[top], -1))
    return jnp.concatenate(parts)


def hot_positions(spec_total: int, hot_ids: jax.Array) -> jax.Array:
    """Invert hot_ids: gid -> slab position ([spec_total] int32, -1 for
    cold rows).  Empty slots (-1) are routed to an out-of-bounds index
    and dropped (JAX wraps negatives BEFORE the OOB drop, so -1 must not
    reach the scatter directly)."""
    pos = jnp.arange(hot_ids.shape[0], dtype=jnp.int32)
    tgt = jnp.where(hot_ids >= 0, hot_ids, spec_total)
    return jnp.full((spec_total,), -1, jnp.int32).at[tgt].set(pos, mode="drop")


def refresh_hot_slab(layout, W_local: jax.Array, hot_ids: jax.Array, emb_ax) -> jax.Array:
    """Mirror the rows named by ``hot_ids`` out of the sharded forward
    slab, replicated: each row's unique owner contributes its bits, every
    other rank contributes zero, and the psum runs on the INTEGER bit
    patterns (int32 for fp32, sign-extended int32 for bf16) — an integer
    sum with one nonzero term is that term verbatim, so the mirror is
    bit-exact (a float psum could perturb signed zeros / NaN payloads).
    Runs inside shard_map over ``emb_ax``."""
    _, g2l = se.layout_gid_maps(layout)
    glob = jnp.take(jnp.asarray(g2l), jnp.where(hot_ids >= 0, hot_ids, 0))
    R = layout.rows_per_shard
    local = glob - jax.lax.axis_index(emb_ax) * R
    own = (hot_ids >= 0) & (glob >= 0) & (local >= 0) & (local < R)
    rows = jnp.take(W_local, jnp.clip(local, 0, R - 1), axis=0)
    if rows.dtype == jnp.bfloat16:
        bits = jax.lax.bitcast_convert_type(rows, jnp.int16)
        bits = jnp.where(own[:, None], bits.astype(jnp.int32), 0)
        bits = jax.lax.psum(bits, emb_ax)
        return jax.lax.bitcast_convert_type(bits.astype(jnp.int16), jnp.bfloat16)
    bits = jax.lax.bitcast_convert_type(rows.astype(jnp.float32), jnp.int32)
    bits = jnp.where(own[:, None], bits, 0)
    bits = jax.lax.psum(bits, emb_ax)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


# ---------------------------------------------------------------------------
# Forward bypass (table mode + sharded index stream)
# ---------------------------------------------------------------------------

def hot_bag_local(
    layout, hot_w: jax.Array, hot_pos: jax.Array, idx: jax.Array, weights: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """(hit [b, S], bag [b, S, E]) for this rank's OWN batch slice, read
    entirely from the replicated hot slab.

    A bag hits only when ALL P of its lookups are hot — partial splits
    would reassociate the fp32 bag sum and break the bitwise contract.
    The bag arithmetic is the owner's ``table_sharded_bag_fwd`` gather
    verbatim (take -> fp32 -> optional per-lookup weight -> sum over P),
    so under ``hot_sync='allreduce'`` a hit bag is bit-identical to the
    all-to-all row it replaces; the caller substitutes with
    ``jnp.where(hit[..., None], bag, emb_out)``."""
    spec = layout.spec
    off = jnp.asarray(spec.row_offsets[layout.slot_to_table], jnp.int32)  # [S]
    gid = idx + off[None, :, None]
    ok = (gid >= 0) & (gid < spec.total_rows)
    pos = jnp.take(hot_pos, jnp.clip(gid, 0, spec.total_rows - 1))
    lk_hit = ok & (pos >= 0)
    hit = jnp.all(lk_hit, axis=2)
    rows = jnp.take(hot_w, jnp.clip(pos, 0, hot_w.shape[0] - 1), axis=0).astype(jnp.float32)
    if weights is not None:
        rows = rows * weights[..., None].astype(jnp.float32)
    return hit, rows.sum(axis=2)


# ---------------------------------------------------------------------------
# The per-step cache epilogue
# ---------------------------------------------------------------------------

def step_cache(mdef, layout, opt, cache: dict, new_emb: dict, emb_ax) -> dict:
    """Advance the cache one step from the POST-update store (runs inside
    shard_map, after sparse_update).

    Promotion and refresh are computed UNCONDITIONALLY and selected with
    ``jnp.where`` on the tick — a ``lax.cond`` whose branches issue
    collectives would deadlock shard_map, and the unconditional form
    keeps every rank's collective schedule identical.  Promotion (every
    ``promote_every`` steps) re-ranks the hot set from the gathered
    counters and FORCES a refresh; otherwise the slab refreshes on the
    ``hot_sync`` cadence (every step for 'allreduce')."""
    sync_n = parse_hot_sync(getattr(mdef, "hot_sync", "allreduce"))
    every = int(getattr(mdef, "promote_every", 1))
    tick = cache["tick"] + jnp.asarray(1, jnp.int32)
    cnt_full = jax.lax.all_gather(
        new_emb["cnt"][:, 0].astype(jnp.int32), emb_ax, axis=0, tiled=True
    )
    new_ids = select_hot(layout, cnt_full, int(mdef.hot_rows), int(getattr(mdef, "sr_seed", 0)))
    promote = (tick % every) == 0
    ids = jnp.where(promote, new_ids, cache["hot_ids"])
    refresh = promote | ((tick % sync_n) == 0)
    slab = refresh_hot_slab(layout, opt.fwd_weights(new_emb), ids, emb_ax)
    return {
        "hot_w": jnp.where(refresh, slab, cache["hot_w"]),
        "hot_ids": ids,
        "hot_pos": hot_positions(layout.spec.total_rows, ids),
        "tick": tick,
    }
