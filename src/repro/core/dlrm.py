"""DLRM assembled from the paper's components, with the hybrid-parallel
train step (contributions C1+C3+C4+C5 composed).

One ``shard_map`` over the full mesh contains the whole step, so every
collective the paper discusses is explicit in the lowered HLO:

    embedding bag fwd        -> psum_scatter (row mode)  |  all_to_all (table)
    dense fwd/bwd            -> local compute (data-parallel over ALL axes)
    embedding fused update   -> all_gather(dY) + owner-masked scatter (C1/Alg.4)
    dense optimizer          -> bucketed reduce-scatter + all-gather (C4)
                                with Split-SGD-BF16 on the shard (C5)

The roofline harness reads those collectives straight out of the compiled
module; EXPERIMENTS.md's comm-volume table checks them against the paper's
Eq. 1 (allreduce) and Eq. 2 (alltoall).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.embedding import EmbeddingSpec
from repro.core import sharded_embedding as se
from repro.dist.exchange import ExchangeConfig
from repro.core.interaction import dot_interaction, interaction_output_dim
from repro.models.mlp import init_mlp, mlp_forward
from repro.optim import row as row_optim


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    num_dense: int                  # dense-feature width (bottom MLP input)
    bottom: tuple[int, ...]         # bottom MLP hidden sizes; last == emb dim
    top: tuple[int, ...]            # top MLP hidden sizes; final 1 appended
    table_rows: tuple[int, ...]     # M_i per table
    emb_dim: int                    # E
    pooling: int                    # P look-ups per table (paper's P)
    batch: int = 2048               # global minibatch
    emb_mode: str = "row"           # 'row' | 'table'  (C3 placement)
    # sparse RowOptimizer for the embedding path (repro/optim/row.py):
    # 'sgd' | 'split_sgd' | 'momentum' | 'adagrad_rowwise' | 'adagrad' |
    # 'momentum_bf16' | 'adagrad_bf16' (compressed bf16-hi state +
    # stochastic rounding) — or a RowOptimizer instance.  None/'' falls
    # back to the legacy ``split_sgd`` bool.  opt_beta / opt_eps override
    # the registered hyperparameter defaults (momentum coefficient,
    # adagrad floor).
    sparse_optimizer: Optional[str] = None
    opt_beta: Optional[float] = None
    opt_eps: Optional[float] = None
    # DEPRECATED C5 on/off sugar (None = the 'split_sgd' default without
    # the DeprecationWarning; read only when sparse_optimizer is unset)
    split_sgd: Optional[bool] = None
    # Pallas fused sparse-bwd + row-optimizer update (the split path is
    # bit-identical to the reference).  None = on where the kernel compiles
    # (TPU), off elsewhere (CPU interpret emulation pays O(shard) per grid
    # step); True/False forces the choice for A/B benchmarking and tests.
    fused_update: Optional[bool] = None
    # typed comm/precision config (repro/dist/exchange.py): exchange
    # lowering + per-collective wire formats + dense error feedback +
    # RS+AG bucketing in ONE frozen dataclass.  Mutually exclusive with
    # the flat kwargs below.
    exchange: Optional[ExchangeConfig] = None
    # sugar: both wire dtypes at once ('fp32' | 'bf16' | 'bf16_sr')
    exchange_dtype: Optional[str] = None
    # DEPRECATED flat kwargs (resolve_exchange coerces + warns):
    compress_grads: Optional[bool] = None   # bf16 wire + error feedback
    num_buckets: Optional[int] = None       # C4 bucketing
    lr: float = 0.1
    mlp_impl: str = "xla"           # 'xla' | 'pallas'
    # 'replicated' reproduces the paper's data loader (every rank reads the
    # full global minibatch — its own noted weak-scaling flaw); 'sharded'
    # feeds batch-sharded indices and all-gathers them over ICI instead,
    # removing the host-side input replication (row AND table mode; table
    # mode also permutes to padded-slot order on chip).
    idx_input: str = "replicated"
    # staged microbatch pipeline (repro/core/pipeline.py): split the global
    # batch into M microbatches with a double-buffered index exchange so
    # the layout-switch collectives overlap dense compute.  1 = monolithic.
    microbatches: int = 1
    # DEPRECATED index-exchange lowering: 'fused' | 'ring' (use
    # exchange=ExchangeConfig(impl=...))
    exchange_impl: Optional[str] = None
    # weighted bags: batch carries 'weights' [B, S, P] in the idx layout
    weighted: bool = False
    # host-pre-sorted sparse update (repro/data/pipeline.py): the loader
    # ships psort_* fields, the step drops the on-device sort (row and
    # table mode — the table host sort folds the padded-slot permute in)
    host_presort: bool = False
    # initial per-step stochastic-rounding counter (only materialized when
    # the resolved optimizer registered stochastic_round=True)
    sr_seed: int = 0
    # frequency-tiered hot-row cache (repro/core/cache.py): replicate the
    # top-``hot_rows`` rows per table (by touch count) on every rank and
    # serve all-hot bags locally, off the all-to-all payload (table mode
    # + idx_input='sharded').  0 = off.
    hot_rows: int = 0
    # re-rank the hot set from the touch counters every this-many steps
    promote_every: int = 1
    # 'allreduce' (mirror refreshed every step; bitwise == cache off) or
    # 'deferred:N' (refresh every N steps; bounded drift)
    hot_sync: str = "allreduce"
    # in-graph step metrics vector (repro/telemetry/metrics.py): cache
    # hits, rows touched, exchange payload bytes, accumulated on device
    # and drained by the train loop.  False (default) = no state key, step
    # bit-identical to a build without telemetry.
    step_metrics: bool = False

    @property
    def spec(self) -> EmbeddingSpec:
        return EmbeddingSpec(self.table_rows, self.emb_dim)

    @property
    def bottom_sizes(self) -> list[int]:
        return [self.num_dense, *self.bottom]

    @property
    def top_sizes(self) -> list[int]:
        f = len(self.table_rows) + 1
        d_in = interaction_output_dim(f, self.emb_dim, "dot")
        return [d_in, *self.top, 1]


def init_dense_params(key: jax.Array, cfg: DLRMConfig) -> dict:
    kb, kt = jax.random.split(key)
    return {"bot": init_mlp(kb, cfg.bottom_sizes),
            "top": init_mlp(kt, cfg.top_sizes)}


def forward_local(dense_hi: dict, emb_out: jax.Array, dense_x: jax.Array,
                  impl: str = "xla") -> jax.Array:
    """Per-device forward on the batch-sharded slice (fully data-parallel)."""
    bot = mlp_forward(dense_hi["bot"], dense_x, final_activation=True,
                      impl=impl)                       # [b, E]
    z = dot_interaction(bot, emb_out)                  # [b, E + F(F-1)/2]
    logits = mlp_forward(dense_hi["top"], z.astype(jnp.bfloat16), impl=impl)
    return logits[:, 0]


def bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    x, y = logits.astype(jnp.float32), labels.astype(jnp.float32)
    return jnp.maximum(x, 0) - x * y + jnp.log1p(jnp.exp(-jnp.abs(x)))


# ---------------------------------------------------------------------------
# Hybrid-parallel step factory
# ---------------------------------------------------------------------------

def mesh_axes(mesh) -> tuple[tuple[str, ...], str, tuple[str, ...]]:
    """(all_axes, model_axis, batch_axes).  The last mesh axis is 'model'."""
    names = tuple(mesh.axis_names)
    return names, names[-1], names[:-1]


def emb_axes_for(cfg: DLRMConfig, mesh):
    """Row mode shards the row space over the FULL mesh (paper: pure
    model-parallel embeddings over all ranks); table mode uses the model
    axis and replicates over the rest."""
    all_axes, model, batch_axes = mesh_axes(mesh)
    if cfg.emb_mode == "row":
        return all_axes, None
    return model, (batch_axes if batch_axes else None)


def make_layout(cfg: DLRMConfig, mesh) -> se.ShardedEmbeddingLayout:
    axes, _ = emb_axes_for(cfg, mesh)
    ns = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple)
                                              else (axes,))]))
    return se.make_layout(cfg.spec, ns, cfg.emb_mode)


def state_struct(cfg: DLRMConfig, mesh, rngs: bool = True):
    """(state pytree of arrays-or-structs, sharding pytree).  Delegates to
    the generic hybrid builder (the DLRM state IS the hybrid skeleton's:
    embedding store + split dense + optional sr counter + optional hot-row
    cache subtree), so optimizer- and cache-driven layout changes stay
    single-sourced.  ``rngs`` is kept for call-site compatibility; only
    ShapeDtypeStructs are ever produced here."""
    del rngs
    from repro.core import hybrid as H
    return H.state_struct(as_hybrid_def(cfg), mesh)


def init_state(key: jax.Array, cfg: DLRMConfig, mesh) -> dict:
    """Materialize a real initial state (small/smoke configs).  Delegates
    to the hybrid builder — bit-identical to the historical in-module
    initializer (same key split, same init distribution)."""
    from repro.core import hybrid as H
    return H.init_state(key, as_hybrid_def(cfg), mesh)


def batch_struct(cfg: DLRMConfig, mesh, layout, *,
                 include_presort: bool | None = None) -> tuple[dict, dict]:
    """(ShapeDtypeStructs, PartitionSpecs) for one global batch.  Kept as
    the DLRM-named entry for the bench/dry-run paths; delegates to the
    generic hybrid builder so the weighted / host-pre-sorted fields stay
    single-sourced."""
    from repro.core import hybrid as H
    return H.batch_struct(as_hybrid_def(cfg), mesh, layout,
                          include_presort=include_presort)


def dlrm_dense_loss(cfg: DLRMConfig):
    """Stage-shaped loss: (dense_hi, emb_out, batch) -> per-shard SUM loss
    (the pipeline's dense_fwd_bwd stage divides by the global batch)."""
    def loss(dense_hi, emb_out, batch):
        logits = forward_local(dense_hi, emb_out, batch["dense_x"],
                               cfg.mlp_impl)
        return bce_with_logits(logits, batch["labels"]).sum()
    return loss


def dlrm_dense_score(cfg: DLRMConfig):
    """Stage-shaped scorer: (dense_hi, emb_out, batch) -> [b] sigmoid."""
    def score(dense_hi, emb_out, batch):
        return jax.nn.sigmoid(forward_local(dense_hi, emb_out,
                                            batch["dense_x"], cfg.mlp_impl))
    return score


def as_hybrid_def(cfg: DLRMConfig):
    """DLRM expressed as the generic hybrid skeleton: the paper topology's
    fwd/bwd pieces become stage-shaped functions the pipeline composes."""
    from repro.core.hybrid import HybridDef
    return HybridDef(
        name=cfg.name, spec=cfg.spec, pooling=cfg.pooling, batch=cfg.batch,
        init_dense=lambda key: init_dense_params(key, cfg),
        dense_loss=dlrm_dense_loss(cfg),
        dense_score=dlrm_dense_score(cfg),
        extras={"dense_x": ((cfg.num_dense,), jnp.bfloat16),
                "labels": ((), jnp.float32)},
        emb_mode=cfg.emb_mode, sparse_optimizer=cfg.sparse_optimizer,
        opt_beta=cfg.opt_beta, opt_eps=cfg.opt_eps, split_sgd=cfg.split_sgd,
        fused_update=cfg.fused_update, exchange=cfg.exchange,
        exchange_dtype=cfg.exchange_dtype, compress_grads=cfg.compress_grads,
        num_buckets=cfg.num_buckets, lr=cfg.lr, emb_lr=cfg.lr,
        idx_input=cfg.idx_input, microbatches=cfg.microbatches,
        exchange_impl=cfg.exchange_impl, weighted=cfg.weighted,
        host_presort=cfg.host_presort, sr_seed=cfg.sr_seed,
        hot_rows=cfg.hot_rows, promote_every=cfg.promote_every,
        hot_sync=cfg.hot_sync, step_metrics=cfg.step_metrics)


def make_train_step(cfg: DLRMConfig, mesh, microbatches: int | None = None):
    """Build the jitted hybrid-parallel train step (staged pipeline; see
    repro/core/pipeline.py).  ``microbatches`` defaults to
    ``cfg.microbatches``; 1 reproduces the monolithic step bit-for-bit.

    Returns (step, state_shardings, batch_shardings, layout); call as
    ``new_state, loss = step(state, batch)``.
    """
    from repro.core import pipeline
    M = cfg.microbatches if microbatches is None else microbatches
    return pipeline.make_pipelined_train_step(as_hybrid_def(cfg), mesh,
                                              microbatches=M)


def make_eval_step(cfg: DLRMConfig, mesh):
    """Forward-only scoring step (serving); returns per-sample sigmoid.
    Reuses the pipeline's index_exchange + embedding_fwd stages."""
    from repro.core import pipeline
    structs, specs, shardings, layout = state_struct(cfg, mesh)
    bstructs, bspecs = batch_struct(cfg, mesh, layout,
                                    include_presort=False)
    all_axes, model, batch_axes = mesh_axes(mesh)
    stages = pipeline.build_stages(as_hybrid_def(cfg), mesh, layout)
    opt = row_optim.resolve(cfg)

    def eval_local(state, batch):
        W_fwd = opt.fwd_weights(state["emb"])
        idx_fwd, _ = stages.index_exchange(batch["idx"], fwd_only=True)
        wgt_fwd = None
        if cfg.weighted:
            wgt_fwd, _ = stages.index_exchange(batch["weights"],
                                               fwd_only=True)
        emb_out = stages.embedding_fwd(W_fwd, idx_fwd, wgt_fwd)
        logits = forward_local(state["dense"]["hi"], emb_out,
                               batch["dense_x"], cfg.mlp_impl)
        return jax.nn.sigmoid(logits)

    ev = compat.shard_map(eval_local, mesh=mesh, in_specs=(specs, bspecs),
                       out_specs=P(all_axes), check_vma=False)
    return jax.jit(ev), shardings, bspecs, layout
