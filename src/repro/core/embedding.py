"""Unified multi-table embedding engine (paper contribution C1).

All S embedding tables of a model are concatenated into ONE row space
``W in R^{M_total x E}`` with per-table row offsets.  This is what makes the
paper's race-free update (Alg. 4: partition the row space, each owner applies
only its own rows) a *sharding rule* instead of a threading trick on TPU, and
it lets heterogeneous table sizes (MLPerf: 3 .. 40M rows) bin-pack cleanly
onto a model-parallel axis.

Layout conventions
------------------
* ``indices``: int32 ``[B, S, P]`` — P lookups ("multi-hot") per table per
  sample (the paper's fixed pooling factor P).  Ragged bags are supported via
  ``bag_lookup_ragged``.
* ``global rows``: ``g = indices + row_offset[table]`` indexes the unified
  space.
* Lookups accumulate in fp32 (long-reduction) regardless of storage dtype.

JAX has no native EmbeddingBag — it is built here from ``jnp.take`` +
``jax.ops.segment_sum`` per the system brief.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    """Static description of a unified multi-table embedding space."""

    table_rows: tuple[int, ...]  # M_i per table (original order)
    dim: int                     # E
    row_pad: int = 8             # pad each table's rows to this multiple

    @property
    def num_tables(self) -> int:
        return len(self.table_rows)

    @property
    def padded_rows(self) -> np.ndarray:
        return np.array([_round_up(m, self.row_pad) for m in self.table_rows],
                        dtype=np.int64)

    @property
    def row_offsets(self) -> np.ndarray:
        """Start row of each table in the unified space (original order)."""
        return np.concatenate([[0], np.cumsum(self.padded_rows)[:-1]]).astype(np.int64)

    @property
    def total_rows(self) -> int:
        return int(self.padded_rows.sum())

    def bytes(self, bytes_per_elem: int = 4) -> int:
        return self.total_rows * self.dim * bytes_per_elem

    # ---- sharding helpers -------------------------------------------------
    def rows_per_shard(self, num_shards: int) -> int:
        return _round_up(self.total_rows, num_shards * self.row_pad) // num_shards

    def binpack_tables(self, num_bins: int) -> list[list[int]]:
        """Greedy bin-pack tables by row count (paper's table-wise placement).

        Returns ``bins[b] = [table ids]`` balanced by rows.  Used by the
        ``table`` sharding mode of :mod:`repro.core.sharded_embedding`.
        """
        order = np.argsort(-self.padded_rows)  # largest first
        bins: list[list[int]] = [[] for _ in range(num_bins)]
        loads = np.zeros(num_bins, dtype=np.int64)
        for t in order:
            b = int(np.argmin(loads))
            bins[b].append(int(t))
            loads[b] += int(self.padded_rows[t])
        return bins


def init_embedding(key: jax.Array, spec: EmbeddingSpec,
                   dtype=jnp.float32, scale: float | None = None) -> jax.Array:
    """Initialize the unified table.  DLRM uses U(-1/sqrt(M), 1/sqrt(M)) per
    table; we use a single scale of the mean table size for simplicity."""
    if scale is None:
        scale = 1.0 / np.sqrt(max(1.0, float(np.mean(self_rows(spec)))))
    return jax.random.uniform(key, (spec.total_rows, spec.dim), dtype=jnp.float32,
                              minval=-scale, maxval=scale).astype(dtype)


def self_rows(spec: EmbeddingSpec) -> np.ndarray:
    return np.asarray(spec.table_rows, dtype=np.float64)


def globalize(spec: EmbeddingSpec, indices: jax.Array) -> jax.Array:
    """Map per-table indices ``[B, S, P]`` to unified row ids."""
    off = jnp.asarray(spec.row_offsets, dtype=indices.dtype)
    return indices + off[None, :, None]


# ---------------------------------------------------------------------------
# Forward bags
# ---------------------------------------------------------------------------

def bag_lookup(W: jax.Array, g: jax.Array,
               weights: jax.Array | None = None) -> jax.Array:
    """EmbeddingBag-sum forward: ``Y[b,s] = sum_p W[g[b,s,p]]`` (paper Alg. 1).

    ``W``: [M, E] (any float dtype), ``g``: [B, S, P] unified row ids.
    Returns fp32 ``[B, S, E]``.
    """
    rows = jnp.take(W, g, axis=0).astype(jnp.float32)  # [B, S, P, E]
    if weights is not None:
        rows = rows * weights[..., None].astype(jnp.float32)
    return rows.sum(axis=2)


def bag_lookup_ragged(W: jax.Array, flat_idx: jax.Array, segment_ids: jax.Array,
                      num_bags: int) -> jax.Array:
    """Ragged EmbeddingBag: ``Y[n] = sum_{i: seg[i]==n} W[flat_idx[i]]``."""
    rows = jnp.take(W, flat_idx, axis=0).astype(jnp.float32)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)


def lookup(W: jax.Array, idx: jax.Array) -> jax.Array:
    """Plain (non-bagged) lookup, e.g. item sequences: idx [...,] -> [..., E]."""
    return jnp.take(W, idx, axis=0)


# ---------------------------------------------------------------------------
# Fused backward + update (paper contribution C1, the 1.6x standalone win).
#
# We never materialize a dense dW [M_total, E].  The cotangent of the bag
# output dY [B, S, E] is scattered directly into the weight as an SGD step:
#     W[g[b,s,p]] -= lr * dY[b,s]
# Duplicate indices accumulate (scatter-add), which is exactly Alg. 3 with the
# atomicity supplied by XLA's deterministic scatter instead of RTM/atomics.
# ---------------------------------------------------------------------------

def bag_update(W: jax.Array, g: jax.Array, dY: jax.Array, lr,
               weights: jax.Array | None = None,
               method: str = "scatter") -> jax.Array:
    """Apply the fused sparse SGD step for a bag lookup.

    ``W``: [M, E]; ``g``: [B, S, P]; ``dY``: [B, S, E] cotangent of the bag
    output.  Returns the updated W.

    ``method``:
      * ``"scatter"`` — XLA scatter-add (Alg. 3; duplicates accumulate via
        the deterministic scatter).  The functional update copies the shard.
      * ``"fused"`` — the Pallas fused kernel
        (:mod:`repro.kernels.embedding_update`): sort + in-VMEM duplicate
        pre-reduction, touched rows only, in-place.  No [B,S,P,E] gradient
        expansion and no shard copy.  ``weights`` [B, S, P] per-lookup bag
        weights ride along as a flat scalar operand scaling each lookup's
        dY row before the pre-reduction (the weighted-bag mirror of the
        scatter path's ``upd * weights``).
    """
    B, S, P = g.shape
    E = W.shape[1]
    if method == "fused":
        from repro.optim import row
        out = row.get("sgd").apply_sparse(
            {"w": W}, row.SparseStream(idx=g, dY=dY, weights=weights), lr,
            fused=True)
        return out["w"]
    upd = jnp.broadcast_to(dY[:, :, None, :], (B, S, P, E))
    if weights is not None:
        upd = upd * weights[..., None]
    upd = (-lr * upd.astype(jnp.float32)).reshape(-1, E).astype(W.dtype)
    return W.at[g.reshape(-1)].add(upd)


def bag_update_split(hi: jax.Array, lo: jax.Array, g: jax.Array,
                     dY: jax.Array, lr, weights: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Fused sparse backward + Split-SGD-BF16 step on a split-storage table
    (paper Alg. 3 + C5): only the rows named by ``g`` are reconstructed,
    stepped and re-split — in VMEM, via the Pallas fused kernel.
    ``weights`` [B, S, P]: optional per-lookup bag weights."""
    from repro.optim import row
    out = row.get("split_sgd").apply_sparse(
        {"hi": hi, "lo": lo}, row.SparseStream(idx=g, dY=dY,
                                               weights=weights), lr,
        fused=True)
    return out["hi"], out["lo"]


def bag_grad_rows(g: jax.Array, dY: jax.Array, num_rows: int) -> jax.Array:
    """Dense gradient (reference / benchmark only): the thing the paper
    avoids.  Materializes dW [num_rows, E] via segment_sum."""
    B, S, P = g.shape
    E = dY.shape[-1]
    upd = jnp.broadcast_to(dY[:, :, None, :], (B, S, P, E)).reshape(-1, E)
    return jax.ops.segment_sum(upd.astype(jnp.float32), g.reshape(-1),
                               num_segments=num_rows)


# ---------------------------------------------------------------------------
# Differentiable bag: gradient flows to the *gathered rows* intermediate, so
# jax.grad gives a [B,S,P,E] cotangent that the sparse optimizer consumes —
# never a dense [M,E] one.  Used when the bag output feeds a larger graph.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=())
def bag_from_rows(rows: jax.Array) -> jax.Array:
    return rows.astype(jnp.float32).sum(axis=2)


def _bag_from_rows_fwd(rows):
    return bag_from_rows(rows), (rows.shape, rows.dtype)


def _bag_from_rows_bwd(res, dY):
    shape, dtype = res
    B, S, P, E = shape
    return (jnp.broadcast_to(dY[:, :, None, :], (B, S, P, E)).astype(dtype),)


bag_from_rows.defvjp(_bag_from_rows_fwd, _bag_from_rows_bwd)
