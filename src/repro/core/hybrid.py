"""Generic hybrid-parallel (C3) train/eval step factory — staged pipeline.

Every recsys architecture here (DLRM, FM, BST, SASRec, DIN) shares one
skeleton: model-parallel unified embedding + data-parallel dense net +
all-to-all / reduce-scatter layout switch + fused sparse update + RS+AG
dense optimizer.  This module hosts the skeleton's *definition*
(:class:`HybridDef`: what a model must provide) and its state/batch
structure builders; the step itself is composed from the explicit
:class:`repro.core.pipeline.Stage` objects —

    index_exchange -> embedding_fwd -> dense_fwd_bwd -> dY_exchange
                   -> sparse_update -> dense_update

— by :func:`repro.core.pipeline.make_pipelined_train_step`, which also
software-pipelines M microbatches with a double-buffered index exchange so
the layout-switch collectives of microbatch i+1 overlap microbatch i's
dense compute (the paper's Sect. VI comm/compute overlap).

:func:`make_train_step` is the ``M = mdef.microbatches`` entry point; with
``microbatches=1`` (the default) it is the degenerate single-stage-chain
case, bit-compatible with the historical monolithic step.  The serve path
(:func:`make_score_step`) reuses the same ``index_exchange`` and
``embedding_fwd`` stages, so a placement or exchange change lands in train
and serve at once.  See docs/pipeline.md for the stage/timeline diagram.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.embedding import EmbeddingSpec
from repro.core import pipeline
from repro.core import sharded_embedding as se
from repro.dist.exchange import ExchangeConfig, resolve_exchange
from repro.optim import data_parallel as dp
from repro.optim import row as row_optim


@dataclasses.dataclass(frozen=True)
class HybridDef:
    """What a hybrid-parallel recsys model must provide."""
    name: str
    spec: EmbeddingSpec
    pooling: int                   # P (max lookups per slot)
    batch: int                     # global batch
    init_dense: Callable[[jax.Array], Any]
    # dense_loss(dense_hi, emb_out [b,S,E] fp32, batch) -> per-shard SUM loss
    dense_loss: Callable[[Any, jax.Array, dict], jax.Array]
    # dense_score(dense_hi, emb_out, batch) -> [b] scores
    dense_score: Callable[[Any, jax.Array, dict], jax.Array]
    # extra batch fields: name -> (shape-after-B, dtype); all batch-sharded
    extras: dict = dataclasses.field(default_factory=dict)
    # slot -> table map (sequence models share one item table across slots)
    slot_to_table: Optional[tuple] = None
    emb_mode: str = "row"
    # sparse RowOptimizer (repro/optim/row.py): registry name ('sgd',
    # 'split_sgd', 'momentum', 'adagrad_rowwise', 'adagrad') or a
    # RowOptimizer instance.  Owns the embedding store layout (weight
    # slab(s) + per-row state slabs) and the single fused apply the
    # sparse_update stage dispatches through.  None/'' falls back to the
    # legacy ``split_sgd`` bool below.
    sparse_optimizer: Optional[Any] = None
    # hyperparameter overrides for the registered optimizer (None = its
    # registered default): momentum coefficient / adagrad denominator floor
    opt_beta: Optional[float] = None
    opt_eps: Optional[float] = None
    # DEPRECATED sugar (only read when sparse_optimizer is unset): True ->
    # sparse_optimizer='split_sgd', False -> 'sgd'.  None (default) keeps
    # the 'split_sgd' fallback without the DeprecationWarning.
    split_sgd: Optional[bool] = None
    # fused Pallas sparse-bwd + row-optimizer update (kernels/
    # embedding_update) — the split path is bit-identical to the reference,
    # touches O(touched rows) instead of O(shard rows).  None (default) =
    # on where the kernel compiles (TPU); off elsewhere, because CPU
    # interpret emulation pays O(shard) per grid step.  True/False forces
    # the choice (A/B, tests).
    fused_update: Optional[bool] = None
    # typed comm/precision config (repro/dist/exchange.py): the index-
    # exchange lowering, the per-collective wire formats of the dY
    # exchange + dense reduce-scatter ('fp32' | 'bf16' | 'bf16_sr'), the
    # dense error feedback, and the RS+AG bucketing, as ONE frozen
    # ExchangeConfig.  Mutually exclusive with the flat kwargs below.
    exchange: Optional[ExchangeConfig] = None
    # sugar: set BOTH wire dtypes at once ('fp32' is today's wire,
    # bitwise; 'bf16' halves the compressible collective bytes; 'bf16_sr'
    # additionally dithers with the seeded sr counter — deterministic and
    # checkpoint-replayable)
    exchange_dtype: Optional[str] = None
    # DEPRECATED flat kwargs, coerced by resolve_exchange with a
    # DeprecationWarning: compress_grads=True == dense_dtype='bf16' with
    # error feedback; num_buckets / exchange_impl map to the same-named
    # ExchangeConfig fields.  None (default) = unset.
    compress_grads: Optional[bool] = None
    num_buckets: Optional[int] = None
    lr: float = 0.01
    emb_lr: float = 0.01
    idx_input: str = "replicated"   # 'sharded': on-chip index exchange
    # staged pipeline (repro/core/pipeline.py): number of microbatches the
    # global batch is split into, with the index exchange double-buffered
    # across them.  1 = the monolithic step.
    microbatches: int = 1
    exchange_impl: Optional[str] = None
    # weighted bags: the batch carries a 'weights' field in the exact
    # layout of 'idx' ([B, S, P] per-lookup bag weights); the forward
    # computes sum(w * row) and the sparse update scales dY per lookup.
    # All-ones weights are bit-identical to unweighted.
    weighted: bool = False
    # host-pre-sorted sparse update (repro/data/pipeline.py): the loader
    # ships per-shard sorted lookup streams as psort_* batch fields and
    # the fused kernel consumes them directly — no on-device sort in the
    # step.  Row AND table mode (the table host sort folds the
    # padded-slot permute in); always the fused kernel on the update path.
    host_presort: bool = False
    # initial value of the per-step stochastic-rounding counter (the
    # replicated int32 ``state["sr"]`` scalar, present only when the
    # resolved RowOptimizer registered stochastic_round=True; incremented
    # once per step and checkpointed, so a resumed run replays the exact
    # dither sequence)
    sr_seed: int = 0
    # frequency-tiered hot-row cache (repro/core/cache.py): > 0 keeps a
    # replicated mirror of the top-``hot_rows`` rows PER TABLE (ranked by
    # the reserved ``cnt`` touch-counter slab) in front of the sharded
    # cold store; bags whose lookups all hit are served locally, off the
    # all-to-all payload (table mode + idx_input='sharded').  0 = off.
    hot_rows: int = 0
    # promotion/demotion cadence: re-rank the hot set from the counters
    # every this-many steps (deterministic, seeded by ``sr_seed``)
    promote_every: int = 1
    # 'allreduce': refresh the mirror from the post-update store every
    # step (bitwise == hot_rows=0); 'deferred:N': refresh every N steps
    # (bounded drift, see docs/cache.md)
    hot_sync: str = "allreduce"
    # in-graph step metrics (repro/telemetry/metrics.py): a replicated
    # float32 counter vector in the train state, accumulated on device by
    # the pipelined step (cache hits, rows touched, exchange payload
    # bytes) and drained by the host every TrainLoopConfig.metrics_every
    # steps — no per-step host syncs.  False (default) adds NO state key
    # and leaves the lowered step bit-identical to a build without it.
    step_metrics: bool = False


# stage-shaped mesh helpers live in pipeline.py; re-exported for callers
_mesh_axes = pipeline.mesh_axes


def _emb_axes(mdef, mesh):
    return pipeline.emb_axes(mdef, mesh)


def make_layout(mdef: HybridDef, mesh) -> se.ShardedEmbeddingLayout:
    axes, _ = _emb_axes(mdef, mesh)
    ns = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple)
                                              else (axes,))]))
    return se.make_layout(mdef.spec, ns, mdef.emb_mode,
                          slot_to_table=mdef.slot_to_table)


def state_struct(mdef: HybridDef, mesh):
    layout = make_layout(mdef, mesh)
    all_axes, model, batch_axes = _mesh_axes(mesh)
    emb_ax, _ = _emb_axes(mdef, mesh)
    ns_total = int(np.prod(list(mesh.shape.values())))
    E = mdef.spec.dim
    dense_tree = jax.eval_shape(lambda: mdef.init_dense(jax.random.PRNGKey(0)))
    n_dense = dp.ravel_size(dense_tree)
    ex_cfg = resolve_exchange(mdef)
    padded = -(-n_dense // (ns_total * ex_cfg.num_buckets)) * (
        ns_total * ex_cfg.num_buckets)
    rows = layout.total_rows
    opt = row_optim.resolve(mdef)
    hot_rows = getattr(mdef, "hot_rows", 0)
    structs = {
        # the RowOptimizer owns the embedding store layout: weight slab(s)
        # plus zero or more per-row state slabs, all sharded by the same
        # row partition (so state persists/reshards next to weights); the
        # hot-row cache adds the reserved ``cnt`` touch-counter slab
        "emb": opt.store_struct(rows, E, counters=hot_rows > 0),
        "dense": {
            "hi": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                dense_tree),
            "lo": jax.ShapeDtypeStruct((padded,), jnp.uint16),
            "err": (jax.ShapeDtypeStruct((padded,), jnp.float32)
                    if ex_cfg.needs_err else None),
        },
    }
    specs = {
        "emb": jax.tree.map(lambda _: P(emb_ax, None), structs["emb"]),
        "dense": {
            "hi": jax.tree.map(lambda _: P(), structs["dense"]["hi"]),
            "lo": P(all_axes),
            "err": P(all_axes) if ex_cfg.needs_err else None,
        },
    }
    if opt.stochastic_round or ex_cfg.needs_sr:
        # per-step stochastic-rounding counter: replicated int32 scalar,
        # consumed by the compressed-state row optimizers and/or the
        # 'bf16_sr' wire encoders
        structs["sr"] = jax.ShapeDtypeStruct((), jnp.int32)
        specs["sr"] = P()
    if hot_rows > 0:
        from repro.core import cache as hot_cache
        structs["cache"] = hot_cache.cache_struct(mdef, layout, opt)
        specs["cache"] = hot_cache.cache_specs(structs["cache"])
    if getattr(mdef, "step_metrics", False):
        from repro.telemetry import metrics as step_mx
        structs["metrics"] = step_mx.metrics_struct()
        specs["metrics"] = P()
    shardings = jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P) or x is None)
    return structs, specs, shardings, layout


def batch_struct(mdef: HybridDef, mesh, layout, batch: int | None = None,
                 *, include_presort: bool | None = None):
    """(ShapeDtypeStructs, PartitionSpecs) for one global batch.

    ``weighted`` models add a ``weights`` field in the exact shape/spec of
    ``idx``.  ``host_presort`` models add the four ``psort_*`` fields of
    ``repro.data.pipeline.presort_batch`` — ``[ns_emb, B*S*P]`` sharded
    over the embedding axes, so each shard receives its own pre-sorted
    update stream.  ``include_presort`` overrides the mdef default (the
    forward-only serve/eval steps never consume the update stream)."""
    all_axes, model, batch_axes = _mesh_axes(mesh)
    B = batch or mdef.batch
    S, Pq = layout.num_orig_slots, mdef.pooling
    if mdef.idx_input not in ("replicated", "sharded"):
        raise ValueError(f"unknown idx_input {mdef.idx_input!r}; "
                         "expected 'replicated' or 'sharded'")
    if mdef.emb_mode == "row":
        idx = jax.ShapeDtypeStruct((B, S, Pq), jnp.int32)
        idx_spec = (P(None, None, None) if mdef.idx_input == "replicated"
                    else P(all_axes, None, None))
    elif mdef.idx_input == "sharded":
        # on-chip exchange: the loader feeds batch-sharded ORIGINAL-slot
        # indices; the index_exchange stage gathers, permutes to padded
        # order and slices this shard's slots (no host-side permute).
        idx = jax.ShapeDtypeStruct((B, S, Pq), jnp.int32)
        idx_spec = P(all_axes, None, None)
    else:
        idx = jax.ShapeDtypeStruct((B, layout.num_padded_slots, Pq),
                                   jnp.int32)
        idx_spec = P(batch_axes if batch_axes else None, model, None)
    structs = {"idx": idx}
    specs = {"idx": idx_spec}
    if mdef.weighted:
        structs["weights"] = jax.ShapeDtypeStruct(idx.shape, jnp.float32)
        specs["weights"] = idx_spec
    include_presort = (mdef.host_presort if include_presort is None
                       else include_presort)
    if include_presort:
        emb_ax, _ = _emb_axes(mdef, mesh)
        axes = emb_ax if isinstance(emb_ax, tuple) else (emb_ax,)
        ns_emb = int(np.prod([mesh.shape[a] for a in axes]))
        # flat lookup count of the per-shard sorted stream: row mode sorts
        # the original-slot stream; table mode the padded-slot stream of
        # each shard's slots (presort_batch folds the permute in)
        slots = S if mdef.emb_mode == "row" else layout.slots_per_shard
        L = B * slots * Pq
        for name, dt in (("psort_rows", jnp.int32),
                         ("psort_bags", jnp.int32),
                         ("psort_msk", jnp.int32),
                         ("psort_wgt", jnp.float32)):
            structs[name] = jax.ShapeDtypeStruct((ns_emb, L), dt)
            specs[name] = P(emb_ax, None)
    for name, (shape, dtype) in mdef.extras.items():
        structs[name] = jax.ShapeDtypeStruct((B, *shape), dtype)
        specs[name] = P(all_axes, *([None] * len(shape)))
    return structs, specs


def batch_struct_from_spec(mdef: HybridDef, mesh, layout, dataset_spec,
                           batch: int | None = None):
    """Batch struct derived from (and validated against) a packed-dataset
    :class:`repro.data.format.DatasetSpec` — the loader-facing entry: a
    spec/model mismatch fails here, at wiring time, with a field-by-field
    message instead of a shape error inside shard_map."""
    dataset_spec.check_model(mdef)
    if dataset_spec.weighted and not mdef.weighted:
        # legal (weights are simply not read) but worth rejecting loudly:
        # the reader WILL yield a weights field the struct won't declare.
        raise ValueError("dataset is weighted but mdef.weighted=False; "
                         "set weighted=True (or strip the weights field)")
    return batch_struct(mdef, mesh, layout, batch)


def init_state(key, mdef: HybridDef, mesh):
    structs, specs, shardings, layout = state_struct(mdef, mesh)
    ke, kd = jax.random.split(key)
    ns_total = int(np.prod(list(mesh.shape.values())))
    scale = 1.0 / np.sqrt(np.mean(mdef.spec.table_rows))
    W = jax.random.uniform(ke, (layout.total_rows, mdef.spec.dim),
                           jnp.float32, -scale, scale)
    dense = mdef.init_dense(kd)
    ex_cfg = resolve_exchange(mdef)
    arrays = dp.dp_global_arrays(dense, ns_total,
                                 compress=ex_cfg.needs_err,
                                 num_buckets=ex_cfg.num_buckets)
    opt = row_optim.resolve(mdef)
    hot_rows = getattr(mdef, "hot_rows", 0)
    emb = opt.init_store(W, counters=hot_rows > 0)
    state = {"emb": emb, "dense": {"hi": arrays["hi"], "lo": arrays["lo"],
                                   "err": arrays["err"]}}
    if opt.stochastic_round or ex_cfg.needs_sr:
        state["sr"] = jnp.asarray(mdef.sr_seed, jnp.int32)
    if hot_rows > 0:
        from repro.core import cache as hot_cache
        state["cache"] = hot_cache.init_cache(mdef, layout, opt)
    if getattr(mdef, "step_metrics", False):
        from repro.telemetry import metrics as step_mx
        state["metrics"] = step_mx.init_metrics()
    return jax.device_put(state, shardings), layout


def make_train_step(mdef: HybridDef, mesh, microbatches: int | None = None):
    """Staged-pipeline train step; ``microbatches`` defaults to
    ``mdef.microbatches`` (1 = the monolithic step, bit-compatible with the
    historical closure)."""
    M = mdef.microbatches if microbatches is None else microbatches
    return pipeline.make_pipelined_train_step(mdef, mesh, microbatches=M)


# the explicit name used throughout benchmarks/tests
make_pipelined_train_step = pipeline.make_pipelined_train_step


def make_score_step(mdef: HybridDef, mesh, batch: int | None = None):
    """Forward-only scoring (serve_p99 / serve_bulk shapes).  Reuses the
    pipeline's index_exchange + embedding_fwd stages — the serve path sees
    every placement/exchange improvement the train path gets."""
    structs, specs, shardings, layout = state_struct(mdef, mesh)
    bstructs, bspecs = batch_struct(mdef, mesh, layout, batch,
                                    include_presort=False)
    all_axes, model, batch_axes = _mesh_axes(mesh)
    stages = pipeline.build_stages(mdef, mesh, layout)
    opt = row_optim.resolve(mdef)

    def score_local(state, batch_d):
        W_fwd = opt.fwd_weights(state["emb"])
        idx_fwd, _ = stages.index_exchange(batch_d["idx"], fwd_only=True)
        wgt_fwd = None
        if mdef.weighted:
            wgt_fwd, _ = stages.index_exchange(batch_d["weights"],
                                               fwd_only=True)
        emb_out = stages.embedding_fwd(W_fwd, idx_fwd, wgt_fwd)
        return mdef.dense_score(state["dense"]["hi"], emb_out, batch_d)

    sc = compat.shard_map(score_local, mesh=mesh, in_specs=(specs, bspecs),
                       out_specs=P(all_axes), check_vma=False)
    return jax.jit(sc), shardings, bspecs, layout


def make_retrieval_step(mdef: HybridDef, mesh, n_candidates: int,
                        target_slot: int, topk: int = 128):
    """retrieval_cand shape: ONE query against ``n_candidates`` candidates.

    The candidate embedding matrix [n_cand, E] enters pre-sharded over the
    full mesh (the offline-built candidate index of a serving system); the
    query's bag output is computed replicated (psum), the target slot is
    substituted with each local candidate, the dense scorer runs batched
    over the local chunk, and a distributed top-k merge produces the global
    result.  Never a loop over candidates."""
    if mdef.weighted:
        raise ValueError("retrieval scores a single replicated query "
                         "against a prebuilt candidate matrix; weighted "
                         "bags are not supported on this path — replace "
                         "the mdef with weighted=False for retrieval")
    structs, specs, shardings, layout = state_struct(mdef, mesh)
    bstructs, bspecs = batch_struct(mdef, mesh, layout, batch=1,
                                    include_presort=False)
    bspecs = jax.tree.map(lambda s: P(*([None] * len(s))), bspecs,
                          is_leaf=lambda x: isinstance(x, P))  # B=1: replicate
    all_axes, model, batch_axes = _mesh_axes(mesh)
    emb_ax, _ = _emb_axes(mdef, mesh)
    if mdef.emb_mode != "row":
        raise ValueError("retrieval step requires emb_mode='row' "
                         f"(got {mdef.emb_mode!r})")
    if mdef.idx_input != "replicated":
        raise ValueError("retrieval step scores ONE replicated query; a "
                         "batch-sharded index stream (idx_input='sharded') "
                         "cannot shard a single sample — replace the mdef "
                         "with idx_input='replicated' for retrieval")
    ns = int(np.prod(list(mesh.shape.values())))
    per = n_candidates // ns
    E = mdef.spec.dim
    opt = row_optim.resolve(mdef)

    def _normalize_batch(batch):
        """Schema-normalize the single-query batch BEFORE shard_map: every
        declared extra is reshaped to ``(1, *schema_shape)``, so rank-1
        (B-squeezed) extras are accepted instead of silently dropped."""
        out = dict(batch)
        for k, (shape, _) in mdef.extras.items():
            if k in out:
                out[k] = jnp.reshape(out[k], (1,) + tuple(shape))
        return out

    def _broadcast_batch(batch):
        """Candidate-batch view of the (normalized) query: declared extras
        broadcast over the local candidate chunk via the schema; unknown
        fields keep the legacy leading-(1,) heuristic."""
        out = {}
        for k, v in batch.items():
            if k in mdef.extras:
                shape = tuple(mdef.extras[k][0])
                out[k] = jnp.broadcast_to(v, (per,) + shape)
            elif hasattr(v, "shape") and v.shape[:1] == (1,):
                out[k] = jnp.broadcast_to(v, (per,) + v.shape[1:])
            else:
                out[k] = v
        return out

    def local(state, batch, cand):
        W_fwd = opt.fwd_weights(state["emb"])
        emb = se.row_bag_fwd_replicated(layout, W_fwd, batch["idx"], emb_ax)
        emb_c = jnp.broadcast_to(emb, (per,) + emb.shape[1:])
        emb_c = emb_c.at[:, target_slot].set(cand.astype(jnp.float32))
        scores = mdef.dense_score(state["dense"]["hi"], emb_c,
                                  _broadcast_batch(batch))
        v, i = jax.lax.top_k(scores, min(topk, per))
        i = i + jax.lax.axis_index(all_axes) * per
        vg = jax.lax.all_gather(v, all_axes, axis=0, tiled=True)
        ig = jax.lax.all_gather(i, all_axes, axis=0, tiled=True)
        vv, pos = jax.lax.top_k(vg, topk)
        return vv, jnp.take(ig, pos)

    cand_struct = jax.ShapeDtypeStruct((n_candidates, E), jnp.bfloat16)
    cand_spec = P(all_axes, None)
    inner = compat.shard_map(local, mesh=mesh,
                       in_specs=(specs, bspecs, cand_spec),
                       out_specs=(P(), P()), check_vma=False)

    def fn(state, batch, cand):
        return inner(state, _normalize_batch(batch), cand)

    arg_structs = (structs, bstructs, cand_struct)
    arg_shardings = (shardings,
                     jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
                     NamedSharding(mesh, cand_spec))
    return jax.jit(fn), arg_structs, arg_shardings, layout
