"""Generic hybrid-parallel (C3) train/eval step factory.

The DLRM step in repro/core/dlrm.py is the paper's exact topology; every
other recsys architecture (FM, BST, SASRec, DIN) shares the same skeleton —
model-parallel unified embedding + data-parallel dense net + all-to-all /
reduce-scatter layout switch + fused sparse update + RS+AG dense optimizer —
and only differs in the dense function and loss.  This factory hosts that
skeleton once.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.embedding import EmbeddingSpec
from repro.core import sharded_embedding as se
from repro.optim import data_parallel as dp
from repro.optim.split_sgd import split_fp32


@dataclasses.dataclass(frozen=True)
class HybridDef:
    """What a hybrid-parallel recsys model must provide."""
    name: str
    spec: EmbeddingSpec
    pooling: int                   # P (max lookups per slot)
    batch: int                     # global batch
    init_dense: Callable[[jax.Array], Any]
    # dense_loss(dense_hi, emb_out [b,S,E] fp32, batch) -> per-shard SUM loss
    dense_loss: Callable[[Any, jax.Array, dict], jax.Array]
    # dense_score(dense_hi, emb_out, batch) -> [b] scores
    dense_score: Callable[[Any, jax.Array, dict], jax.Array]
    # extra batch fields: name -> (shape-after-B, dtype); all batch-sharded
    extras: dict = dataclasses.field(default_factory=dict)
    # slot -> table map (sequence models share one item table across slots)
    slot_to_table: Optional[tuple] = None
    emb_mode: str = "row"
    split_sgd: bool = True
    # fused Pallas sparse-bwd + Split-SGD row update (kernels/embedding_update)
    # — bit-identical to the reference path, touches O(unique rows) instead of
    # O(shard rows).  None (default) = on where the kernel compiles (TPU);
    # off elsewhere, because CPU interpret emulation pays O(shard) per grid
    # step.  True/False forces the choice (A/B, tests).
    fused_update: Optional[bool] = None
    compress_grads: bool = False
    num_buckets: int = 4
    lr: float = 0.01
    emb_lr: float = 0.01
    idx_input: str = "replicated"   # 'sharded': on-chip index exchange


def _mesh_axes(mesh):
    names = tuple(mesh.axis_names)
    return names, names[-1], names[:-1]


def _emb_axes(mdef, mesh):
    all_axes, model, batch_axes = _mesh_axes(mesh)
    if mdef.emb_mode == "row":
        return all_axes, None
    return model, (batch_axes if batch_axes else None)


def make_layout(mdef: HybridDef, mesh) -> se.ShardedEmbeddingLayout:
    axes, _ = _emb_axes(mdef, mesh)
    ns = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple)
                                              else (axes,))]))
    return se.make_layout(mdef.spec, ns, mdef.emb_mode,
                          slot_to_table=mdef.slot_to_table)


def state_struct(mdef: HybridDef, mesh):
    layout = make_layout(mdef, mesh)
    all_axes, model, batch_axes = _mesh_axes(mesh)
    emb_ax, _ = _emb_axes(mdef, mesh)
    ns_total = int(np.prod(list(mesh.shape.values())))
    E = mdef.spec.dim
    dense_tree = jax.eval_shape(lambda: mdef.init_dense(jax.random.PRNGKey(0)))
    n_dense = dp.ravel_size(dense_tree)
    padded = -(-n_dense // (ns_total * mdef.num_buckets)) * (
        ns_total * mdef.num_buckets)
    rows = layout.total_rows
    structs = {
        "emb": ({"hi": jax.ShapeDtypeStruct((rows, E), jnp.bfloat16),
                 "lo": jax.ShapeDtypeStruct((rows, E), jnp.uint16)}
                if mdef.split_sgd else
                {"w": jax.ShapeDtypeStruct((rows, E), jnp.float32)}),
        "dense": {
            "hi": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
                dense_tree),
            "lo": jax.ShapeDtypeStruct((padded,), jnp.uint16),
            "err": (jax.ShapeDtypeStruct((padded,), jnp.float32)
                    if mdef.compress_grads else None),
        },
    }
    specs = {
        "emb": jax.tree.map(lambda _: P(emb_ax, None), structs["emb"]),
        "dense": {
            "hi": jax.tree.map(lambda _: P(), structs["dense"]["hi"]),
            "lo": P(all_axes),
            "err": P(all_axes) if mdef.compress_grads else None,
        },
    }
    shardings = jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P) or x is None)
    return structs, specs, shardings, layout


def batch_struct(mdef: HybridDef, mesh, layout, batch: int | None = None):
    all_axes, model, batch_axes = _mesh_axes(mesh)
    B = batch or mdef.batch
    S, Pq = layout.num_orig_slots, mdef.pooling
    if mdef.emb_mode == "row":
        idx = jax.ShapeDtypeStruct((B, S, Pq), jnp.int32)
        idx_spec = (P(None, None, None) if mdef.idx_input == "replicated"
                    else P(all_axes, None, None))
    else:
        idx = jax.ShapeDtypeStruct((B, layout.num_padded_slots, Pq),
                                   jnp.int32)
        idx_spec = P(batch_axes if batch_axes else None, model, None)
    structs = {"idx": idx}
    specs = {"idx": idx_spec}
    for name, (shape, dtype) in mdef.extras.items():
        structs[name] = jax.ShapeDtypeStruct((B, *shape), dtype)
        specs[name] = P(all_axes, *([None] * len(shape)))
    return structs, specs


def init_state(key, mdef: HybridDef, mesh):
    structs, specs, shardings, layout = state_struct(mdef, mesh)
    ke, kd = jax.random.split(key)
    ns_total = int(np.prod(list(mesh.shape.values())))
    scale = 1.0 / np.sqrt(np.mean(mdef.spec.table_rows))
    W = jax.random.uniform(ke, (layout.total_rows, mdef.spec.dim),
                           jnp.float32, -scale, scale)
    dense = mdef.init_dense(kd)
    arrays = dp.dp_global_arrays(dense, ns_total,
                                 compress=mdef.compress_grads,
                                 num_buckets=mdef.num_buckets)
    emb = ({"hi": split_fp32(W)[0], "lo": split_fp32(W)[1]}
           if mdef.split_sgd else {"w": W})
    state = {"emb": emb, "dense": {"hi": arrays["hi"], "lo": arrays["lo"],
                                   "err": arrays["err"]}}
    return jax.device_put(state, shardings), layout


def make_train_step(mdef: HybridDef, mesh):
    structs, specs, shardings, layout = state_struct(mdef, mesh)
    bstructs, bspecs = batch_struct(mdef, mesh, layout)
    all_axes, model, batch_axes = _mesh_axes(mesh)
    emb_ax, replica_ax = _emb_axes(mdef, mesh)
    B = mdef.batch
    fused = (jax.default_backend() == "tpu" if mdef.fused_update is None
             else mdef.fused_update)

    def step_local(state, batch):
        emb_store = state["emb"]
        W_fwd = emb_store["hi"] if mdef.split_sgd else emb_store["w"]
        idx = batch["idx"]
        if mdef.emb_mode == "row" and mdef.idx_input == "sharded":
            idx = jax.lax.all_gather(idx, emb_ax, axis=0, tiled=True)
        emb_out = se.sharded_bag_fwd(layout, W_fwd, idx, emb_ax)

        def loss_fn(dense_hi, emb_out):
            return mdef.dense_loss(dense_hi, emb_out, batch) / B

        (loss, (g_dense, d_emb)) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(state["dense"]["hi"], emb_out)

        dY = se.gather_dY(layout, d_emb, emb_ax, replica_ax)
        if mdef.split_sgd:
            hi2, lo2 = se.apply_update_scan(
                layout, (emb_store["hi"], emb_store["lo"]), idx, dY,
                mdef.emb_lr, emb_ax, split=True, replica_axes=replica_ax,
                fused=fused)
            new_emb = {"hi": hi2, "lo": lo2}
        else:
            # NB: the fused fp32 kernel pre-reduces duplicates (one rounding
            # per row) where the reference scatter-adds per lookup, so the
            # two non-split paths are close but not bit-identical.
            w2 = se.apply_update_scan(layout, emb_store["w"], idx, dY,
                                      mdef.emb_lr, emb_ax, split=False,
                                      replica_axes=replica_ax, fused=fused)
            new_emb = {"w": w2}

        st = dp.DPState(hi=state["dense"]["hi"], lo_shard=state["dense"]["lo"],
                        mom_shard=None, err_shard=state["dense"]["err"])
        st2 = dp.rs_ag_split_sgd(st, g_dense, mdef.lr, all_axes,
                                 compress=mdef.compress_grads,
                                 num_buckets=mdef.num_buckets, mean=False)
        new_state = {"emb": new_emb,
                     "dense": {"hi": st2.hi, "lo": st2.lo_shard,
                               "err": st2.err_shard}}
        return new_state, jax.lax.psum(loss, all_axes)

    step = compat.shard_map(step_local, mesh=mesh, in_specs=(specs, bspecs),
                         out_specs=(specs, P()), check_vma=False)
    return jax.jit(step, donate_argnums=(0,)), shardings, bspecs, layout


def make_score_step(mdef: HybridDef, mesh, batch: int | None = None):
    """Forward-only scoring (serve_p99 / serve_bulk shapes)."""
    structs, specs, shardings, layout = state_struct(mdef, mesh)
    bstructs, bspecs = batch_struct(mdef, mesh, layout, batch)
    all_axes, model, batch_axes = _mesh_axes(mesh)
    emb_ax, _ = _emb_axes(mdef, mesh)

    def score_local(state, batch_d):
        W_fwd = state["emb"]["hi"] if mdef.split_sgd else state["emb"]["w"]
        idx = batch_d["idx"]
        if mdef.emb_mode == "row" and mdef.idx_input == "sharded":
            idx = jax.lax.all_gather(idx, emb_ax, axis=0, tiled=True)
        emb_out = se.sharded_bag_fwd(layout, W_fwd, idx, emb_ax)
        return mdef.dense_score(state["dense"]["hi"], emb_out, batch_d)

    sc = compat.shard_map(score_local, mesh=mesh, in_specs=(specs, bspecs),
                       out_specs=P(all_axes), check_vma=False)
    return jax.jit(sc), shardings, bspecs, layout


def make_retrieval_step(mdef: HybridDef, mesh, n_candidates: int,
                        target_slot: int, topk: int = 128):
    """retrieval_cand shape: ONE query against ``n_candidates`` candidates.

    The candidate embedding matrix [n_cand, E] enters pre-sharded over the
    full mesh (the offline-built candidate index of a serving system); the
    query's bag output is computed replicated (psum), the target slot is
    substituted with each local candidate, the dense scorer runs batched
    over the local chunk, and a distributed top-k merge produces the global
    result.  Never a loop over candidates."""
    structs, specs, shardings, layout = state_struct(mdef, mesh)
    bstructs, bspecs = batch_struct(mdef, mesh, layout, batch=1)
    bspecs = jax.tree.map(lambda s: P(*([None] * len(s))), bspecs,
                          is_leaf=lambda x: isinstance(x, P))  # B=1: replicate
    all_axes, model, batch_axes = _mesh_axes(mesh)
    emb_ax, _ = _emb_axes(mdef, mesh)
    assert mdef.emb_mode == "row", "retrieval step requires row mode"
    ns = int(np.prod(list(mesh.shape.values())))
    per = n_candidates // ns
    E = mdef.spec.dim

    def local(state, batch, cand):
        W_fwd = state["emb"]["hi"] if mdef.split_sgd else state["emb"]["w"]
        emb = se.row_bag_fwd_replicated(layout, W_fwd, batch["idx"], emb_ax)
        emb_c = jnp.broadcast_to(emb, (per,) + emb.shape[1:])
        emb_c = emb_c.at[:, target_slot].set(cand.astype(jnp.float32))
        batch_c = {k: (jnp.broadcast_to(v, (per,) + v.shape[1:])
                       if hasattr(v, "shape") and v.shape[:1] == (1,) else v)
                   for k, v in batch.items()}
        scores = mdef.dense_score(state["dense"]["hi"], emb_c, batch_c)
        v, i = jax.lax.top_k(scores, min(topk, per))
        i = i + jax.lax.axis_index(all_axes) * per
        vg = jax.lax.all_gather(v, all_axes, axis=0, tiled=True)
        ig = jax.lax.all_gather(i, all_axes, axis=0, tiled=True)
        vv, pos = jax.lax.top_k(vg, topk)
        return vv, jnp.take(ig, pos)

    cand_struct = jax.ShapeDtypeStruct((n_candidates, E), jnp.bfloat16)
    cand_spec = P(all_axes, None)
    fn = compat.shard_map(local, mesh=mesh,
                       in_specs=(specs, bspecs, cand_spec),
                       out_specs=(P(), P()), check_vma=False)
    arg_structs = (structs, bstructs, cand_struct)
    arg_shardings = (shardings,
                     jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
                     NamedSharding(mesh, cand_spec))
    return jax.jit(fn), arg_structs, arg_shardings, layout
