"""DLRM feature-interaction ops (paper Sect. II: "self dot product ...
translates to a batched matrix-matrix multiplication as a key kernel")."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tril_indices(F: int, offset: int = -1) -> tuple[np.ndarray, np.ndarray]:
    """Static lower-triangle (i>j) index pair for the self-dot output."""
    return np.tril_indices(F, offset)


def dot_interaction(dense: jax.Array, emb: jax.Array,
                    self_interaction: bool = False) -> jax.Array:
    """DLRM dot interaction.

    ``dense``: [B, E] bottom-MLP output; ``emb``: [B, S, E] bag outputs.
    Concatenates into Z [B, F=S+1, E], computes Z Z^T and keeps the strict
    lower triangle, then concatenates the dense vector back:
    output [B, E + F(F-1)/2].
    """
    B, S, E = emb.shape
    Z = jnp.concatenate([dense[:, None, :], emb], axis=1)  # [B, F, E]
    F = S + 1
    ZZt = jnp.einsum("bfe,bge->bfg", Z, Z,
                     preferred_element_type=jnp.float32)  # [B, F, F]
    li, lj = tril_indices(F, 0 if self_interaction else -1)
    flat = ZZt.reshape(B, F * F)
    pairs = jnp.take(flat, jnp.asarray(li * F + lj), axis=1)
    return jnp.concatenate([dense.astype(jnp.float32), pairs], axis=1)


def concat_interaction(dense: jax.Array, emb: jax.Array) -> jax.Array:
    """The simple 'Concat' interaction variant from the paper."""
    B, S, E = emb.shape
    return jnp.concatenate(
        [dense.astype(jnp.float32), emb.reshape(B, S * E).astype(jnp.float32)],
        axis=1)


def interaction_output_dim(num_features: int, dim: int,
                           kind: str = "dot", self_interaction: bool = False) -> int:
    """Static output width of the interaction (F = S+1 incl. bottom MLP)."""
    F = num_features
    if kind == "concat":
        return F * dim
    pairs = F * (F + 1) // 2 if self_interaction else F * (F - 1) // 2
    return dim + pairs
