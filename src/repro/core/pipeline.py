"""Staged microbatch pipeline for the hybrid-parallel train step.

The paper's scaling story (Sect. VI) rests on overlapping the embedding
layout-switch collectives (index exchange + all-to-all / reduce-scatter)
with dense compute: on 64 sockets those collectives are the dominant
non-compute cost.  A monolithic step closure gives the compiler one serial
dependence chain per batch; this module decomposes the step into explicit
:class:`Stage` objects and software-pipelines them over M microbatches:

    index_exchange   loader layout -> compute layout for the index stream
                     (row mode: all_gather over the embedding axes; table
                     mode: replica gather / on-chip permute+slice).  DOUBLE
                     BUFFERED: microbatch i+1's exchange is issued before
                     microbatch i's compute consumes buffer i, so the two
                     have no data dependence and XLA's latency-hiding
                     scheduler can overlap them.  jax.lax exposes no public
                     async collective start/done pair; ``exchange_impl=
                     "ring"`` decomposes the gather into ns-1 ppermute
                     chunks — finer units the scheduler can interleave —
                     and is the hook an async start/done lowers into when
                     the API lands.
    embedding_fwd    model-parallel bag forward + layout switch
                     (psum_scatter in row mode, all_to_all in table mode).
    dense_fwd_bwd    data-parallel dense forward/backward on one
                     microbatch; returns (loss, dense grads, emb cotangent).
    dY_exchange      the mirror collective of the fwd layout switch, per
                     microbatch (overlaps the NEXT microbatch's compute).
    sparse_update    ONE fused sparse-backward + SGD application on the
                     concatenated, order-restored index/cotangent stream
                     (bit-identical to the unpipelined step — see below).
    dense_update     ONE bucketed RS+AG Split-SGD step on the accumulated
                     dense gradient (C4+C5).

Microbatch partition and bit-exactness
--------------------------------------
Microbatch i is "every device's i-th slice of its local batch share".
For batch-sharded inputs that is a contiguous local slice; for replicated
index streams it is the matching strided selection (device-major layout
``[ns, M, c]`` sliced at ``[:, i]``), so the bag output of each microbatch
lands on exactly the rows whose dense features the device already holds.
Every microbatch's forward/backward runs against the step's INITIAL
weights (classic gradient accumulation), per-microbatch update streams are
concatenated and restored to the full-batch order with a static
permutation, and the sparse update is applied ONCE — hence
``make_pipelined_train_step(M=1)`` is bit-identical to the legacy
monolithic step and ``M>1`` is bit-identical on the embedding path (the
accumulated dense gradient sums per-microbatch partial sums, which
reassociates the reduction; see tests/test_pipeline.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import sharded_embedding as se
from repro.data.pipeline import PSORT_KEYS
from repro.dist import exchange as exchange_cfg
from repro.optim import data_parallel as dp
from repro.optim import row as row_optim


# ---------------------------------------------------------------------------
# Stage plumbing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One named, composable piece of the hybrid step (runs INSIDE
    shard_map).  ``comm`` labels the collective the stage issues —
    introspection/debugging metadata only (the benchmark overlap model in
    benchmarks/bench_comm_model.py is analytic and does not read it)."""

    name: str
    fn: Callable
    comm: str = ""

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


@dataclasses.dataclass(frozen=True)
class PipelineStages:
    """The staged decomposition of one hybrid-parallel train step."""

    index_exchange: Stage
    embedding_fwd: Stage
    dense_fwd_bwd: Stage
    dY_exchange: Stage
    sparse_update: Stage
    dense_update: Stage


def mesh_axes(mesh) -> tuple[tuple[str, ...], str, tuple[str, ...]]:
    """(all_axes, model_axis, batch_axes).  The last mesh axis is 'model'."""
    names = tuple(mesh.axis_names)
    return names, names[-1], names[:-1]


def emb_axes(mdef, mesh):
    """Row mode shards the row space over the FULL mesh; table mode uses the
    model axis and replicates over the rest."""
    all_axes, model, batch_axes = mesh_axes(mesh)
    if mdef.emb_mode == "row":
        return all_axes, None
    return model, (batch_axes if batch_axes else None)


# one source of truth for the device-major flattening rule
_combined_axis_index = dp.combined_axis_index


def validate_pipeline(mdef, mesh, microbatches: int) -> None:
    """Reject unsupported (emb_mode, idx_input, M) combinations with a
    clear error instead of silently mis-sharding."""
    if mdef.emb_mode not in ("row", "table"):
        raise ValueError(f"unknown emb_mode {mdef.emb_mode!r}; "
                         "expected 'row' or 'table'")
    if mdef.idx_input not in ("replicated", "sharded"):
        raise ValueError(f"unknown idx_input {mdef.idx_input!r}; "
                         "expected 'replicated' or 'sharded'")
    # unknown exchange_impl / wire dtype, flat-kwarg vs typed-config
    # conflicts, bad num_buckets — all fail here, loudly
    exchange_cfg.resolve_exchange(mdef)
    if microbatches < 1:
        raise ValueError(f"microbatches must be >= 1, got {microbatches}")
    ns = int(np.prod(list(mesh.shape.values())))
    if mdef.batch % (microbatches * ns):
        raise ValueError(
            f"global batch {mdef.batch} must be divisible by microbatches "
            f"* mesh size = {microbatches} * {ns}")
    hot_rows = int(getattr(mdef, "hot_rows", 0))
    if hot_rows < 0:
        raise ValueError(f"hot_rows must be >= 0, got {hot_rows}")
    # validated even with the cache off: a malformed 'deferred:' string
    # should fail at build time, not when hot_rows is finally turned on
    from repro.core import cache as hot_cache
    hot_cache.parse_hot_sync(getattr(mdef, "hot_sync", "allreduce"))
    if hot_rows > 0:
        if int(getattr(mdef, "promote_every", 1)) < 1:
            raise ValueError("promote_every must be >= 1, got "
                             f"{mdef.promote_every}")
        if hot_rows > mdef.spec.total_rows:
            raise ValueError(
                f"hot_rows {hot_rows} exceeds the unified row space "
                f"({mdef.spec.total_rows} rows)")
    row_optim.resolve(mdef)   # unknown sparse_optimizer fails here, loudly


# ---------------------------------------------------------------------------
# ppermute-chunked exchange (the "async" lowering of the index gather)
# ---------------------------------------------------------------------------

def _ring_all_gather_1d(x: jax.Array, axis_name) -> jax.Array:
    """Tiled all_gather over ONE named axis as ns-1 ppermute steps.  Output
    is bit-identical to ``jax.lax.all_gather(..., tiled=True)`` (pure data
    movement, no arithmetic), but each chunk is an independent op the
    scheduler can interleave with compute."""
    ns = compat.axis_size(axis_name)
    if ns == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    chunk = x.shape[0]
    out = jnp.zeros((ns * chunk,) + x.shape[1:], x.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, x, idx * chunk, axis=0)
    cur = x
    perm = [(i, (i + 1) % ns) for i in range(ns)]
    for k in range(1, ns):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        src = jnp.mod(idx - k, ns)          # after k shifts: chunk of idx-k
        out = jax.lax.dynamic_update_slice_in_dim(out, cur, src * chunk,
                                                  axis=0)
    return out


def ring_all_gather(x: jax.Array, axis_name) -> jax.Array:
    """Tiled all_gather over a (tuple of) mesh axes via ppermute rings,
    minor axis first — same block order as the fused collective."""
    axes = axis_name if isinstance(axis_name, (tuple, list)) else (axis_name,)
    for ax in reversed(tuple(axes)):
        x = _ring_all_gather_1d(x, ax)
    return x


def _exchange_collective(x: jax.Array, axis_name, impl: str) -> jax.Array:
    if impl == "ring":
        return ring_all_gather(x, axis_name)
    return jax.lax.all_gather(x, axis_name, axis=0, tiled=True)


# ---------------------------------------------------------------------------
# Stage construction
# ---------------------------------------------------------------------------

def build_stages(mdef, mesh, layout) -> PipelineStages:
    """Bind the model definition to the five pipeline stages.  All returned
    callables run INSIDE shard_map over the full mesh."""
    all_axes, model, batch_axes = mesh_axes(mesh)
    emb_ax, replica_ax = emb_axes(mdef, mesh)
    nb = (int(np.prod([mesh.shape[a] for a in batch_axes]))
          if batch_axes else 1)
    ex_cfg = exchange_cfg.resolve_exchange(mdef)
    impl = ex_cfg.impl
    B = mdef.batch
    fused = (jax.default_backend() == "tpu" if mdef.fused_update is None
             else mdef.fused_update)
    opt = row_optim.resolve(mdef)

    def exchange(idx_mb, fwd_only: bool = False):
        """Index stream: loader layout -> compute layout for one
        microbatch.  Returns (idx_fwd, idx_upd): the forward consumes
        ``idx_fwd``; the sparse update consumes ``idx_upd`` (the full
        microbatch in device-major order, matching dY_exchange).
        ``fwd_only`` (serve path) skips the update-side gather."""
        if mdef.emb_mode == "row":
            if mdef.idx_input == "sharded":
                g = _exchange_collective(idx_mb, emb_ax, impl)
                return g, g
            return idx_mb, idx_mb
        if mdef.idx_input == "sharded":
            # on-chip exchange replaces the replicated loader AND the
            # host-side permute_indices: gather the original-slot stream,
            # permute to padded-slot order, slice this shard's slots.
            full = _exchange_collective(idx_mb, all_axes, impl)
            padded = se.permute_indices(layout, full)     # [Bm, n_pad, P]
            K = layout.slots_per_shard
            m_idx = jax.lax.axis_index(model)
            idx_upd = jax.lax.dynamic_slice_in_dim(padded, m_idx * K, K,
                                                   axis=1)
            if nb > 1:
                c = idx_upd.shape[0] // nb
                d_idx = _combined_axis_index(batch_axes)
                idx_fwd = jax.lax.dynamic_slice_in_dim(idx_upd, d_idx * c,
                                                       c, axis=0)
            else:
                idx_fwd = idx_upd
            return idx_fwd, idx_upd
        # paper loader: padded-slot order, already model-sharded slots;
        # the update additionally needs every replica's batch rows.
        if fwd_only:
            return idx_mb, None
        idx_upd = (_exchange_collective(idx_mb, replica_ax, impl)
                   if replica_ax is not None else idx_mb)
        return idx_mb, idx_upd

    def embedding_fwd(W_fwd, idx_fwd, wgt_fwd=None):
        return se.sharded_bag_fwd(layout, W_fwd, idx_fwd, emb_ax, wgt_fwd)

    def dense_fwd_bwd(dense_hi, emb_out, batch_mb):
        def loss_fn(hi, e):
            return mdef.dense_loss(hi, e, batch_mb) / B
        loss, (g_dense, d_emb) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(dense_hi, emb_out)
        return loss, g_dense, d_emb

    def dY_exchange(d_emb, seed=None, tag=0):
        # seed = the per-step sr counter (None outside the train step,
        # e.g. the stage profiler — the dither then keys off step 0);
        # tag = the microbatch index, so no two payloads share a stream
        return se.gather_dY(layout, d_emb, emb_ax, replica_ax,
                            wire_dtype=ex_cfg.dY_dtype, seed=seed, tag=tag)

    def sparse_update(emb_store, idx_upd, dY, weights=None, presort=None,
                      seed=None):
        # ONE dispatcher for every registered RowOptimizer: the presorted
        # stream (repro/data/pipeline.py — no on-device sort, bag weights
        # baked into sorted_wgt) and the sorting scan/fused paths all go
        # through RowOptimizer.apply_sparse.  ``seed`` is the per-step
        # stochastic-rounding counter (state["sr"], present only when the
        # optimizer asked for one) — forwarded opaquely, so this stage
        # stays optimizer-agnostic.  NB: the fused fp32 kernels
        # pre-reduce duplicates (one rounding per row) where the sgd
        # reference scatter-adds per lookup, so those two paths are close
        # but not bit-identical; the split path is bitwise either way.
        return se.apply_update(layout, emb_store, opt, idx_upd, dY,
                               mdef.emb_lr, emb_ax, replica_axes=None,
                               fused=fused, weights=weights,
                               presort=presort, seed=seed)

    def dense_update(dense_state, g_dense, seed=None):
        st = dp.DPState(hi=dense_state["hi"], lo_shard=dense_state["lo"],
                        mom_shard=None, err_shard=dense_state["err"])
        st2 = dp.rs_ag_split_sgd(st, g_dense, mdef.lr, all_axes,
                                 wire_dtype=ex_cfg.dense_dtype,
                                 error_feedback=ex_cfg.error_feedback,
                                 num_buckets=ex_cfg.num_buckets, mean=False,
                                 seed=seed)
        return {"hi": st2.hi, "lo": st2.lo_shard, "err": st2.err_shard}

    ex_comm = ("all_gather(idx)" if mdef.idx_input == "sharded"
               or mdef.emb_mode == "table" else "none")
    fwd_comm = ("psum_scatter" if mdef.emb_mode == "row" else "all_to_all")
    return PipelineStages(
        index_exchange=Stage("index_exchange", exchange, comm=ex_comm),
        embedding_fwd=Stage("embedding_fwd", embedding_fwd, comm=fwd_comm),
        dense_fwd_bwd=Stage("dense_fwd_bwd", dense_fwd_bwd, comm="none"),
        dY_exchange=Stage("dY_exchange", dY_exchange,
                          comm=("all_gather(dY)" if mdef.emb_mode == "row"
                                else "all_to_all(dY)")),
        sparse_update=Stage("sparse_update", sparse_update, comm="none"),
        dense_update=Stage("dense_update", dense_update, comm="rs+ag"),
    )


# ---------------------------------------------------------------------------
# Microbatch slicing and stream-order restoration
# ---------------------------------------------------------------------------

def _slice_local(v: jax.Array, i: int, M: int) -> jax.Array:
    c = v.shape[0] // M
    return jax.lax.slice_in_dim(v, i * c, (i + 1) * c, axis=0)


def _slice_idx(idx, i: int, M: int, mdef, repl_width: int):
    """Microbatch i of the index stream.  Batch-sharded streams slice the
    local share contiguously; REPLICATED streams take the matching strided
    selection (device-major ``[width, M, c]`` at ``[:, i]``) so the bag
    output of the microbatch lands on the rows whose dense features each
    device already holds."""
    if M == 1:
        return idx
    if mdef.idx_input == "sharded":
        return _slice_local(idx, i, M)
    Bl = idx.shape[0]
    c = Bl // (repl_width * M)
    r = idx.reshape((repl_width, M, c) + idx.shape[1:])
    return r[:, i].reshape((repl_width * c,) + idx.shape[1:])


def _interleave_perm(B: int, M: int, ns: int) -> np.ndarray:
    """Static permutation restoring the concatenated per-microbatch update
    stream (order: microbatch-major ``(i, device, j)``) to the full-batch
    device-major order ``(device, i, j)`` the M=1 step sees."""
    c = B // (M * ns)
    return np.arange(B).reshape(M, ns, c).transpose(1, 0, 2).reshape(-1)


# ---------------------------------------------------------------------------
# The pipelined step factory
# ---------------------------------------------------------------------------

def make_pipelined_train_step(mdef, mesh, microbatches: int = 1):
    """Build the staged, microbatched hybrid-parallel train step.

    ``microbatches=1`` composes the stages back into exactly the legacy
    monolithic step (bit-identical outputs).  ``microbatches=M`` splits the
    global batch into M microbatches, double-buffers the index exchange
    (microbatch i+1's collective is issued while microbatch i computes),
    accumulates dense gradients across microbatches into a single RS+AG,
    and applies ONE sparse update on the order-restored concatenated
    stream.

    Returns (jitted step, state shardings, batch specs, layout) — the same
    contract as the legacy ``make_train_step``.
    """
    from repro.core import hybrid  # deferred: hybrid imports this module

    M = int(microbatches)
    validate_pipeline(mdef, mesh, M)
    structs, specs, shardings, layout = hybrid.state_struct(mdef, mesh)
    bstructs, bspecs = hybrid.batch_struct(mdef, mesh, layout)
    all_axes, model, batch_axes = mesh_axes(mesh)
    ns = int(np.prod(list(mesh.shape.values())))
    nm = mesh.shape[model]
    stages = build_stages(mdef, mesh, layout)
    # replicated index streams carry the device-major layout of the axes
    # the stream is replicated over: the full mesh in row mode, the model
    # axis in table mode (the batch dim is already sharded over the rest).
    repl_width = ns if mdef.emb_mode == "row" else nm
    perm = (jnp.asarray(_interleave_perm(mdef.batch, M, ns))
            if M > 1 else None)
    weighted = getattr(mdef, "weighted", False)
    presorted = getattr(mdef, "host_presort", False)
    opt = row_optim.resolve(mdef)
    emb_ax, _ = emb_axes(mdef, mesh)
    cache_on = int(getattr(mdef, "hot_rows", 0)) > 0
    # the exact forward bypass needs every bag computed whole by ONE
    # shard and the rank's own index slice available locally: table mode
    # with the on-chip index exchange.  Row mode's psum_scatter folds
    # arithmetic INTO the collective, so a bypass there could not be
    # bitwise; the cache still maintains counters / hot set (and serves
    # the bench model), it just cannot substitute bags.
    bypass = (cache_on and mdef.emb_mode == "table"
              and mdef.idx_input == "sharded")
    if cache_on:
        from repro.core import cache as hot_cache
    metrics_on = bool(getattr(mdef, "step_metrics", False))
    if metrics_on:
        from repro.telemetry import metrics as step_mx

    def step_local(state, batch):
        emb_store = state["emb"]
        W_fwd = opt.fwd_weights(emb_store)
        dense_hi = state["dense"]["hi"]
        # per-step stochastic-rounding seed: a replicated int32 counter in
        # the train state (present when the optimizer registered
        # stochastic_round=True OR a 'bf16_sr' wire format is configured),
        # consumed by the epilogue sparse_update and the bf16_sr wire
        # encoders, incremented once per step — so resume-from-checkpoint
        # replays the exact dither sequence, state AND wire.
        sr = state.get("sr")
        # host-pre-sorted update stream: each shard's [1, L] block of the
        # psort_* batch fields (leading dim = combined mesh index, the
        # same device-major order the restored idx stream carries).  The
        # fields describe the FULL batch, so they bypass microbatching
        # and feed the single epilogue sparse_update.
        presort = (tuple(batch[k][0] for k in PSORT_KEYS)
                   if presorted else None)

        def microbatch(i):
            items = ((k, v) for k, v in batch.items()
                     if k not in PSORT_KEYS)
            if M == 1:
                return dict(items)
            # weights ride the exact layout of idx -> same slicing rule
            return {k: (_slice_idx(v, i, M, mdef, repl_width)
                        if k in ("idx", "weights")
                        else _slice_local(v, i, M))
                    for k, v in items}

        # -- prologue: microbatch 0's index exchange ----------------------
        ex = [None] * M
        exw = [None] * M
        ex[0] = stages.index_exchange(microbatch(0)["idx"])
        if weighted:
            # the weight stream undergoes the IDENTICAL layout switch
            exw[0] = stages.index_exchange(microbatch(0)["weights"])

        loss_acc = None
        g_acc = None
        idx_parts, dY_parts, wgt_parts = [], [], []
        for i in range(M):
            if i + 1 < M:
                # double buffer: issue microbatch i+1's exchange BEFORE
                # microbatch i's compute — no data dependence between the
                # two, so the scheduler can overlap collective and compute.
                ex[i + 1] = stages.index_exchange(microbatch(i + 1)["idx"])
                if weighted:
                    exw[i + 1] = stages.index_exchange(
                        microbatch(i + 1)["weights"])
            idx_fwd, idx_upd = ex[i]
            wgt_fwd, wgt_upd = exw[i] if weighted else (None, None)
            emb_out = stages.embedding_fwd(W_fwd, idx_fwd, wgt_fwd)
            mb = microbatch(i)
            if bypass:
                # hot-row cache: bags whose lookups ALL hit the
                # replicated hot slab are recomputed from the rank's OWN
                # index slice with the owner's exact bag arithmetic and
                # substituted — those bags no longer depend on the
                # all-to-all payload.  The cold-store update below is
                # unchanged (write-through), so under hot_sync=
                # 'allreduce' this is bitwise invisible.
                cache = state["cache"]
                hit, hot_bag = hot_cache.hot_bag_local(
                    layout, cache["hot_w"], cache["hot_pos"], mb["idx"],
                    mb.get("weights") if weighted else None)
                emb_out = jnp.where(hit[..., None], hot_bag, emb_out)
            loss, g_dense, d_emb = stages.dense_fwd_bwd(
                dense_hi, emb_out, mb)
            dY = stages.dY_exchange(d_emb, seed=sr, tag=i)
            loss_acc = loss if loss_acc is None else loss_acc + loss
            g_acc = (g_dense if g_acc is None
                     else jax.tree.map(jnp.add, g_acc, g_dense))
            idx_parts.append(idx_upd)
            dY_parts.append(dY)
            if weighted:
                wgt_parts.append(wgt_upd)

        # -- epilogue: one sparse update on the order-restored stream -----
        def restore(parts):
            if M == 1:
                return parts[0]
            return jnp.take(jnp.concatenate(parts, axis=0), perm, axis=0)

        idx_full, dY_full = restore(idx_parts), restore(dY_parts)
        wgt_full = restore(wgt_parts) if weighted else None
        new_emb = stages.sparse_update(emb_store, idx_full, dY_full,
                                       weights=wgt_full, presort=presort,
                                       seed=sr)
        new_dense = stages.dense_update(state["dense"], g_acc, seed=sr)
        new_state = {"emb": new_emb, "dense": new_dense}
        if sr is not None:
            new_state["sr"] = sr + jnp.asarray(1, sr.dtype)
        if cache_on:
            # cache epilogue: promotion + mirror refresh read the POST-
            # update store, so an 'allreduce' mirror equals the cold
            # store entering the next step.
            new_state["cache"] = hot_cache.step_cache(
                mdef, layout, opt, state["cache"], new_emb, emb_ax)
        if metrics_on:
            # metrics epilogue: accumulate this step's counters into the
            # replicated state["metrics"] vector.  Reads only the raw
            # index stream and the PRE-step hot set — the same inputs
            # the forward consumed — and writes only its own slot, so
            # the training outputs are untouched (and with step_metrics
            # off, none of this exists in the lowered program).
            idx_raw = batch["idx"]
            if mdef.idx_input == "sharded":
                # batch-sharded original-slot stream: every rank counts
                # its own disjoint slice, psum makes it global
                rows = jax.lax.psum(
                    step_mx.valid_lookups(layout, idx_raw), all_axes)
            elif mdef.emb_mode == "row":
                # replicated stream: the local count IS the global count
                rows = step_mx.valid_lookups(layout, idx_raw)
            else:
                # paper loader, table mode: padded-slot stream, slots
                # sharded over 'model', batch over the rest — disjoint
                # (row, slot) cells, so psum over everything is global
                rows = jax.lax.psum(
                    step_mx.valid_lookups_padded(layout, idx_raw, model),
                    all_axes)
            if bypass:
                hl, hb = step_mx.cache_hit_counts(
                    layout, state["cache"]["hot_pos"], idx_raw)
                hit_lookups = jax.lax.psum(hl, all_axes)
                skipped = jax.lax.psum(hb, all_axes)
            else:
                hit_lookups = jnp.float32(0)
                skipped = jnp.float32(0)
            bags = jnp.float32(mdef.batch * layout.num_orig_slots)
            payload = (bags - skipped) * jnp.float32(mdef.spec.dim * 4)
            new_state["metrics"] = state["metrics"] + step_mx.pack(
                steps=1.0, hit_lookups=hit_lookups, skipped_bags=skipped,
                bags=bags, rows_touched=rows,
                exchange_payload_bytes=payload)
        return new_state, jax.lax.psum(loss_acc, all_axes)

    step = compat.shard_map(step_local, mesh=mesh, in_specs=(specs, bspecs),
                            out_specs=(specs, P()), check_vma=False)
    return jax.jit(step, donate_argnums=(0,)), shardings, bspecs, layout
