"""Hybrid-parallel embedding (paper contribution C3) as shard_map-inner ops.

The model side addresses the embedding through SLOTS: the index array is
``[B, S_slots, P]`` and each slot maps to a table via ``slot_to_table``
(identity by default).  Slot sharing is how sequence models reuse one item
table across positions (BST/SASRec/DIN) — updates from all slots of a table
accumulate into the same rows.

Two model-parallel placements over the unified row space of
:class:`repro.core.embedding.EmbeddingSpec`:

``table`` (paper-faithful)
    Tables are greedy-bin-packed onto the ``model`` axis (paper IV-B: "we
    simply distribute tables across available ranks").  Each shard computes
    full-batch bags for its own slots, then ONE fused
    ``jax.lax.all_to_all`` switches model->data parallel layout before the
    interaction — the end state of the paper's ScatterList -> Fused Scatter ->
    Alltoall hillclimb.  Max model-parallel width = number of tables
    (paper Tab. II "Maximum ranks to scale").

``row`` (beyond-paper)
    Every shard owns a contiguous row-range of ALL tables — the TPU-native
    generalization of the race-free update (Alg. 4): ownership is the
    partition.  Forward = masked local partial bags + ``psum_scatter`` (the
    all-to-all and the bag reduction fuse into one reduce-scatter); width is
    unbounded by the table count, which is what 1000+ node meshes need.

Both modes expose:
    fwd:     idx (+ local weight shard) -> [B_mp, S, E] batch-sharded output
    update:  dY [B_mp, S, E] -> new local weight shard (fused bwd+optimizer,
             contribution C1 — no dense dW is ever materialized)

All functions are designed to run INSIDE ``jax.shard_map``; ``axis_name`` is
the model axis (possibly a tuple of axes).  ``B`` below is the per-data-shard
batch; the fwd output is further batch-split over the model axis
(B_mp = B / num_shards), so the dense net downstream is data-parallel over
every mesh axis, exactly like the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import EmbeddingSpec, _round_up


@dataclasses.dataclass(frozen=True)
class ShardedEmbeddingLayout:
    """Static placement of a unified embedding space over ``num_shards``."""

    spec: EmbeddingSpec
    num_shards: int
    mode: str                      # "row" | "table"
    rows_per_shard: int
    slot_to_table: np.ndarray      # [S_slots] table id per model slot
    # row mode: global row offset per SLOT:
    row_offsets: Optional[np.ndarray] = None
    # table mode:
    slots_per_shard: int = 0
    # padded (bin-major) slot order; -1 for dummy:
    padded_slots: Optional[np.ndarray] = None   # [num_shards*slots_per_shard]
    # row offset (relative to shard start) per padded position:
    slot_local_offsets: Optional[np.ndarray] = None
    # original slot -> padded position:
    slot_position: Optional[np.ndarray] = None

    @property
    def total_rows(self) -> int:
        return self.num_shards * self.rows_per_shard

    @property
    def num_orig_slots(self) -> int:
        return len(self.slot_to_table)

    @property
    def num_padded_slots(self) -> int:
        return self.num_shards * self.slots_per_shard


def make_layout(spec: EmbeddingSpec, num_shards: int, mode: str = "row",
                slot_to_table=None) -> ShardedEmbeddingLayout:
    s2t = (np.arange(spec.num_tables, dtype=np.int64)
           if slot_to_table is None
           else np.asarray(slot_to_table, dtype=np.int64))
    if mode == "row":
        rows = _round_up(spec.total_rows,
                         num_shards * spec.row_pad) // num_shards
        return ShardedEmbeddingLayout(
            spec=spec, num_shards=num_shards, mode="row",
            rows_per_shard=rows, slot_to_table=s2t,
            row_offsets=spec.row_offsets[s2t])
    if mode != "table":
        raise ValueError(f"unknown mode {mode!r}")
    bins = spec.binpack_tables(num_shards)   # tables -> bins (may be empty)
    padded = spec.padded_rows
    # bin-local row offset per table
    table_bin = np.zeros(spec.num_tables, np.int64)
    table_off = np.zeros(spec.num_tables, np.int64)
    max_bin_rows = 0
    for b, tables in enumerate(bins):
        off = 0
        for t in tables:
            table_bin[t] = b
            table_off[t] = off
            off += int(padded[t])
        max_bin_rows = max(max_bin_rows, off)
    # +row_pad spare guarantees a scratch row for dummy slots on every shard.
    rows_per_shard = _round_up(max_bin_rows + spec.row_pad, spec.row_pad)
    # group SLOTS by their table's bin
    slots_by_bin: list[list[int]] = [[] for _ in range(num_shards)]
    for s, t in enumerate(s2t):
        slots_by_bin[table_bin[t]].append(s)
    slots_per_shard = max(1, max(len(g) for g in slots_by_bin))
    n_pad = num_shards * slots_per_shard
    padded_slots = np.full(n_pad, -1, np.int64)
    local_off = np.full(n_pad, rows_per_shard - 1, np.int64)  # dummies
    slot_position = np.zeros(len(s2t), np.int64)
    for b, group in enumerate(slots_by_bin):
        for j, s in enumerate(group):
            p = b * slots_per_shard + j
            padded_slots[p] = s
            local_off[p] = table_off[s2t[s]]
            slot_position[s] = p
    return ShardedEmbeddingLayout(
        spec=spec, num_shards=num_shards, mode="table",
        rows_per_shard=rows_per_shard, slot_to_table=s2t,
        slots_per_shard=slots_per_shard, padded_slots=padded_slots,
        slot_local_offsets=local_off, slot_position=slot_position)


def permute_indices(layout: ShardedEmbeddingLayout, idx: jax.Array
                    ) -> jax.Array:
    """[B, S, P] original-slot indices -> [B, num_padded_slots, P] padded
    order (table mode).  Dummy slots read index 0 (the scratch row)."""
    assert layout.mode == "table"
    src = np.where(layout.padded_slots >= 0, layout.padded_slots, 0)
    out = jnp.take(idx, jnp.asarray(src), axis=1)
    dummy = jnp.asarray((layout.padded_slots < 0))[None, :, None]
    return jnp.where(dummy, 0, out)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _partial_bag_masked(W_local: jax.Array, local_rows: jax.Array,
                        valid: jax.Array,
                        weights: Optional[jax.Array] = None) -> jax.Array:
    rows = jnp.take(W_local, jnp.clip(local_rows, 0, W_local.shape[0] - 1),
                    axis=0).astype(jnp.float32)
    if weights is not None:
        # weighted bag: Y = sum_p w_p * W[g_p].  w == 1.0 multiplies
        # exactly, so an all-ones weight stream keeps the unweighted
        # bit-identity contract.
        rows = rows * weights[..., None].astype(jnp.float32)
    rows = jnp.where(valid[..., None], rows, 0.0)
    return rows.sum(axis=2)  # [B, S, E] fp32


def _batch_chunks(B: int, S: int, P: int, E: int,
                  budget_bytes: int | None = None) -> int:
    """Pick a batch-chunk count so the transient [chunk,S,P,E] fp32 gather
    stays under ``budget_bytes`` (paper configs reach P=100: the unchunked
    expansion would be tens of GB).  REPRO_EMB_CHUNK_BUDGET overrides (the
    roofline cost builds disable chunking so cost_analysis sees one body)."""
    import os as _os
    if budget_bytes is None:
        budget_bytes = int(_os.environ.get("REPRO_EMB_CHUNK_BUDGET",
                                           128 * 2**20))
    per_row = S * P * E * 4
    chunk = max(1, budget_bytes // max(per_row, 1))
    if chunk >= B:
        return 1
    n = (B + chunk - 1) // chunk
    while B % n:  # need uniform chunks for lax.scan
        n += 1
    return n


def row_sharded_bag_fwd(layout: ShardedEmbeddingLayout, W_local: jax.Array,
                        idx: jax.Array, axis_name,
                        weights: Optional[jax.Array] = None) -> jax.Array:
    """Row mode forward.  ``axis_name`` may be a TUPLE of mesh axes — the
    production config shards the row space over the FULL mesh (the paper's
    pure model-parallel embedding, scaled past the table count).  ``idx``
    [B, S, P] is replicated over ``axis_name``; ``weights`` [B, S, P]
    optional per-lookup bag weights (same layout as ``idx``); output is
    [B/num_shards, S, E] (reduce-scatter over the batch dim).

    The gather+bag is scanned over batch chunks so the [chunk,S,P,E]
    transient stays bounded for large pooling factors."""
    g = idx + jnp.asarray(layout.row_offsets, idx.dtype)[None, :, None]
    start = jax.lax.axis_index(axis_name) * layout.rows_per_shard
    local = g - start
    B, S, P = idx.shape
    E = W_local.shape[1]
    n = _batch_chunks(B, S, P, E)
    if n == 1:
        valid = (local >= 0) & (local < layout.rows_per_shard)
        part = _partial_bag_masked(W_local, local, valid, weights)
    else:
        def body(_, inp):
            loc_c = inp[0]
            w_c = inp[1] if weights is not None else None
            valid = (loc_c >= 0) & (loc_c < layout.rows_per_shard)
            return None, _partial_bag_masked(W_local, loc_c, valid, w_c)
        xs = (local.reshape(n, B // n, S, P),)
        if weights is not None:
            xs += (weights.reshape(n, B // n, S, P),)
        _, part = jax.lax.scan(body, None, xs)
        part = part.reshape(B, S, E)
    # bf16 wire (HC3): the reduce-scatter is the dominant collective of the
    # hybrid step and the bag output feeds a bf16 dense net anyway.
    part = part.astype(jnp.bfloat16)
    return jax.lax.psum_scatter(part, axis_name, scatter_dimension=0,
                                tiled=True).astype(jnp.float32)


def table_sharded_bag_fwd(layout: ShardedEmbeddingLayout, W_local: jax.Array,
                          idx_slots_local: jax.Array, axis_name,
                          weights: Optional[jax.Array] = None
                          ) -> jax.Array:
    """Table mode forward.  ``idx_slots_local`` [B, slots_per_shard, P] is
    the padded-slot index array already sharded over the model axis;
    ``weights`` optional per-lookup bag weights in the same layout.  Output
    is [B/num_shards, S_orig, E] in ORIGINAL slot order."""
    K = layout.slots_per_shard
    shard = jax.lax.axis_index(axis_name)
    off_all = jnp.asarray(layout.slot_local_offsets).reshape(
        layout.num_shards, K)
    local = idx_slots_local + jax.lax.dynamic_index_in_dim(
        off_all, shard, axis=0, keepdims=False)[None, :, None]
    B, _, P = local.shape
    E = W_local.shape[1]
    n = _batch_chunks(B, K, P, E)

    def bag(loc, w=None):
        rows = jnp.take(W_local, jnp.clip(loc, 0, W_local.shape[0] - 1),
                        axis=0).astype(jnp.float32)
        if w is not None:
            rows = rows * w[..., None].astype(jnp.float32)
        return rows.sum(axis=2)

    if n == 1:
        part = bag(local, weights)               # [B, K, E] full local batch
    else:
        xs = (local.reshape(n, B // n, K, P),)
        if weights is not None:
            xs += (weights.reshape(n, B // n, K, P),)
        _, part = jax.lax.scan(
            lambda c, inp: (None, bag(inp[0], inp[1] if weights is not None
                                      else None)), None, xs)
        part = part.reshape(B, K, E)
    out = jax.lax.all_to_all(part, axis_name, split_axis=0, concat_axis=1,
                             tiled=True)         # [B/ns, num_padded, E]
    # back to original slot order (drop dummy slots):
    return jnp.take(out, jnp.asarray(layout.slot_position), axis=1)


def sharded_bag_fwd(layout: ShardedEmbeddingLayout, W_local: jax.Array,
                    idx_local: jax.Array, axis_name,
                    weights: Optional[jax.Array] = None) -> jax.Array:
    if layout.mode == "row":
        return row_sharded_bag_fwd(layout, W_local, idx_local, axis_name,
                                   weights)
    return table_sharded_bag_fwd(layout, W_local, idx_local, axis_name,
                                 weights)


def row_bag_fwd_replicated(layout: ShardedEmbeddingLayout, W_local, idx,
                           axis_name) -> jax.Array:
    """Row-mode bag with a REPLICATED [B, S, E] output (psum instead of
    reduce-scatter).  Used when B < num_shards, e.g. the retrieval step's
    single query."""
    local, valid = _local_rows(layout, idx, axis_name)
    part = _partial_bag_masked(W_local, local, valid)
    return jax.lax.psum(part, axis_name)


# ---------------------------------------------------------------------------
# Fused backward + update (sparse optimizer; C1)
# ---------------------------------------------------------------------------

def _local_rows(layout: ShardedEmbeddingLayout, idx_local: jax.Array,
                axis_name) -> tuple[jax.Array, jax.Array]:
    """(local_row [B,S,P], valid [B,S,P]) for this shard, either mode."""
    if layout.mode == "row":
        g = idx_local + jnp.asarray(layout.row_offsets,
                                    idx_local.dtype)[None, :, None]
        start = jax.lax.axis_index(axis_name) * layout.rows_per_shard
        local = g - start
        valid = (local >= 0) & (local < layout.rows_per_shard)
        return local, valid
    K = layout.slots_per_shard
    shard = jax.lax.axis_index(axis_name)
    off_all = jnp.asarray(layout.slot_local_offsets).reshape(
        layout.num_shards, K)
    local = idx_local + jax.lax.dynamic_index_in_dim(
        off_all, shard, axis=0, keepdims=False)[None, :, None]
    valid = jnp.ones(local.shape, bool)
    return local, valid


def gather_dY(layout: ShardedEmbeddingLayout, dY_mp: jax.Array, axis_name,
              replica_axes=None) -> jax.Array:
    """Bring the batch-model-sharded cotangent dY [B/ns, S, E] back to the
    layout each shard scatters from: row mode all-gathers the batch over the
    model axes; table mode inverse-all_to_alls to [B, K, E] padded-slot order
    (plus an optional replica gather over the data axes)."""
    if layout.mode == "row":
        return jax.lax.all_gather(dY_mp.astype(jnp.bfloat16), axis_name,
                                  axis=0, tiled=True).astype(jnp.float32)
    src = np.where(layout.padded_slots >= 0, layout.padded_slots, 0)
    dY_slots = jnp.take(dY_mp, jnp.asarray(src), axis=1)
    dummy = jnp.asarray(layout.padded_slots < 0)[None, :, None]
    dY_slots = jnp.where(dummy, 0.0, dY_slots)
    dY_local = jax.lax.all_to_all(dY_slots, axis_name, split_axis=1,
                                  concat_axis=0, tiled=True)
    if replica_axes is not None:
        dY_local = jax.lax.all_gather(dY_local, replica_axes, axis=0,
                                      tiled=True)
    return dY_local


def apply_rows_sgd(W_local: jax.Array, tgt: jax.Array, grad: jax.Array,
                   lr) -> jax.Array:
    """Plain scatter-add SGD on local rows (duplicates accumulate) —
    Alg. 3 with XLA's deterministic scatter supplying the atomicity."""
    return W_local.at[tgt].add((-lr * grad).astype(W_local.dtype))


def apply_update_scan(layout: ShardedEmbeddingLayout, W_local, idx_local,
                      dY: jax.Array, lr, axis_name, split: bool = False,
                      replica_axes=None, fused: bool = False,
                      weights: Optional[jax.Array] = None):
    """Fused sparse bwd+SGD, scanned over batch chunks (bounded transients;
    paper configs reach P=100 where the naive [B,S,P,E] expansion is tens
    of GB).

    ``W_local``: [rows, E] array, or a (hi, lo) pair when ``split``.
    ``idx_local``: [B, S_or_K, P]; ``dY``: matching [B, S_or_K, E] (already
    passed through :func:`gather_dY`).  ``weights``: optional [B, S_or_K,
    P] per-lookup bag weights in the same layout as ``idx_local`` (the
    weighted-bag cotangent is ``w * dY``).  In table mode with replica
    axes the index (and weight) arrays are gathered the same way as dY.

    ``fused=True`` routes each chunk through the Pallas fused kernel
    (:mod:`repro.kernels.embedding_update`): the [cb,S,P,E] gradient
    expansion is never built (the kernel reads dY rows by bag id), duplicate
    rows are pre-reduced in VMEM, and the shard is updated in place on the
    touched rows only.  Split results are bit-identical to the reference."""
    if layout.mode == "table" and replica_axes is not None:
        idx_local = jax.lax.all_gather(idx_local, replica_axes, axis=0,
                                       tiled=True)
        if weights is not None:
            weights = jax.lax.all_gather(weights, replica_axes, axis=0,
                                         tiled=True)
    local, valid = _local_rows(layout, idx_local, axis_name)
    B, S, P = local.shape
    E = dY.shape[-1]
    n = _batch_chunks(B, S, P, E)
    cb = B // n

    def chunk_update(W, loc_c, val_c, dY_c, wgt_c=None):
        if fused:
            from repro.kernels import ops
            tgt = loc_c.reshape(-1)
            val = val_c.reshape(-1)
            dYr = dY_c.reshape(cb * S, E)
            w = None if wgt_c is None else wgt_c.reshape(-1)
            if split:
                hi, lo = W
                return ops.fused_embedding_update(hi, lo, tgt, dYr, lr,
                                                  valid=val, weights=w,
                                                  pooling=P)
            return ops.fused_embedding_update_fp32(W, tgt, dYr, lr,
                                                   valid=val, weights=w,
                                                   pooling=P)
        grad = jnp.broadcast_to(dY_c[:, :, None, :],
                                (cb, S, P, E)).astype(jnp.float32)
        if wgt_c is not None:
            grad = grad * wgt_c[..., None].astype(jnp.float32)
        grad = jnp.where(val_c[..., None], grad, 0.0).reshape(-1, E)
        tgt = jnp.where(val_c, loc_c, 0).reshape(-1)
        if split:
            hi, lo = W
            return apply_rows_split_sgd(hi, lo, tgt, grad, lr)
        return apply_rows_sgd(W, tgt, grad, lr)

    if n == 1:
        return chunk_update(W_local, local, valid, dY, weights)

    def body(W, inp):
        return chunk_update(W, *inp), None

    xs = (local.reshape(n, cb, S, P), valid.reshape(n, cb, S, P),
          dY.reshape(n, cb, S, E))
    if weights is not None:
        xs += (weights.reshape(n, cb, S, P),)
    W_out, _ = jax.lax.scan(body, W_local, xs)
    return W_out


def apply_update_presorted(layout: ShardedEmbeddingLayout, W_local,
                           presort: tuple, dY: jax.Array, lr,
                           split: bool = False):
    """Sparse bwd+SGD on a HOST-PRE-SORTED lookup stream — the fast path
    fed by ``repro.data.pipeline.presort_batch`` (row mode).

    ``presort``: this shard's ``(sorted_rows, sorted_bags, sorted_msk,
    sorted_wgt)`` [L] arrays (bag weights, if any, are already baked into
    ``sorted_wgt``).  ``dY``: [B, S, E] full-batch cotangent from
    :func:`gather_dY`.  Always the fused Pallas kernel — nothing to sort
    and only scalars were shipped, so no batch chunking is needed (the
    kernel never builds a [B,S,P,E] expansion).  Bit-identical to the
    sorting path whenever that path runs unchunked (``_batch_chunks`` ==
    1); a chunked reference applies per-chunk partial updates whose
    per-row rounding differs from the single pre-reduction here."""
    srows, sbags, smsk, swgt = presort
    from repro.kernels import ops
    E = dY.shape[-1]
    dYr = dY.reshape(-1, E)
    if split:
        hi, lo = W_local
        return ops.fused_embedding_update_presorted(hi, lo, srows, sbags,
                                                    smsk, swgt, dYr, lr)
    return ops.fused_embedding_update_fp32_presorted(W_local, srows, sbags,
                                                     smsk, swgt, dYr, lr)


def row_grad_rows(layout: ShardedEmbeddingLayout, idx: jax.Array,
                  dY_mp: jax.Array, axis_name
                  ) -> tuple[jax.Array, jax.Array]:
    """Row mode (unchunked; tests / small configs): all-gather dY over the
    model axes (mirror of the fwd reduce-scatter), mask to OWNED rows —
    Alg. 4 as a sharding rule.  Returns (tgt [n], grad [n, E])."""
    dY = jax.lax.all_gather(dY_mp, axis_name, axis=0, tiled=True)
    local, valid = _local_rows(layout, idx, axis_name)
    B, S, P = idx.shape
    E = dY.shape[-1]
    grad = jnp.broadcast_to(dY[:, :, None, :], (B, S, P, E)
                            ).astype(jnp.float32)
    grad = jnp.where(valid[..., None], grad, 0.0)
    tgt = jnp.where(valid, local, 0).reshape(-1)
    return tgt, grad.reshape(-1, E)


def table_grad_rows(layout: ShardedEmbeddingLayout, idx_slots_local,
                    dY_mp: jax.Array, axis_name
                    ) -> tuple[jax.Array, jax.Array]:
    """Table mode (unchunked; tests / small configs)."""
    dY_local = gather_dY(layout, dY_mp, axis_name)
    local, valid = _local_rows(layout, idx_slots_local, axis_name)
    B, K, P = local.shape
    E = dY_local.shape[-1]
    grad = jnp.broadcast_to(dY_local[:, :, None, :], (B, K, P, E))
    tgt = jnp.clip(local, 0, layout.rows_per_shard - 1).reshape(-1)
    return tgt, grad.astype(jnp.float32).reshape(-1, E)


def grad_rows(layout: ShardedEmbeddingLayout, idx_local: jax.Array,
              dY_mp: jax.Array, axis_name) -> tuple[jax.Array, jax.Array]:
    if layout.mode == "row":
        return row_grad_rows(layout, idx_local, dY_mp, axis_name)
    return table_grad_rows(layout, idx_local, dY_mp, axis_name)


def replicate_grad_rows(tgt: jax.Array, grad: jax.Array, replica_axes
                        ) -> tuple[jax.Array, jax.Array]:
    """Table mode on a 2D+ mesh replicates each table shard over the data
    axes; every replica must apply the updates of ALL replicas to stay
    consistent.  All-gathers the sparse (tgt, grad) row lists over
    ``replica_axes`` — the paper-noted cost of table-wise placement on wide
    meshes (row mode avoids it entirely)."""
    tgt_all = jax.lax.all_gather(tgt, replica_axes, axis=0, tiled=True)
    grad_all = jax.lax.all_gather(grad, replica_axes, axis=0, tiled=True)
    return tgt_all, grad_all


# ---------------------------------------------------------------------------
# Split-SGD-BF16 sparse row update (contribution C5 on the sparse path).
# Gather-modify-scatter needs duplicate indices PRE-REDUCED (unlike
# scatter-add); the reference path dedups with a sort + run-length
# segment-sum, then applies an exact fp32 update on the touched rows — but
# its functional scatter still copies the whole (hi, lo) shard every step.
# The fused Pallas path (repro.kernels.embedding_update, ``fused=True``
# here and in apply_update_scan) moves the dedup accumulation into VMEM and
# updates the shard in place: bytes/step drops from O(shard_rows) to
# O(unique_touched_rows) — see the table in that module's docstring and
# benchmarks/bench_split_sgd.py for the roofline numbers.  Outputs are
# bit-identical between the two paths (tests/test_embedding_update.py).
# ---------------------------------------------------------------------------

def dedup_rows(tgt: jax.Array, upd: jax.Array, num_rows: int
               ) -> tuple[jax.Array, jax.Array]:
    """Sum duplicate targets.  Returns (rep [n], summed [n, E]); positions
    for empty run segments get rep == num_rows (out of bounds -> the
    subsequent scatter DROPS them, JAX's default OOB-scatter mode)."""
    order = jnp.argsort(tgt)
    sg = jnp.take(tgt, order)
    su = jnp.take(upd, order, axis=0)
    newseg = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              (sg[1:] != sg[:-1]).astype(jnp.int32)])
    uid = jnp.cumsum(newseg)
    n = tgt.shape[0]
    summed = jax.ops.segment_sum(su, uid, num_segments=n)
    rep = jnp.full((n,), num_rows, dtype=sg.dtype).at[uid].min(sg)
    return rep, summed


def apply_rows_split_sgd(hi: jax.Array, lo: jax.Array, tgt: jax.Array,
                         grad: jax.Array, lr, fused: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """Exact-fp32 sparse SGD on split-bf16 storage (see
    repro.optim.split_sgd).  ``tgt`` may contain duplicates.

    ``fused=False`` (reference): segment_sum the per-row gradients, gather
    the touched rows, combine/step/split, and scatter back — the functional
    scatter copies the whole shard.  ``fused=True``: one Pallas pass
    (:mod:`repro.kernels.embedding_update`) that pre-reduces duplicates in
    VMEM and rewrites only the touched rows in place; bit-identical output."""
    if fused:
        from repro.kernels import ops
        return ops.fused_embedding_update(hi, lo, tgt, grad, lr, pooling=1)
    from repro.optim.split_sgd import combine_split, split_fp32
    rep, summed = dedup_rows(tgt, grad, hi.shape[0])
    safe = jnp.minimum(rep, hi.shape[0] - 1)   # gather side must be in-bounds
    h = jnp.take(hi, safe, axis=0)
    l = jnp.take(lo, safe, axis=0)
    w32 = combine_split(h, l)
    w32 = w32 - lr * summed
    nh, nl = split_fp32(w32)
    # rep == num_rows rows (empty segments) are dropped by the scatter.
    return hi.at[rep].set(nh), lo.at[rep].set(nl)
