"""Hybrid-parallel embedding (paper contribution C3) as shard_map-inner ops.

The model side addresses the embedding through SLOTS: the index array is
``[B, S_slots, P]`` and each slot maps to a table via ``slot_to_table``
(identity by default).  Slot sharing is how sequence models reuse one item
table across positions (BST/SASRec/DIN) — updates from all slots of a table
accumulate into the same rows.

Two model-parallel placements over the unified row space of
:class:`repro.core.embedding.EmbeddingSpec`:

``table`` (paper-faithful)
    Tables are greedy-bin-packed onto the ``model`` axis (paper IV-B: "we
    simply distribute tables across available ranks").  Each shard computes
    full-batch bags for its own slots, then ONE fused
    ``jax.lax.all_to_all`` switches model->data parallel layout before the
    interaction — the end state of the paper's ScatterList -> Fused Scatter ->
    Alltoall hillclimb.  Max model-parallel width = number of tables
    (paper Tab. II "Maximum ranks to scale").

``row`` (beyond-paper)
    Every shard owns a contiguous row-range of ALL tables — the TPU-native
    generalization of the race-free update (Alg. 4): ownership is the
    partition.  Forward = masked local partial bags + ``psum_scatter`` (the
    all-to-all and the bag reduction fuse into one reduce-scatter); width is
    unbounded by the table count, which is what 1000+ node meshes need.

Both modes expose:
    fwd:     idx (+ local weight shard) -> [B_mp, S, E] batch-sharded output
    update:  dY [B_mp, S, E] -> new local weight shard (fused bwd+optimizer,
             contribution C1 — no dense dW is ever materialized)

All functions are designed to run INSIDE ``jax.shard_map``; ``axis_name`` is
the model axis (possibly a tuple of axes).  ``B`` below is the per-data-shard
batch; the fwd output is further batch-split over the model axis
(B_mp = B / num_shards), so the dense net downstream is data-parallel over
every mesh axis, exactly like the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import EmbeddingSpec, _round_up


@dataclasses.dataclass(frozen=True)
class ShardedEmbeddingLayout:
    """Static placement of a unified embedding space over ``num_shards``."""

    spec: EmbeddingSpec
    num_shards: int
    mode: str                      # "row" | "table"
    rows_per_shard: int
    slot_to_table: np.ndarray      # [S_slots] table id per model slot
    # row mode: global row offset per SLOT:
    row_offsets: Optional[np.ndarray] = None
    # table mode:
    slots_per_shard: int = 0
    # padded (bin-major) slot order; -1 for dummy:
    padded_slots: Optional[np.ndarray] = None   # [num_shards*slots_per_shard]
    # row offset (relative to shard start) per padded position:
    slot_local_offsets: Optional[np.ndarray] = None
    # original slot -> padded position:
    slot_position: Optional[np.ndarray] = None

    @property
    def total_rows(self) -> int:
        return self.num_shards * self.rows_per_shard

    @property
    def num_orig_slots(self) -> int:
        return len(self.slot_to_table)

    @property
    def num_padded_slots(self) -> int:
        return self.num_shards * self.slots_per_shard


def make_layout(spec: EmbeddingSpec, num_shards: int, mode: str = "row",
                slot_to_table=None) -> ShardedEmbeddingLayout:
    s2t = (np.arange(spec.num_tables, dtype=np.int64)
           if slot_to_table is None
           else np.asarray(slot_to_table, dtype=np.int64))
    if mode == "row":
        rows = _round_up(spec.total_rows,
                         num_shards * spec.row_pad) // num_shards
        return ShardedEmbeddingLayout(
            spec=spec, num_shards=num_shards, mode="row",
            rows_per_shard=rows, slot_to_table=s2t,
            row_offsets=spec.row_offsets[s2t])
    if mode != "table":
        raise ValueError(f"unknown mode {mode!r}")
    bins = spec.binpack_tables(num_shards)   # tables -> bins (may be empty)
    padded = spec.padded_rows
    # bin-local row offset per table
    table_bin = np.zeros(spec.num_tables, np.int64)
    table_off = np.zeros(spec.num_tables, np.int64)
    max_bin_rows = 0
    for b, tables in enumerate(bins):
        off = 0
        for t in tables:
            table_bin[t] = b
            table_off[t] = off
            off += int(padded[t])
        max_bin_rows = max(max_bin_rows, off)
    # +row_pad spare guarantees a scratch row for dummy slots on every shard.
    rows_per_shard = _round_up(max_bin_rows + spec.row_pad, spec.row_pad)
    # group SLOTS by their table's bin
    slots_by_bin: list[list[int]] = [[] for _ in range(num_shards)]
    for s, t in enumerate(s2t):
        slots_by_bin[table_bin[t]].append(s)
    slots_per_shard = max(1, max(len(g) for g in slots_by_bin))
    n_pad = num_shards * slots_per_shard
    padded_slots = np.full(n_pad, -1, np.int64)
    local_off = np.full(n_pad, rows_per_shard - 1, np.int64)  # dummies
    slot_position = np.zeros(len(s2t), np.int64)
    for b, group in enumerate(slots_by_bin):
        for j, s in enumerate(group):
            p = b * slots_per_shard + j
            padded_slots[p] = s
            local_off[p] = table_off[s2t[s]]
            slot_position[s] = p
    return ShardedEmbeddingLayout(
        spec=spec, num_shards=num_shards, mode="table",
        rows_per_shard=rows_per_shard, slot_to_table=s2t,
        slots_per_shard=slots_per_shard, padded_slots=padded_slots,
        slot_local_offsets=local_off, slot_position=slot_position)


def layout_gid_maps(layout: ShardedEmbeddingLayout
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Static numpy maps between LAYOUT row positions and SPEC-GLOBAL row
    ids (``gid`` = ``spec.row_offsets[t] + table-local row``, the layout-
    independent identity the hot-row cache keys its membership on so it
    survives elastic reshards).

    Returns ``(l2g [layout.total_rows], g2l [spec.total_rows])``, both
    int32 with -1 for positions that map nowhere: layout padding
    (row-mode tail, table-mode bin slack and the dummy-slot scratch row)
    on the ``l2g`` side, per-table ``row_pad`` gaps in the unified gid
    space on the ``g2l`` side."""
    spec = layout.spec
    l2g = np.full(layout.total_rows, -1, np.int32)
    if layout.mode == "row":
        # row-mode layout rows ARE the unified spec rows, padded up to
        # num_shards * rows_per_shard — but gids inside per-table padding
        # gaps belong to no table, so map only the real rows
        for t, rows_t in enumerate(spec.table_rows):
            base = int(spec.row_offsets[t])
            l2g[base:base + rows_t] = base + np.arange(rows_t, dtype=np.int32)
    else:
        for pos, s in enumerate(layout.padded_slots):
            if s < 0:
                continue
            t = int(layout.slot_to_table[s])
            rows_t = int(spec.table_rows[t])
            base = ((pos // layout.slots_per_shard) * layout.rows_per_shard
                    + int(layout.slot_local_offsets[pos]))
            l2g[base:base + rows_t] = (int(spec.row_offsets[t])
                                       + np.arange(rows_t, dtype=np.int32))
    g2l = np.full(spec.total_rows, -1, np.int32)
    owned = np.nonzero(l2g >= 0)[0]
    g2l[l2g[owned]] = owned.astype(np.int32)
    return l2g, g2l


def permute_indices(layout: ShardedEmbeddingLayout, idx: jax.Array
                    ) -> jax.Array:
    """[B, S, P] original-slot indices -> [B, num_padded_slots, P] padded
    order (table mode).  Dummy slots read index 0 (the scratch row)."""
    assert layout.mode == "table"
    src = np.where(layout.padded_slots >= 0, layout.padded_slots, 0)
    out = jnp.take(idx, jnp.asarray(src), axis=1)
    dummy = jnp.asarray((layout.padded_slots < 0))[None, :, None]
    return jnp.where(dummy, 0, out)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _partial_bag_masked(W_local: jax.Array, local_rows: jax.Array,
                        valid: jax.Array,
                        weights: Optional[jax.Array] = None) -> jax.Array:
    rows = jnp.take(W_local, jnp.clip(local_rows, 0, W_local.shape[0] - 1),
                    axis=0).astype(jnp.float32)
    if weights is not None:
        # weighted bag: Y = sum_p w_p * W[g_p].  w == 1.0 multiplies
        # exactly, so an all-ones weight stream keeps the unweighted
        # bit-identity contract.
        rows = rows * weights[..., None].astype(jnp.float32)
    rows = jnp.where(valid[..., None], rows, 0.0)
    return rows.sum(axis=2)  # [B, S, E] fp32


def _batch_chunks(B: int, S: int, P: int, E: int,
                  budget_bytes: int | None = None) -> int:
    """Pick a batch-chunk count so the transient [chunk,S,P,E] fp32 gather
    stays under ``budget_bytes`` (paper configs reach P=100: the unchunked
    expansion would be tens of GB).  REPRO_EMB_CHUNK_BUDGET overrides (the
    roofline cost builds disable chunking so cost_analysis sees one body)."""
    import os as _os
    if budget_bytes is None:
        budget_bytes = int(_os.environ.get("REPRO_EMB_CHUNK_BUDGET",
                                           128 * 2**20))
    per_row = S * P * E * 4
    chunk = max(1, budget_bytes // max(per_row, 1))
    if chunk >= B:
        return 1
    n = (B + chunk - 1) // chunk
    while B % n:  # need uniform chunks for lax.scan
        n += 1
    return n


def row_sharded_bag_fwd(layout: ShardedEmbeddingLayout, W_local: jax.Array,
                        idx: jax.Array, axis_name,
                        weights: Optional[jax.Array] = None) -> jax.Array:
    """Row mode forward.  ``axis_name`` may be a TUPLE of mesh axes — the
    production config shards the row space over the FULL mesh (the paper's
    pure model-parallel embedding, scaled past the table count).  ``idx``
    [B, S, P] is replicated over ``axis_name``; ``weights`` [B, S, P]
    optional per-lookup bag weights (same layout as ``idx``); output is
    [B/num_shards, S, E] (reduce-scatter over the batch dim).

    The gather+bag is scanned over batch chunks so the [chunk,S,P,E]
    transient stays bounded for large pooling factors."""
    g = idx + jnp.asarray(layout.row_offsets, idx.dtype)[None, :, None]
    start = jax.lax.axis_index(axis_name) * layout.rows_per_shard
    local = g - start
    B, S, P = idx.shape
    E = W_local.shape[1]
    n = _batch_chunks(B, S, P, E)
    if n == 1:
        valid = (local >= 0) & (local < layout.rows_per_shard)
        part = _partial_bag_masked(W_local, local, valid, weights)
    else:
        def body(_, inp):
            loc_c = inp[0]
            w_c = inp[1] if weights is not None else None
            valid = (loc_c >= 0) & (loc_c < layout.rows_per_shard)
            return None, _partial_bag_masked(W_local, loc_c, valid, w_c)
        xs = (local.reshape(n, B // n, S, P),)
        if weights is not None:
            xs += (weights.reshape(n, B // n, S, P),)
        _, part = jax.lax.scan(body, None, xs)
        part = part.reshape(B, S, E)
    # bf16 wire (HC3): the reduce-scatter is the dominant collective of the
    # hybrid step and the bag output feeds a bf16 dense net anyway.
    part = part.astype(jnp.bfloat16)
    return jax.lax.psum_scatter(part, axis_name, scatter_dimension=0,
                                tiled=True).astype(jnp.float32)


def table_sharded_bag_fwd(layout: ShardedEmbeddingLayout, W_local: jax.Array,
                          idx_slots_local: jax.Array, axis_name,
                          weights: Optional[jax.Array] = None
                          ) -> jax.Array:
    """Table mode forward.  ``idx_slots_local`` [B, slots_per_shard, P] is
    the padded-slot index array already sharded over the model axis;
    ``weights`` optional per-lookup bag weights in the same layout.  Output
    is [B/num_shards, S_orig, E] in ORIGINAL slot order."""
    K = layout.slots_per_shard
    shard = jax.lax.axis_index(axis_name)
    off_all = jnp.asarray(layout.slot_local_offsets).reshape(
        layout.num_shards, K)
    local = idx_slots_local + jax.lax.dynamic_index_in_dim(
        off_all, shard, axis=0, keepdims=False)[None, :, None]
    B, _, P = local.shape
    E = W_local.shape[1]
    n = _batch_chunks(B, K, P, E)

    def bag(loc, w=None):
        rows = jnp.take(W_local, jnp.clip(loc, 0, W_local.shape[0] - 1),
                        axis=0).astype(jnp.float32)
        if w is not None:
            rows = rows * w[..., None].astype(jnp.float32)
        return rows.sum(axis=2)

    if n == 1:
        part = bag(local, weights)               # [B, K, E] full local batch
    else:
        xs = (local.reshape(n, B // n, K, P),)
        if weights is not None:
            xs += (weights.reshape(n, B // n, K, P),)
        _, part = jax.lax.scan(
            lambda c, inp: (None, bag(inp[0], inp[1] if weights is not None
                                      else None)), None, xs)
        part = part.reshape(B, K, E)
    out = jax.lax.all_to_all(part, axis_name, split_axis=0, concat_axis=1,
                             tiled=True)         # [B/ns, num_padded, E]
    # back to original slot order (drop dummy slots):
    return jnp.take(out, jnp.asarray(layout.slot_position), axis=1)


def sharded_bag_fwd(layout: ShardedEmbeddingLayout, W_local: jax.Array,
                    idx_local: jax.Array, axis_name,
                    weights: Optional[jax.Array] = None) -> jax.Array:
    if layout.mode == "row":
        return row_sharded_bag_fwd(layout, W_local, idx_local, axis_name,
                                   weights)
    return table_sharded_bag_fwd(layout, W_local, idx_local, axis_name,
                                 weights)


def row_bag_fwd_replicated(layout: ShardedEmbeddingLayout, W_local, idx,
                           axis_name) -> jax.Array:
    """Row-mode bag with a REPLICATED [B, S, E] output (psum instead of
    reduce-scatter).  Used when B < num_shards, e.g. the retrieval step's
    single query."""
    local, valid = _local_rows(layout, idx, axis_name)
    part = _partial_bag_masked(W_local, local, valid)
    return jax.lax.psum(part, axis_name)


# ---------------------------------------------------------------------------
# Fused backward + update (sparse optimizer; C1)
# ---------------------------------------------------------------------------

def _local_rows(layout: ShardedEmbeddingLayout, idx_local: jax.Array,
                axis_name) -> tuple[jax.Array, jax.Array]:
    """(local_row [B,S,P], valid [B,S,P]) for this shard, either mode."""
    if layout.mode == "row":
        g = idx_local + jnp.asarray(layout.row_offsets,
                                    idx_local.dtype)[None, :, None]
        start = jax.lax.axis_index(axis_name) * layout.rows_per_shard
        local = g - start
        valid = (local >= 0) & (local < layout.rows_per_shard)
        return local, valid
    K = layout.slots_per_shard
    shard = jax.lax.axis_index(axis_name)
    off_all = jnp.asarray(layout.slot_local_offsets).reshape(
        layout.num_shards, K)
    local = idx_local + jax.lax.dynamic_index_in_dim(
        off_all, shard, axis=0, keepdims=False)[None, :, None]
    valid = jnp.ones(local.shape, bool)
    return local, valid


def _wire_rank(axis_name, replica_axes) -> jax.Array:
    """Global sender index over every axis the dY exchange spans — the rank
    coordinate of the wire-dither tag, so no two devices' payloads share a
    stream.  Uses the single-sourced device-major flattening rule."""
    from repro.optim.data_parallel import combined_axis_index
    axes: list = []
    if replica_axes is not None:
        axes += list(replica_axes if isinstance(replica_axes, (tuple, list))
                     else [replica_axes])
    axes += list(axis_name if isinstance(axis_name, (tuple, list))
                 else [axis_name])
    return combined_axis_index(tuple(axes))


def gather_dY(layout: ShardedEmbeddingLayout, dY_mp: jax.Array, axis_name,
              replica_axes=None, wire_dtype: str = "fp32", seed=None,
              tag: int = 0) -> jax.Array:
    """Bring the batch-model-sharded cotangent dY [B/ns, S, E] back to the
    layout each shard scatters from: row mode all-gathers the batch over the
    model axes; table mode inverse-all_to_alls to [B, K, E] padded-slot order
    (plus an optional replica gather over the data axes).

    ``wire_dtype`` selects the on-wire precision (repro/dist/exchange.py).
    Row mode has ALWAYS shipped a round-to-nearest bf16 payload (matching
    the bf16 psum_scatter forward), so ``'fp32'`` and ``'bf16'`` both keep
    that historical wire bit-for-bit and ``'bf16_sr'`` swaps the rounding
    for the seeded counter dither.  Table mode moves fp32 by default;
    ``'bf16'``/``'bf16_sr'`` halve the all_to_all (and replica-gather)
    payload.  ``seed`` is the replicated per-step sr counter; ``tag`` the
    static payload site within the step (microbatch index).

    16-bit payloads cross the collective as BITCAST uint16 lanes, not as
    a bf16-typed array: ``convert(collective(convert(x)))`` is a pure
    data-movement sandwich XLA legally simplifies back onto an fp32
    carrier (the rounding survives; the byte saving does not), while a
    bitcast is opaque to the algebraic simplifier — the compiled HLO
    genuinely moves 2 bytes/element (checked by
    benchmarks/bench_comm_model.py --exchange-dtype against the lowered
    collective bytes).  Bitcasting changes no payload bits, so this is
    value-identical to the convert-based wire."""
    from repro.dist import exchange as exchange_cfg
    from repro.optim import stochastic

    def _sr(x):
        return stochastic.sr_round_bf16_wire(
            x, jnp.int32(0) if seed is None else seed,
            exchange_cfg.wire_tag(exchange_cfg.TAG_DY, tag,
                                  _wire_rank(axis_name, replica_axes)))

    if layout.mode == "row":
        payload = (_sr(dY_mp) if wire_dtype == "bf16_sr"
                   else dY_mp.astype(jnp.bfloat16))
        wire = jax.lax.bitcast_convert_type(payload, jnp.uint16)
        wire = jax.lax.all_gather(wire, axis_name, axis=0, tiled=True)
        return jax.lax.bitcast_convert_type(
            wire, jnp.bfloat16).astype(jnp.float32)
    src = np.where(layout.padded_slots >= 0, layout.padded_slots, 0)
    dY_slots = jnp.take(dY_mp, jnp.asarray(src), axis=1)
    dummy = jnp.asarray(layout.padded_slots < 0)[None, :, None]
    dY_slots = jnp.where(dummy, 0.0, dY_slots)
    narrow = wire_dtype in ("bf16", "bf16_sr")
    if narrow:
        dY_slots = (_sr(dY_slots) if wire_dtype == "bf16_sr"
                    else dY_slots.astype(jnp.bfloat16))
        dY_slots = jax.lax.bitcast_convert_type(dY_slots, jnp.uint16)
    dY_local = jax.lax.all_to_all(dY_slots, axis_name, split_axis=1,
                                  concat_axis=0, tiled=True)
    if replica_axes is not None:
        dY_local = jax.lax.all_gather(dY_local, replica_axes, axis=0,
                                      tiled=True)
    if narrow:
        dY_local = jax.lax.bitcast_convert_type(dY_local, jnp.bfloat16)
    return dY_local.astype(jnp.float32)


def _row_sorted_streams(layout: ShardedEmbeddingLayout, g_flat: jax.Array,
                        start, pooling: int,
                        weights_flat: Optional[jax.Array] = None) -> tuple:
    """Device-side sorted streams for the ROW-mode fused update, computed
    from the GLOBAL row ids: one axis-INVARIANT stable argsort of the
    global keys, then an elementwise localization into this shard's
    window (subtract ``start``, mask, clip).  Three reasons this shape —
    and not a per-shard sort of the axis-index-derived local rows:

    * the global sort is computed once and identically on every shard
      (the per-shard sorts were ns identical-cost argsorts of shifted
      keys);
    * per touched row the run holds the SAME lookups in the SAME stable
      flat order as the per-shard local sort (shifting all keys by
      ``start`` permutes nothing within the owned window), so the kernel
      output is bit-identical to the host-pre-sorted stream;
    * XLA CPU (jax<0.5) miscompiles the interpret-mode kernel under
      jit+shard_map when its scalar-prefetch operands descend from an
      axis_index-dependent argsort — the invariant sort + elementwise
      localization is the formulation it compiles correctly (verified
      against the pure-jnp oracle; see tests/test_row_optim.py).

    Non-owned lookups keep ``msk == 0`` and clip to row 0 / R-1 — exact
    no-op rewrites (stateless kinds) or flag-guarded write-throughs
    (stateful kinds) under the kernel's liveness contract."""
    G = layout.total_rows
    R = layout.rows_per_shard
    in_range = (g_flat >= 0) & (g_flat < G)
    key = jnp.where(in_range, g_flat, G).astype(jnp.int32)
    order = jnp.argsort(key)                 # stable: ties in flat order
    skey = jnp.take(key, order)
    bags = (order // pooling).astype(jnp.int32)
    wgt = (jnp.ones(key.shape, jnp.float32) if weights_flat is None
           else jnp.take(weights_flat.astype(jnp.float32), order))
    local = skey - start
    msk = ((skey < G) & (local >= 0) & (local < R)).astype(jnp.int32)
    rows = jnp.clip(local, 0, R - 1)
    return rows, bags, msk, wgt


def apply_update(layout: ShardedEmbeddingLayout, store: dict, optimizer,
                 idx_local, dY: jax.Array, lr, axis_name,
                 replica_axes=None, fused: bool = False,
                 weights: Optional[jax.Array] = None,
                 presort: Optional[tuple] = None, seed=None) -> dict:
    """THE sparse update of the hybrid step: one entry point for every
    registered :class:`repro.optim.row.RowOptimizer`, every placement mode
    and every stream shape (replacing the former ``apply_update_scan`` /
    ``apply_update_presorted`` / ``apply_rows_*`` surface).

    ``store``: the optimizer's EmbeddingStore dict — this shard's weight
    slab(s) plus per-row state slabs, all on the same row partition.
    ``idx_local``: [B, S_or_K, P]; ``dY``: matching [B, S_or_K, E]
    (already passed through :func:`gather_dY`).  ``weights``: optional
    [B, S_or_K, P] per-lookup bag weights in the layout of ``idx_local``.
    In table mode with replica axes the index (and weight) arrays are
    gathered the same way as dY.

    ``presort``: this shard's host-pre-sorted ``(sorted_rows, sorted_bags,
    sorted_msk, sorted_wgt)`` [L] arrays (``repro.data.pipeline
    .presort_batch``, row AND table mode; bag weights already baked into
    ``sorted_wgt``) — always the fused Pallas kernel, no on-device sort,
    no batch chunking (only scalars were shipped and the kernel never
    builds a [B,S,P,E] expansion).  Bit-identical to the sorting path
    whenever that path runs unchunked.

    ``fused=True`` runs the Pallas kernel on the FULL stream, unchunked —
    the kernel ships only [L] scalars and never builds a [B,S,P,E]
    expansion (duplicates pre-reduced in VMEM, weights and state updated
    in place on the touched rows only; split results bit-identical to
    the reference).  ``fused=False`` runs the reference row math, chunked
    over the batch to bound the gradient-expansion transients (paper
    configs reach P=100 where the naive expansion is tens of GB); for
    STATEFUL optimizers the chunked reference accumulates the per-row
    gradient across chunks first and applies the optimizer transition
    once — per-chunk transitions would compound the momentum decay /
    Adagrad accumulate n times per step.

    ``seed``: int32 per-step stochastic-rounding seed, forwarded verbatim
    to every ``apply_sparse``/``apply_rows_reduced`` call (the compressed
    bf16-hi state optimizers dither with it; deterministic optimizers
    ignore it) — this module stays per-optimizer-agnostic."""
    from repro.optim.row import SparseStream
    if presort is not None:
        return optimizer.apply_sparse(store, SparseStream(presort=presort,
                                                          dY=dY), lr,
                                      seed=seed, fused=True)
    if layout.mode == "table" and replica_axes is not None:
        idx_local = jax.lax.all_gather(idx_local, replica_axes, axis=0,
                                       tiled=True)
        if weights is not None:
            weights = jax.lax.all_gather(weights, replica_axes, axis=0,
                                         tiled=True)
    if fused and layout.mode == "row":
        # device-sorted fused path: sort the global stream once
        # (axis-invariant), localize elementwise, and feed the kernel's
        # presorted entry — unchunked, like the host-pre-sorted path (the
        # kernel ships only [L] scalars and never builds a [B,S,P,E]
        # expansion), so the result is bit-identical to host_presort.
        g = idx_local + jnp.asarray(layout.row_offsets,
                                    idx_local.dtype)[None, :, None]
        start = jax.lax.axis_index(axis_name) * layout.rows_per_shard
        streams = _row_sorted_streams(
            layout, g.reshape(-1), start, idx_local.shape[-1],
            None if weights is None else weights.reshape(-1))
        return optimizer.apply_sparse(store, SparseStream(presort=streams,
                                                          dY=dY), lr,
                                      seed=seed)
    if fused and layout.mode == "table" and layout.num_shards > 1 \
            and jax.default_backend() != "tpu":
        # KNOWN LIMITATION: XLA CPU (jax<0.5) miscompiles the
        # interpret-mode kernel under jit+shard_map when the sorted
        # streams descend from the axis-varying padded-slot offsets, and
        # table mode has no axis-invariant sort formulation (each shard
        # sorts genuinely different slot content).  Fall back to the
        # reference math here — identical semantics (the split path is
        # bit-identical to the kernel by contract); the multi-shard
        # table KERNEL path is exercised via host_presort, and on TPU
        # (compiled, non-interpret) the direct path stays on.
        fused = False
    local, valid = _local_rows(layout, idx_local, axis_name)
    B, S, P = local.shape
    E = dY.shape[-1]
    if fused:
        # table-mode fused (TPU): the kernel ships only [L] scalars and
        # reads dY rows by bag id — there is no [B,S,P,E] expansion to
        # bound, so never chunk (chunking would also re-run stateful
        # transitions per chunk; one apply keeps them once-per-step)
        return optimizer.apply_sparse(
            store, SparseStream(idx=local, dY=dY, valid=valid,
                                weights=weights), lr, seed=seed, fused=True)
    n = _batch_chunks(B, S, P, E)
    cb = B // n

    def chunk_update(st, loc_c, val_c, dY_c, wgt_c=None):
        return optimizer.apply_sparse(
            st, SparseStream(idx=loc_c, dY=dY_c, valid=val_c,
                             weights=wgt_c), lr, seed=seed, fused=False)

    if n == 1:
        return chunk_update(store, local, valid, dY, weights)
    if optimizer.state_keys:
        # stateful reference, chunked: the optimizer transition (momentum
        # decay, Adagrad accumulate) must run ONCE per touched row per
        # step — re-running it per chunk compounds the decay beta^n-style
        # and squares partial sums.  Two phases: scatter-accumulate the
        # per-row gradient across chunks (the [cb,S,P,E] expansion stays
        # chunk-bounded), then one reduced transition on the unique rows.
        rows = optimizer.fwd_weights(store).shape[0]

        def acc_chunk(dW, inp):
            loc_c, val_c, dY_c = inp[0], inp[1], inp[2]
            wgt_c = inp[3] if weights is not None else None
            grad = jnp.broadcast_to(dY_c[:, :, None, :],
                                    (cb, S, P, E)).astype(jnp.float32)
            if wgt_c is not None:
                grad = grad * wgt_c[..., None].astype(jnp.float32)
            tgt_c = jnp.where(val_c, loc_c, rows)   # OOB -> scatter-drop
            return dW.at[tgt_c.reshape(-1)].add(grad.reshape(-1, E)), None

        xs = (local.reshape(n, cb, S, P), valid.reshape(n, cb, S, P),
              dY.reshape(n, cb, S, E))
        if weights is not None:
            xs += (weights.reshape(n, cb, S, P),)
        dW, _ = jax.lax.scan(acc_chunk, jnp.zeros((rows, E), jnp.float32),
                             xs)
        from repro.optim.row import bump_counters, dedup_targets
        touch = jnp.where(valid, local, rows).reshape(-1)
        if "cnt" in store:
            # this branch bypasses apply_sparse (which owns the reserved
            # touch-counter bump), so bump the full un-deduplicated stream
            # here — apply_rows_reduced carries the slab through untouched
            store = dict(store)
            store["cnt"] = bump_counters(store["cnt"], touch, rows)
        rep = dedup_targets(touch, rows)
        summed = jnp.take(dW, jnp.minimum(rep, rows - 1), axis=0)
        return optimizer.apply_rows_reduced(store, rep, summed, lr,
                                            seed=seed)

    def body(st, inp):
        return chunk_update(st, *inp), None

    xs = (local.reshape(n, cb, S, P), valid.reshape(n, cb, S, P),
          dY.reshape(n, cb, S, E))
    if weights is not None:
        xs += (weights.reshape(n, cb, S, P),)
    store_out, _ = jax.lax.scan(body, store, xs)
    return store_out


def row_grad_rows(layout: ShardedEmbeddingLayout, idx: jax.Array,
                  dY_mp: jax.Array, axis_name
                  ) -> tuple[jax.Array, jax.Array]:
    """Row mode (unchunked; tests / small configs): all-gather dY over the
    model axes (mirror of the fwd reduce-scatter), mask to OWNED rows —
    Alg. 4 as a sharding rule.  Returns (tgt [n], grad [n, E])."""
    dY = jax.lax.all_gather(dY_mp, axis_name, axis=0, tiled=True)
    local, valid = _local_rows(layout, idx, axis_name)
    B, S, P = idx.shape
    E = dY.shape[-1]
    grad = jnp.broadcast_to(dY[:, :, None, :], (B, S, P, E)
                            ).astype(jnp.float32)
    grad = jnp.where(valid[..., None], grad, 0.0)
    tgt = jnp.where(valid, local, 0).reshape(-1)
    return tgt, grad.reshape(-1, E)


def table_grad_rows(layout: ShardedEmbeddingLayout, idx_slots_local,
                    dY_mp: jax.Array, axis_name
                    ) -> tuple[jax.Array, jax.Array]:
    """Table mode (unchunked; tests / small configs)."""
    dY_local = gather_dY(layout, dY_mp, axis_name)
    local, valid = _local_rows(layout, idx_slots_local, axis_name)
    B, K, P = local.shape
    E = dY_local.shape[-1]
    grad = jnp.broadcast_to(dY_local[:, :, None, :], (B, K, P, E))
    tgt = jnp.clip(local, 0, layout.rows_per_shard - 1).reshape(-1)
    return tgt, grad.astype(jnp.float32).reshape(-1, E)


def grad_rows(layout: ShardedEmbeddingLayout, idx_local: jax.Array,
              dY_mp: jax.Array, axis_name) -> tuple[jax.Array, jax.Array]:
    if layout.mode == "row":
        return row_grad_rows(layout, idx_local, dY_mp, axis_name)
    return table_grad_rows(layout, idx_local, dY_mp, axis_name)


def replicate_grad_rows(tgt: jax.Array, grad: jax.Array, replica_axes
                        ) -> tuple[jax.Array, jax.Array]:
    """Table mode on a 2D+ mesh replicates each table shard over the data
    axes; every replica must apply the updates of ALL replicas to stay
    consistent.  All-gathers the sparse (tgt, grad) row lists over
    ``replica_axes`` — the paper-noted cost of table-wise placement on wide
    meshes (row mode avoids it entirely)."""
    tgt_all = jax.lax.all_gather(tgt, replica_axes, axis=0, tiled=True)
    grad_all = jax.lax.all_gather(grad, replica_axes, axis=0, tiled=True)
    return tgt_all, grad_all


# ---------------------------------------------------------------------------
# NOTE on the optimizer math: the per-row update rules (Split-SGD's
# combine/step/split, momentum, row-wise Adagrad, ...) live in
# ``repro.optim.row`` — this module owns only the PLACEMENT concerns
# (layout -> local rows, replica gathers, batch chunking) and hands each
# chunk to ``RowOptimizer.apply_sparse``.  The reference oracles
# (``dedup_rows``, ``apply_rows_sgd``, ``apply_rows_split_sgd``) moved
# there with it.
# ---------------------------------------------------------------------------
