from repro.data import graph, synthetic  # noqa: F401
