from repro.data import format, graph, pipeline, reader, synthetic  # noqa: F401
from repro.data.format import DatasetSpec, ShardWriter  # noqa: F401
from repro.data.pipeline import HostPipeline, presort_batch  # noqa: F401
from repro.data.reader import ShardedReader  # noqa: F401
