"""CLI entry: ``python -m repro.data synthetic|criteo ...`` (see
repro/data/format.py for the subcommands)."""

from repro.data.format import main

main()
