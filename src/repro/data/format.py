"""Packed shard format for streaming recsys ingestion.

The paper's headline is not just single-socket speed but "fitting
ultra-large datasets": click-log training streams terabytes through the
cluster, so the loader must (a) never deserialize on the hot path and
(b) shard cleanly over the data axis.  Characterization work (Gupta et
al. 2020, Hsia et al. 2020) shows ingestion + irregular sparse-index
handling dominate recsys cycles once compute is optimized — hence a
binary, memory-mappable format instead of TSV/parquet decode per batch.

One dataset = a directory:

    dataset.json            DatasetSpec + shard manifest (the sidecar)
    shard-00000.bin         packed samples
    shard-00001.bin         ...

Shard file layout (all little-endian, every array 8-byte aligned):

    +--------------------------------------------------------------+
    | header (32 B): magic 'RPKS' | u32 version | u64 num_samples  |
    |                u32 num_slots | u32 num_dense | u32 flags     |
    |                u32 n_arrays                                  |
    +--------------------------------------------------------------+
    | section table: n_arrays x (u64 offset, u64 nbytes)           |
    +--------------------------------------------------------------+
    | dense    [N, num_dense] f32          (if num_dense > 0)      |
    | labels   [N] f32                     (if flags & LABELS)     |
    | per slot s in 0..S-1 (CSR):                                  |
    |   offsets_s [N+1] i64                                        |
    |   indices_s [nnz_s] i32                                      |
    |   weights_s [nnz_s] f32              (if flags & WEIGHTS)    |
    +--------------------------------------------------------------+

The CSR offsets make ragged bags representable; the writer emits the
fixed-width ``pooling`` layout the models consume, for which the reader's
decode is a pure ``reshape`` of an mmap view (zero-copy on contiguous
sample ranges).  Index values are PER-TABLE (original slot order) —
exactly what ``repro.core.hybrid.batch_struct`` expects for
``idx_input in ('replicated', 'sharded')`` row mode and sharded table
mode; globalization to the unified row space stays on device.
"""

from __future__ import annotations

import dataclasses
import json
import struct
from pathlib import Path
from typing import Iterable, Iterator, Optional

import numpy as np

MAGIC = b"RPKS"
VERSION = 1
FLAG_LABELS = 1
FLAG_WEIGHTS = 2
SPEC_NAME = "dataset.json"
_HEADER = struct.Struct("<4sIQIIII")        # magic, ver, N, S, D, flags, n_arr
_SECTION = struct.Struct("<QQ")


def _align8(n: int) -> int:
    return (n + 7) & ~7


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Schema sidecar: everything a consumer needs to build the batch
    struct (``repro.core.hybrid.batch_struct_from_spec``) without touching
    a shard file."""

    table_rows: tuple                    # rows per TABLE
    pooling: int                         # P lookups per slot (fixed width)
    num_dense: int = 0
    slot_to_table: Optional[tuple] = None  # slot -> table (None = identity)
    labels: bool = True
    weighted: bool = False               # per-lookup bag weights present

    @property
    def slots(self) -> tuple:
        return (self.slot_to_table if self.slot_to_table is not None
                else tuple(range(len(self.table_rows))))

    @property
    def num_slots(self) -> int:
        return len(self.slots)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["table_rows"] = list(self.table_rows)
        d["slot_to_table"] = (None if self.slot_to_table is None
                              else list(self.slot_to_table))
        return d

    @classmethod
    def from_json(cls, d: dict) -> "DatasetSpec":
        return cls(table_rows=tuple(d["table_rows"]),
                   pooling=int(d["pooling"]),
                   num_dense=int(d.get("num_dense", 0)),
                   slot_to_table=(None if d.get("slot_to_table") is None
                                  else tuple(d["slot_to_table"])),
                   labels=bool(d.get("labels", True)),
                   weighted=bool(d.get("weighted", False)))

    # -- model compatibility -------------------------------------------------

    def check(self, table_rows, pooling: int, num_dense: int = 0,
              labels: bool = True, slot_to_table=None,
              weighted: bool = False) -> None:
        """Raise ValueError listing every mismatch between this dataset and
        a model's expectations (fail loudly at wiring time, not step 1)."""
        errs = []
        if tuple(self.table_rows) != tuple(table_rows):
            errs.append(f"table_rows {tuple(self.table_rows)} != model "
                        f"{tuple(table_rows)}")
        if self.pooling != pooling:
            errs.append(f"pooling {self.pooling} != model {pooling}")
        if self.num_dense != num_dense:
            errs.append(f"num_dense {self.num_dense} != model {num_dense}")
        if bool(self.labels) != bool(labels):
            errs.append(f"labels {self.labels} != model {labels}")
        s2t = (None if slot_to_table is None else tuple(slot_to_table))
        if (self.slot_to_table or None) != (s2t or None):
            if self.slots != (s2t if s2t is not None
                              else tuple(range(len(table_rows)))):
                errs.append(f"slot_to_table {self.slot_to_table} != model "
                            f"{s2t}")
        if weighted and not self.weighted:
            errs.append("model expects per-lookup weights; dataset is "
                        "unweighted")
        if errs:
            raise ValueError("DatasetSpec incompatible with model: "
                             + "; ".join(errs))

    def check_model(self, mdef) -> None:
        """Check against a :class:`repro.core.hybrid.HybridDef` (or a
        DLRMConfig via ``as_hybrid_def``).  Every batch field the model
        declares must be coverable by the format — extras beyond
        dense_x/labels (seq_mask, hist_mask, ...) are not representable
        in packed shards and are rejected HERE, not as a pytree mismatch
        inside shard_map."""
        extras = getattr(mdef, "extras", {})
        unsupported = sorted(set(extras) - {"dense_x", "labels"})
        if unsupported:
            raise ValueError(
                f"model declares batch extras {unsupported} the packed "
                "shard format cannot carry (it stores dense_x/labels/"
                "sparse indices+weights only)")
        num_dense = (extras["dense_x"][0][0] if "dense_x" in extras else 0)
        self.check(mdef.spec.table_rows, mdef.pooling, num_dense=num_dense,
                   labels="labels" in extras,
                   slot_to_table=getattr(mdef, "slot_to_table", None),
                   weighted=getattr(mdef, "weighted", False))


# ---------------------------------------------------------------------------
# Writing
# ---------------------------------------------------------------------------

def _write_shard(path: Path, spec: DatasetSpec, idx: np.ndarray,
                 dense: Optional[np.ndarray], labels: Optional[np.ndarray],
                 weights: Optional[np.ndarray]) -> int:
    """Write one shard from fixed-width arrays (idx [n,S,P] int32, dense
    [n,D] f32, labels [n] f32, weights [n,S,P] f32).  Returns n."""
    n, S, P = idx.shape
    flags = (FLAG_LABELS if spec.labels else 0) | (
        FLAG_WEIGHTS if spec.weighted else 0)
    arrays: list[np.ndarray] = []
    if spec.num_dense:
        arrays.append(np.ascontiguousarray(dense, np.float32))
    if spec.labels:
        arrays.append(np.ascontiguousarray(labels, np.float32))
    offs = (np.arange(n + 1, dtype=np.int64) * P)
    for s in range(S):
        arrays.append(offs)
        arrays.append(np.ascontiguousarray(idx[:, s, :].reshape(-1),
                                           np.int32))
        if spec.weighted:
            arrays.append(np.ascontiguousarray(
                weights[:, s, :].reshape(-1), np.float32))
    off = _align8(_HEADER.size + _SECTION.size * len(arrays))
    table = []
    for a in arrays:
        table.append((off, a.nbytes))
        off = _align8(off + a.nbytes)
    with open(path, "wb") as f:
        f.write(_HEADER.pack(MAGIC, VERSION, n, S, spec.num_dense, flags,
                             len(arrays)))
        for o, nb in table:
            f.write(_SECTION.pack(o, nb))
        pos = _HEADER.size + _SECTION.size * len(arrays)
        for a, (o, nb) in zip(arrays, table):
            f.write(b"\0" * (o - pos))
            f.write(a.tobytes())
            pos = o + nb
    return n


class ShardWriter:
    """Accumulate fixed-width batches and flush packed shard files.

    ``append_batch`` takes the dict layout the synthetic generators emit
    (``idx`` [b, S, P] int32 (+ ``dense_x``, ``labels``, ``weights``));
    shards of ``samples_per_shard`` samples are written as they fill and
    ``close()`` flushes the remainder + the ``dataset.json`` sidecar."""

    def __init__(self, out_dir, spec: DatasetSpec,
                 samples_per_shard: int = 8192):
        if samples_per_shard < 1:
            raise ValueError("samples_per_shard must be >= 1")
        self.out_dir = Path(out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.spec = spec
        self.samples_per_shard = samples_per_shard
        self._buf: list[dict] = []
        self._buffered = 0
        self._shards: list[dict] = []
        self._closed = False

    def append_batch(self, batch: dict) -> None:
        idx = np.asarray(batch["idx"])
        b, S, P = idx.shape
        if S != self.spec.num_slots or P != self.spec.pooling:
            raise ValueError(f"batch idx {idx.shape} does not match spec "
                             f"(S={self.spec.num_slots}, "
                             f"P={self.spec.pooling})")
        rows = np.asarray(self.spec.table_rows)[np.asarray(self.spec.slots)]
        if idx.min() < 0 or (idx.max(axis=(0, 2)) >= rows).any():
            raise ValueError("index out of range for table_rows")
        rec = {"idx": idx.astype(np.int32)}
        if self.spec.num_dense:
            rec["dense_x"] = np.asarray(batch["dense_x"], np.float32)
        if self.spec.labels:
            rec["labels"] = np.asarray(batch["labels"], np.float32)
        if self.spec.weighted:
            rec["weights"] = np.asarray(batch["weights"], np.float32)
        self._buf.append(rec)
        self._buffered += b
        while self._buffered >= self.samples_per_shard:
            self._flush(self.samples_per_shard)

    def _take(self, n: int) -> dict:
        out: dict[str, list] = {k: [] for k in self._buf[0]}
        got = 0
        while got < n:
            rec = self._buf[0]
            b = rec["idx"].shape[0]
            take = min(b, n - got)
            for k, v in rec.items():
                out[k].append(v[:take])
            if take == b:
                self._buf.pop(0)
            else:
                self._buf[0] = {k: v[take:] for k, v in rec.items()}
            got += take
        self._buffered -= n
        return {k: np.concatenate(v, axis=0) for k, v in out.items()}

    def _flush(self, n: int) -> None:
        rec = self._take(n)
        name = f"shard-{len(self._shards):05d}.bin"
        _write_shard(self.out_dir / name, self.spec, rec["idx"],
                     rec.get("dense_x"), rec.get("labels"),
                     rec.get("weights"))
        self._shards.append({"file": name, "num_samples": n})

    def close(self) -> dict:
        if self._closed:
            raise RuntimeError("ShardWriter already closed")
        if self._buffered:
            self._flush(self._buffered)
        manifest = {
            "format": "repro-packed-shards",
            "version": VERSION,
            "spec": self.spec.to_json(),
            "samples_per_shard": self.samples_per_shard,
            "num_samples": sum(s["num_samples"] for s in self._shards),
            "shards": self._shards,
        }
        (self.out_dir / SPEC_NAME).write_text(json.dumps(manifest, indent=1))
        self._closed = True
        return manifest


def load_manifest(data_dir) -> tuple[DatasetSpec, dict]:
    p = Path(data_dir) / SPEC_NAME
    if not p.exists():
        raise FileNotFoundError(f"no {SPEC_NAME} under {data_dir}")
    manifest = json.loads(p.read_text())
    if manifest.get("format") != "repro-packed-shards":
        raise ValueError(f"{p} is not a repro-packed-shards manifest")
    if manifest.get("version") != VERSION:
        raise ValueError(f"unsupported shard format version "
                         f"{manifest.get('version')} (reader is {VERSION})")
    return DatasetSpec.from_json(manifest["spec"]), manifest


def write_shards(batches: Iterable[dict], out_dir, spec: DatasetSpec,
                 num_samples: int, samples_per_shard: int = 8192) -> dict:
    """Drain ``batches`` (any iterator of synthetic-layout dicts, e.g.
    ``repro.data.synthetic.dlrm_stream``) until ``num_samples`` samples are
    packed.  Returns the manifest."""
    w = ShardWriter(out_dir, spec, samples_per_shard)
    got = 0
    for b in batches:
        idx = np.asarray(b["idx"])
        take = min(idx.shape[0], num_samples - got)
        if take < idx.shape[0]:
            b = {k: np.asarray(v)[:take] for k, v in b.items()}
        w.append_batch(b)
        got += take
        if got >= num_samples:
            break
    if got < num_samples:
        raise ValueError(f"stream exhausted at {got}/{num_samples} samples")
    return w.close()


# ---------------------------------------------------------------------------
# Converters
# ---------------------------------------------------------------------------

def criteo_tsv_to_shards(tsv_path, out_dir, table_rows,
                         samples_per_shard: int = 8192,
                         log_transform: bool = True,
                         batch: int = 4096) -> dict:
    """Convert a Criteo-TSV-style click log (label \\t 13 int dense \\t 26
    hex categorical per line; empty fields allowed) into packed shards.
    Categorical values hash into ``table_rows[t]`` rows; dense ints get the
    standard ``log1p`` transform.  pooling = 1 (one-hot slots)."""
    table_rows = tuple(int(r) for r in table_rows)
    S = len(table_rows)
    spec = DatasetSpec(table_rows=table_rows, pooling=1, num_dense=13,
                       labels=True, weighted=False)
    w = ShardWriter(out_dir, spec, samples_per_shard)
    idx_b, den_b, lab_b = [], [], []

    def flush():
        if not idx_b:
            return
        w.append_batch({"idx": np.stack(idx_b)[:, :, None],
                        "dense_x": np.stack(den_b),
                        "labels": np.asarray(lab_b, np.float32)})
        idx_b.clear(), den_b.clear(), lab_b.clear()

    with open(tsv_path) as f:
        for line in f:
            parts = line.rstrip("\n").split("\t")
            if len(parts) != 1 + 13 + S:
                raise ValueError(f"bad Criteo line: {len(parts)} fields, "
                                 f"expected {1 + 13 + S}")
            lab_b.append(float(parts[0] or 0))
            dense = np.array([float(x or 0) for x in parts[1:14]], np.float32)
            if log_transform:
                dense = np.log1p(np.maximum(dense, 0.0))
            den_b.append(dense)
            idx_b.append(np.array(
                [int(c, 16) % table_rows[t] if c else 0
                 for t, c in enumerate(parts[14:])], np.int32))
            if len(idx_b) >= batch:
                flush()
    flush()
    return w.close()


def pack_synthetic(out_dir, table_rows, pooling: int, num_samples: int,
                   num_dense: int = 0, alpha: float = 0.0, seed: int = 0,
                   slot_to_table=None, labels: bool = True,
                   weighted: bool = False, samples_per_shard: int = 8192,
                   batch: int = 4096) -> dict:
    """Pack a seeded synthetic stream (repro.data.synthetic) — the
    "synthetic -> packed -> train" leg of docs/data.md, and the round-trip
    fixture of tests/test_ingest.py."""
    from repro.data.synthetic import SparseBatchSpec, sparse_batch
    spec = DatasetSpec(table_rows=tuple(table_rows), pooling=pooling,
                       num_dense=num_dense, slot_to_table=slot_to_table,
                       labels=labels, weighted=weighted)
    rng = np.random.default_rng(seed)
    sspec = SparseBatchSpec(tuple(table_rows), slot_to_table, pooling, batch,
                            num_dense=num_dense, alpha=alpha, labels=labels)

    def stream() -> Iterator[dict]:
        while True:
            b = sparse_batch(rng, sspec)
            if weighted:
                b["weights"] = rng.uniform(
                    0.5, 1.5, b["idx"].shape).astype(np.float32)
            yield b

    return write_shards(stream(), out_dir, spec, num_samples,
                        samples_per_shard)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="Pack datasets into the repro shard format")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sy = sub.add_parser("synthetic", help="pack a seeded synthetic stream")
    sy.add_argument("--out", required=True)
    sy.add_argument("--tables", required=True,
                    help="comma-separated rows per table, e.g. 1000,2000")
    sy.add_argument("--pooling", type=int, default=1)
    sy.add_argument("--num-dense", type=int, default=0)
    sy.add_argument("--num-samples", type=int, default=65536)
    sy.add_argument("--samples-per-shard", type=int, default=8192)
    sy.add_argument("--alpha", type=float, default=0.0)
    sy.add_argument("--seed", type=int, default=0)
    sy.add_argument("--weighted", action="store_true")
    cr = sub.add_parser("criteo", help="convert a Criteo-style TSV")
    cr.add_argument("--out", required=True)
    cr.add_argument("--tsv", required=True)
    cr.add_argument("--tables", required=True)
    cr.add_argument("--samples-per-shard", type=int, default=8192)
    args = ap.parse_args(argv)
    rows = tuple(int(x) for x in args.tables.split(","))
    if args.cmd == "synthetic":
        m = pack_synthetic(args.out, rows, args.pooling, args.num_samples,
                           num_dense=args.num_dense, alpha=args.alpha,
                           seed=args.seed, weighted=args.weighted,
                           samples_per_shard=args.samples_per_shard)
    else:
        m = criteo_tsv_to_shards(args.tsv, args.out, rows,
                                 samples_per_shard=args.samples_per_shard)
    print(f"packed {m['num_samples']} samples into {len(m['shards'])} "
          f"shard(s) under {args.out}")


if __name__ == "__main__":
    main()
