"""Graph generation + a REAL fanout neighbor sampler (minibatch_lg needs
one, per the brief).  All host-side numpy, seeded.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray     # [N+1]
    indices: np.ndarray    # [E] neighbor ids
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return len(self.indices)


def random_powerlaw_graph(n_nodes: int, n_edges: int, seed: int = 0,
                          alpha: float = 1.1) -> CSRGraph:
    """Degree-skewed random graph in CSR (preferential-attachment-ish:
    endpoints drawn from a zipf over node ids)."""
    rng = np.random.default_rng(seed)
    u = rng.random(n_edges)
    src = np.minimum((u ** (-1.0 / alpha) - 1.0).astype(np.int64),
                     n_nodes - 1)
    dst = rng.integers(0, n_nodes, n_edges)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(indptr, dst.astype(np.int32), n_nodes)


def random_edge_list(n_nodes: int, n_edges: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, n_nodes, n_edges).astype(np.int32),
            rng.integers(0, n_nodes, n_edges).astype(np.int32))


@dataclasses.dataclass
class NeighborSampler:
    """Uniform fanout sampler (GraphSAGE-style).  For each target node,
    samples fanout[0] neighbors, then fanout[1] neighbors of each, and emits
    a PADDED local subgraph: node 0 is the target, edges point child->parent
    (message direction), masked beyond the real count."""

    graph: CSRGraph
    fanout: tuple = (15, 10)
    n_pad: int = 192
    e_pad: int = 192
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def _sample_neighbors(self, node: int, k: int) -> np.ndarray:
        lo, hi = self.graph.indptr[node], self.graph.indptr[node + 1]
        deg = hi - lo
        if deg == 0:
            return np.empty(0, np.int64)
        pick = self._rng.integers(0, deg, min(k, deg))
        return self.graph.indices[lo + pick].astype(np.int64)

    def sample(self, target: int) -> dict:
        nodes = [int(target)]
        local = {int(target): 0}
        src, dst = [], []
        frontier = [(int(target), 0)]
        for depth, k in enumerate(self.fanout):
            nxt = []
            for parent, ploc in frontier:
                for nb in self._sample_neighbors(parent, k):
                    nb = int(nb)
                    if nb not in local:
                        if len(nodes) >= self.n_pad:
                            continue
                        local[nb] = len(nodes)
                        nodes.append(nb)
                    if len(src) < self.e_pad:
                        src.append(local[nb])
                        dst.append(ploc)
                        nxt.append((nb, local[nb]))
            frontier = nxt
        n, e = len(nodes), len(src)
        out = {
            "nodes": np.pad(np.asarray(nodes, np.int64), (0, self.n_pad - n)),
            "n_real": n,
            "src": np.pad(np.asarray(src, np.int32), (0, self.e_pad - e)),
            "dst": np.pad(np.asarray(dst, np.int32), (0, self.e_pad - e)),
            "edge_mask": np.pad(np.ones(e, np.float32),
                                (0, self.e_pad - e)),
        }
        return out

    def sample_batch(self, targets: np.ndarray, feats: np.ndarray,
                     labels: np.ndarray, coord_dim: int = 3) -> dict:
        """Batched padded subgraphs + gathered features for
        egnn_steps.make_minibatch_train_step."""
        subs = [self.sample(int(t)) for t in targets]
        G = len(subs)
        batch = {
            "feats": np.stack([feats[s["nodes"]] for s in subs]
                              ).astype(np.float32),
            "coords": self._rng.standard_normal(
                (G, self.n_pad, coord_dim)).astype(np.float32),
            "src": np.stack([s["src"] for s in subs]),
            "dst": np.stack([s["dst"] for s in subs]),
            "edge_mask": np.stack([s["edge_mask"] for s in subs]),
            "labels": labels[targets].astype(np.int32),
        }
        return batch
