"""Threaded host-side ingestion pipeline + per-batch index pre-sort.

Two jobs, both off the device critical path:

1. **Overlap**: a worker thread pulls batches from the source (a
   :class:`repro.data.reader.ShardedReader`, a synthetic generator, ...),
   runs the host prep, and parks the result in a bounded queue — so shard
   decode + prep for batch ``n+1`` runs while the devices execute step
   ``n``.  Compose with :func:`repro.train.loop.prefetch_to_device` for
   the H2D leg (this thread produces host arrays; that one device_puts
   them — both are thin wrappers over :class:`ThreadedIterator`, the one
   shared worker/queue/poison implementation).  Worker failures are
   delivered to the consumer as a POISONED queue entry and re-raised
   promptly — the loop never hangs on a dead loader.

2. **Pre-sort**: the fused sparse-update kernel
   (repro/kernels/embedding_update.py) wants the flat lookup stream
   sorted by local row id so duplicate rows form contiguous runs.
   Without host prep, every step pays an on-device ``argsort`` over
   ``L = B*S*P`` keys.  :func:`presort_batch` computes, per embedding
   shard, the EXACT arrays ``kernels.embedding_update.sort_lookups``
   would produce — stable sort permutations are unique, so numpy here
   and XLA there yield bit-identical results — and ships them as batch
   fields (``psort_*``, sharded over the embedding axes).  The step then
   feeds the kernel directly (``host_presort=True`` on the model def)
   and the device sort disappears from the hot path.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterable, Iterator, Optional

import numpy as np

from repro import telemetry

PSORT_KEYS = ("psort_rows", "psort_bags", "psort_msk", "psort_wgt")


def presort_batch(layout, idx: np.ndarray,
                  weights: Optional[np.ndarray] = None) -> dict:
    """Per-shard sorted lookup streams for one global batch (row AND table
    sharding modes).

    ``layout``: :class:`repro.core.sharded_embedding.ShardedEmbeddingLayout`.
    ``idx`` [B, S, P] ORIGINAL-SLOT per-table indices — the same
    global-order stream the step's sparse update consumes (the microbatch
    pipeline restores device-major == global order before the one sparse
    update, so these fields are M-invariant).  ``weights`` [B, S, P]
    optional per-lookup bag weights.

    Row mode sorts each shard's owner-masked local-row stream
    (``L = B*S*P``).  Table mode first folds in the padded-slot permute
    the device-side exchange performs (``permute_indices``: original ->
    padded order, dummy slots read index 0 / weight 0) and sorts each
    shard's slot-offset stream (``L = B*slots_per_shard*P``) — so
    ``host_presort=True`` works in both placement modes.

    Returns ``{psort_rows, psort_bags, psort_msk, psort_wgt}``, each
    ``[num_shards, L]`` — row ``k`` belongs to the shard with embedding-
    axis index ``k`` (shard the leading dim over the embedding axes).
    Bit-compatibility with the on-device ``sort_lookups`` path is
    structural: same int32 key construction, and a stable argsort's
    permutation is uniquely determined by the keys, so
    ``np.argsort(kind='stable')`` here equals ``jnp.argsort`` there.
    """
    B, S, P = idx.shape
    ns, R = layout.num_shards, layout.rows_per_shard
    # int32 end-to-end: the device computes local rows in the index dtype
    if layout.mode == "row":
        off = np.asarray(layout.row_offsets, np.int32)
        g = np.asarray(idx, np.int32) + off[None, :, None]
        locals_ = [(g - np.int32(s * R)).reshape(-1) for s in range(ns)]
        wflat = (None if weights is None
                 else [np.asarray(weights, np.float32).reshape(-1)] * ns)
    elif layout.mode == "table":
        # fold the device-side padded-slot permute into the host sort:
        # original slots -> padded (bin-major) order, dummy slots read
        # index 0 (the scratch row) with weight 0 — exactly the
        # permute_indices + zeroed-weights stream the exchange ships
        src = np.where(layout.padded_slots >= 0, layout.padded_slots, 0)
        dummy = layout.padded_slots < 0
        padded = np.asarray(idx, np.int32)[:, src, :]
        padded[:, dummy, :] = 0
        if weights is not None:
            wp = np.asarray(weights, np.float32)[:, src, :]
            wp[:, dummy, :] = 0.0
        K = layout.slots_per_shard
        off = np.asarray(layout.slot_local_offsets,
                         np.int32).reshape(ns, K)
        locals_ = [(padded[:, s * K:(s + 1) * K, :]
                    + off[s][None, :, None]).reshape(-1)
                   for s in range(ns)]
        wflat = (None if weights is None
                 else [wp[:, s * K:(s + 1) * K, :].reshape(-1)
                       for s in range(ns)])
    else:
        raise ValueError(f"unknown layout mode {layout.mode!r}")
    L = locals_[0].shape[0]
    rows = np.empty((ns, L), np.int32)
    bags = np.empty((ns, L), np.int32)
    msk = np.empty((ns, L), np.int32)
    wgt = np.empty((ns, L), np.float32)
    for s in range(ns):
        local = locals_[s]
        valid = (local >= 0) & (local < R)
        key = np.where(valid, local, R).astype(np.int32)
        order = np.argsort(key, kind="stable")
        skey = key[order]
        rows[s] = np.minimum(skey, R - 1)
        bags[s] = (order // P).astype(np.int32)
        msk[s] = (skey < R).astype(np.int32)
        wgt[s] = 1.0 if wflat is None else wflat[s][order]
    return {"psort_rows": rows, "psort_bags": bags, "psort_msk": msk,
            "psort_wgt": wgt}


_DONE = object()


class _Poison:
    """Queue sentinel carrying a worker exception to the consumer."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class _Stopped(Exception):
    """Internal: close() was requested while the worker held an item."""


class ThreadedIterator:
    """Worker thread + bounded queue + poison sentinel, once.

    Pulls from ``source`` on a daemon thread, applies ``transform`` (the
    host prep: shard decode, pre-sort, device_put, ...) and parks results
    in a ``depth``-bounded queue — backpressure keeps the worker at most
    ``depth`` items (+1 in hand) ahead of the consumer.  Order is
    preserved exactly.  A worker exception poisons the queue and
    re-raises at the consumer's next pull: a dead producer FAILS the
    consumer, it never hangs it.

    ``close()`` stops the worker promptly even when it is blocked on a
    full queue (the put loop watches the stop flag), drains the queue
    and joins — abandoning a partially-consumed stream does not leak a
    blocked thread or its queued items.  ``stats`` counts ``prep_s``
    (worker: source pull + transform), ``wait_s`` (consumer blocked on
    the queue), ``batches`` and ``retries``.

    Resilience knobs: ``retries`` bounds a retry-with-backoff on
    TRANSIENT worker exceptions (a flaky shard read whose ``__next__``
    can be called again; generators that die stay dead and simply end
    the stream) — beyond the budget the queue is poisoned as before.
    ``faults`` is an optional :class:`repro.faults.FaultPlan`; the
    worker fires the ``loader.next`` site once per pull (step-indexed by
    pull count), which is where drills inject loader deaths and stalls.
    After a poison is delivered the stream goes STICKY-DEAD: the
    exception is raised once and later pulls see ``StopIteration`` —
    a consumer that absorbs the error (skip-batch budget) must never
    hang on the dead worker's empty queue.
    """

    def __init__(self, source: Iterable, *,
                 transform: Optional[Callable] = None, depth: int = 2,
                 name: str = "ThreadedIterator", retries: int = 0,
                 retry_backoff_s: float = 0.05, faults=None):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._source = source
        self._transform = transform
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._retries = retries
        self._retry_backoff_s = retry_backoff_s
        self._faults = faults
        self.stats = {"prep_s": 0.0, "wait_s": 0.0, "batches": 0,
                      "retries": 0}
        self._thread = threading.Thread(target=self._work, daemon=True,
                                        name=name)
        self._started = False

    def _put(self, item) -> None:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue
        raise _Stopped

    def _work(self) -> None:
        try:
            it = iter(self._source)
            failures = 0
            pulls = 0
            while not self._stop.is_set():
                t0 = time.perf_counter()
                try:
                    if self._faults is not None:
                        self._faults.fire("loader.next", step=pulls)
                    # span lands on this worker's own trace track (the
                    # thread name: HostPipeline / prefetch_to_device)
                    with telemetry.span("ingest/prep", cat="ingest",
                                        pull=pulls):
                        item = next(it)
                        if self._transform is not None:
                            item = self._transform(item)
                except StopIteration:
                    self._put(_DONE)
                    return
                except _Stopped:
                    raise
                except Exception as e:  # noqa: BLE001 — bounded retry
                    # transient worker failure: retry the pull (sources
                    # whose __next__ is re-callable survive; a dead
                    # generator raises StopIteration on the retry and the
                    # stream ends); past the budget, poison as usual.
                    # InjectedCrash is a BaseException: never retried.
                    if failures < self._retries:
                        failures += 1
                        self.stats["retries"] += 1
                        time.sleep(self._retry_backoff_s
                                   * (2 ** (failures - 1)))
                        continue
                    raise
                pulls += 1
                self.stats["prep_s"] += time.perf_counter() - t0
                self._put(item)
        except _Stopped:
            pass
        except BaseException as e:  # noqa: BLE001 — poison, don't hang
            try:
                self._put(_Poison(e))
            except _Stopped:
                pass

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if not self._started:
            self._thread.start()
            self._started = True
        t0 = time.perf_counter()
        item = self._q.get()
        self.stats["wait_s"] += time.perf_counter() - t0
        if item is _DONE:
            # sticky: repeated next() calls and CHAINED consumers (e.g.
            # the prefetch_to_device worker reading a closed HostPipeline)
            # must also observe end-of-stream instead of blocking forever
            try:
                self._q.put_nowait(_DONE)
            except queue.Full:
                pass
            raise StopIteration
        if isinstance(item, _Poison):
            # sticky-dead: the worker exited after poisoning, so a consumer
            # that catches this exception (TrainLoop's skip-batch budget)
            # and pulls again must observe end-of-stream, not block forever
            # on an empty queue nothing refills
            try:
                self._q.put_nowait(_DONE)
            except queue.Full:
                pass
            raise item.exc
        self.stats["batches"] += 1
        return item

    def close(self) -> None:
        """Stop the worker (promptly, even when blocked on a full queue),
        drain its items, join, and leave a sticky end-of-stream sentinel
        so any consumer currently blocked in ``__next__`` — or pulling
        later — gets StopIteration instead of hanging.  Idempotent."""
        self._stop.set()
        if self._started:
            deadline = time.monotonic() + 5.0
            while (self._thread.is_alive()
                   and time.monotonic() < deadline):
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    time.sleep(0.005)
            self._thread.join(timeout=1.0)
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        try:
            self._q.put_nowait(_DONE)
        except queue.Full:
            pass


class HostPipeline(ThreadedIterator):
    """Background-thread batch prep with bounded lookahead.

    ``batches``: any iterator/iterable of batch dicts (ShardedReader,
    synthetic stream, ...).  ``presort=True`` attaches the ``psort_*``
    fields of :func:`presort_batch` (requires ``layout``); the model def
    consuming them must set ``host_presort=True`` so its batch struct
    declares the fields.

    Iteration re-raises worker exceptions at the consumer's next pull
    (poisoned-queue sentinel — a dead loader fails the step, it does not
    hang it); ``close()`` releases the worker of an abandoned stream.
    ``stats`` feeds ``bench_ingest.py``'s overlap fraction.
    """

    def __init__(self, batches: Iterable[dict], *, layout=None,
                 presort: bool = False, depth: int = 2, retries: int = 0,
                 faults=None):
        if presort and layout is None:
            raise ValueError("presort=True requires the embedding layout")
        self._layout = layout
        self._presort = presort
        super().__init__(batches, transform=self._prep, depth=depth,
                         name="HostPipeline", retries=retries,
                         faults=faults)

    def _prep(self, b: dict) -> dict:
        out = dict(b)
        if self._presort:
            out.update(presort_batch(self._layout, out["idx"],
                                     out.get("weights")))
        return out
