"""Distributed shard reader: mmap-backed, rank-sliced, deterministically
shuffled.

Determinism contract (tests/test_ingest.py):

* The GLOBAL epoch order is a pure function of ``(seed, epoch,
  num_samples, shuffle window)`` — independent of rank count.  Rank ``r``
  of ``R`` takes rows ``[r*B/R, (r+1)*B/R)`` of every global batch, the
  same slice a ``P(('data', ...))`` sharding assigns it, so concatenating
  the rank streams reconstructs the single-reader stream bit-for-bit and
  a job can change rank count without changing the training trajectory.
* Two-level shuffle: level 1 permutes shuffle windows (window size
  defaults to the manifest's ``samples_per_shard``, i.e. shard
  permutation); level 2 permutes samples within each window (intra-shard
  shuffle with bounded memory).  With an explicit ``window`` the order is
  also invariant to how the dataset was re-sharded on disk.
* ``shuffle=False`` is sequential file order — resharding-invariant by
  construction, and the fast path: for a batch whose samples are one
  contiguous range inside one shard, dense/labels come back as mmap
  VIEWS and the fixed-width CSR index decode degenerates to a reshape +
  slot stack (one memcpy, no per-sample work).
"""

from __future__ import annotations

from pathlib import Path
from typing import Iterator, Optional

import numpy as np

from repro.data.format import (FLAG_LABELS, FLAG_WEIGHTS, MAGIC, VERSION,
                               DatasetSpec, _HEADER, _SECTION, load_manifest)


class PackedShard:
    """mmap view of one packed shard file (see format.py for the layout).
    Arrays are exposed as zero-copy numpy views into the map."""

    def __init__(self, path):
        self.path = Path(path)
        raw = np.memmap(self.path, dtype=np.uint8, mode="r")
        magic, ver, n, S, D, flags, n_arr = _HEADER.unpack(
            bytes(raw[:_HEADER.size]))
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        if ver != VERSION:
            raise ValueError(f"{path}: version {ver}, reader is {VERSION}")
        self.num_samples, self.num_slots, self.num_dense = int(n), S, D
        self.has_labels = bool(flags & FLAG_LABELS)
        self.has_weights = bool(flags & FLAG_WEIGHTS)
        table = [
            _SECTION.unpack_from(raw, _HEADER.size + i * _SECTION.size)
            for i in range(n_arr)
        ]

        def view(i, dtype):
            off, nbytes = table[i]
            return raw[off:off + nbytes].view(dtype)

        i = 0
        self.dense = (view(i, np.float32).reshape(n, D) if D else None)
        i += bool(D)
        self.labels = view(i, np.float32) if self.has_labels else None
        i += self.has_labels
        self._offsets, self._indices, self._weights = [], [], []
        for _ in range(S):
            self._offsets.append(view(i, np.int64)); i += 1
            self._indices.append(view(i, np.int32)); i += 1
            if self.has_weights:
                self._weights.append(view(i, np.float32)); i += 1
            else:
                self._weights.append(None)
        self._fixed: dict[tuple[int, int], bool] = {}

    def fixed_pooling(self, s: int, pooling: int) -> bool:
        """True when slot ``s`` is uniformly ``pooling``-wide — the layout
        the writer emits, where decode is a reshape of the index view.
        The offsets scan is cached: the mmap is immutable, and re-checking
        [N+1] offsets per slot per batch would rival the decode cost."""
        key = (s, pooling)
        if key not in self._fixed:
            o = self._offsets[s]
            self._fixed[key] = bool(o[-1] == self.num_samples * pooling
                                    and (np.diff(o) == pooling).all())
        return self._fixed[key]

    def slot_idx(self, s: int, ids: np.ndarray, pooling: int,
                 out_w: Optional[np.ndarray] = None) -> np.ndarray:
        """Gather [len(ids), pooling] int32 indices for slot ``s`` (ragged
        bags are right-padded with index 0 / weight 0).  When ``out_w`` is
        given the per-lookup weights are gathered into it."""
        if self.fixed_pooling(s, pooling):
            mat = self._indices[s].reshape(self.num_samples, pooling)
            if out_w is not None:
                out_w[...] = self._weights[s].reshape(
                    self.num_samples, pooling)[ids]
            return mat[ids]
        if not self.has_weights:
            raise ValueError(
                f"{self.path}: slot {s} has ragged bags but no weights — "
                "padding needs weight 0 to be a no-op; repack the dataset "
                "weighted or fixed-width")
        o = self._offsets[s]
        out = np.zeros((len(ids), pooling), np.int32)
        if out_w is not None:
            out_w[...] = 0.0
        for j, sid in enumerate(ids):
            lo, hi = int(o[sid]), int(o[sid + 1])
            k = min(hi - lo, pooling)
            out[j, :k] = self._indices[s][lo:lo + k]
            if out_w is not None:
                out_w[j, :k] = self._weights[s][lo:lo + k]
        return out


class ShardedReader:
    """Iterate packed shards as model-ready batches.

    ``rank``/``num_ranks`` slice each GLOBAL batch over the data axis (see
    the module docstring for why that — and not whole-shard assignment —
    is what makes the epoch order rank-count-invariant).  The single-host
    drivers here run ``num_ranks=1`` and let ``jax.device_put`` place the
    global batch; a multi-host deployment gives each host its slice.

    Yields dicts: ``idx`` [b, S, P] int32 (+ ``dense_x`` [b, D] f32,
    ``labels`` [b] f32, ``weights`` [b, S, P] f32 per the DatasetSpec).
    """

    def __init__(self, data_dir, batch: int, *, rank: int = 0,
                 num_ranks: int = 1, seed: int = 0, shuffle: bool = True,
                 window: Optional[int] = None, drop_remainder: bool = True):
        if not (0 <= rank < num_ranks):
            raise ValueError(f"rank {rank} not in [0, {num_ranks})")
        if batch % num_ranks:
            raise ValueError(f"batch {batch} not divisible by num_ranks "
                             f"{num_ranks}")
        self.spec, self.manifest = load_manifest(data_dir)
        self.data_dir = Path(data_dir)
        self.batch, self.rank, self.num_ranks = batch, rank, num_ranks
        self.seed, self.shuffle = seed, shuffle
        self.window = int(window or self.manifest["samples_per_shard"])
        self.drop_remainder = drop_remainder
        self.shards = [PackedShard(self.data_dir / s["file"])
                       for s in self.manifest["shards"]]
        counts = np.array([s.num_samples for s in self.shards], np.int64)
        self.num_samples = int(counts.sum())
        if self.num_samples != self.manifest["num_samples"]:
            raise ValueError("manifest/shard sample-count mismatch")
        self._starts = np.concatenate([[0], np.cumsum(counts)])
        if not drop_remainder and self.num_samples % batch:
            raise ValueError("drop_remainder=False requires num_samples "
                             "divisible by batch")
        if batch > self.num_samples:
            raise ValueError(f"batch {batch} > dataset {self.num_samples}")

    # -- epoch order ---------------------------------------------------------

    def iter_epoch_windows(self, epoch: int) -> Iterator[np.ndarray]:
        """Global sample order for one epoch, streamed one shuffle window
        at a time (rank-independent).  O(window) memory — the shuffle
        never materializes the full O(N) permutation, which matters at
        the terabyte scale the format targets."""
        N, W = self.num_samples, self.window
        if not self.shuffle:
            for lo in range(0, N, W):
                yield np.arange(lo, min(lo + W, N), dtype=np.int64)
            return
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch]))
        nwin = -(-N // W)
        for w in rng.permutation(nwin):        # level 1: window permutation
            lo = int(w) * W
            m = min(W, N - lo)
            yield lo + rng.permutation(m)      # level 2: intra-window

    def epoch_order(self, epoch: int) -> np.ndarray:
        """Materialized epoch order (tests / small datasets); iteration
        itself uses the streamed :meth:`iter_epoch_windows`."""
        return np.concatenate(list(self.iter_epoch_windows(epoch)))

    def batches_per_epoch(self) -> int:
        return self.num_samples // self.batch

    # -- gather --------------------------------------------------------------

    def _gather(self, ids: np.ndarray) -> dict:
        spec = self.spec
        S, P = spec.num_slots, spec.pooling
        b = len(ids)
        sh = np.searchsorted(self._starts, ids, side="right") - 1
        first = self.shards[sh[0]]
        local = ids - self._starts[sh]
        contig = bool((sh == sh[0]).all() and (np.diff(local) == 1).all())
        out: dict[str, np.ndarray] = {}
        if contig and all(first.fixed_pooling(s, P) for s in range(S)):
            # fast path: one contiguous range in one shard -> mmap views
            # (dense/labels) + a reshape/stack of the index views
            lo, hi = int(local[0]), int(local[0]) + b
            out["idx"] = np.stack(
                [first._indices[s].reshape(first.num_samples, P)[lo:hi]
                 for s in range(S)], axis=1)
            if spec.num_dense:
                out["dense_x"] = first.dense[lo:hi]
            if spec.labels:
                out["labels"] = first.labels[lo:hi]
            if spec.weighted:
                out["weights"] = np.stack(
                    [first._weights[s].reshape(first.num_samples, P)[lo:hi]
                     for s in range(S)], axis=1)
            return out
        idx = np.empty((b, S, P), np.int32)
        wgt = np.empty((b, S, P), np.float32) if spec.weighted else None
        if spec.num_dense:
            out["dense_x"] = np.empty((b, spec.num_dense), np.float32)
        if spec.labels:
            out["labels"] = np.empty((b,), np.float32)
        for u in np.unique(sh):
            sel = np.flatnonzero(sh == u)
            shard, loc = self.shards[u], local[sh == u]
            for s in range(S):
                w_out = (np.empty((len(loc), P), np.float32)
                         if spec.weighted else None)
                idx[sel, s, :] = shard.slot_idx(s, loc, P, out_w=w_out)
                if spec.weighted:
                    wgt[sel, s, :] = w_out
            if spec.num_dense:
                out["dense_x"][sel] = shard.dense[loc]
            if spec.labels:
                out["labels"][sel] = shard.labels[loc]
        out["idx"] = idx
        if spec.weighted:
            out["weights"] = wgt
        return out

    # -- iteration -----------------------------------------------------------

    def epoch_batches(self, epoch: int) -> Iterator[dict]:
        B, R, r = self.batch, self.num_ranks, self.rank
        share = B // R
        buf = np.empty(0, np.int64)        # O(window + batch) id buffer
        produced, total = 0, self.batches_per_epoch()
        for win in self.iter_epoch_windows(epoch):
            buf = np.concatenate([buf, win])
            while len(buf) >= B and produced < total:
                yield self._gather(buf[r * share:(r + 1) * share])
                buf = buf[B:]
                produced += 1
        # trailing < batch ids dropped (drop_remainder)

    def batches(self, epochs: Optional[int] = None) -> Iterator[dict]:
        epoch = 0
        while epochs is None or epoch < epochs:
            yield from self.epoch_batches(epoch)
            epoch += 1

    def __iter__(self) -> Iterator[dict]:
        return self.batches()

    def nbytes_per_batch(self) -> int:
        """Decoded bytes one rank pulls per batch (bench accounting)."""
        spec = self.spec
        b = self.batch // self.num_ranks
        n = b * spec.num_slots * spec.pooling * 4
        if spec.weighted:
            n *= 2
        n += b * spec.num_dense * 4 + (b * 4 if spec.labels else 0)
        return n
