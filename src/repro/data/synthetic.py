"""Synthetic data generators (seeded, host-side numpy).

The paper evaluates with random datasets for small/large and the Criteo
Terabyte set for MLPerf; the key *performance-relevant* property of real
click logs is the skewed index distribution (the paper's Fig. 8 contention
analysis: "a lot of contention with the terabyte dataset causing up to 10x
slowdown").  ``alpha`` controls a Zipf-like skew so benchmarks can reproduce
both regimes.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


def zipf_indices(rng: np.random.Generator, vocab: int, size, alpha: float
                 ) -> np.ndarray:
    """alpha == 0 -> uniform; larger alpha -> heavier head skew."""
    if alpha <= 0:
        return rng.integers(0, vocab, size, dtype=np.int64)
    # inverse-CDF sampling of a truncated zipf: ranks ~ u^(-1/(alpha));
    # clip in FLOAT space first (tiny alpha overflows any integer type)
    u = rng.random(size)
    with np.errstate(over="ignore"):
        ranks = np.clip(u ** (-1.0 / alpha) - 1.0, 0.0, float(vocab - 1))
    return ranks.astype(np.int64)


@dataclasses.dataclass
class SparseBatchSpec:
    table_rows: tuple          # rows per TABLE
    slot_to_table: Optional[tuple]  # slot -> table (None = identity)
    pooling: int
    batch: int
    num_dense: int = 0
    alpha: float = 0.0         # index skew
    seq_mask: bool = False     # emit all-ones seq_mask (sasrec)
    hist_mask: bool = False    # emit all-ones hist_mask (din)
    labels: bool = True

    @property
    def slots(self):
        return (self.slot_to_table if self.slot_to_table is not None
                else tuple(range(len(self.table_rows))))


def sparse_batch(rng: np.random.Generator, spec: SparseBatchSpec) -> dict:
    """One global batch for the hybrid-parallel models (original slot
    order; callers permute for table mode)."""
    B, P = spec.batch, spec.pooling
    cols = []
    for t in spec.slots:
        cols.append(zipf_indices(rng, spec.table_rows[t], (B, P), spec.alpha))
    batch = {"idx": np.stack(cols, axis=1).astype(np.int32)}
    if spec.num_dense:
        batch["dense_x"] = rng.standard_normal(
            (B, spec.num_dense)).astype(np.float32)
    if spec.labels:
        batch["labels"] = rng.integers(0, 2, (B,)).astype(np.float32)
    if spec.seq_mask:
        batch["seq_mask"] = np.ones((B, 50), np.float32)
    if spec.hist_mask:
        batch["hist_mask"] = np.ones((B, 100), np.float32)
    return batch


def dlrm_stream(seed: int, cfg, alpha: float = 0.0) -> Iterator[dict]:
    """Batches for repro.core.dlrm.DLRMConfig (row mode slot order)."""
    rng = np.random.default_rng(seed)
    spec = SparseBatchSpec(cfg.table_rows, None, cfg.pooling, cfg.batch,
                           num_dense=cfg.num_dense, alpha=alpha)
    while True:
        b = sparse_batch(rng, spec)
        b["dense_x"] = b["dense_x"].astype(np.float32)
        yield b


def hybrid_stream(seed: int, mdef, alpha: float = 0.0) -> Iterator[dict]:
    """Batches for repro.core.hybrid.HybridDef models."""
    rng = np.random.default_rng(seed)
    spec = SparseBatchSpec(
        mdef.spec.table_rows, mdef.slot_to_table, mdef.pooling, mdef.batch,
        alpha=alpha, labels="labels" in mdef.extras,
        seq_mask="seq_mask" in mdef.extras,
        hist_mask="hist_mask" in mdef.extras)
    while True:
        yield sparse_batch(rng, spec)


def token_stream(seed: int, vocab: int, batch: int, seq: int
                 ) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
