"""Distribution helpers: mesh-axis conventions and GSPMD placement policies."""
