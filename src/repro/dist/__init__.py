"""Distribution helpers: mesh-axis conventions and GSPMD placement policies."""

from repro.dist.exchange import ExchangeConfig, resolve_exchange  # noqa: E402,F401
