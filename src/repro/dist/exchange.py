"""Typed communication/precision config for the hybrid step's collectives.

The update path has been compressed since the start (bf16 optimizer state,
Split-SGD bf16 weights) but the pipeline's two dominant collectives — the
dY exchange (``all_gather(dY)`` in row mode, ``all_to_all(dY)`` in table
mode) and the dense-gradient reduce-scatter — historically moved fp32 in
table mode.  This module owns the knob that compresses them, and the API
those knobs hang off:

:class:`ExchangeConfig`
    One frozen dataclass consolidating the comm/precision surface that
    used to sprawl across flat ``HybridDef`` kwargs: the index-exchange
    lowering (``exchange_impl``), the dense bf16-wire error feedback
    (``compress_grads``), the RS+AG bucketing (``num_buckets``), and the
    new per-collective wire dtypes.  Models pass
    ``exchange=ExchangeConfig(...)``; the old flat kwargs are still
    accepted and coerced here (with a ``DeprecationWarning``).

Wire formats (per collective, ``dY_dtype`` / ``dense_dtype``):

``"fp32"``
    The historical wire — bitwise identical to the pre-config step.  (In
    ROW mode the dY gather has ALWAYS been a round-to-nearest bf16
    payload, matching the bf16 ``psum_scatter`` forward; ``"fp32"`` keeps
    exactly that historical wire rather than inflating it.)
``"bf16"``
    Round-to-nearest truncation on the wire: halves the table-mode dY
    all_to_all and the dense reduce-scatter payloads.  On the dense path
    this is the legacy ``compress_grads`` scheme — the fp32 quantization
    residual of each device's own contribution is carried to the next
    step (error feedback) so the update stays unbiased.

    The dY payloads are bitcast to uint16 around the collective so the
    compiled HLO genuinely moves 2 bytes/element (see
    ``sharded_embedding.gather_dY``).  The dense reduce-scatter is a
    REDUCTION — its wire format is the per-contribution quantization
    (each device's bucket is rounded to bf16 before the sum), which is
    the value-level contract; the carrier dtype is backend-dependent
    because jax upcasts sub-fp32 psums to fp32 accumulation, so the
    modeled RS byte saving applies to wire-native collective backends.
``"bf16_sr"``
    Seeded stochastic rounding (repro/optim/stochastic.py): the 16-bit
    dither is a counter-based pure function of ``(sr counter, payload
    tag, element index)``, so every rank computes the same bits for its
    payload and a run resumed from a checkpoint replays the EXACT wire
    dither (the replicated ``state["sr"]`` scalar is part of the
    checkpoint).  Unbiased without carrying an error slab.

Degeneration contract (tests/test_exchange.py): values that are already
representable in bf16 — zeros included, so all-zero gradients — survive
ANY wire format bitwise, because truncation of an exact value is exact
and the SR dither (<= 0xFFFF on the discarded mantissa half) cannot
carry into the kept half.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.optim import stochastic

WIRE_DTYPES = ("fp32", "bf16", "bf16_sr")
EXCHANGE_IMPLS = ("fused", "ring")
# bytes per element actually moved by the collective under each format
WIRE_ITEMSIZE = {"fp32": 4, "bf16": 2, "bf16_sr": 2}

# high-bit stream bases separating the two wire-dither tag namespaces:
# the dY exchange tags payloads by microbatch, the dense reduce-scatter
# by bucket — both additionally mix the sender's rank (wire_tag), so no
# two payloads in a step share a dither stream, and neither collides
# with the row-state dither of repro/optim/stochastic.sr_noise.
TAG_DY = 0xDE100000
TAG_DENSE = 0xD5E00000


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    """Comm/precision config of the hybrid step's collectives.

    ``impl``
        Index-exchange lowering: ``'fused'`` (one all_gather) or
        ``'ring'`` (ppermute-chunked — finer units for the latency-hiding
        scheduler; bit-identical result).
    ``dY_dtype`` / ``dense_dtype``
        Wire format of the dY exchange / the dense gradient
        reduce-scatter (see module docstring).  The all-gather of updated
        dense weights is ALWAYS bf16 (the Split-SGD hi half) and is not
        configurable here.
    ``error_feedback``
        Dense ``'bf16'`` wire only: carry each device's fp32 quantization
        residual to the next step (requires the ``err`` state slab, which
        the state builders materialize iff :attr:`needs_err`).  Ignored
        for ``'fp32'`` (nothing to feed back) and ``'bf16_sr'`` (the
        dither already unbiases the wire).
    ``num_buckets``
        RS+AG bucketing of the flat dense gradient (paper C4): bucket
        k+1's collectives overlap bucket k's shard update.
    """

    impl: str = "fused"
    dY_dtype: str = "fp32"
    dense_dtype: str = "fp32"
    error_feedback: bool = True
    num_buckets: int = 4

    def __post_init__(self):
        if self.impl not in EXCHANGE_IMPLS:
            raise ValueError(
                f"unknown exchange_impl {self.impl!r}; expected 'fused' "
                "(one all_gather) or 'ring' (ppermute-chunked)")
        for field, v in (("dY_dtype", self.dY_dtype),
                         ("dense_dtype", self.dense_dtype)):
            if v not in WIRE_DTYPES:
                raise ValueError(f"unknown {field} {v!r}; expected one of "
                                 f"{WIRE_DTYPES}")
        if self.num_buckets < 1:
            raise ValueError(
                f"num_buckets must be >= 1, got {self.num_buckets}")

    @property
    def needs_sr(self) -> bool:
        """Whether any wire format consumes the per-step ``sr`` counter."""
        return "bf16_sr" in (self.dY_dtype, self.dense_dtype)

    @property
    def needs_err(self) -> bool:
        """Whether the dense path carries the error-feedback ``err`` slab."""
        return self.dense_dtype == "bf16" and self.error_feedback


def resolve_exchange(mdef) -> ExchangeConfig:
    """The ONE reader of a model definition's comm/precision surface.

    Precedence: a typed ``exchange=ExchangeConfig(...)`` wins and must be
    the only spelling (mixing it with any flat kwarg raises — a stale
    flat override silently losing to the typed config would be worse).
    Otherwise the flat kwargs are coerced: ``exchange_dtype`` is
    supported sugar setting BOTH wire dtypes; ``exchange_impl`` /
    ``compress_grads`` / ``num_buckets`` are deprecated and warn."""
    typed = getattr(mdef, "exchange", None)
    sugar = getattr(mdef, "exchange_dtype", None)
    impl = getattr(mdef, "exchange_impl", None)
    compress = getattr(mdef, "compress_grads", None)
    buckets = getattr(mdef, "num_buckets", None)
    if typed is not None:
        if not isinstance(typed, ExchangeConfig):
            raise TypeError("exchange must be an ExchangeConfig, got "
                            f"{type(typed).__name__}")
        clash = [n for n, v in (("exchange_dtype", sugar),
                                ("exchange_impl", impl),
                                ("compress_grads", compress),
                                ("num_buckets", buckets)) if v is not None]
        if clash:
            raise ValueError(
                "pass either exchange=ExchangeConfig(...) or the flat "
                f"kwargs, not both (flat also set: {', '.join(clash)})")
        return typed
    deprecated = [n for n, v in (("exchange_impl", impl),
                                 ("compress_grads", compress),
                                 ("num_buckets", buckets)) if v is not None]
    if deprecated:
        warnings.warn(
            f"flat kwarg(s) {', '.join(deprecated)} are deprecated; pass "
            "exchange=ExchangeConfig(impl=..., dense_dtype=..., "
            "num_buckets=...) instead (docs/pipeline.md, 'Communication "
            "precision')", DeprecationWarning, stacklevel=3)
    if sugar is not None and compress is not None:
        raise ValueError(
            "exchange_dtype and compress_grads both set: compress_grads "
            "is legacy sugar for dense_dtype='bf16' — drop it (or pass a "
            "full exchange=ExchangeConfig(...))")
    if sugar is not None:
        dY = dense = sugar
    else:
        dY = "fp32"
        dense = "bf16" if compress else "fp32"
    return ExchangeConfig(
        impl=impl if impl is not None else "fused",
        dY_dtype=dY, dense_dtype=dense, error_feedback=True,
        num_buckets=buckets if buckets is not None else 4)


def wire_itemsize(dtype: str) -> int:
    return WIRE_ITEMSIZE[dtype]


def wire_tag(base: int, site: int, rank) -> jax.Array:
    """uint32 stream tag for one wire payload: a static stream base
    (:data:`TAG_DY` / :data:`TAG_DENSE`), a static site within the step
    (microbatch index / bucket index), and the traced sender rank, spread
    onto decorrelating Weyl constants.  Purely positional — no sampler
    state — so the tag (and therefore the dither) of every payload is
    reproducible from the checkpointed ``sr`` counter alone."""
    return (jnp.uint32(base)
            ^ jnp.uint32((site * 0x9E3779B1) & 0xFFFFFFFF)
            ^ jnp.asarray(rank).astype(jnp.uint32) * jnp.uint32(0x85EBCA6B))


def wire_encode(x: jax.Array, dtype: str, seed=None, tag=None) -> jax.Array:
    """fp32 -> on-wire payload under ``dtype``.  ``'fp32'`` is the
    identity; ``'bf16'`` rounds to nearest; ``'bf16_sr'`` adds the seeded
    counter dither (``seed`` = the replicated per-step sr counter,
    ``tag`` from :func:`wire_tag`)."""
    if dtype == "fp32":
        return x
    if dtype == "bf16":
        return x.astype(jnp.bfloat16)
    if dtype != "bf16_sr":
        raise ValueError(f"unknown wire dtype {dtype!r}; expected one of "
                         f"{WIRE_DTYPES}")
    seed = jnp.int32(0) if seed is None else seed
    return stochastic.sr_round_bf16_wire(x, seed, tag)


def wire_decode(x: jax.Array) -> jax.Array:
    """On-wire payload -> fp32 (exact: bf16 -> fp32 widening)."""
    return x.astype(jnp.float32)
