"""Placement policy: Megatron-style TP + FSDP PartitionSpecs for LM trees.

Mesh convention (shared with repro.core.hybrid / repro.core.dlrm): the LAST
mesh axis is ``model``; every other axis is data-parallel.  Policies:

* ``tp`` — Megatron tensor parallel: column-parallel projections shard the
  OUTPUT dim over ``model`` (wq/wk/wv/wg/wu, unembed), row-parallel ones the
  INPUT dim (wo, wd), so each pair needs one collective.  The embedding is
  vocab-parallel (``model`` on the vocab dim).
* ``fsdp`` — ZeRO-3 style weight sharding over the DATA axes (over the FULL
  mesh when tp is off).  Applied to the matmul input dim, which GSPMD
  all-gathers just-in-time.
* MoE expert weights keep expert-parallel placement over the data axes and
  TP over the FFN dim REGARDLESS of the dense policy — the EP all-to-all in
  :mod:`repro.models.transformer` assumes it.
* Norm/bias vectors and routers are replicated.

Leaves are classified by their dict key (``wq``/``wo``/``embed``/... );
leading stack dims (layers, experts) stay unsharded.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL = "model"

_ROW = frozenset({"wo", "wd"})           # row-parallel: model on input dim
_REPLICATED = frozenset({"router"})


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes: every mesh axis except ``model``."""
    return tuple(a for a in mesh.axis_names if a != MODEL)


def named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _path_keys(path) -> list[str]:
    return [str(k.key) for k in path if hasattr(k, "key")]


def lm_param_specs(params, fsdp: bool = True, tp: bool = True):
    """PartitionSpec tree for an LM param tree (see module docstring).

    ``params`` may hold arrays or ShapeDtypeStructs (eval_shape trees).
    """
    def spec(path, leaf):
        n = len(leaf.shape)
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        if name in _REPLICATED or "norm" in name or name.startswith("ln"):
            return P(*([None] * n))
        if name == "embed":                      # (vocab, d): vocab-parallel
            return P(MODEL if tp else None,
                     _fsdp_axis(fsdp, tp) if fsdp else None)
        moe = "moe" in keys and "shared" not in keys
        if moe and name in ("wg", "wu"):         # (..., E, d, f): EP + TP
            return P(*([None] * (n - 3)), "data", None, MODEL)
        if moe and name == "wd":                 # (..., E, f, d)
            return P(*([None] * (n - 3)), "data", MODEL, None)
        if n < 2:
            return P(*([None] * n))
        lead = [None] * (n - 2)
        if name in _ROW and tp:
            return P(*lead, MODEL, "data" if fsdp else None)
        col_in = _fsdp_axis(fsdp, tp) if fsdp else None
        col_out = MODEL if tp else None
        return P(*lead, col_in, col_out)

    return jax.tree_util.tree_map_with_path(spec, params)


def _fsdp_axis(fsdp: bool, tp: bool):
    """FSDP spans the data axes, or the FULL mesh when TP is off (ZeRO-3
    over every device)."""
    return "data" if tp else ("data", MODEL)
