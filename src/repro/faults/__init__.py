"""Deterministic fault injection + structured failure-event logging.

The drill harness behind ``docs/resilience.md``: a seeded, step-indexed
:class:`FaultPlan` fires faults through explicit hook points in the
checkpoint / train / data layers, and a :class:`FailureLog` records every
recovery action those layers take.  ``tests/test_faults.py`` runs the
kill matrix; ``benchmarks/bench_resilience.py`` prices recovery.
"""

from repro.faults.log import FailureLog  # noqa: F401
from repro.faults.plan import (  # noqa: F401
    CKPT_SITES,
    NO_FAULTS,
    SITES,
    Fault,
    FaultPlan,
    InjectedCrash,
    corrupt_checkpoint,
)
