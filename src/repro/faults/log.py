"""Structured failure-event log.

Every recovery action in the resilience stack — a checkpoint write retry,
a corrupt checkpoint skipped during the restore scan, a skipped batch, a
preemption — records a structured event here instead of (only) printing.
Drills and the resilience bench assert on ``counts()``; operators tail the
JSON-lines file.

Events are plain dicts: ``{"kind": ..., "t": <unix time>, **fields}``.
Thread-safe (the checkpoint writer thread and loader workers record
concurrently with the train loop).
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import Counter
from pathlib import Path
from typing import Optional

from repro import telemetry


class FailureLog:
    """Append-only event list, optionally mirrored to a ``.jsonl`` file.

    The mirror is flushed AND fsynced per event: these lines exist for the
    post-mortem of a process that may die on the very next instruction, so
    an event buffered in userspace (or the page cache) is an event lost.
    Each event is also an instant on the process trace timeline (track
    ``faults``), so recovery actions line up against the train-loop and
    checkpoint-writer spans in Perfetto.
    """

    def __init__(self, path: Optional[str] = None):
        self.events: list[dict] = []
        self.path = Path(path) if path else None
        self._lock = threading.Lock()
        if self.path is not None:
            self.path.parent.mkdir(parents=True, exist_ok=True)

    def record(self, kind: str, **fields) -> dict:
        event = {"kind": kind, "t": time.time(), **fields}
        with self._lock:
            self.events.append(event)
            if self.path is not None:
                with self.path.open("a") as f:
                    f.write(json.dumps(event, default=str) + "\n")
                    f.flush()
                    os.fsync(f.fileno())
        telemetry.instant(f"fault/{kind}", cat="fault", track="faults",
                          **{k: str(v) for k, v in fields.items()})
        return event

    def counts(self) -> dict[str, int]:
        with self._lock:
            return dict(Counter(e["kind"] for e in self.events))

    def of_kind(self, kind: str) -> list[dict]:
        with self._lock:
            return [e for e in self.events if e["kind"] == kind]

    def __len__(self) -> int:
        with self._lock:
            return len(self.events)
