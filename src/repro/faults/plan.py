"""Deterministic fault injection for resilience drills.

A :class:`FaultPlan` is a seeded, step-indexed schedule of :class:`Fault`s
fired through EXPLICIT hook points (``plan.fire(site, step=...)``) that the
checkpoint / train / data layers call at their failure-prone boundaries —
no monkeypatching, so the injected control flow is exactly the production
control flow.  The registered sites:

=========================  =====================================================
site                       fired by
=========================  =====================================================
``ckpt.write.arrays``      ``CheckpointManager`` before writing ``arrays.npz``
``ckpt.write.meta``        before writing ``meta.json``
``ckpt.commit``            between the tmp-dir write and ``os.replace``
``loader.next``            ``ThreadedIterator`` worker, once per source pull
``train.step``             ``TrainLoop`` inside the timed step window
=========================  =====================================================

Actions:

* ``raise``   — raise ``exc`` (default ``RuntimeError``); models transient
  failures (ENOSPC via ``exc=OSError(errno.ENOSPC, ...)``, a flaky shard
  read, ...).  Retry/backoff layers are allowed to absorb these.
* ``crash``   — raise :class:`InjectedCrash` (a ``BaseException``): simulated
  process death.  Retry handlers for transient IO MUST NOT swallow it, and
  a drilled ``TrainLoop`` dies without writing its final checkpoint —
  exactly like a real ``kill -9``.
* ``partial`` — marker returned to the hook: the checkpoint writer COMMITS a
  torn ``arrays.npz`` (truncated bytes behind a valid-looking directory)
  and then crashes — the torn-write case checksum verification exists for.
* ``stall``   — sleep ``delay_s`` at the site, then continue (injected
  straggler / loader stall; shows up in step timing, not correctness).
* ``preempt`` / ``sigterm`` — marker for ``TrainLoop``: simulate host
  preemption (``sigterm`` delivers a real ``signal.SIGTERM`` to the process
  when the loop runs on the main thread; ``preempt`` sets the stop flag
  directly, the non-main-thread degradation).

Every fire is recorded on ``plan.fired`` (and the optional
:class:`repro.faults.log.FailureLog`), so drills can assert the fault
actually happened.  ``FaultPlan.random`` derives a schedule from a seed via
``numpy.random.default_rng`` — same seed, same faults, every run.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable, Optional, Union

ACTIONS = ("raise", "crash", "partial", "stall", "preempt", "sigterm")

CKPT_SITES = ("ckpt.write.arrays", "ckpt.write.meta", "ckpt.commit")
SITES = CKPT_SITES + ("loader.next", "train.step")


class InjectedCrash(BaseException):
    """Simulated process death at a fault site.

    Deliberately a ``BaseException``: the bounded-retry paths for transient
    IO catch ``OSError``/``Exception`` and must never absorb a crash — a
    crashed process does not get to retry, and a drilled ``TrainLoop``
    skips its final checkpoint on the way out.
    """


@dataclasses.dataclass
class Fault:
    """One scheduled fault: fire ``action`` at ``site`` on step ``step``.

    ``step=None`` arms the fault for the first ``times`` fires of the site
    regardless of step.  ``exc`` is the exception to raise for
    ``action="raise"`` — an instance or a zero-arg factory.
    """

    site: str
    action: str = "raise"
    step: Optional[int] = None
    times: int = 1
    exc: Union[BaseException, Callable[[], BaseException], None] = None
    delay_s: float = 0.0

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r} (one of {ACTIONS})")

    def make_exc(self) -> BaseException:
        if self.exc is None:
            at = "" if self.step is None else f" step {self.step}"
            return RuntimeError(f"injected fault at {self.site}{at}")
        return self.exc() if callable(self.exc) else self.exc


class FaultPlan:
    """A deterministic, step-indexed schedule of faults.

    Thread-safe: hook points fire from loader worker threads and the
    checkpoint writer thread as well as the train loop.  Sites the plan
    does not name are free (``fire`` returns ``None`` without work), so an
    empty plan is safe to leave permanently wired in.
    """

    def __init__(self, faults: Iterable[Fault] = (), log=None):
        self._faults = [dataclasses.replace(f) for f in faults]
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self.fired: list[tuple[str, int, str]] = []
        self.log = log

    # ------------------------------------------------------------- build
    @classmethod
    def single(cls, site: str, action: str = "raise", step: Optional[int] = None, **kw) -> "FaultPlan":
        return cls([Fault(site, action=action, step=step, **kw)])

    @classmethod
    def random(
        cls,
        seed: int,
        sites: Iterable[str],
        steps: int,
        rate: float = 0.05,
        action: str = "raise",
        log=None,
    ) -> "FaultPlan":
        """Seeded pseudo-random schedule: each (site, step) pair fires with
        probability ``rate``.  Pure function of ``seed`` — drills replay."""
        import numpy as np

        rng = np.random.default_rng(seed)
        faults = [
            Fault(site, action=action, step=s)
            for site in sites
            for s in range(steps)
            if rng.random() < rate
        ]
        return cls(faults, log=log)

    # -------------------------------------------------------------- fire
    def fire(self, site: str, step: Optional[int] = None) -> Optional[Fault]:
        """Hook point.  Returns ``None`` (no fault armed here), performs the
        fault's action (raise / crash / sleep), or returns the matched
        :class:`Fault` for marker actions the site interprets itself."""
        with self._lock:
            count = self._counters.get(site, 0)
            self._counters[site] = count + 1
            at = count if step is None else step
            hit = None
            for f in self._faults:
                if f.times > 0 and f.site == site and (f.step is None or f.step == at):
                    hit = f
                    break
            if hit is None:
                return None
            hit.times -= 1
            self.fired.append((site, at, hit.action))
        if self.log is not None:
            self.log.record("fault_injected", site=site, step=at, action=hit.action)
        if hit.action == "raise":
            raise hit.make_exc()
        if hit.action == "crash":
            raise InjectedCrash(f"injected crash at {site} step {at}")
        if hit.action == "stall":
            time.sleep(hit.delay_s)
        return hit

    def count(self, site: Optional[str] = None) -> int:
        """How many faults have fired (at ``site``, or in total)."""
        with self._lock:
            return len([f for f in self.fired if site is None or f[0] == site])


#: Shared empty plan: ``NO_FAULTS.fire(...)`` is a cheap no-op, so
#: production call sites never need a None check.
NO_FAULTS = FaultPlan()


def corrupt_checkpoint(directory, step: int, mode: str = "flip", seed: int = 0) -> str:
    """Deterministically damage a COMMITTED checkpoint — the drill utility
    for bit-rot / torn-write scenarios that happen outside the writer's
    control.  Returns the damaged file's path.

    ``mode``: ``flip`` xor-flips 16 seeded byte positions of
    ``arrays.npz``; ``truncate`` cuts it to a third; ``no_meta`` deletes
    ``meta.json`` (an incomplete directory); ``meta_garbage`` overwrites
    ``meta.json`` with non-JSON bytes.
    """
    import numpy as np
    from pathlib import Path

    cdir = Path(directory) / f"step_{step}"
    arrays = cdir / "arrays.npz"
    meta = cdir / "meta.json"
    if mode == "flip":
        raw = bytearray(arrays.read_bytes())
        rng = np.random.default_rng(seed)
        # flip inside the payload region, away from the zip end-of-archive
        # record, so np.load still opens the file and verification has to
        # catch the damage by CHECKSUM, not by parse failure
        lo = len(raw) // 4
        hi = len(raw) - 1024 if len(raw) > 2048 else (3 * len(raw)) // 4
        hi = max(hi, lo + 1)
        for pos in rng.integers(lo, hi, size=16):
            raw[int(pos)] ^= 0xFF
        arrays.write_bytes(bytes(raw))
        return str(arrays)
    if mode == "truncate":
        raw = arrays.read_bytes()
        arrays.write_bytes(raw[: max(1, len(raw) // 3)])
        return str(arrays)
    if mode == "no_meta":
        meta.unlink()
        return str(meta)
    if mode == "meta_garbage":
        meta.write_bytes(b"\x00not json\xff")
        return str(meta)
    raise ValueError(f"unknown corruption mode {mode!r}")
