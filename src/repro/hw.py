"""Target-hardware constants (TPU v5e) used by the roofline analysis.

The container runs on CPU; these describe the TARGET platform that the
dry-run artifacts are analyzed against (see EXPERIMENTS.md section Roofline).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    hbm_bw: float           # bytes/s per chip
    ici_bw_per_link: float  # bytes/s per ICI link (one direction)
    ici_links: int          # links per chip participating in collectives
    hbm_bytes: int          # HBM capacity per chip
    vmem_bytes: int         # VMEM per core


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)

# MXU / VPU native tile granularities — BlockSpec shapes in kernels/ are
# multiples of these.
MXU_DIM = 128
SUBLANE = 8
LANE = 128
