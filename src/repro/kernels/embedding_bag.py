"""Pallas TPU kernel: EmbeddingBag-sum forward (paper Alg. 1, contribution C1).

The hot loop of DLRM.  On CPU the paper streams consecutive cache lines per
row and parallelizes over bags; the TPU-native structure is a
``PrefetchScalarGridSpec``: the index array is scalar-prefetched so the
pipeline can issue the HBM->VMEM row DMA for lookup (n, p+1) while row
(n, p) is being accumulated in VMEM.  The bag dimension is the outer grid
axis (= the paper's ``#pragma omp parallel for`` over N), the pooling
dimension the inner one, and the row accumulation is fp32.

This kernel should run at HBM-bandwidth roofline — the GUPS-like
expectation the paper states in Sect. II.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, o_ref, *, pooling: int, bags_per_block: int):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += w_ref[...].astype(jnp.float32)


def embedding_bag_pallas(W: jax.Array, idx: jax.Array,
                         interpret: bool = False) -> jax.Array:
    """W [M, E], idx [N, P] int32 -> [N, E] fp32 bag sums.

    E must be lane-aligned (multiple of 128) for the TPU target; the ops.py
    wrapper pads smaller embedding dims.
    """
    M, E = W.shape
    N, P = idx.shape
    grid = (N, P)
    return pl.pallas_call(
        functools.partial(_kernel, pooling=P, bags_per_block=1),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # one embedding row per step, chosen by the prefetched index
                pl.BlockSpec((1, E), lambda n, p, idx_ref: (idx_ref[n, p], 0)),
            ],
            out_specs=pl.BlockSpec((1, E), lambda n, p, idx_ref: (n, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, E), jnp.float32),
        interpret=interpret,
    )(idx, W)
