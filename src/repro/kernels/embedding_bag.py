"""Pallas TPU kernel: EmbeddingBag-sum forward (paper Alg. 1, contribution C1).

The hot loop of DLRM.  On CPU the paper streams consecutive cache lines per
row and parallelizes over bags; the TPU-native structure is a
``PrefetchScalarGridSpec``: the index array is scalar-prefetched so the
pipeline can issue the HBM->VMEM row DMA for lookup (n, j, p+1) while row
(n, j, p) is being accumulated in VMEM.  The grid is blocked over BAGS —
``bags_per_block`` bags share one VMEM output block, so the output is
written back once per ``bags_per_block * P`` row fetches instead of once
per bag (the write-combining the paper gets from its cache-blocked loop).
Row accumulation is fp32.

Storage dtype is polymorphic: pass the bf16 ``hi`` half of a Split-SGD
table (:mod:`repro.optim.split_sgd`) and the forward reads 2 bytes/elem —
the paper's bf16-table forward — while still accumulating in fp32.

This kernel should run at HBM-bandwidth roofline — the GUPS-like
expectation the paper states in Sect. II.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, o_ref):
    j = pl.program_id(1)
    p = pl.program_id(2)

    @pl.when((j == 0) & (p == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[pl.ds(j, 1), :] += w_ref[...].astype(jnp.float32)


def embedding_bag_pallas(W: jax.Array, idx: jax.Array,
                         bags_per_block: int = 8,
                         interpret: bool = False) -> jax.Array:
    """W [M, E] (fp32 or bf16-``hi``), idx [N, P] int32 -> [N, E] fp32 bag
    sums.

    ``N % bags_per_block == 0`` and E lane-aligned (multiple of 128) on the
    TPU target; the ops.py wrapper pads both.
    """
    M, E = W.shape
    N, P = idx.shape
    bpb = min(bags_per_block, N)
    assert N % bpb == 0, (N, bpb)
    grid = (N // bpb, bpb, P)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # one embedding row per step, chosen by the prefetched index
                pl.BlockSpec((1, E),
                             lambda n, j, p, idx_ref:
                             (idx_ref[n * bpb + j, p], 0)),
            ],
            out_specs=pl.BlockSpec((bpb, E),
                                   lambda n, j, p, idx_ref: (n, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, E), jnp.float32),
        interpret=interpret,
    )(idx, W)
