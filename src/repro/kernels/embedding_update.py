"""Pallas TPU kernel: fused sparse embedding backward + Split-SGD row update
(paper Alg. 3 + contribution C5 composed — the operator behind the headline
110x).

The embedding backward is NOT a gradient materialization: it is a scatter-SGD
applied directly to the table.  The paper's CPU kernel walks the minibatch's
rows and applies ``W[r] -= lr * sum(dY of bags touching r)`` in one pass; the
TPU-native structure here is a ``PrefetchScalarGridSpec`` over the SORTED
flat lookups:

* XLA side (cheap, O(L) on int32): sort the flat local row ids, so duplicate
  rows form contiguous runs and each touched row is visited exactly once.
* The sorted row ids are scalar-prefetched and drive the (hi, lo) row DMA —
  a new row block is fetched only when the run changes.
* Inside the kernel the duplicate contributions are accumulated in a VMEM
  fp32 scratch (segment accumulation), then at the run end the row is
  reconstructed ``(hi<<16)|lo``, stepped ``w -= lr * acc``, and re-split —
  all in VMEM.
* ``input_output_aliases`` makes the update in-place on the HBM table, so
  rows NOT touched by the minibatch are never read, never written, and no
  dense ``dW`` or fp32 shard copy ever exists.

Bytes per step (shard of M rows x E, L flat lookups, U unique touched rows,
NB = L / pooling bags):

    path                         reads                       writes
    ------------------------------------------------------------------
    reference (segment_sum +     L*E*4 (grad expand)         M*E*4 (new hi+lo
    combine_split + functional   + U*E*4 (gather hi,lo)       shard copies)
    scatter)                     + M*E*4 (scatter copy-in)
    fused (this kernel)          U*E*4 (hi+lo rows)          U*E*4 (hi+lo rows)
                                 + NB*E*4 (dY)

i.e. the fused path touches ``O(U)`` row data instead of ``O(M)`` shard data
— the bandwidth profile Hsia et al. (2020) identify as the dominant memory
bottleneck of DLRM-class training.

Numerics: duplicate contributions are pre-reduced in fp32 in sorted order —
the same order ``jax.ops.segment_sum`` uses on sorted segments — and the
step is applied once per row, so the result is bit-identical to the
``dedup_rows`` + ``combine_split`` reference path
(:func:`repro.optim.row.apply_rows_split_sgd`).

Stateful row optimizers (momentum / Adagrad; :mod:`repro.optim.row`) ride
the SAME machinery with one extra row-addressed operand: the per-row
optimizer-state slab (a momentum row, an elementwise accumulator row, or a
per-row scalar lane) is DMA'd by the same ``rows[i]`` index map as the
weight row, updated once at the run end, and written back through its own
``input_output_aliases`` entry — state traffic stays O(touched rows) per
step, exactly like the weights.  A run consisting ONLY of masked padding
lookups (the sorted tail) must not touch state (``beta * m`` is not a
no-op the way ``w - lr * 0`` is), so the stateful kernels carry a 1-word
SMEM liveness flag per run and write the operand back unchanged when no
valid lookup contributed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# plain lax bit ops — trace fine inside the kernel body, and sharing the
# exact expressions with the optimizer is what makes the bit-identity claim
# structural rather than coincidental
from repro.optim.split_sgd import combine_split, split_fp32
from repro.optim.stochastic import sr_noise, sr_round_bf16


def _run_bounds(rows_ref, i):
    """(is_start, is_end) of the sorted duplicate run at position ``i``."""
    L = pl.num_programs(0)
    row = rows_ref[i]
    prev = rows_ref[jnp.maximum(i - 1, 0)]
    nxt = rows_ref[jnp.minimum(i + 1, L - 1)]
    return (i == 0) | (row != prev), (i == L - 1) | (nxt != row)


def _kernel_split(rows_ref, bags_ref, msk_ref, lr_ref, wgt_ref, hi_ref,
                  lo_ref, dY_ref, nhi_ref, nlo_ref, acc_ref):
    i = pl.program_id(0)
    is_start, is_end = _run_bounds(rows_ref, i)

    @pl.when(is_start)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # masked accumulate: padding / invalid (non-owned) lookups add exact 0.0.
    # Weighted bags scale each lookup's cotangent row BEFORE the VMEM
    # pre-reduction.  The compiler contracts the scale into the accumulate
    # (FMA — observed on the XLA CPU backend even through barriers/bitcasts,
    # and what Mosaic emits on TPU), so the WEIGHTED result sits within
    # 1 ulp/step of the pre-scaled segment_sum reference rather than
    # bitwise on it; weight == 1.0 multiplies exactly, so the unweighted
    # path keeps its bit-identity contract.
    g = dY_ref[...].astype(jnp.float32) * wgt_ref[i]
    acc_ref[...] += jnp.where(msk_ref[i] != 0, g, 0.0)

    @pl.when(is_end)
    def _apply():
        # same expression as the combine_split reference: XLA contracts the
        # mul+sub identically under jit, so the update is bit-identical to
        # the jitted segment_sum + combine_split path
        w32 = combine_split(hi_ref[...], lo_ref[...])
        w32 = w32 - lr_ref[0] * acc_ref[...]
        nh, nl = split_fp32(w32)
        nhi_ref[...] = nh
        nlo_ref[...] = nl


def _kernel_fp32(rows_ref, bags_ref, msk_ref, lr_ref, wgt_ref, w_ref,
                 dY_ref, nw_ref, acc_ref):
    i = pl.program_id(0)
    is_start, is_end = _run_bounds(rows_ref, i)

    @pl.when(is_start)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = dY_ref[...].astype(jnp.float32) * wgt_ref[i]
    acc_ref[...] += jnp.where(msk_ref[i] != 0, g, 0.0)

    @pl.when(is_end)
    def _apply():
        w32 = w_ref[...].astype(jnp.float32) - lr_ref[0] * acc_ref[...]
        nw_ref[...] = w32.astype(nw_ref.dtype)


def _accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref, acc_ref, flg_ref,
                    i):
    """Shared preamble of the stateful kernels: zero the VMEM accumulator
    and the SMEM liveness flag at a run start, masked-accumulate this
    lookup's weighted cotangent row, and OR its validity into the flag.
    Returns (is_end, run-liveness-so-far is in ``flg_ref``)."""
    is_start, is_end = _run_bounds(rows_ref, i)

    @pl.when(is_start)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        flg_ref[0] = 0

    g = dY_ref[...].astype(jnp.float32) * wgt_ref[i]
    acc_ref[...] += jnp.where(msk_ref[i] != 0, g, 0.0)
    flg_ref[0] = flg_ref[0] | msk_ref[i]
    return is_end


def _kernel_momentum(rows_ref, bags_ref, msk_ref, hp_ref, wgt_ref, w_ref,
                     m_ref, dY_ref, nw_ref, nm_ref, acc_ref, flg_ref):
    """fp32 weights + fp32 momentum row.  hp = [lr, beta, eps]."""
    i = pl.program_id(0)
    is_end = _accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref, acc_ref,
                             flg_ref, i)

    @pl.when(is_end)
    def _apply():
        live = flg_ref[0] != 0
        m_old = m_ref[...].astype(jnp.float32)
        m_new = hp_ref[1] * m_old + acc_ref[...]
        w_old = w_ref[...].astype(jnp.float32)
        w_new = w_old - hp_ref[0] * m_new
        nm_ref[...] = jnp.where(live, m_new, m_old).astype(nm_ref.dtype)
        nw_ref[...] = jnp.where(live, w_new, w_old).astype(nw_ref.dtype)


def _kernel_adagrad(rows_ref, bags_ref, msk_ref, hp_ref, wgt_ref, w_ref,
                    s_ref, dY_ref, nw_ref, ns_ref, acc_ref, flg_ref):
    """fp32 weights + fp32 elementwise accumulator row.  hp = [lr, beta,
    eps]; ``s += g^2``, ``w -= lr * g / (sqrt(s) + eps)`` per touched row
    on the pre-reduced gradient."""
    i = pl.program_id(0)
    is_end = _accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref, acc_ref,
                             flg_ref, i)

    @pl.when(is_end)
    def _apply():
        live = flg_ref[0] != 0
        acc = acc_ref[...]
        s_old = s_ref[...].astype(jnp.float32)
        s_new = s_old + acc * acc
        w_old = w_ref[...].astype(jnp.float32)
        w_new = w_old - hp_ref[0] * acc / (jnp.sqrt(s_new) + hp_ref[2])
        ns_ref[...] = jnp.where(live, s_new, s_old).astype(ns_ref.dtype)
        nw_ref[...] = jnp.where(live, w_new, w_old).astype(nw_ref.dtype)


def _make_kernel_adagrad_rowwise(e_real: int):
    """Row-wise Adagrad (Naumov et al. 2019): ONE accumulator scalar per
    row — ``s += mean_e(g^2)``, ``w -= lr * g / (sqrt(s) + eps)``.  The
    state operand is a (1, Ws) lane block whose lanes all carry the same
    scalar (lane 0 is authoritative); ``e_real`` is the unpadded embedding
    width so the mean ignores lane padding (padded dY lanes are zero)."""

    def kernel(rows_ref, bags_ref, msk_ref, hp_ref, wgt_ref, w_ref, s_ref,
               dY_ref, nw_ref, ns_ref, acc_ref, flg_ref):
        i = pl.program_id(0)
        is_end = _accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref,
                                 acc_ref, flg_ref, i)

        @pl.when(is_end)
        def _apply():
            live = flg_ref[0] != 0
            acc = acc_ref[...]
            s_old = s_ref[0, 0]
            s_new = s_old + jnp.sum(acc * acc) / e_real
            w_old = w_ref[...].astype(jnp.float32)
            w_new = w_old - hp_ref[0] * acc / (jnp.sqrt(s_new) + hp_ref[2])
            s_out = jnp.where(live, s_new, s_old)
            ns_ref[...] = jnp.broadcast_to(s_out, ns_ref.shape
                                           ).astype(ns_ref.dtype)
            nw_ref[...] = jnp.where(live, w_new, w_old).astype(nw_ref.dtype)

    return kernel


def _kernel_freq(rows_ref, bags_ref, msk_ref, hp_ref, wgt_ref, w_ref,
                 s_ref, dY_ref, nw_ref, nc_ref, acc_ref, flg_ref):
    """Frequency-adaptive sparse LR (``adagrad_freq``): fp32 weights + the
    reserved int32 touch-counter lane.  hp = [lr, 0, eps].  The counter is
    ALREADY bumped by ``RowOptimizer.apply_sparse`` before the kernel runs
    (+1 per valid lookup, O(touched rows)), so the kernel only READS it —
    ``w -= lr * g / (sqrt(max(cnt, 1)) + eps)`` per touched row — and
    passes the slab through unchanged (lane 0 authoritative; ops.py pads
    the [M, 1] slab to the lane width on the compiled path)."""
    i = pl.program_id(0)
    is_end = _accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref, acc_ref,
                             flg_ref, i)
    nc_ref[...] = s_ref[...]

    @pl.when(is_end)
    def _apply():
        live = flg_ref[0] != 0
        c = s_ref[0, 0].astype(jnp.float32)
        denom = jnp.sqrt(jnp.maximum(c, 1.0)) + hp_ref[2]
        w_old = w_ref[...].astype(jnp.float32)
        w_new = w_old - hp_ref[0] * acc_ref[...] / denom
        nw_ref[...] = jnp.where(live, w_new, w_old).astype(nw_ref.dtype)


def _kernel_momentum_bf16(rows_ref, bags_ref, msk_ref, hp_ref, sd_ref,
                          wgt_ref, w_ref, m_ref, dY_ref, nw_ref, nm_ref,
                          acc_ref, flg_ref):
    """fp32 weights + COMPRESSED bf16-hi momentum row with seeded
    stochastic rounding.  hp = [lr, beta, eps]; sd = [seed].  The bf16 ->
    fp32 decode is exact, the transition runs in fp32, and only the store
    back to the state slab rounds — with the counter-based dither of
    :mod:`repro.optim.stochastic`, so the reference scan computes the
    same bits for the same (seed, row, lane)."""
    i = pl.program_id(0)
    is_end = _accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref, acc_ref,
                             flg_ref, i)

    @pl.when(is_end)
    def _apply():
        live = flg_ref[0] != 0
        m_old = m_ref[...]
        m_new = hp_ref[1] * m_old.astype(jnp.float32) + acc_ref[...]
        w_old = w_ref[...].astype(jnp.float32)
        w_new = w_old - hp_ref[0] * m_new
        noise = sr_noise(sd_ref[0], rows_ref[i][None], m_new.shape[-1])
        nm_ref[...] = jnp.where(live, sr_round_bf16(m_new, noise), m_old)
        nw_ref[...] = jnp.where(live, w_new, w_old).astype(nw_ref.dtype)


def _kernel_adagrad_bf16(rows_ref, bags_ref, msk_ref, hp_ref, sd_ref,
                         wgt_ref, w_ref, s_ref, dY_ref, nw_ref, ns_ref,
                         acc_ref, flg_ref):
    """fp32 weights + COMPRESSED bf16-hi elementwise Adagrad accumulator
    with seeded stochastic rounding.  The weight step uses the UNROUNDED
    fp32 ``s_new`` (rounding only affects what the next step decodes)."""
    i = pl.program_id(0)
    is_end = _accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref, acc_ref,
                             flg_ref, i)

    @pl.when(is_end)
    def _apply():
        live = flg_ref[0] != 0
        acc = acc_ref[...]
        s_old = s_ref[...]
        s_new = s_old.astype(jnp.float32) + acc * acc
        w_old = w_ref[...].astype(jnp.float32)
        w_new = w_old - hp_ref[0] * acc / (jnp.sqrt(s_new) + hp_ref[2])
        noise = sr_noise(sd_ref[0], rows_ref[i][None], s_new.shape[-1])
        ns_ref[...] = jnp.where(live, sr_round_bf16(s_new, noise), s_old)
        nw_ref[...] = jnp.where(live, w_new, w_old).astype(nw_ref.dtype)


def _row_specs(E, n_out):
    """(in_specs tail, out_specs) for the row-addressed operands.  The
    scalar-prefetch refs (rows, bags, msk, then the kernel's scalar
    operands — hyperparameters, optional SR seed, weights; SMEM is the
    TPU-legal home for kernel scalars) are appended to every index_map;
    the maps are variadic in everything after (rows, bags) so one spec
    serves any scalar-prefetch arity."""
    row = pl.BlockSpec((1, E), lambda i, rows, bags, *_: (rows[i], 0))
    bag = pl.BlockSpec((1, E), lambda i, rows, bags, *_: (bags[i], 0))
    return row, bag, [row] * n_out


def fused_update_split_pallas(hi: jax.Array, lo: jax.Array,
                              sorted_rows: jax.Array, sorted_bags: jax.Array,
                              sorted_msk: jax.Array, sorted_wgt: jax.Array,
                              dY: jax.Array, lr, interpret: bool = False
                              ) -> tuple[jax.Array, jax.Array]:
    """Fused sparse-backward + Split-SGD-BF16 update, in place on (hi, lo).

    ``hi`` [M, E] bf16 / ``lo`` [M, E] uint16: the split table shard.
    ``sorted_rows`` [L] int32: ASCENDING local row id per flat lookup
    (duplicates contiguous; padding entries must repeat an in-range row and
    carry ``sorted_msk == 0``).  ``sorted_bags`` [L] int32: row of ``dY``
    holding each lookup's cotangent.  ``sorted_wgt`` [L] fp32: per-lookup
    bag weight (1.0 for plain sum bags) scaling the cotangent row before
    the VMEM pre-reduction.  ``dY`` [NB, E].  Returns the updated (hi, lo);
    rows not named in ``sorted_rows`` are untouched (aliased buffers, no
    shard copy).  E must be lane-aligned on the TPU target (ops.py pads).
    """
    M, E = hi.shape
    L = sorted_rows.shape[0]
    row, bag, outs = _row_specs(E, 2)
    lr_arr = jnp.full((1,), lr, jnp.float32)
    return pl.pallas_call(
        _kernel_split,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(L,),
            in_specs=[row, row, bag],
            out_specs=outs,
            scratch_shapes=[pltpu.VMEM((1, E), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((M, E), jnp.bfloat16),
                   jax.ShapeDtypeStruct((M, E), jnp.uint16)],
        # args: (rows, bags, msk, lr, wgt, hi, lo, dY) -> alias hi/lo->outs
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(sorted_rows, sorted_bags, sorted_msk, lr_arr, sorted_wgt, hi, lo, dY)


def fused_update_fp32_pallas(W: jax.Array, sorted_rows: jax.Array,
                             sorted_bags: jax.Array, sorted_msk: jax.Array,
                             sorted_wgt: jax.Array, dY: jax.Array, lr,
                             interpret: bool = False) -> jax.Array:
    """fp32/bf16-storage variant of :func:`fused_update_split_pallas`:
    ``W[r] -= lr * sum(wgt * dY[bags of r])`` on the touched rows only."""
    M, E = W.shape
    L = sorted_rows.shape[0]
    row, bag, outs = _row_specs(E, 1)
    lr_arr = jnp.full((1,), lr, jnp.float32)
    return pl.pallas_call(
        _kernel_fp32,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(L,),
            in_specs=[row, bag],
            out_specs=outs,
            scratch_shapes=[pltpu.VMEM((1, E), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((M, E), W.dtype)],
        # args: (rows, bags, msk, lr, wgt, W, dY) -> alias W->out0
        input_output_aliases={5: 0},
        interpret=interpret,
    )(sorted_rows, sorted_bags, sorted_msk, lr_arr, sorted_wgt, W, dY)[0]


def _state_spec(Ws):
    """Row-addressed (1, Ws) BlockSpec for a per-row optimizer-state slab —
    the same ``rows[i]`` index map as the weight row, at the slab's own
    width (E for momentum / elementwise Adagrad, the padded scalar lane
    for row-wise Adagrad)."""
    return pl.BlockSpec((1, Ws), lambda i, rows, bags, *_: (rows[i], 0))


def _stateful_call(kernel, w: jax.Array, s: jax.Array, sorted_rows,
                   sorted_bags, sorted_msk, sorted_wgt, dY, hp,
                   interpret: bool, extra_scalars: tuple = ()):
    """Shared pallas_call plumbing for the (weights, state) kernels:
    scalar-prefetch stream + two row-addressed aliased operands + the VMEM
    accumulator and the SMEM run-liveness flag.  ``extra_scalars``: extra
    scalar-prefetch operands (e.g. the int32 stochastic-rounding seed),
    handed to the kernel BETWEEN ``hp`` and ``wgt`` — the index maps are
    variadic, so any arity rides the same specs."""
    M, E = w.shape
    Ws = s.shape[1]
    L = sorted_rows.shape[0]
    row, bag, _ = _row_specs(E, 0)
    st = _state_spec(Ws)
    n_sp = 5 + len(extra_scalars)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_sp,
            grid=(L,),
            in_specs=[row, st, bag],
            out_specs=[row, st],
            scratch_shapes=[pltpu.VMEM((1, E), jnp.float32),
                            pltpu.SMEM((1,), jnp.int32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((M, E), w.dtype),
                   jax.ShapeDtypeStruct((M, Ws), s.dtype)],
        # args: (rows, bags, msk, hp, *extra, wgt, w, s, dY); alias the
        # row-addressed w/s operands onto the outputs
        input_output_aliases={n_sp: 0, n_sp + 1: 1},
        interpret=interpret,
    )(sorted_rows, sorted_bags, sorted_msk, hp, *extra_scalars,
      sorted_wgt, w, s, dY)


def fused_update_momentum_pallas(w: jax.Array, mom: jax.Array, sorted_rows,
                                 sorted_bags, sorted_msk, sorted_wgt, dY,
                                 lr, beta, interpret: bool = False
                                 ) -> tuple[jax.Array, jax.Array]:
    """Fused sparse-backward + heavy-ball momentum update, in place on
    ``(w, mom)``: per touched row ``m = beta * m + sum(wgt * dY)``,
    ``w -= lr * m``.  ``mom`` [M, E] fp32 rides the same sorted-index
    scalar prefetch as the weight row; untouched rows' weights AND state
    are never read or written."""
    hp = jnp.stack([jnp.asarray(lr, jnp.float32),
                    jnp.asarray(beta, jnp.float32),
                    jnp.zeros((), jnp.float32)])
    return _stateful_call(_kernel_momentum, w, mom, sorted_rows, sorted_bags,
                          sorted_msk, sorted_wgt, dY, hp, interpret)


def fused_update_adagrad_pallas(w: jax.Array, acc: jax.Array, sorted_rows,
                                sorted_bags, sorted_msk, sorted_wgt, dY,
                                lr, eps, rowwise: bool, e_real: int,
                                interpret: bool = False
                                ) -> tuple[jax.Array, jax.Array]:
    """Fused sparse-backward + Adagrad update, in place on ``(w, acc)``.

    ``rowwise=False``: ``acc`` [M, E] elementwise second-moment sum.
    ``rowwise=True``: ``acc`` [M, Ws] per-row scalar lane (every lane
    carries the row's accumulator; lane 0 authoritative) and the squared
    gradient is averaged over ``e_real`` embedding lanes before the
    accumulate — O(M) state instead of O(M*E)."""
    hp = jnp.stack([jnp.asarray(lr, jnp.float32),
                    jnp.zeros((), jnp.float32),
                    jnp.asarray(eps, jnp.float32)])
    kernel = (_make_kernel_adagrad_rowwise(e_real) if rowwise
              else _kernel_adagrad)
    return _stateful_call(kernel, w, acc, sorted_rows, sorted_bags,
                          sorted_msk, sorted_wgt, dY, hp, interpret)


def fused_update_momentum_bf16_pallas(w: jax.Array, mom: jax.Array,
                                      sorted_rows, sorted_bags, sorted_msk,
                                      sorted_wgt, dY, lr, beta, seed,
                                      interpret: bool = False
                                      ) -> tuple[jax.Array, jax.Array]:
    """:func:`fused_update_momentum_pallas` with the momentum slab stored
    COMPRESSED as bf16-hi: per touched row ``m = beta * decode(m) +
    sum(wgt * dY)`` in fp32, ``w -= lr * m``, and the new ``m`` is written
    back stochastically rounded under ``seed`` — half the state bytes per
    touched row, unbiased in expectation."""
    hp = jnp.stack([jnp.asarray(lr, jnp.float32),
                    jnp.asarray(beta, jnp.float32),
                    jnp.zeros((), jnp.float32)])
    sd = jnp.full((1,), seed, jnp.int32)
    return _stateful_call(_kernel_momentum_bf16, w, mom, sorted_rows,
                          sorted_bags, sorted_msk, sorted_wgt, dY, hp,
                          interpret, extra_scalars=(sd,))


def fused_update_adagrad_bf16_pallas(w: jax.Array, acc: jax.Array,
                                     sorted_rows, sorted_bags, sorted_msk,
                                     sorted_wgt, dY, lr, eps, seed,
                                     interpret: bool = False
                                     ) -> tuple[jax.Array, jax.Array]:
    """Elementwise Adagrad with the accumulator slab stored COMPRESSED as
    bf16-hi + stochastic rounding (seeded).  The weight step divides by
    ``sqrt`` of the UNROUNDED fp32 accumulator."""
    hp = jnp.stack([jnp.asarray(lr, jnp.float32),
                    jnp.zeros((), jnp.float32),
                    jnp.asarray(eps, jnp.float32)])
    sd = jnp.full((1,), seed, jnp.int32)
    return _stateful_call(_kernel_adagrad_bf16, w, acc, sorted_rows,
                          sorted_bags, sorted_msk, sorted_wgt, dY, hp,
                          interpret, extra_scalars=(sd,))


def fused_update_freq_pallas(w: jax.Array, cnt: jax.Array, sorted_rows,
                             sorted_bags, sorted_msk, sorted_wgt, dY, lr,
                             eps, interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """Fused sparse-backward + frequency-adaptive LR update, in place on
    ``(w, cnt)``: per touched row ``w -= lr * sum(wgt * dY) /
    (sqrt(max(cnt, 1)) + eps)`` where ``cnt`` [M, Ws] int32 is the
    reserved touch-counter slab, pre-bumped by the caller
    (``RowOptimizer.apply_sparse``) and carried through UNCHANGED here —
    the counter transition is a cheap XLA scatter-add, not kernel work."""
    hp = jnp.stack([jnp.asarray(lr, jnp.float32),
                    jnp.zeros((), jnp.float32),
                    jnp.asarray(eps, jnp.float32)])
    return _stateful_call(_kernel_freq, w, cnt, sorted_rows, sorted_bags,
                          sorted_msk, sorted_wgt, dY, hp, interpret)


def sort_lookups(tgt: jax.Array, valid: jax.Array | None, num_rows: int,
                 pooling: int, weights: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Host/XLA-side prep: sort flat lookups by row so duplicates form runs.

    ``tgt`` [L] int32 local row ids (may be out of range where invalid);
    ``valid`` [L] bool or None; flat lookup ``i`` reads bag ``i // pooling``.
    ``weights`` [L] fp32 per-lookup bag weights or None (sum bags).
    Invalid/padding lookups are sorted to the tail as a zero-contribution
    run on the last row (a bit-exact no-op rewrite of that row).  Returns
    (sorted_rows, sorted_bags, sorted_msk, sorted_wgt) — ready for the
    kernels above.  Only scalars are sorted; the [*, E] gradient data is
    never permuted or expanded.
    """
    valid = ((tgt >= 0) & (tgt < num_rows)) if valid is None else (
        valid & (tgt >= 0) & (tgt < num_rows))
    key = jnp.where(valid, tgt, num_rows).astype(jnp.int32)
    order = jnp.argsort(key)                      # stable: ties in flat order
    sorted_key = jnp.take(key, order)
    sorted_rows = jnp.minimum(sorted_key, num_rows - 1)
    sorted_bags = (order // pooling).astype(jnp.int32)
    sorted_msk = (sorted_key < num_rows).astype(jnp.int32)
    sorted_wgt = (jnp.ones(tgt.shape, jnp.float32) if weights is None
                  else jnp.take(weights.astype(jnp.float32), order))
    return sorted_rows, sorted_bags, sorted_msk, sorted_wgt
