"""Pallas TPU kernel: causal flash attention (online softmax), with GQA,
gemma2 logit soft-capping, and local (sliding-window) masking.

Not a paper contribution per se — the LM-family assigned architectures need
it — but it follows the same design rule as the paper's GEMM (C2): the
softmax epilogue happens while the score tile is in VMEM, and KV blocks
stream HBM->VMEM down the innermost grid axis.  Out-of-range KV blocks
(causal future / beyond the local window) are skipped at grid level, which
is what makes the gemma2 local layers sub-quadratic.

Decode (Lq << Lk) uses right-aligned positions: query i has absolute
position Lk - Lq + i.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nk: int, lq: int, lk: int, scale: float,
            causal: bool, softcap: float, window: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos0 = qi * bq + (lk - lq)          # absolute position of first query
    kpos0 = ki * bk
    needed = kpos0 < lk                  # key-padding block
    if causal:
        needed &= kpos0 <= qpos0 + bq - 1
    if window > 0:
        needed &= kpos0 + bk - 1 > qpos0 - window

    @pl.when(needed)
    def _body():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < lk
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, softcap: float = 0.0,
                           window: int = 0, scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           lq_real: int | None = None,
                           lk_real: int | None = None,
                           interpret: bool = False) -> jax.Array:
    """q [BH, Lq, D]; k, v [BH, Lk, D] (GQA heads pre-expanded by index_map
    in ops.py, or pass matching BH).  Lq/Lk must be multiples of bq/bk
    (ops.py pads; ``l{q,k}_real`` are the unpadded lengths used for
    position/padding masks)."""
    BH, Lq, D = q.shape
    Lk = k.shape[1]
    bq, bk = min(bq, Lq), min(bk, Lk)
    assert Lq % bq == 0 and Lk % bk == 0
    nk = Lk // bk
    grid = (BH, Lq // bq, nk)
    kern = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk,
        lq=(lq_real if lq_real is not None else Lq),
        lk=(lk_real if lk_real is not None else Lk),
        scale=(scale if scale is not None else D ** -0.5),
        causal=causal, softcap=softcap, window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
