"""Pallas TPU kernel: blocked GEMM with fused bias+activation epilogue.

TPU-native adaptation of the paper's batch-reduce GEMM MLP (Alg. 5).  The
CPU version blocks [C_b][N_b][b_n][b_c] for cache/TLB locality and JITs a
microkernel; on TPU the analogous structure is a (M/bm, N/bn, K/bk) grid of
MXU-aligned VMEM tiles with an fp32 accumulator scratch that lives in VMEM
across the K loop, and the activation applied while the C tile is still in
VMEM — the paper's "ReLU can directly happen inside a custom GEMM routine
when the C matrix is still hot in caches" (Sect. II).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int, activation: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "sigmoid":
            y = jax.nn.sigmoid(y)
        o_ref[...] = y.astype(o_ref.dtype)


def fused_mlp_pallas(x: jax.Array, w: jax.Array, b: jax.Array,
                     activation: str = "relu",
                     bm: int = 256, bn: int = 256, bk: int = 512,
                     out_dtype=jnp.float32, interpret: bool = False
                     ) -> jax.Array:
    """y = act(x @ w + b).  x [M, K], w [K, N], b [N].

    Block sizes are clamped to the problem and padded shapes must be
    MXU-friendly; the ops.py wrapper handles padding of ragged edges.
    """
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, b.reshape(1, N))
