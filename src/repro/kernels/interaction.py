"""Pallas TPU kernel: batched self dot-product interaction (paper Sect. II:
"a self dot product ... translates to a batched matrix-matrix multiplication
as a key kernel").

Z [B, F, E] -> Z Z^T [B, F, F], batched over B with a block of bags resident
in VMEM; the (tiny) F x F output tile stays in registers/VMEM so the
downstream triangle extraction fuses on top.  F is the feature count
(S tables + 1 bottom-MLP vector), typically 9..65 — far below MXU size, so
the win comes from batching many bags per VMEM block, not from the MXU tile
shape itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, o_ref):
    z = z_ref[...]
    o_ref[...] = jax.lax.dot_general(
        z, z, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def interaction_pallas(z: jax.Array, bb: int = 8,
                       interpret: bool = False) -> jax.Array:
    """z [B, F, E] -> [B, F, F] fp32."""
    B, F, E = z.shape
    bb = min(bb, B)
    assert B % bb == 0, (B, bb)
    return pl.pallas_call(
        _kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, F, E), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb, F, F), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, F, F), jnp.float32),
        interpret=interpret,
    )(z)
