"""Jitted public wrappers around the Pallas kernels.

Handles shape padding to hardware-aligned blocks, GQA head expansion, and
the interpret switch (``interpret=True`` executes the kernel bodies in
Python — the validation mode on this CPU container; on TPU it compiles to
Mosaic).  Default interpret mode follows the backend.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.embedding_update import (fused_update_adagrad_pallas,
                                            fused_update_fp32_pallas,
                                            fused_update_momentum_pallas,
                                            fused_update_split_pallas,
                                            sort_lookups)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_mlp import fused_mlp_pallas
from repro.kernels.interaction import interaction_pallas
from repro.kernels.split_sgd import split_sgd_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_dim(x: jax.Array, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x, x.shape[axis]
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), x.shape[axis]


@partial(jax.jit, static_argnames=("activation", "interpret"))
def fused_mlp_layer(x, w, b, activation: str = "relu",
                    interpret: bool | None = None):
    """act(x @ w + b) with fp32 accumulation.  Pads to (8,128) multiples."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, M = _pad_dim(x, 0, 8)
    xp, K = _pad_dim(xp, 1, 128)
    wp, _ = _pad_dim(w, 0, 128)
    wp, N = _pad_dim(wp, 1, 128)
    bp, _ = _pad_dim(b, 0, 128)
    bm = min(256, max(8, xp.shape[0] // 8 * 8 if xp.shape[0] < 256 else 256))
    # clamp blocks to padded dims
    def blk(dim, pref):
        return dim if dim < pref else pref
    out = fused_mlp_pallas(xp, wp, bp, activation,
                           bm=blk(xp.shape[0], 256), bn=blk(wp.shape[1], 256),
                           bk=blk(xp.shape[1], 512), interpret=interpret)
    return out[:M, :N]


@partial(jax.jit, static_argnames=("bags_per_block", "interpret"))
def embedding_bag(W, idx, bags_per_block: int = 8,
                  interpret: bool | None = None):
    """W [M, E] (fp32, or the bf16 ``hi`` half for 2-byte/elem reads), idx
    [N, P] -> [N, E] fp32 bag sums.  Lane-pads E and pads N to a multiple of
    ``bags_per_block`` (padding bags read row 0 and are sliced off)."""
    interpret = _default_interpret() if interpret is None else interpret
    Wp, E = _pad_dim(W, 1, 128)
    idxp, N = _pad_dim(idx, 0, min(bags_per_block, idx.shape[0]))
    out = embedding_bag_pallas(Wp, idxp, bags_per_block=bags_per_block,
                               interpret=interpret)
    return out[:N, :E]


# ---------------------------------------------------------------------------
# Fused sparse row-optimizer update — ONE entry point for every registered
# RowOptimizer (repro/optim/row.py), replacing the former 4-way
# fused_embedding_update{,_fp32}{,_presorted} surface.  Nothing outside
# repro.optim.row should call these: model/pipeline code goes through
# ``RowOptimizer.apply_sparse``, which owns the store layout and the
# reference-path parity contracts.
# ---------------------------------------------------------------------------

ROW_KINDS = ("sgd", "split_sgd", "momentum", "adagrad", "adagrad_rowwise")


def _call_row_kernel(kind, store, srows, sbags, smsk, swgt, dY, lr, beta,
                     eps, e_real, interpret):
    """Invoke the kind's Pallas entry on (already lane-aligned) slabs."""
    if kind == "split_sgd":
        nh, nl = fused_update_split_pallas(store["hi"], store["lo"], srows,
                                           sbags, smsk, swgt, dY, lr,
                                           interpret=interpret)
        return {"hi": nh, "lo": nl}
    if kind == "sgd":
        return {"w": fused_update_fp32_pallas(store["w"], srows, sbags,
                                              smsk, swgt, dY, lr,
                                              interpret=interpret)}
    if kind == "momentum":
        nw, nm = fused_update_momentum_pallas(store["w"], store["mom"],
                                              srows, sbags, smsk, swgt, dY,
                                              lr, beta, interpret=interpret)
        return {"w": nw, "mom": nm}
    if kind in ("adagrad", "adagrad_rowwise"):
        nw, ns = fused_update_adagrad_pallas(
            store["w"], store["acc"], srows, sbags, smsk, swgt, dY, lr,
            eps, kind == "adagrad_rowwise", e_real, interpret=interpret)
        return {"w": nw, "acc": ns}
    raise ValueError(f"unknown row-optimizer kind {kind!r}; "
                     f"expected one of {ROW_KINDS}")


def _dispatch_row_kernel(kind, store, srows, sbags, smsk, swgt, dY, lr,
                         beta, eps, interpret):
    """Pad every slab's lane dim to a 128 multiple (compiled path), run
    the kind's Pallas kernel on the sorted stream, and slice the padding
    back off per slab.  On the compiled TPU path a non-128-multiple width
    is padded, which copies the slab and forfeits the O(unique_rows)
    traffic — production shards keep E % 128 == 0 so the pad is a no-op
    (the adagrad_rowwise [M, 1] scalar lane always pads; its per-row
    traffic is one fp32 either way).  Interpret mode (the CPU validation
    path) has no lane constraint and never pads."""
    e_real = (store["hi"] if kind == "split_sgd" else store["w"]).shape[1]
    if interpret:
        return _call_row_kernel(kind, store, srows, sbags, smsk, swgt, dY,
                                lr, beta, eps, e_real, True)
    widths = {k: v.shape[1] for k, v in store.items()}
    padded = {k: _pad_dim(v, 1, 128)[0] for k, v in store.items()}
    dYp, _ = _pad_dim(dY, 1, 128)
    out = _call_row_kernel(kind, padded, srows, sbags, smsk, swgt, dYp,
                           lr, beta, eps, e_real, interpret)
    return {k: v[:, :widths[k]] for k, v in out.items()}


@partial(jax.jit, static_argnames=("kind", "pooling", "interpret"))
def fused_row_update(kind, store, tgt, dY, lr, beta=0.0, eps=0.0,
                     valid=None, weights=None, *, pooling: int = 1,
                     interpret: bool | None = None):
    """Fused sparse-backward + row-optimizer update (paper Alg. 3 + C5,
    generalized to pluggable per-row state).

    ``kind``: one of :data:`ROW_KINDS`.  ``store``: the optimizer's
    EmbeddingStore dict — weight slab(s) (``hi``/``lo`` split-bf16 or
    ``w`` fp32) plus zero or more per-row state slabs (``mom``/``acc``),
    all row-aligned on the same shard layout.  ``tgt`` [L] int32 local row
    per flat lookup (out-of-range or ``valid == False`` entries contribute
    nothing).  ``dY`` [L // pooling, E]: bag cotangents — flat lookup ``i``
    reads ``dY[i // pooling]``; the [L, E] per-lookup gradient expansion of
    the reference path is never materialized.  ``weights`` [L] optional
    per-lookup bag weights scaling each cotangent row before the in-VMEM
    duplicate pre-reduction.  Returns the updated store: only touched rows
    (weights AND state) are read/written, in place via aliasing.  The
    unweighted ``split_sgd`` result is bit-identical to the jitted
    ``apply_rows_split_sgd`` reference; the WEIGHTED accumulation is
    FMA-contracted and sits within 1 ulp/step of the pre-scaled
    reference."""
    interpret = _default_interpret() if interpret is None else interpret
    M = (store["hi"] if kind == "split_sgd" else store["w"]).shape[0]
    srows, sbags, smsk, swgt = sort_lookups(tgt, valid, M, pooling, weights)
    return _dispatch_row_kernel(kind, store, srows, sbags, smsk, swgt, dY,
                                lr, beta, eps, interpret)


@partial(jax.jit, static_argnames=("kind", "interpret"))
def fused_row_update_presorted(kind, store, srows, sbags, smsk, swgt, dY,
                               lr, beta=0.0, eps=0.0, *,
                               interpret: bool | None = None):
    """:func:`fused_row_update` with the sort done ON THE HOST: the caller
    supplies the ``(sorted_rows, sorted_bags, sorted_msk, sorted_wgt)``
    arrays of ``sort_lookups`` (produced per shard by
    ``repro.data.pipeline.presort_batch`` while the previous step runs on
    device) and the per-step XLA argsort disappears from the hot path.
    Bit-identical to the sorting entry point — a stable sort's permutation
    is unique, so host and device sorts agree exactly."""
    interpret = _default_interpret() if interpret is None else interpret
    return _dispatch_row_kernel(kind, store, srows, sbags, smsk, swgt, dY,
                                lr, beta, eps, interpret)


@partial(jax.jit, static_argnames=("interpret",))
def interaction_self_dot(z, interpret: bool | None = None):
    """z [B, F, E] -> [B, F, F] fp32 batched self-dot."""
    interpret = _default_interpret() if interpret is None else interpret
    zp, F = _pad_dim(z, 1, 8)       # sublane-align the F dim
    zp, E = _pad_dim(zp, 2, 128)
    bb = 8
    zb, B = _pad_dim(zp, 0, bb)
    out = interaction_pallas(zb, bb=bb, interpret=interpret)
    return out[:B, :F, :F]


@partial(jax.jit, static_argnames=("interpret",))
def split_sgd_update(hi, lo, g, lr, interpret: bool | None = None):
    """Flat split-SGD step on arbitrary-shaped leaves (raveled + padded)."""
    interpret = _default_interpret() if interpret is None else interpret
    shape = hi.shape
    n = hi.size
    hif, _ = _pad_dim(hi.reshape(-1), 0, 1024)
    lof, _ = _pad_dim(lo.reshape(-1), 0, 1024)
    gf, _ = _pad_dim(g.reshape(-1), 0, 1024)
    block = min(8 * 128 * 64, hif.shape[0])
    nh, nl = split_sgd_pallas(hif, lof, gf, lr, block=block,
                              interpret=interpret)
    return nh[:n].reshape(shape), nl[:n].reshape(shape)


@partial(jax.jit, static_argnames=("causal", "softcap", "window", "scale",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, softcap: float = 0.0,
                    window: int = 0, scale: float | None = None,
                    interpret: bool | None = None):
    """q [B,H,Lq,D], k/v [B,Hkv,Lk,D] (H % Hkv == 0) -> [B,H,Lq,D].

    GQA is handled by repeating KV heads (grid-level index aliasing keeps
    HBM traffic at the Hkv level on TPU; in interpret mode it is a copy)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, H, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(B * H, Lq, D)
    kf = k.reshape(B * H, Lk, D)
    vf = v.reshape(B * H, Lk, D)
    bq = min(128, max(8, Lq))
    bk = min(128, Lk)
    qf, _ = _pad_dim(qf, 1, bq)
    kf, _ = _pad_dim(kf, 1, bk)
    vf, _ = _pad_dim(vf, 1, bk)
    # NOTE: padded queries are garbage rows sliced off below; padded keys are
    # masked inside the kernel via lk_real.
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, softcap=softcap, window=window,
        scale=scale, bq=bq, bk=bk, lq_real=Lq, lk_real=Lk,
        interpret=interpret)
    return out[:, :Lq].reshape(B, H, Lq, D)
