"""Jitted public wrappers around the Pallas kernels.

Handles shape padding to hardware-aligned blocks, GQA head expansion, and
the interpret switch (``interpret=True`` executes the kernel bodies in
Python — the validation mode on this CPU container; on TPU it compiles to
Mosaic).  Default interpret mode follows the backend.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.embedding_update import sort_lookups
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_mlp import fused_mlp_pallas
from repro.kernels.interaction import interaction_pallas
from repro.kernels.split_sgd import split_sgd_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_dim(x: jax.Array, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x, x.shape[axis]
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), x.shape[axis]


@partial(jax.jit, static_argnames=("activation", "interpret"))
def fused_mlp_layer(x, w, b, activation: str = "relu",
                    interpret: bool | None = None):
    """act(x @ w + b) with fp32 accumulation.  Pads to (8,128) multiples."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, M = _pad_dim(x, 0, 8)
    xp, K = _pad_dim(xp, 1, 128)
    wp, _ = _pad_dim(w, 0, 128)
    wp, N = _pad_dim(wp, 1, 128)
    bp, _ = _pad_dim(b, 0, 128)
    bm = min(256, max(8, xp.shape[0] // 8 * 8 if xp.shape[0] < 256 else 256))
    # clamp blocks to padded dims
    def blk(dim, pref):
        return dim if dim < pref else pref
    out = fused_mlp_pallas(xp, wp, bp, activation,
                           bm=blk(xp.shape[0], 256), bn=blk(wp.shape[1], 256),
                           bk=blk(xp.shape[1], 512), interpret=interpret)
    return out[:M, :N]


@partial(jax.jit, static_argnames=("bags_per_block", "interpret"))
def embedding_bag(W, idx, bags_per_block: int = 8,
                  interpret: bool | None = None):
    """W [M, E] (fp32, or the bf16 ``hi`` half for 2-byte/elem reads), idx
    [N, P] -> [N, E] fp32 bag sums.  Lane-pads E and pads N to a multiple of
    ``bags_per_block`` (padding bags read row 0 and are sliced off)."""
    interpret = _default_interpret() if interpret is None else interpret
    Wp, E = _pad_dim(W, 1, 128)
    idxp, N = _pad_dim(idx, 0, min(bags_per_block, idx.shape[0]))
    out = embedding_bag_pallas(Wp, idxp, bags_per_block=bags_per_block,
                               interpret=interpret)
    return out[:N, :E]


# ---------------------------------------------------------------------------
# Fused sparse row-optimizer update — ONE entry point for every registered
# RowOptimizer (repro/optim/row.py), replacing the former 4-way
# fused_embedding_update{,_fp32}{,_presorted} surface.  Nothing outside
# repro.optim.row should call these: model/pipeline code goes through
# ``RowOptimizer.apply_sparse``, which owns the store layout and the
# reference-path parity contracts.
#
# There is NO per-optimizer dispatch here (enforced by a source-scan
# test): the optimizer instance carries its own fused Pallas entry as the
# ``kernel`` registration hook, and this module only owns the generic
# plumbing — lane padding, the sorted-stream prep, the interpret switch.
# ``register()`` alone (plus one kernel body) adds an optimizer.
# ---------------------------------------------------------------------------


def _coerce_opt(opt):
    """Accept a RowOptimizer instance or a registry name (legacy callers/
    benches pass strings)."""
    if isinstance(opt, str):
        from repro.optim import row as row_optim
        return row_optim.get(opt)
    return opt


def _dispatch_row_kernel(opt, store, srows, sbags, smsk, swgt, dY, lr,
                         seed, interpret):
    """Pad every slab's lane dim to a 128 multiple (compiled path), run
    the optimizer's Pallas kernel hook on the sorted stream, and slice
    the padding back off per slab.  On the compiled TPU path a
    non-128-multiple width is padded, which copies the slab and forfeits
    the O(unique_rows) traffic — production shards keep E % 128 == 0 so
    the pad is a no-op (a [M, 1] per-row scalar lane always pads; its
    per-row traffic is one scalar either way).  Interpret mode (the CPU
    validation path) has no lane constraint and never pads."""
    e_real = store[opt.weight_keys[0]].shape[1]
    if interpret:
        return opt.kernel(opt, store, srows, sbags, smsk, swgt, dY, lr,
                          seed, e_real, True)
    widths = {k: v.shape[1] for k, v in store.items()}
    padded = {k: _pad_dim(v, 1, 128)[0] for k, v in store.items()}
    dYp, _ = _pad_dim(dY, 1, 128)
    out = opt.kernel(opt, padded, srows, sbags, smsk, swgt, dYp, lr, seed,
                     e_real, interpret)
    return {k: v[:, :widths[k]] for k, v in out.items()}


@partial(jax.jit, static_argnames=("opt", "pooling", "interpret"))
def fused_row_update(opt, store, tgt, dY, lr, *, seed=0, valid=None,
                     weights=None, pooling: int = 1,
                     interpret: bool | None = None):
    """Fused sparse-backward + row-optimizer update (paper Alg. 3 + C5,
    generalized to pluggable per-row state).

    ``opt``: a registered RowOptimizer (or its registry name) — its
    ``kernel`` hook owns which Pallas body runs.  ``store``: the
    optimizer's EmbeddingStore dict — weight slab(s) (``hi``/``lo``
    split-bf16 or ``w`` fp32) plus zero or more per-row state slabs
    (``mom``/``acc``, fp32 or compressed bf16-hi), all row-aligned on the
    same shard layout.  ``tgt`` [L] int32 local row per flat lookup
    (out-of-range or ``valid == False`` entries contribute nothing).
    ``dY`` [L // pooling, E]: bag cotangents — flat lookup ``i`` reads
    ``dY[i // pooling]``; the [L, E] per-lookup gradient expansion of the
    reference path is never materialized.  ``weights`` [L] optional
    per-lookup bag weights scaling each cotangent row before the in-VMEM
    duplicate pre-reduction.  ``seed``: int32 per-step stochastic-rounding
    seed (ignored by deterministic optimizers).  Returns the updated
    store: only touched rows (weights AND state) are read/written, in
    place via aliasing.  The unweighted ``split_sgd`` result is
    bit-identical to the jitted ``apply_rows_split_sgd`` reference; the
    WEIGHTED accumulation is FMA-contracted and sits within 1 ulp/step of
    the pre-scaled reference."""
    opt = _coerce_opt(opt)
    interpret = _default_interpret() if interpret is None else interpret
    M = store[opt.weight_keys[0]].shape[0]
    srows, sbags, smsk, swgt = sort_lookups(tgt, valid, M, pooling, weights)
    return _dispatch_row_kernel(opt, store, srows, sbags, smsk, swgt, dY,
                                lr, seed, interpret)


@partial(jax.jit, static_argnames=("opt", "interpret"))
def fused_row_update_presorted(opt, store, srows, sbags, smsk, swgt, dY,
                               lr, *, seed=0,
                               interpret: bool | None = None):
    """:func:`fused_row_update` with the sort done ON THE HOST: the caller
    supplies the ``(sorted_rows, sorted_bags, sorted_msk, sorted_wgt)``
    arrays of ``sort_lookups`` (produced per shard by
    ``repro.data.pipeline.presort_batch`` while the previous step runs on
    device) and the per-step XLA argsort disappears from the hot path.
    Bit-identical to the sorting entry point — a stable sort's permutation
    is unique, so host and device sorts agree exactly."""
    opt = _coerce_opt(opt)
    interpret = _default_interpret() if interpret is None else interpret
    return _dispatch_row_kernel(opt, store, srows, sbags, smsk, swgt, dY,
                                lr, seed, interpret)


@partial(jax.jit, static_argnames=("interpret",))
def interaction_self_dot(z, interpret: bool | None = None):
    """z [B, F, E] -> [B, F, F] fp32 batched self-dot."""
    interpret = _default_interpret() if interpret is None else interpret
    zp, F = _pad_dim(z, 1, 8)       # sublane-align the F dim
    zp, E = _pad_dim(zp, 2, 128)
    bb = 8
    zb, B = _pad_dim(zp, 0, bb)
    out = interaction_pallas(zb, bb=bb, interpret=interpret)
    return out[:B, :F, :F]


@partial(jax.jit, static_argnames=("interpret",))
def split_sgd_update(hi, lo, g, lr, interpret: bool | None = None):
    """Flat split-SGD step on arbitrary-shaped leaves (raveled + padded)."""
    interpret = _default_interpret() if interpret is None else interpret
    shape = hi.shape
    n = hi.size
    hif, _ = _pad_dim(hi.reshape(-1), 0, 1024)
    lof, _ = _pad_dim(lo.reshape(-1), 0, 1024)
    gf, _ = _pad_dim(g.reshape(-1), 0, 1024)
    block = min(8 * 128 * 64, hif.shape[0])
    nh, nl = split_sgd_pallas(hif, lof, gf, lr, block=block,
                              interpret=interpret)
    return nh[:n].reshape(shape), nl[:n].reshape(shape)


@partial(jax.jit, static_argnames=("causal", "softcap", "window", "scale",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, softcap: float = 0.0,
                    window: int = 0, scale: float | None = None,
                    interpret: bool | None = None):
    """q [B,H,Lq,D], k/v [B,Hkv,Lk,D] (H % Hkv == 0) -> [B,H,Lq,D].

    GQA is handled by repeating KV heads (grid-level index aliasing keeps
    HBM traffic at the Hkv level on TPU; in interpret mode it is a copy)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, H, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(B * H, Lq, D)
    kf = k.reshape(B * H, Lk, D)
    vf = v.reshape(B * H, Lk, D)
    bq = min(128, max(8, Lq))
    bk = min(128, Lk)
    qf, _ = _pad_dim(qf, 1, bq)
    kf, _ = _pad_dim(kf, 1, bk)
    vf, _ = _pad_dim(vf, 1, bk)
    # NOTE: padded queries are garbage rows sliced off below; padded keys are
    # masked inside the kernel via lk_real.
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, softcap=softcap, window=window,
        scale=scale, bq=bq, bk=bk, lq_real=Lq, lk_real=Lk,
        interpret=interpret)
    return out[:, :Lq].reshape(B, H, Lq, D)
