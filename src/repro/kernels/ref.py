"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>.py`` kernel is validated against the function of the same name
here (tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag(W: jax.Array, idx: jax.Array) -> jax.Array:
    """Bag-sum forward: W [M, E], idx [N, P] -> [N, E] fp32 (paper Alg. 1)."""
    return jnp.take(W, idx, axis=0).astype(jnp.float32).sum(axis=1)


def fused_mlp_layer(x: jax.Array, w: jax.Array, b: jax.Array,
                    activation: str = "relu") -> jax.Array:
    """y = act(x @ w + b), fp32 accumulation (paper Alg. 5 + fused epilogue)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = y + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif activation != "none":
        raise ValueError(activation)
    return y


def interaction_self_dot(z: jax.Array) -> jax.Array:
    """Batched self dot: z [B, F, E] -> [B, F, F] fp32 (paper Sect. II)."""
    return jnp.einsum("bfe,bge->bfg", z, z, preferred_element_type=jnp.float32)


def split_sgd_update(hi: jax.Array, lo: jax.Array, g: jax.Array, lr
                     ) -> tuple[jax.Array, jax.Array]:
    """Exact-fp32 SGD on split-bf16 storage (paper Sect. VII)."""
    from repro.optim.split_sgd import combine_split, split_fp32
    w32 = combine_split(hi, lo) - lr * g.astype(jnp.float32)
    return split_fp32(w32)


def fused_row_update_split(hi: jax.Array, lo: jax.Array, tgt: jax.Array,
                           dY: jax.Array, lr, pooling: int = 1
                           ) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels/embedding_update: expand dY to per-lookup rows,
    dedup duplicates via sort + segment-sum, exact-fp32 step on touched
    rows.  Run it JITTED when asserting bit-equality (XLA contracts the
    mul+sub of the update the same way in both paths only under jit)."""
    from repro.optim.row import apply_rows_split_sgd
    grad = jnp.take(dY, jnp.arange(tgt.shape[0]) // pooling, axis=0)
    return apply_rows_split_sgd(hi, lo, tgt, grad, lr)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, softcap: float = 0.0,
                    window: int = 0, scale: float | None = None) -> jax.Array:
    """Reference attention.  q [B,H,Lq,D], k/v [B,Hkv,Lk,D] (GQA: H % Hkv == 0).

    ``softcap`` > 0 applies gemma2's logit soft-capping; ``window`` > 0
    restricts keys to (i - window, i] (local/sliding attention).  For decode
    (Lq < Lk) positions are right-aligned: query i sits at absolute position
    Lk - Lq + i.
    """
    B, H, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = H // Hkv
    kx = jnp.repeat(k, rep, axis=1)
    vx = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kx,
                   preferred_element_type=jnp.float32)
    s = s * (scale if scale is not None else D ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Lq)[:, None] + (Lk - Lq)
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(p.dtype)
                      ).astype(q.dtype)
