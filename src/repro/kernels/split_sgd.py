"""Pallas TPU kernel: fused Split-SGD-BF16 update (paper Sect. VII, C5).

One pass over (hi, lo, grad): reconstruct fp32 = (hi<<16)|lo, apply the SGD
step, split back.  Reads 2+2+4 and writes 2+2 bytes per parameter — the
bandwidth profile the paper's optimizer-pass analysis assumes.  Pure
elementwise, so a 1D grid of lane-aligned VMEM blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hi_ref, lo_ref, g_ref, lr_ref, nhi_ref, nlo_ref):
    hb = jax.lax.bitcast_convert_type(hi_ref[...], jnp.uint16
                                      ).astype(jnp.uint32)
    bits = (hb << 16) | lo_ref[...].astype(jnp.uint32)
    w32 = jax.lax.bitcast_convert_type(bits, jnp.float32)
    w32 = w32 - lr_ref[0] * g_ref[...].astype(jnp.float32)
    nbits = jax.lax.bitcast_convert_type(w32, jnp.uint32)
    nhi_ref[...] = jax.lax.bitcast_convert_type(
        (nbits >> 16).astype(jnp.uint16), jnp.bfloat16)
    nlo_ref[...] = (nbits & jnp.uint32(0xFFFF)).astype(jnp.uint16)


def split_sgd_pallas(hi: jax.Array, lo: jax.Array, g: jax.Array, lr,
                     block: int = 8 * 128 * 64, interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """hi [n] bf16, lo [n] uint16, g [n] -> (hi', lo').  n % block == 0
    (ops.py pads)."""
    n = hi.shape[0]
    block = min(block, n)
    assert n % block == 0, (n, block)
    lr_arr = jnp.full((1,), lr, jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.bfloat16),
                   jax.ShapeDtypeStruct((n,), jnp.uint16)],
        interpret=interpret,
    )(hi, lo, g, lr_arr)
