import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (architecture x input shape) on
the production meshes and record memory/cost analysis + the collective
schedule.  MUST be run as a script/module — the XLA_FLAGS line above runs
before any other import (jax locks the device count on first init).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch egnn     # one arch
    PYTHONPATH=src python -m repro.launch.dryrun --arch fm --shape train_batch
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod only|skip|both

Results land in results/dryrun/<arch>__<shape>__<mesh>.json, one file per
cell, so interrupted runs resume for free.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import base as cfgbase
from repro.launch.mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=?\s*(\w+)?\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (compiled) HLO.

    Matches ops like ``%all-reduce.5 = f32[1024,256]{...} all-reduce(...)``;
    we scan result-shape annotations on lines whose op name is a collective.
    """
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "u16": 2, "s16": 2, "f64": 8, "pred": 1, "u8": 1,
                   "s8": 1, "c64": 8, "u64": 8, "s64": 8}
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    line_re = re.compile(
        r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^ ]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)")
    shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")

    def shape_bytes(dt, dims):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        return n * dtype_bytes.get(dt, 4)

    for m in line_re.finditer(hlo_text):
        tuple_part, dt, dims, op = m.groups()
        size = 0
        if tuple_part is not None:
            for sm in shape_re.finditer(tuple_part):
                size += shape_bytes(*sm.groups())
        else:
            size = shape_bytes(dt, dims)
        totals[op] = totals.get(op, 0) + size
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def run_cell(arch: str, shape: str, mesh, mesh_name: str,
             overrides=None) -> dict:
    ad = cfgbase.get(arch)
    cell = next(c for c in ad.cells if c.shape == shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "kind": cell.kind, "status": None}
    if cell.skip:
        rec.update(status="skipped", skip_reason=cell.skip)
        return rec
    t0 = time.time()
    build = ad.build(shape, mesh, **(overrides or {}))
    rec["meta"] = {k: v for k, v in build.meta.items()
                   if isinstance(v, (int, float, str, list, tuple))}
    with jax.set_mesh(mesh):
        lowered = build.fn.lower(*build.args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                   "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    rec["collectives"] = parse_collective_bytes(compiled.as_text())
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", choices=["only", "skip", "both"],
                    default="both")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    meshes = []
    if args.multi_pod in ("skip", "both"):
        meshes.append(("pod1x16x16", make_production_mesh(multi_pod=False)))
    if args.multi_pod in ("only", "both"):
        meshes.append(("pod2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else cfgbase.list_archs()
    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            ad = cfgbase.get(arch)
            for cell in ad.cells:
                if args.shape and cell.shape != args.shape:
                    continue
                out = RESULTS / f"{arch}__{cell.shape}__{mesh_name}.json"
                if out.exists():
                    rec = json.loads(out.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} {cell.shape} {mesh_name}: "
                              f"{rec['status']}")
                        n_ok += rec["status"] == "ok"
                        n_skip += rec["status"] == "skipped"
                        continue
                print(f"[run] {arch} {cell.shape} {mesh_name} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, cell.shape, mesh, mesh_name)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": cell.shape,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                out.write_text(json.dumps(rec, indent=2))
                status = rec["status"]
                if status == "ok":
                    n_ok += 1
                    mem = rec["memory"]["peak_estimate_bytes"] / 2**30
                    print(f"  ok: peak~{mem:.2f} GiB/device, "
                          f"flops={rec['cost']['flops']:.3g}, "
                          f"coll={rec['collectives']['total_bytes']:.3g}B, "
                          f"compile={rec['compile_s']}s", flush=True)
                elif status == "skipped":
                    n_skip += 1
                    print(f"  skipped: {rec['skip_reason']}")
                else:
                    n_fail += 1
                    print(f"  ERROR: {rec['error']}", flush=True)
    print(f"\ndry-run summary: ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
