"""Production mesh construction.

IMPORTANT: functions, not module-level constants — importing this module
never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax init;
smoke tests and benchmarks must keep seeing 1 device).
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ('data' x 'model'); 2 pods adds a leading 'pod'
    axis.  Matches the dry-run requirement verbatim."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/benchmarks (e.g. (2,4) on 8 CPU devices,
    or 1D meshes emulating the paper's 8-/64-socket systems)."""
    return compat.make_mesh(shape, axes)
