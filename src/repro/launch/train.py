"""Training driver.

Runs REDUCED-scale versions of the registered architectures on the local
device set (the full configs are exercised via the dry-run).  Examples:

    PYTHONPATH=src python -m repro.launch.train --arch dlrm-small --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch fm --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 20 --preset smoke

With XLA_FLAGS=--xla_force_host_platform_device_count=8 the hybrid-parallel
paths run on a real (2, 4) mesh; single-device otherwise.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.launch.mesh import make_mesh
from repro.train import TrainLoop, TrainLoopConfig


def _bspec_shardings(mesh, bspecs):
    """NamedShardings for a batch-spec tree, so the prefetch iterator's
    device_put lands each batch directly in the step's input placement."""
    from repro.dist import sharding
    return sharding.named(mesh, bspecs)


def local_mesh():
    n = len(jax.devices())
    if n >= 8:
        return make_mesh((n // 4, 4), ("data", "model"))
    if n > 1:
        return make_mesh((1, n), ("data", "model"))
    return make_mesh((1, 1), ("data", "model"))


def reduced_dlrm(name: str, batch: int):
    from repro.core.dlrm import DLRMConfig
    if name == "dlrm-100m":
        # ~103M params: the end-to-end "train a ~100M model" driver
        return DLRMConfig(name=name, num_dense=64, bottom=(128, 64),
                          top=(256, 128), table_rows=(200_000,) * 8,
                          emb_dim=64, pooling=20, batch=batch)
    return DLRMConfig(name=name, num_dense=64, bottom=(64, 32),
                      top=(64, 32), table_rows=(5000,) * 8, emb_dim=32,
                      pooling=10, batch=batch)


def reduced_hybrid(name: str, batch: int):
    from repro.models import recsys as R
    if name == "fm":
        return R.make_fm((10_000,) * 39, batch=batch)
    if name == "bst":
        return R.make_bst(50_000, (1000,) * 8, batch=batch)
    if name == "sasrec":
        return R.make_sasrec(50_000, batch=batch)
    if name == "din":
        return R.make_din(50_000, (1000,) * 4, batch=batch)
    raise KeyError(name)


def reduced_lm(name: str, batch: int, seq: int):
    from repro.models.transformer import TransformerConfig
    base = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
                d_ff=256, vocab=512, seq_shard=False, tp_size=1)
    if "moe" in name or "deepseek" in name:
        base.update(n_experts=8, top_k=2, moe_d_ff=64)
    if "deepseek" in name:
        base.update(mla=True, q_lora=64, kv_lora=64, qk_nope=16, qk_rope=16,
                    v_head=32, n_heads=4, d_head=32)
    if "gemma2" in name:
        base.update(local_global=True, window=64, attn_softcap=50.0,
                    final_softcap=30.0, embed_scale=True)
    return TransformerConfig(name=name, **base), batch, seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--alpha", type=float, default=0.0,
                    help="index-skew for sparse streams (paper Fig. 8)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="staged-pipeline microbatches (core/pipeline.py): "
                         "double-buffered index exchange overlap")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host-side device_put-ahead window (0 = off)")
    args = ap.parse_args()

    mesh = local_mesh()
    print(f"[train] devices={len(jax.devices())} mesh={dict(mesh.shape)}")
    key = jax.random.PRNGKey(0)
    batch_shardings = None

    if args.arch.startswith("dlrm"):
        from repro.core import dlrm as D
        from repro.data.synthetic import dlrm_stream
        cfg = dataclasses.replace(reduced_dlrm(args.arch, args.batch),
                                  lr=args.lr,
                                  microbatches=args.microbatches)
        state, layout = D.init_state(key, cfg, mesh)
        step, shardings, bspecs, _ = D.make_train_step(cfg, mesh)
        batch_shardings = _bspec_shardings(mesh, bspecs)
        stream = ({k: jax.numpy.asarray(v) for k, v in b.items()}
                  for b in dlrm_stream(0, cfg, args.alpha))
        n_params = cfg.spec.total_rows * cfg.emb_dim
        print(f"[train] {args.arch}: ~{n_params/1e6:.1f}M embedding params")
    elif args.arch in ("fm", "bst", "sasrec", "din"):
        from repro.core import hybrid as H
        from repro.data.synthetic import hybrid_stream
        mdef = dataclasses.replace(reduced_hybrid(args.arch, args.batch),
                                   lr=args.lr, emb_lr=args.lr,
                                   microbatches=args.microbatches)
        state, layout = H.init_state(key, mdef, mesh)
        step, shardings, bspecs, _ = H.make_train_step(mdef, mesh)
        batch_shardings = _bspec_shardings(mesh, bspecs)
        stream = ({k: jax.numpy.asarray(v) for k, v in b.items()}
                  for b in hybrid_stream(0, mdef, args.alpha))
    else:
        from repro.models import lm_steps
        from repro.data.synthetic import token_stream
        if args.microbatches != 1:
            raise SystemExit(
                "--microbatches applies to the recsys hybrid pipeline "
                "(dlrm/fm/bst/sasrec/din); LM archs microbatch via "
                "TransformerConfig.microbatch instead")
        cfg, B, L = reduced_lm(args.arch, args.batch, args.seq)
        state = lm_steps.init_lm_state(key, cfg, mesh)
        step, structs, shardings = lm_steps.make_lm_train_step(
            cfg, mesh, B, L, lr=args.lr)
        shardings = shardings[0]
        stream = ({k: jax.numpy.asarray(v) for k, v in b.items()}
                  for b in token_stream(0, cfg.vocab, B, L))

    loop = TrainLoop(
        TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                        prefetch=args.prefetch),
        step, state, stream,
        state_shardings=shardings if args.ckpt_dir else None,
        batch_shardings=batch_shardings)
    loop.run()
    print(f"[train] done: first loss {loop.losses[0]:.4f} "
          f"-> last {loop.losses[-1]:.4f}")
    if loop.monitor.events:
        print(f"[train] stragglers observed: {len(loop.monitor.events)}")


if __name__ == "__main__":
    main()
