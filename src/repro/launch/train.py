"""Training driver.

Runs REDUCED-scale versions of the registered architectures on the local
device set (the full configs are exercised via the dry-run).  Examples:

    PYTHONPATH=src python -m repro.launch.train --arch dlrm-small --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch fm --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 20 --preset smoke

With XLA_FLAGS=--xla_force_host_platform_device_count=8 the hybrid-parallel
paths run on a real (2, 4) mesh; single-device otherwise.

Recsys archs can stream a PACKED dataset (docs/data.md) instead of the
in-process synthetic generator:

    python -m repro.data.format synthetic --out /data/ds \
        --tables 5000,...x8 --pooling 10 --num-dense 64 --num-samples 65536
    python -m repro.launch.train --arch dlrm-small --data-dir /data/ds \
        --data-format packed --host-presort

``--host-presort`` moves the sparse-update index sort off the device and
into the loader's worker thread (row and table mode; see
repro/data/pipeline.py), and ``--optimizer`` selects the sparse
RowOptimizer of the embedding path (docs/optim.md).
"""

from __future__ import annotations

import argparse
import dataclasses
from pathlib import Path

import jax
import numpy as np

from repro import telemetry
from repro.launch.mesh import make_mesh
from repro.train import TrainLoop, TrainLoopConfig


def _bspec_shardings(mesh, bspecs):
    """NamedShardings for a batch-spec tree, so the prefetch iterator's
    device_put lands each batch directly in the step's input placement."""
    from repro.dist import sharding
    return sharding.named(mesh, bspecs)


def local_mesh():
    n = len(jax.devices())
    if n >= 8:
        return make_mesh((n // 4, 4), ("data", "model"))
    if n > 1:
        return make_mesh((1, n), ("data", "model"))
    return make_mesh((1, 1), ("data", "model"))


def packed_stream(args, expect, layout, host_presort: bool):
    """Build the packed-shard loader chain for a recsys arch: ShardedReader
    (mmap + two-level shuffle) -> HostPipeline (threaded decode + optional
    per-batch pre-sort).  ``expect`` carries the model-side schema the
    DatasetSpec must match (fail at wiring time, not inside shard_map)."""
    from repro.data.pipeline import HostPipeline
    from repro.data.reader import ShardedReader
    unsupported = sorted(set(expect.get("extras", ()))
                         - {"dense_x", "labels"})
    if unsupported:
        raise SystemExit(
            f"--data-format packed cannot feed this arch: batch extras "
            f"{unsupported} are not representable in the shard format "
            "(dense_x/labels/sparse+weights only) — use the synthetic "
            "stream for it")
    reader = ShardedReader(args.data_dir, batch=expect["batch"],
                           seed=args.seed, shuffle=True)
    reader.spec.check(expect["table_rows"], expect["pooling"],
                      num_dense=expect.get("num_dense", 0),
                      labels=expect.get("labels", True),
                      slot_to_table=expect.get("slot_to_table"),
                      weighted=expect.get("weighted", False))
    if reader.spec.weighted and not expect.get("weighted", False):
        raise SystemExit("dataset carries per-lookup weights but the model "
                         "is unweighted — pass --weighted (or repack "
                         "without weights)")
    print(f"[train] packed dataset: {reader.num_samples} samples in "
          f"{len(reader.shards)} shard(s), "
          f"{reader.batches_per_epoch()} batches/epoch"
          + (", host pre-sort ON" if host_presort else ""))
    return HostPipeline(reader, layout=layout, presort=host_presort)


def serve_smoke(mdef, mesh, publisher, batch, buckets):
    """Post-train serving smoke (--serve-smoke): continuous batching over
    the published snapshot with a burst of single-sample requests sliced
    from one synthetic batch; per-bucket latency + freshness printed."""
    from repro.serve import ContinuousBatchingServer, make_bucket_scorers
    registry = publisher.registry
    score_fns, pad_batch = make_bucket_scorers(
        mdef, mesh, buckets, lambda: registry.current().state)
    n = int(np.asarray(batch["idx"]).shape[0])
    payloads = [{k: np.asarray(v)[i] for k, v in batch.items()}
                for i in range(n)]
    with ContinuousBatchingServer(score_fns, pad_batch,
                                  max_wait_ms=2.0) as srv:
        handles = [srv.submit(p) for p in payloads]
        scores = [h.result(timeout=120.0) for h in handles]
        stats = srv.stats()
        pct = srv.percentiles()
    print(f"[serve] smoke: {len(scores)} requests scored in "
          f"{sum(stats['batches'].values())} batches "
          f"(padded rows: {stats['padded']})")
    for b in sorted(pct):
        p = pct[b]
        print(f"[serve]   bucket {b:>4}: p50 {p['p50_ms']:8.2f} ms   "
              f"p99 {p['p99_ms']:8.2f} ms   n={p['n']}")
    f = publisher.freshness()
    print(f"[serve] snapshot v{f['version']}: {f['steps_behind']} steps / "
          f"{f['seconds_behind']:.2f}s behind the training head")


def reduced_dlrm(name: str, batch: int):
    from repro.core.dlrm import DLRMConfig
    if name == "dlrm-100m":
        # ~103M params: the end-to-end "train a ~100M model" driver
        return DLRMConfig(name=name, num_dense=64, bottom=(128, 64),
                          top=(256, 128), table_rows=(200_000,) * 8,
                          emb_dim=64, pooling=20, batch=batch)
    return DLRMConfig(name=name, num_dense=64, bottom=(64, 32),
                      top=(64, 32), table_rows=(5000,) * 8, emb_dim=32,
                      pooling=10, batch=batch)


def reduced_hybrid(name: str, batch: int):
    from repro.models import recsys as R
    if name == "fm":
        return R.make_fm((10_000,) * 39, batch=batch)
    if name == "bst":
        return R.make_bst(50_000, (1000,) * 8, batch=batch)
    if name == "sasrec":
        return R.make_sasrec(50_000, batch=batch)
    if name == "din":
        return R.make_din(50_000, (1000,) * 4, batch=batch)
    raise KeyError(name)


def reduced_lm(name: str, batch: int, seq: int):
    from repro.models.transformer import TransformerConfig
    base = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
                d_ff=256, vocab=512, seq_shard=False, tp_size=1)
    if "moe" in name or "deepseek" in name:
        base.update(n_experts=8, top_k=2, moe_d_ff=64)
    if "deepseek" in name:
        base.update(mla=True, q_lora=64, kv_lora=64, qk_nope=16, qk_rope=16,
                    v_head=32, n_heads=4, d_head=32)
    if "gemma2" in name:
        base.update(local_global=True, window=64, attn_softcap=50.0,
                    final_softcap=30.0, embed_scale=True)
    return TransformerConfig(name=name, **base), batch, seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default=None,
                    help="sparse RowOptimizer for the embedding path "
                         "(repro/optim/row.py): sgd | split_sgd | momentum "
                         "| adagrad_rowwise | adagrad | momentum_bf16 | "
                         "adagrad_bf16 (the _bf16 kinds store compressed "
                         "bf16-hi state with seeded stochastic rounding) | "
                         "adagrad_freq (frequency-adaptive LR off the "
                         "hot-row cache's touch counters); default keeps "
                         "the arch's configured optimizer (split_sgd)")
    ap.add_argument("--beta", type=float, default=None,
                    help="momentum coefficient override for --optimizer")
    ap.add_argument("--eps", type=float, default=None,
                    help="adagrad denominator floor override for "
                         "--optimizer")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="checkpoint cadence in completed steps (preemption "
                         "cost: up to ckpt-every-1 steps of lost work)")
    ap.add_argument("--skip-batch-budget", type=int, default=0,
                    help="transient loader failures absorbed per run "
                         "(each skip is logged; beyond the budget the "
                         "failure propagates)")
    ap.add_argument("--event-log", default=None,
                    help="append structured failure/recovery events "
                         "(checkpoint retries, corrupt-checkpoint skips, "
                         "batch skips, preemptions) to this .jsonl file")
    ap.add_argument("--alpha", type=float, default=0.0,
                    help="index-skew for sparse streams (paper Fig. 8)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="staged-pipeline microbatches (core/pipeline.py): "
                         "double-buffered index exchange overlap")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host-side device_put-ahead window (0 = off)")
    ap.add_argument("--data-dir", default=None,
                    help="packed-shard dataset directory (docs/data.md)")
    ap.add_argument("--data-format", choices=("synthetic", "packed"),
                    default=None,
                    help="batch source; defaults to 'packed' when "
                         "--data-dir is given, else 'synthetic'")
    ap.add_argument("--host-presort", action="store_true",
                    help="pre-sort the sparse-update index stream on the "
                         "loader thread (row and table mode; drops the "
                         "on-device sort from the step)")
    ap.add_argument("--seed", type=int, default=0,
                    help="data order seed (reader epoch shuffle); also "
                         "seeds the stochastic-rounding counter of the "
                         "_bf16 compressed-state optimizers")
    ap.add_argument("--weighted", action="store_true",
                    help="weighted bags: consume the packed dataset's "
                         "per-lookup weight arrays (recsys archs)")
    ap.add_argument("--hot-rows", type=int, default=0,
                    help="frequency-tiered hot-row cache (docs/cache.md): "
                         "replicate the top-K touched rows PER TABLE on "
                         "every rank so hot bags skip the all-to-all "
                         "(table mode); 0 = off")
    ap.add_argument("--promote-every", type=int, default=1,
                    help="hot-set promotion cadence in steps (counter-"
                         "driven, deterministic across ranks/restarts)")
    ap.add_argument("--hot-sync", default="allreduce",
                    help="hot-slab refresh: 'allreduce' (every step; "
                         "bitwise == cache off) or 'deferred:N' (refresh "
                         "every N steps; bounded staleness)")
    ap.add_argument("--exchange-dtype", default=None,
                    choices=("fp32", "bf16", "bf16_sr"),
                    help="wire format of the dY exchange + dense gradient "
                         "reduce-scatter (docs/pipeline.md 'Communication "
                         "precision'): fp32 = today's wire (bitwise), "
                         "bf16 = round-to-nearest (dense leg carries "
                         "error feedback), bf16_sr = seeded stochastic "
                         "rounding (deterministic, checkpoint-replayable)")
    ap.add_argument("--trace-dir", default=None,
                    help="enable the process tracer (docs/telemetry.md): "
                         "writes <dir>/trace.json (Chrome trace-event "
                         "JSON, open in Perfetto), <dir>/heartbeat.jsonl "
                         "(per-window train-loop heartbeats) and — unless "
                         "--event-log points elsewhere — "
                         "<dir>/events.jsonl; recsys archs append a "
                         "per-stage pipeline profile to the trace")
    ap.add_argument("--step-metrics", action="store_true",
                    help="accumulate in-graph step metrics (cache hits, "
                         "rows touched, exchange payload bytes) in a "
                         "replicated state vector, drained every "
                         "--metrics-every steps (recsys archs)")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="in-graph metrics drain / heartbeat cadence "
                         "(steps)")
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="preemption drill: request a stop at this step "
                         "(records a 'preempted' event, writes the final "
                         "checkpoint) — gives smoke traces a fault track")
    ap.add_argument("--publish-every", type=int, default=0,
                    help="publish a read-only serving snapshot of the "
                         "bf16-hi tables every N completed steps "
                         "(docs/serve.md; recsys archs); snapshot version "
                         "and train-to-serve freshness ride the heartbeat; "
                         "0 = off")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="after training, drive a continuous-batching "
                         "serving smoke over the published snapshot "
                         "(per-bucket latency percentiles printed)")
    ap.add_argument("--serve-buckets", default="8,32,128",
                    help="compiled serving batch-shape ladder for "
                         "--serve-smoke (ascending, comma-separated)")
    args = ap.parse_args()
    if args.trace_dir:
        telemetry.configure(enabled=True, trace_dir=args.trace_dir)
    if args.data_format is None:
        args.data_format = "packed" if args.data_dir else "synthetic"
    if args.data_format == "packed" and not args.data_dir:
        raise SystemExit("--data-format packed requires --data-dir")
    if args.weighted and args.data_format != "packed":
        raise SystemExit("--weighted needs a weighted packed dataset "
                         "(the synthetic streams carry no weights); pack "
                         "one with `python -m repro.data synthetic "
                         "--weighted ...`")

    mesh = local_mesh()
    print(f"[train] devices={len(jax.devices())} mesh={dict(mesh.shape)}")
    key = jax.random.PRNGKey(0)
    batch_shardings = None

    if args.host_presort and args.data_format != "packed":
        raise SystemExit("--host-presort rides the packed loader's worker "
                         "thread; add --data-dir/--data-format packed")
    if ((args.beta is not None or args.eps is not None)
            and args.optimizer is None):
        raise SystemExit("--beta/--eps tune a sparse optimizer; name one "
                         "with --optimizer")
    if args.optimizer is not None:
        from repro.optim import row as row_optim
        row_optim.get(args.optimizer)   # unknown name fails here, loudly

    if args.arch.startswith("dlrm"):
        from repro.core import dlrm as D
        from repro.data.synthetic import dlrm_stream
        cfg = dataclasses.replace(reduced_dlrm(args.arch, args.batch),
                                  lr=args.lr,
                                  sparse_optimizer=args.optimizer,
                                  opt_beta=args.beta, opt_eps=args.eps,
                                  microbatches=args.microbatches,
                                  host_presort=args.host_presort,
                                  weighted=args.weighted,
                                  sr_seed=args.seed,
                                  hot_rows=args.hot_rows,
                                  promote_every=args.promote_every,
                                  hot_sync=args.hot_sync,
                                  exchange_dtype=args.exchange_dtype,
                                  step_metrics=args.step_metrics)
        state, layout = D.init_state(key, cfg, mesh)
        profile_def = D.as_hybrid_def(cfg)
        step, shardings, bspecs, _ = D.make_train_step(cfg, mesh)
        batch_shardings = _bspec_shardings(mesh, bspecs)
        if args.data_format == "packed":
            stream = packed_stream(
                args, dict(batch=cfg.batch, table_rows=cfg.table_rows,
                           pooling=cfg.pooling, num_dense=cfg.num_dense,
                           weighted=cfg.weighted),
                layout, args.host_presort)
        else:
            stream = dlrm_stream(0, cfg, args.alpha)
        smoke_stream = lambda: dlrm_stream(1, cfg, args.alpha)  # noqa: E731
        n_params = cfg.spec.total_rows * cfg.emb_dim
        print(f"[train] {args.arch}: ~{n_params/1e6:.1f}M embedding params")
    elif args.arch in ("fm", "bst", "sasrec", "din"):
        from repro.core import hybrid as H
        from repro.data.synthetic import hybrid_stream
        mdef = dataclasses.replace(reduced_hybrid(args.arch, args.batch),
                                   lr=args.lr, emb_lr=args.lr,
                                   sparse_optimizer=args.optimizer,
                                   opt_beta=args.beta, opt_eps=args.eps,
                                   microbatches=args.microbatches,
                                   host_presort=args.host_presort,
                                   weighted=args.weighted,
                                   sr_seed=args.seed,
                                   hot_rows=args.hot_rows,
                                   promote_every=args.promote_every,
                                   hot_sync=args.hot_sync,
                                   exchange_dtype=args.exchange_dtype,
                                   step_metrics=args.step_metrics)
        state, layout = H.init_state(key, mdef, mesh)
        profile_def = mdef
        step, shardings, bspecs, _ = H.make_train_step(mdef, mesh)
        batch_shardings = _bspec_shardings(mesh, bspecs)
        if args.data_format == "packed":
            stream = packed_stream(
                args, dict(batch=mdef.batch,
                           table_rows=mdef.spec.table_rows,
                           pooling=mdef.pooling,
                           num_dense=(mdef.extras["dense_x"][0][0]
                                      if "dense_x" in mdef.extras else 0),
                           labels="labels" in mdef.extras,
                           slot_to_table=mdef.slot_to_table,
                           extras=tuple(mdef.extras),
                           weighted=mdef.weighted),
                layout, args.host_presort)
        else:
            stream = hybrid_stream(0, mdef, args.alpha)
        smoke_stream = lambda: hybrid_stream(1, mdef, args.alpha)  # noqa: E731
    else:
        from repro.models import lm_steps
        from repro.data.synthetic import token_stream
        if args.data_format == "packed":
            raise SystemExit("--data-dir/--data-format packed is the recsys "
                             "ingestion path (dlrm/fm/bst/sasrec/din); LM "
                             "archs stream tokens")
        if args.microbatches != 1:
            raise SystemExit(
                "--microbatches applies to the recsys hybrid pipeline "
                "(dlrm/fm/bst/sasrec/din); LM archs microbatch via "
                "TransformerConfig.microbatch instead")
        if args.optimizer is not None:
            raise SystemExit(
                "--optimizer selects the sparse embedding RowOptimizer of "
                "the recsys hybrid step (dlrm/fm/bst/sasrec/din); LM archs "
                "use the dense Split-SGD path")
        if args.hot_rows:
            raise SystemExit(
                "--hot-rows caches hot embedding rows of the recsys hybrid "
                "step (dlrm/fm/bst/sasrec/din); LM archs have no sparse "
                "embedding path")
        if args.exchange_dtype is not None:
            raise SystemExit(
                "--exchange-dtype compresses the recsys hybrid step's dY "
                "exchange + dense reduce-scatter (dlrm/fm/bst/sasrec/din); "
                "LM archs have no exchange collectives")
        if args.step_metrics:
            raise SystemExit(
                "--step-metrics counts the recsys hybrid step's sparse "
                "traffic (dlrm/fm/bst/sasrec/din); LM archs have no "
                "metrics vector")
        if args.publish_every or args.serve_smoke:
            raise SystemExit(
                "--publish-every/--serve-smoke publish the recsys serving "
                "snapshot (dlrm/fm/bst/sasrec/din); LM archs have no "
                "serving path")
        cfg, B, L = reduced_lm(args.arch, args.batch, args.seq)
        profile_def = None
        state = lm_steps.init_lm_state(key, cfg, mesh)
        step, structs, shardings = lm_steps.make_lm_train_step(
            cfg, mesh, B, L, lr=args.lr)
        shardings = shardings[0]
        stream = ({k: jax.numpy.asarray(v) for k, v in b.items()}
                  for b in token_stream(0, cfg.vocab, B, L))

    publisher = None
    serve_stats = None
    if args.publish_every or args.serve_smoke:
        from repro.serve import SnapshotPublisher, combined_serve_stats
        publisher = SnapshotPublisher(
            profile_def,
            publish_every=args.publish_every or max(args.steps, 1))
        publisher.publish(0, state)   # v1: tables before training starts
        serve_stats = combined_serve_stats(publisher)
        print(f"[serve] snapshot v1 published "
              f"({publisher.registry.current().emb_bytes / 1e6:.2f} MB "
              f"serving table), cadence {publisher.publish_every} steps")

    event_log = None
    if args.event_log or args.trace_dir:
        from repro.faults import FailureLog
        event_log = FailureLog(args.event_log
                               or str(Path(args.trace_dir) / "events.jsonl"))
    faults = None
    if args.preempt_at is not None:
        from repro.faults import FaultPlan
        faults = FaultPlan.single("train.step", "preempt",
                                  step=args.preempt_at)
        faults.log = event_log
    heartbeat_path = (str(Path(args.trace_dir) / "heartbeat.jsonl")
                      if args.trace_dir else None)
    loop = TrainLoop(
        TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every,
                        prefetch=args.prefetch,
                        skip_batch_budget=args.skip_batch_budget,
                        heartbeat_path=heartbeat_path,
                        heartbeat_every=args.metrics_every,
                        metrics_every=args.metrics_every),
        step, state, stream,
        state_shardings=shardings if args.ckpt_dir else None,
        batch_shardings=batch_shardings, faults=faults,
        event_log=event_log, step_hook=publisher, serve_stats=serve_stats)
    try:
        loop.run()
        if args.trace_dir and profile_def is not None:
            from repro.telemetry import stages as stage_profiler
            print("[train] profiling pipeline stages (barrier mode)")
            stage_profiler.profile_stages(profile_def,
                                          tracer=telemetry.get_tracer())
        if args.serve_smoke:
            buckets = tuple(int(b) for b in args.serve_buckets.split(","))
            serve_smoke(profile_def, mesh, publisher,
                        next(smoke_stream()), buckets)
    finally:
        if hasattr(stream, "close"):
            stream.close()        # release the HostPipeline worker
        if args.trace_dir:
            out = telemetry.export()
            print(f"[train] trace written: {out}")
    print(f"[train] done: first loss {loop.losses[0]:.4f} "
          f"-> last {loop.losses[-1]:.4f}")
    if loop.monitor.events:
        print(f"[train] stragglers observed: {len(loop.monitor.events)}")


if __name__ == "__main__":
    main()
