"""Attention implementations for the LM family.

Three paths, selected by shape/backend:

* ``pallas``   — the flash kernel (TPU target; tests run it interpreted).
* ``chunked``  — pure-jnp q-chunked attention via ``lax.scan`` (per-chunk
  row softmax, bounded [B,H,bq,Lk] transient).  The dry-run/XLA path for
  training and prefill: quadratic-memory-safe at 32k.
* ``decode``   — einsum attention for Lq==1 with a KV cache.  Written
  GSPMD-friendly: with the cache's Lk dim sharded (sequence parallelism for
  long_500k), XLA turns the softmax reductions and the PV contraction into
  psums over the sequence shards — flash-decode as a sharding consequence.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def repeat_kv(x: jax.Array, rep: int) -> jax.Array:
    return x if rep == 1 else jnp.repeat(x, rep, axis=1)


def _mask(qpos, kpos, causal: bool, window: int, lk_valid: int | None = None):
    m = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    if lk_valid is not None:
        m &= kpos < lk_valid
    return m


def chunked_attention(q, k, v, *, causal=True, softcap=0.0, window=0,
                      scale=None, bq=256, unroll=False) -> jax.Array:
    """q [B,H,Lq,D], k/v [B,Hkv,Lk,D] -> [B,H,Lq,D].  Scans q chunks."""
    B, H, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    k = repeat_kv(k, H // Hkv)
    v = repeat_kv(v, H // Hkv)
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Lq)
    if Lq % bq:
        bq = int(np.gcd(bq, Lq))
    nq = Lq // bq

    Dv = v.shape[-1]   # may differ from D (MLA: v_head != qk dim)

    # local attention: only a window+bq slice of K/V is reachable from a
    # q-chunk — slice it instead of scoring all Lk keys (gemma2's local
    # layers at 32k prefill otherwise waste 8x compute+bytes;
    # EXPERIMENTS.md section Perf iter. 4)
    wsz = min(Lk, window + bq) if window > 0 else Lk
    sliced = 0 < wsz < Lk

    def chunk(carry, qc_i):
        qc, i = qc_i
        q0 = (Lk - Lq) + i * bq            # absolute pos of first query
        if sliced:
            start = jnp.clip(q0 - window + 1, 0, Lk - wsz)
            kk = jax.lax.dynamic_slice_in_dim(k, start, wsz, axis=2)
            vv = jax.lax.dynamic_slice_in_dim(v, start, wsz, axis=2)
            kpos = start + jnp.arange(wsz)[None, :]
        else:
            kk, vv = k, v
            kpos = jnp.arange(Lk)[None, :]
        s = jnp.einsum("bhqd,bhkd->bhqk", qc, kk,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q0 + jnp.arange(bq)[:, None]
        s = jnp.where(_mask(qpos, kpos, causal, window)[None, None], s,
                      -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)
        return carry, o

    if nq == 1:
        _, o = chunk(None, (q, 0))
        return o.astype(q.dtype)
    qs = q.reshape(B, H, nq, bq, D).transpose(2, 0, 1, 3, 4)
    # remat each q-chunk: without it the scan saves every chunk's softmax
    # residuals — the full quadratic [B,H,Lq,Lk] this code exists to avoid.
    _, os = jax.lax.scan(jax.checkpoint(chunk), None, (qs, jnp.arange(nq)),
                         unroll=True if unroll else 1)
    return (os.transpose(1, 2, 0, 3, 4).reshape(B, H, Lq, Dv)
            ).astype(q.dtype)


def decode_attention(q, k, v, *, softcap=0.0, window=0, scale=None,
                     kv_len=None) -> jax.Array:
    """One-token attention.  q [B,H,1,D], k/v [B,Hkv,Lk,D].

    ``kv_len``: per-batch valid cache length [B] (positions >= kv_len are
    masked) — the cache array itself is a static ring of max length."""
    B, H, _, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    k = repeat_kv(k, H // Hkv)
    v = repeat_kv(v, H // Hkv)
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(Lk)[None, None, None, :]
    if kv_len is None:
        valid = jnp.ones((B, 1, 1, Lk), bool)
        qpos = Lk - 1
    else:
        valid = kpos < kv_len[:, None, None, None]
        qpos = kv_len[:, None, None, None] - 1
    # window may be a traced per-layer scalar (decode layer scan); a static 0
    # means "global".
    if isinstance(window, jax.Array) or window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v).astype(q.dtype)


def attention(q, k, v, *, causal=True, softcap=0.0, window=0, scale=None,
              impl: str = "chunked", bq: int = 256,
              unroll: bool = False) -> jax.Array:
    """Dispatcher used by the transformer; decode shapes route to the einsum
    path regardless of impl."""
    if q.shape[2] == 1:
        return decode_attention(q, k, v, softcap=softcap, window=window,
                                scale=scale)
    if impl == "pallas":
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, softcap=softcap,
                               window=window, scale=scale)
    return chunked_attention(q, k, v, causal=causal, softcap=softcap,
                             window=window, scale=scale, bq=bq,
                             unroll=unroll)


# ---------------------------------------------------------------------------
# RoPE / norms, shared by every LM arch
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x [..., L, D] with D even; positions [..., L] absolute."""
    D = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, D // 2, dtype=jnp.float32) / (D // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., L, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
