"""E(n)-equivariant GNN (EGNN, arXiv:2102.09844) — the assigned GNN arch.

Message passing is built from ``jnp.take`` (edge gathers) +
``jax.ops.segment_sum`` (node scatters) — no sparse formats (BCOO avoided by
design, per the brief).  EGNN is the "cheap equivariant" regime: scalar
distance features in the message MLP + an equivariant coordinate update; no
spherical harmonics / tensor products.

Layer (h: node features, x: coordinates, edges j->i):
    m_ij = phi_e([h_i, h_j, ||x_i-x_j||^2])
    x_i' = x_i + (1/deg_i) sum_j (x_i - x_j) * phi_x(m_ij)
    h_i' = phi_h([h_i, sum_j m_ij]) + h_i

Distribution (train step in repro/launch): edges sharded over the full mesh,
node features replicated for the gathers; per-shard partial aggregates are
``psum_scatter`` over a node shard, the node MLPs run node-sharded, and an
``all_gather`` rebuilds the replicated features for the next layer — the
same ownership pattern as the paper's Alg. 4.

Citation/product graphs have no geometry: coordinates are synthesized
(random normal, fixed seed) — EGNN runs unchanged; noted in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.mlp import init_mlp, mlp_forward


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    coord_dim: int = 3
    graph_level: bool = False      # molecule: pooled regression head
    update_coords: bool = True


def init_egnn_params(key, cfg: EGNNConfig) -> dict:
    ks = jax.random.split(key, 3 + cfg.n_layers)
    h = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(ks[i], 3)
        layers.append({
            "phi_e": init_mlp(k1, [2 * h + 1, h, h]),
            "phi_x": init_mlp(k2, [h, h, 1]),
            "phi_h": init_mlp(k3, [2 * h, h, h]),
        })
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "encoder": init_mlp(ks[-3], [cfg.d_feat, h]),
        "layers": layers,
        "head": init_mlp(ks[-2], [h, h, cfg.n_classes]),
    }


def egnn_layer(h, x, src, dst, lp, edge_mask=None, num_nodes=None):
    """h [N, H], x [N, C], src/dst [E] (message j=src -> i=dst).

    Returns PARTIAL aggregates (magg, dx_raw, deg) so edge-sharded callers
    can psum them before the degree normalization (a per-shard local degree
    would be inconsistent).  Coordinate updates use the normalized
    difference (x_i-x_j)/(|x_i-x_j|+1) — the standard EGNN stabilization.
    """
    N = h.shape[0] if num_nodes is None else num_nodes
    hs = jnp.take(h, src, axis=0)
    hd = jnp.take(h, dst, axis=0)
    diff = (jnp.take(x, dst, axis=0) - jnp.take(x, src, axis=0)
            ).astype(jnp.float32)                               # x_i - x_j
    d2 = (diff ** 2).sum(-1, keepdims=True)
    # eps inside the sqrt: padded/self edges have diff == 0 and d(sqrt)|_0
    # is inf — NaN gradients without it
    diff_n = diff / (jnp.sqrt(d2 + 1e-6) + 1.0)
    m = mlp_forward(lp["phi_e"],
                    jnp.concatenate([hs, hd, d2.astype(hs.dtype)], -1),
                    final_activation=True)                     # [E, H] fp32
    if edge_mask is not None:
        m = m * edge_mask[:, None]
    w = jnp.tanh(mlp_forward(lp["phi_x"], m.astype(h.dtype)))  # [E, 1]
    if edge_mask is not None:
        w = w * edge_mask[:, None]
    deg = jax.ops.segment_sum(
        (jnp.ones_like(w[:, 0]) if edge_mask is None else edge_mask),
        dst, num_segments=N)
    dx_raw = jax.ops.segment_sum(diff_n * w, dst, num_segments=N)
    magg = jax.ops.segment_sum(m, dst, num_segments=N)          # [N, H]
    return magg, dx_raw, deg


def normalize_dx(dx_raw, deg):
    return dx_raw / jnp.maximum(deg, 1.0)[:, None]


def egnn_node_update(h, magg, lp):
    out = mlp_forward(lp["phi_h"],
                      jnp.concatenate([h, magg.astype(h.dtype)], -1),
                      final_activation=True)
    return h + out.astype(h.dtype)


def egnn_forward(params, feats, coords, src, dst, cfg: EGNNConfig,
                 edge_mask=None):
    """Single-device reference forward (tests / smoke).  Returns [N, classes]
    node logits or pooled graph output."""
    h = mlp_forward(params["encoder"], feats.astype(jnp.bfloat16),
                    final_activation=True).astype(jnp.bfloat16)
    x = coords.astype(jnp.float32)

    def body(carry, lp):
        h, x = carry
        magg, dx_raw, deg = egnn_layer(h, x, src, dst, lp, edge_mask)
        h = egnn_node_update(h, magg, lp)
        if cfg.update_coords:
            x = x + normalize_dx(dx_raw, deg)
        return (h, x), None

    (h, x), _ = jax.lax.scan(body, (h, x), params["layers"])
    return mlp_forward(params["head"], h)                       # [N, classes]


def egnn_loss(params, batch, cfg: EGNNConfig):
    """Node classification CE over labeled nodes, or graph-level MSE."""
    logits = egnn_forward(params, batch["feats"], batch["coords"],
                          batch["src"], batch["dst"], cfg,
                          batch.get("edge_mask"))
    if cfg.graph_level:
        pooled = jax.ops.segment_sum(logits, batch["graph_ids"],
                                     num_segments=batch["n_graphs"])
        pred = pooled[:, 0]
        return ((pred - batch["targets"]) ** 2).mean()
    labels = batch["labels"]
    mask = batch.get("label_mask")
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    ce = lse - lab
    if mask is not None:
        return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce.mean()
