"""Distributed EGNN train steps.

Two regimes:

* full-graph (cora / ogb_products / flattened molecule batches): edges
  sharded over the FULL mesh, node features replicated for the gathers;
  per-layer partial aggregates are ``psum_scatter`` onto a node shard, the
  node MLP runs node-sharded, and an ``all_gather`` rebuilds the replicated
  features — the paper's Alg. 4 ownership pattern, applied to nodes.

* sampled minibatch (minibatch_lg): pure DP — each device trains on its own
  padded subgraphs from the fanout sampler (repro/data/graph.py); grads are
  psum'd and the Split-SGD update runs replicated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.models.egnn import (EGNNConfig, egnn_layer, egnn_node_update,
                               init_egnn_params, normalize_dx)
from repro.models.mlp import mlp_forward
from repro.optim import split_sgd


def _round_up(x, m):
    return (x + m - 1) // m * m


def _axes(mesh):
    return tuple(mesh.axis_names)


def _ns(mesh):
    return int(np.prod(list(mesh.shape.values())))


def egnn_state_structs(cfg: EGNNConfig, mesh):
    pshape = jax.eval_shape(
        lambda: init_egnn_params(jax.random.PRNGKey(0), cfg))
    mk = lambda dt: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt), pshape)
    structs = {"hi": mk(jnp.bfloat16), "lo": mk(jnp.uint16)}
    specs = jax.tree.map(lambda _: P(), structs)
    return structs, specs, jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def init_egnn_state(key, cfg, mesh):
    params = init_egnn_params(key, cfg)
    hi_lo = jax.tree.map(split_sgd.split_fp32, params)
    leaf = lambda x: isinstance(x, tuple)
    state = {"hi": jax.tree.map(lambda t: t[0], hi_lo, is_leaf=leaf),
             "lo": jax.tree.map(lambda t: t[1], hi_lo, is_leaf=leaf)}
    _, _, sh = egnn_state_structs(cfg, mesh)
    return jax.device_put(state, sh)


def fullgraph_batch_structs(cfg: EGNNConfig, mesh, n_nodes, n_edges,
                            graph_level_graphs: int = 0):
    """Padded global shapes: nodes to ns*8, edges to ns."""
    ns = _ns(mesh)
    N = _round_up(n_nodes, ns * 8)
    E = _round_up(n_edges, ns)
    AX = _axes(mesh)
    structs = {
        "feats": jax.ShapeDtypeStruct((N, cfg.d_feat), jnp.bfloat16),
        "coords": jax.ShapeDtypeStruct((N, cfg.coord_dim), jnp.float32),
        "src": jax.ShapeDtypeStruct((E,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.float32),
    }
    specs = {"feats": P(None, None), "coords": P(None, None),
             "src": P(AX), "dst": P(AX), "edge_mask": P(AX)}
    if graph_level_graphs:
        structs["graph_ids"] = jax.ShapeDtypeStruct((N,), jnp.int32)
        structs["targets"] = jax.ShapeDtypeStruct((graph_level_graphs,),
                                                  jnp.float32)
        specs["graph_ids"] = P(None)
        specs["targets"] = P()
    else:
        structs["labels"] = jax.ShapeDtypeStruct((N,), jnp.int32)
        structs["label_mask"] = jax.ShapeDtypeStruct((N,), jnp.float32)
        specs["labels"] = P(None)
        specs["label_mask"] = P(None)
    return structs, specs, (N, E)


def make_fullgraph_train_step(cfg: EGNNConfig, mesh, n_nodes, n_edges,
                              lr=1e-2, graph_level_graphs: int = 0,
                              unroll: bool = False):
    sstructs, sspecs, sshard = egnn_state_structs(cfg, mesh)
    bstructs, bspecs, (N, E) = fullgraph_batch_structs(
        cfg, mesh, n_nodes, n_edges, graph_level_graphs)
    AX = _axes(mesh)
    ns = _ns(mesh)
    Nsh = N // ns

    def fwd(hi, batch):
        # encoder on the node shard, gather to replicated
        shard = jax.lax.axis_index(AX)
        feats_sh = jax.lax.dynamic_slice_in_dim(batch["feats"], shard * Nsh,
                                                Nsh, axis=0)
        h_sh = mlp_forward(hi["encoder"], feats_sh, final_activation=True
                           ).astype(jnp.bfloat16)
        h = jax.lax.all_gather(h_sh, AX, axis=0, tiled=True)     # [N, H]
        x = batch["coords"]

        def body(carry, lp):
            h, x = carry
            magg, dx_raw, deg = egnn_layer(h, x, batch["src"], batch["dst"],
                                           lp, batch["edge_mask"],
                                           num_nodes=N)
            # partial aggregates -> node shard, update, regather
            magg_sh = jax.lax.psum_scatter(magg, AX, scatter_dimension=0,
                                           tiled=True)
            h_sh = jax.lax.dynamic_slice_in_dim(h, shard * Nsh, Nsh, 0)
            h_sh = egnn_node_update(h_sh, magg_sh, lp)
            h = jax.lax.all_gather(h_sh, AX, axis=0, tiled=True)
            if cfg.update_coords:
                # sum partials THEN normalize by the global degree
                dx_raw = jax.lax.psum(dx_raw, AX)
                deg = jax.lax.psum(deg, AX)
                x = x + normalize_dx(dx_raw, deg)
            return (h, x), None

        (h, x), _ = jax.lax.scan(jax.checkpoint(body), (h, x), hi["layers"],
                                 unroll=True if unroll else 1)
        # head on node shard
        h_sh = jax.lax.dynamic_slice_in_dim(h, shard * Nsh, Nsh, 0)
        return mlp_forward(hi["head"], h_sh), shard              # [Nsh, C]

    def loss_fn(hi, batch):
        logits, shard = fwd(hi, batch)
        if graph_level_graphs:
            gids = jax.lax.dynamic_slice_in_dim(batch["graph_ids"],
                                                shard * Nsh, Nsh, 0)
            pooled = jax.ops.segment_sum(logits, gids,
                                         num_segments=graph_level_graphs)
            pooled = jax.lax.psum(pooled, AX)
            pred = pooled[:, 0]
            return ((pred - batch["targets"]) ** 2).mean()
        labels = jax.lax.dynamic_slice_in_dim(batch["labels"],
                                              shard * Nsh, Nsh, 0)
        lmask = jax.lax.dynamic_slice_in_dim(batch["label_mask"],
                                             shard * Nsh, Nsh, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
        num = jax.lax.psum((lse - lab) * lmask, AX).sum()
        den = jax.lax.psum(lmask.sum(), AX)
        return num / jnp.maximum(den, 1.0)

    def step(state, batch):
        loss, g = jax.value_and_grad(loss_fn)(state["hi"], batch)
        g = jax.lax.psum(g, AX)
        out = jax.tree.map(
            lambda h, l, gg: split_sgd.update_leaf(h, l, gg, lr),
            state["hi"], state["lo"], g)
        leaf = lambda x: isinstance(x, tuple)
        new = {"hi": jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
               "lo": jax.tree.map(lambda t: t[1], out, is_leaf=leaf)}
        return new, loss

    sm = compat.shard_map(step, mesh=mesh, in_specs=(sspecs, bspecs),
                       out_specs=(sspecs, P()), check_vma=False)
    jitted = jax.jit(sm, donate_argnums=(0,))
    return jitted, (sstructs, bstructs), (sshard, jax.tree.map(
        lambda s: NamedSharding(mesh, s), bspecs,
        is_leaf=lambda x: isinstance(x, P)))


def minibatch_batch_structs(cfg: EGNNConfig, mesh, n_graphs, n_pad, e_pad):
    AX = _axes(mesh)
    structs = {
        "feats": jax.ShapeDtypeStruct((n_graphs, n_pad, cfg.d_feat),
                                      jnp.bfloat16),
        "coords": jax.ShapeDtypeStruct((n_graphs, n_pad, cfg.coord_dim),
                                       jnp.float32),
        "src": jax.ShapeDtypeStruct((n_graphs, e_pad), jnp.int32),
        "dst": jax.ShapeDtypeStruct((n_graphs, e_pad), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((n_graphs, e_pad), jnp.float32),
        "labels": jax.ShapeDtypeStruct((n_graphs,), jnp.int32),
    }
    specs = {k: P(AX, *([None] * (len(s.shape) - 1)))
             for k, s in structs.items()}
    return structs, specs


def make_minibatch_train_step(cfg: EGNNConfig, mesh, n_graphs, n_pad, e_pad,
                              lr=1e-2, unroll: bool = False):
    """Sampled-subgraph DP: one padded subgraph per target node, target is
    local node 0."""
    sstructs, sspecs, sshard = egnn_state_structs(cfg, mesh)
    bstructs, bspecs = minibatch_batch_structs(cfg, mesh, n_graphs, n_pad,
                                               e_pad)
    AX = _axes(mesh)

    def one_graph(hi, feats, coords, src, dst, emask):
        h = mlp_forward(hi["encoder"], feats, final_activation=True
                        ).astype(jnp.bfloat16)
        x = coords

        def body(carry, lp):
            h, x = carry
            magg, dx_raw, deg = egnn_layer(h, x, src, dst, lp, emask,
                                           num_nodes=n_pad)
            h = egnn_node_update(h, magg, lp)
            if cfg.update_coords:
                x = x + normalize_dx(dx_raw, deg)
            return (h, x), None

        (h, x), _ = jax.lax.scan(body, (h, x), hi["layers"],
                                 unroll=True if unroll else 1)
        return mlp_forward(hi["head"], h[:1])[0]        # target node logits

    def loss_fn(hi, batch):
        logits = jax.vmap(
            lambda f, c, s, d, m: one_graph(hi, f, c, s, d, m)
        )(batch["feats"], batch["coords"], batch["src"], batch["dst"],
          batch["edge_mask"])                            # [g_local, C]
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
        return jax.lax.psum((lse - lab).sum(), AX) / n_graphs

    def step(state, batch):
        loss, g = jax.value_and_grad(loss_fn)(state["hi"], batch)
        g = jax.lax.psum(g, AX)
        out = jax.tree.map(
            lambda h, l, gg: split_sgd.update_leaf(h, l, gg, lr),
            state["hi"], state["lo"], g)
        leaf = lambda x: isinstance(x, tuple)
        new = {"hi": jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
               "lo": jax.tree.map(lambda t: t[1], out, is_leaf=leaf)}
        return new, loss

    sm = compat.shard_map(step, mesh=mesh, in_specs=(sspecs, bspecs),
                       out_specs=(sspecs, P()), check_vma=False)
    jitted = jax.jit(sm, donate_argnums=(0,))
    return jitted, (sstructs, bstructs), (sshard, jax.tree.map(
        lambda s: NamedSharding(mesh, s), bspecs,
        is_leaf=lambda x: isinstance(x, P)))
