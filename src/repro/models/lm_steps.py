"""Jitted train/prefill/decode step factories for the LM family.

Distribution is GSPMD: param trees carry Megatron TP specs
(repro/dist/sharding.py), batch enters sharded over the DP axes, and XLA
inserts the collectives.  The optimizer is Split-SGD-BF16 (+momentum) on the
TP-sharded params — C5 is placement-agnostic, which is the paper's
"transferable to all other topologies" claim in action.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import transformer as tf
from repro.optim import split_sgd


def lm_state_structs(cfg: tf.TransformerConfig, mesh, momentum: bool = True):
    """(structs, shardings) for {'hi','lo','mom'} without materializing."""
    pshape = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.lm_param_specs(pshape, fsdp=cfg.fsdp,
                               tp=cfg.tp_size > 1)
    mk = lambda dt: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt), pshape)
    structs = {"hi": mk(jnp.bfloat16), "lo": mk(jnp.uint16)}
    spec_tree = {"hi": specs, "lo": specs}
    if momentum:
        structs["mom"] = mk(jnp.float32)
        spec_tree["mom"] = specs
    return structs, spec_tree, shd.named(mesh, spec_tree)


def init_lm_state(key, cfg: tf.TransformerConfig, mesh, momentum=True):
    params = tf.init_params(key, cfg)
    hi_lo = jax.tree.map(split_sgd.split_fp32, params)
    leaf = lambda x: isinstance(x, tuple)
    state = {"hi": jax.tree.map(lambda t: t[0], hi_lo, is_leaf=leaf),
             "lo": jax.tree.map(lambda t: t[1], hi_lo, is_leaf=leaf)}
    if momentum:
        state["mom"] = jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
    _, _, shardings = lm_state_structs(cfg, mesh, momentum)
    return jax.device_put(state, shardings)


def make_lm_train_step(cfg: tf.TransformerConfig, mesh, B: int, L: int,
                       lr: float = 1e-2, beta: float = 0.9,
                       momentum: bool = True):
    structs, spec_tree, shardings = lm_state_structs(cfg, mesh, momentum)
    bdp = cfg.dp_axes   # pure-DP configs span the whole mesh (HC1)
    bstructs = {"tokens": jax.ShapeDtypeStruct((B, L), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, L), jnp.int32)}
    bshard = {"tokens": NamedSharding(mesh, P(bdp, None)),
              "labels": NamedSharding(mesh, P(bdp, None))}

    def grads_of(state, batch):
        """Loss+grads, optionally accumulated over microbatches (gradient
        accumulation divides activation transients by cfg.microbatch while
        keeping the global batch — the standard large-scale fit lever)."""
        mb = max(1, cfg.microbatch)
        if mb == 1:
            return jax.value_and_grad(
                lambda hi: tf.lm_loss(hi, batch["tokens"], batch["labels"],
                                      cfg))(state["hi"])
        toks = batch["tokens"].reshape(mb, B // mb, L)
        labs = batch["labels"].reshape(mb, B // mb, L)

        def cons(t):
            # pin the fp32 accumulator to the param sharding — GSPMD
            # otherwise under-shards it (observed: a 2.2 GiB half-replicated
            # embed grad on gemma2)
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                t, shardings["hi"])

        # bf16 accumulation: matches the non-microbatched path's gradient
        # dtype and halves the accumulator footprint (fp32 accum on 236B
        # params costs 6.8 GiB/device on top of the weights).
        g0 = cons(jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                               state["hi"]))

        def body(carry, inp):
            acc_l, acc_g = carry
            t, l = inp
            loss, g = jax.value_and_grad(
                lambda hi: tf.lm_loss(hi, t, l, cfg))(state["hi"])
            acc_g = cons(jax.tree.map(
                lambda a, gg: (a + gg).astype(a.dtype), acc_g, g))
            return (acc_l + loss, acc_g), None

        (loss, g), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), g0),
                                    (toks, labs))
        return loss / mb, jax.tree.map(lambda x: x / mb, g)

    def upd_leaf(h, l, g, m=None):
        """Split-SGD on one leaf; stacked-layer leaves are scanned over the
        layer dim so the fp32 reconstruct/bit temporaries stay per-layer
        (a 236B param tree otherwise materializes multi-GiB w32 buffers)."""
        if h.ndim >= 3 and h.shape[0] > 1 and not cfg.cost_mode:
            def body(_, s):
                if m is None:
                    hh, ll, gg = s
                    return None, split_sgd.update_leaf(hh, ll, gg, lr)
                hh, ll, gg, mm = s
                return None, split_sgd.update_leaf(hh, ll, gg, lr, mm, beta)
            xs = (h, l, g) if m is None else (h, l, g, m)
            _, out = jax.lax.scan(body, None, xs)
            return out
        if m is None:
            return split_sgd.update_leaf(h, l, g, lr)
        return split_sgd.update_leaf(h, l, g, lr, m, beta)

    def step(state, batch):
        loss, grads = grads_of(state, batch)
        leaf = lambda x: isinstance(x, tuple)
        if momentum:
            out = jax.tree.map(upd_leaf, state["hi"], state["lo"], grads,
                               state["mom"])
            new = {"hi": jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
                   "lo": jax.tree.map(lambda t: t[1], out, is_leaf=leaf),
                   "mom": jax.tree.map(lambda t: t[2], out, is_leaf=leaf)}
        else:
            out = jax.tree.map(lambda h, l, g: upd_leaf(h, l, g),
                               state["hi"], state["lo"], grads)
            new = {"hi": jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
                   "lo": jax.tree.map(lambda t: t[1], out, is_leaf=leaf)}
        return new, loss

    jitted = jax.jit(step, in_shardings=(shardings, bshard),
                     out_shardings=(shardings, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
    return jitted, (structs, bstructs), (shardings, bshard)


def _param_structs(cfg, mesh):
    pshape = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.lm_param_specs(pshape, fsdp=cfg.fsdp,
                               tp=cfg.tp_size > 1)
    structs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), pshape)
    return structs, shd.named(mesh, specs)


def cache_structs(cfg: tf.TransformerConfig, mesh, B: int, Lmax: int):
    """KV-cache ShapeDtypeStructs + shardings.

    Decode writes one position per step; a SEQ-sharded cache turns that
    scatter into GSPMD's replicate-fallback reshard (HC2 in EXPERIMENTS.md
    section Perf: ~1e11 collective bytes/step on internlm2).  So when the
    batch covers the DP axes we shard HEADS over 'model' when divisible,
    else the HEAD DIM — the per-step write is then shard-local.  Only the
    long-context B=1 cell keeps sequence sharding (a 500k cache must split
    along seq; its decode reads amortize the reshard)."""
    bdp = shd.batch_axes(mesh)
    ndp = int(np.prod([mesh.shape[a] for a in bdp]))
    tp = mesh.shape["model"]
    nl = cfg.n_layers
    batch_ok = B % ndp == 0
    if cfg.mla:
        structs = {
            "c_kv": jax.ShapeDtypeStruct((nl, B, Lmax, cfg.kv_lora),
                                         jnp.bfloat16),
            "k_rope": jax.ShapeDtypeStruct((nl, B, Lmax, cfg.qk_rope),
                                           jnp.bfloat16),
        }
        if batch_ok:
            # latent dim sharded; the per-step write stays local
            spec = {"c_kv": P(None, bdp, None, shd.MODEL),
                    "k_rope": P(None, bdp, None,
                                shd.MODEL if cfg.qk_rope % tp == 0
                                else None)}
        else:
            spec = {"c_kv": P(None, None, shd.all_axes(mesh), None),
                    "k_rope": P(None, None, shd.all_axes(mesh), None)}
    else:
        shape = (nl, B, cfg.n_kv_heads, Lmax, cfg.d_head)
        structs = {"k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
                   "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16)}
        if batch_ok and cfg.n_kv_heads % tp == 0:
            spec = {k: P(None, bdp, shd.MODEL, None, None)
                    for k in ("k", "v")}
        elif batch_ok and cfg.d_head % tp == 0:
            spec = {k: P(None, bdp, None, None, shd.MODEL)
                    for k in ("k", "v")}
        elif batch_ok:
            spec = {k: P(None, bdp, None, shd.MODEL, None)
                    for k in ("k", "v")}
        else:
            spec = {k: P(None, None, None, shd.all_axes(mesh), None)
                    for k in ("k", "v")}
    return structs, spec, shd.named(mesh, spec)


def make_prefill_step(cfg: tf.TransformerConfig, mesh, B: int, L: int):
    pstructs, pshard = _param_structs(cfg, mesh)
    bdp = shd.batch_axes(mesh)
    ndp = int(np.prod([mesh.shape[a] for a in bdp]))
    tstruct = jax.ShapeDtypeStruct((B, L), jnp.int32)
    tshard = NamedSharding(mesh, P(bdp, None))
    _, cspec, cshard = cache_structs(cfg, mesh, B, L)
    mb = max(1, min(cfg.prefill_microbatch, B // ndp))
    while B % mb or (B // mb) % ndp:
        mb -= 1

    def run(params, tokens):
        if mb == 1:
            return tf.prefill(params, tokens, cfg)
        # batch-chunked prefill: sequential half-batches bound the MoE
        # dispatch transients (serving-style)
        toks = tokens.reshape(mb, B // mb, L)
        _, (logits, cache) = jax.lax.scan(
            lambda _, t: (None, tf.prefill(params, t, cfg)), None, toks)
        logits = logits.reshape(B, -1)
        cache = jax.tree.map(
            lambda a: a.transpose(1, 0, *range(2, a.ndim)).reshape(
                a.shape[1], B, *a.shape[3:]), cache)
        return logits, cache

    jitted = jax.jit(run, in_shardings=(pshard, tshard),
                     out_shardings=(NamedSharding(mesh, P(bdp, shd.MODEL)),
                                    cshard))
    return jitted, (pstructs, tstruct), (pshard, tshard)


def make_decode_step(cfg: tf.TransformerConfig, mesh, B: int, Lmax: int):
    pstructs, pshard = _param_structs(cfg, mesh)
    cstructs, cspec, cshard = cache_structs(cfg, mesh, B, Lmax)
    bdp = shd.batch_axes(mesh)
    ndp = int(np.prod([mesh.shape[a] for a in bdp]))
    batch_ok = B % ndp == 0
    tok_spec = P(bdp) if batch_ok else P()
    logit_spec = P(bdp, shd.MODEL) if batch_ok else P(None, shd.MODEL)
    tstruct = jax.ShapeDtypeStruct((B,), jnp.int32)
    pstruct = jax.ShapeDtypeStruct((B,), jnp.int32)
    tshard = NamedSharding(mesh, tok_spec)

    def run(params, cache, tokens, pos):
        return tf.decode_step(params, cache, tokens, pos, cfg)

    jitted = jax.jit(
        run,
        in_shardings=(pshard, cshard, tshard, tshard),
        out_shardings=(NamedSharding(mesh, logit_spec), cshard),
        donate_argnums=(1,))
    return jitted, (pstructs, cstructs, tstruct, pstruct), (pshard, cshard,
                                                            tshard, tshard)