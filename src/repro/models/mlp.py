"""MLP stack (paper contribution C2's consumer).

Forward runs in bf16 with fp32 accumulation; the activation (ReLU) is fused
into the GEMM epilogue — via the Pallas ``fused_mlp`` kernel on TPU, or left
to XLA fusion on other backends (``impl='xla'``, the dry-run path).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def init_mlp(key: jax.Array, sizes: Sequence[int], dtype=jnp.float32) -> dict:
    """``sizes = [in, h1, ..., out]`` -> {'w': [...], 'b': [...]}."""
    ws, bs = [], []
    for i, (cin, cout) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        std = (2.0 / (cin + cout)) ** 0.5
        ws.append((jax.random.normal(k, (cin, cout), jnp.float32) * std
                   ).astype(dtype))
        bs.append(jnp.zeros((cout,), dtype))
    return {"w": ws, "b": bs}


def mlp_forward(params: dict, x: jax.Array, final_activation: bool = False,
                impl: str = "xla") -> jax.Array:
    """Apply the stack; ReLU between layers, optionally on the last one."""
    n = len(params["w"])
    h = x
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        act = final_activation or i < n - 1
        if impl == "pallas":
            from repro.kernels.ops import fused_mlp_layer
            h = fused_mlp_layer(h.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                                b, activation="relu" if act else "none")
        else:
            y = jnp.dot(h.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32) + b.astype(jnp.float32)
            h = jax.nn.relu(y) if act else y
        h = h.astype(jnp.bfloat16) if i < n - 1 else h
    return h  # final layer fp32


def mlp_sizes(params: dict) -> list[int]:
    return [params["w"][0].shape[0]] + [w.shape[1] for w in params["w"]]


def allreduce_bytes(sizes: Sequence[int], bytes_per_elem: int = 4) -> int:
    """Paper Eq. 1: SZ_allreduce = sum_l f_i*f_o + f_o (per rank,
    rank-count-independent — the strong-scaling wall)."""
    total = 0
    for cin, cout in zip(sizes[:-1], sizes[1:]):
        total += cin * cout + cout
    return total * bytes_per_elem
