"""The four assigned recsys architectures as HybridDef models:

    fm       FM 2-way (Rendle, ICDM'10) via the O(nk) sum-square trick
    bst      Behavior Sequence Transformer (arXiv:1905.06874)
    sasrec   self-attentive sequential rec (arXiv:1808.09781)
    din      Deep Interest Network target attention (arXiv:1706.06978)

All share the paper's hybrid-parallel skeleton (repro/core/hybrid.py): one
unified embedding space (items + context fields concatenated), model-parallel
over the mesh, dense nets data-parallel.  Sequence lookups reuse the bag
machinery with P=1 per position (a bag of one IS a lookup), so the paper's
all-to-all/reduce-scatter layout switch covers sequence models too.

The ``retrieval_cand`` shape (1 query x 1M candidates) is a batched-dot /
candidate-sharded scoring step with a distributed top-k merge — never a loop.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.embedding import EmbeddingSpec
from repro.core.hybrid import HybridDef
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.attention import chunked_attention


def bce_sum(logits, labels):
    x, y = logits.astype(jnp.float32), labels.astype(jnp.float32)
    return (jnp.maximum(x, 0) - x * y + jnp.log1p(jnp.exp(-jnp.abs(x)))).sum()


# ---------------------------------------------------------------------------
# FM — n_sparse=39, embed_dim=10, fm-2way
# The unified table carries E=11 per row: dims 0..9 are the factor vector v,
# dim 10 is the linear weight w (one lookup serves both terms).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FMSizes:
    n_fields: int = 39
    k: int = 10


def fm_dense_init(key):
    return {"bias": jnp.zeros((1,), jnp.float32)}


def fm_score(dense_hi, emb_out, batch, k: int = 10):
    v = emb_out[:, :, :k]                   # [B, S, k] fp32
    w = emb_out[:, :, k]                    # [B, S]
    sv = v.sum(axis=1)                      # [B, k]
    fm2 = 0.5 * ((sv * sv).sum(-1) - (v * v).sum(axis=(1, 2)))
    return dense_hi["bias"][0].astype(jnp.float32) + w.sum(-1) + fm2


def make_fm(table_rows, batch=65536, **kw) -> HybridDef:
    sizes = FMSizes()
    spec = EmbeddingSpec(tuple(table_rows), sizes.k + 1)
    return HybridDef(
        name="fm", spec=spec, pooling=1, batch=batch,
        init_dense=fm_dense_init,
        dense_loss=lambda hi, e, b: bce_sum(fm_score(hi, e, b, sizes.k),
                                            b["labels"]),
        dense_score=lambda hi, e, b: fm_score(hi, e, b, sizes.k),
        extras={"labels": ((), jnp.float32)}, **kw)


# ---------------------------------------------------------------------------
# BST — embed_dim=32, seq_len=20, 1 transformer block, 8 heads,
#       MLP 1024-512-256.  Slots: [0..19]=behavior seq, [20]=target item,
#       [21..28]=context fields.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BSTSizes:
    seq_len: int = 20
    emb_dim: int = 32
    n_heads: int = 8
    n_ctx: int = 8
    mlp: tuple = (1024, 512, 256)


def bst_dense_init(key, s: BSTSizes = BSTSizes()):
    ks = iter(jax.random.split(key, 8))
    d = s.emb_dim
    L = s.seq_len + 1
    mlp_in = L * d + s.n_ctx * d
    return {
        "pos": jax.random.normal(next(ks), (L, d), jnp.float32) * 0.02,
        "wq": jax.random.normal(next(ks), (d, d), jnp.float32) * d ** -0.5,
        "wk": jax.random.normal(next(ks), (d, d), jnp.float32) * d ** -0.5,
        "wv": jax.random.normal(next(ks), (d, d), jnp.float32) * d ** -0.5,
        "wo": jax.random.normal(next(ks), (d, d), jnp.float32) * d ** -0.5,
        "ffn": init_mlp(next(ks), [d, 4 * d, d]),
        "mlp": init_mlp(next(ks), [mlp_in, *s.mlp, 1]),
    }


def bst_score(dense_hi, emb_out, batch, s: BSTSizes = BSTSizes()):
    B = emb_out.shape[0]
    d, H = s.emb_dim, s.n_heads
    L = s.seq_len + 1
    seq = emb_out[:, :L].astype(jnp.bfloat16) + \
        dense_hi["pos"].astype(jnp.bfloat16)[None]
    ctx = emb_out[:, L:]
    q = jnp.dot(seq, dense_hi["wq"]).reshape(B, L, H, d // H)
    k = jnp.dot(seq, dense_hi["wk"]).reshape(B, L, H, d // H)
    v = jnp.dot(seq, dense_hi["wv"]).reshape(B, L, H, d // H)
    o = chunked_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, d)
    h = seq + jnp.dot(o, dense_hi["wo"]).astype(jnp.bfloat16)
    h = h + mlp_forward(dense_hi["ffn"], h).astype(jnp.bfloat16)
    flat = jnp.concatenate([h.reshape(B, L * d).astype(jnp.float32),
                            ctx.reshape(B, -1)], axis=-1)
    return mlp_forward(dense_hi["mlp"], flat.astype(jnp.bfloat16))[:, 0]


def make_bst(item_vocab, ctx_rows, batch=65536, **kw) -> HybridDef:
    s = BSTSizes()
    # ONE shared item table; seq+target slots all map to it (slot_to_table)
    rows = (item_vocab,) + tuple(ctx_rows)
    spec = EmbeddingSpec(rows, s.emb_dim)
    s2t = tuple([0] * (s.seq_len + 1)) + tuple(range(1, 1 + len(ctx_rows)))
    return HybridDef(
        name="bst", spec=spec, pooling=1, batch=batch,
        init_dense=lambda k: bst_dense_init(k, s),
        dense_loss=lambda hi, e, b: bce_sum(bst_score(hi, e, b, s),
                                            b["labels"]),
        dense_score=lambda hi, e, b: bst_score(hi, e, b, s),
        extras={"labels": ((), jnp.float32)}, slot_to_table=s2t, **kw)


# ---------------------------------------------------------------------------
# SASRec — embed_dim=50, 2 blocks, 1 head, seq_len=50.
# Slots: [0..49]=input seq, [50..99]=positive next items, [100..149]=sampled
# negatives.  BCE over (pos, neg) per position (the paper's objective).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SASRecSizes:
    seq_len: int = 50
    emb_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1


def sasrec_dense_init(key, s: SASRecSizes = SASRecSizes()):
    ks = iter(jax.random.split(key, 2 + 5 * s.n_blocks))
    d = s.emb_dim
    blocks = []
    for _ in range(s.n_blocks):
        blocks.append({
            "wq": jax.random.normal(next(ks), (d, d)) * d ** -0.5,
            "wk": jax.random.normal(next(ks), (d, d)) * d ** -0.5,
            "wv": jax.random.normal(next(ks), (d, d)) * d ** -0.5,
            "wo": jax.random.normal(next(ks), (d, d)) * d ** -0.5,
            "ffn": init_mlp(next(ks), [d, d, d]),
        })
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return {"pos": jax.random.normal(next(ks), (s.seq_len, d)) * 0.02,
            "blocks": blocks}


def sasrec_user_rep(dense_hi, seq_emb, s: SASRecSizes = SASRecSizes()):
    """seq_emb [B, L, E] fp32 -> causal user representations [B, L, E]."""
    B, L, d = seq_emb.shape
    h = seq_emb.astype(jnp.bfloat16) + \
        dense_hi["pos"].astype(jnp.bfloat16)[None]
    H = s.n_heads

    def block(h, bp):
        q = jnp.dot(h, bp["wq"]).reshape(B, L, H, d // H).transpose(0, 2, 1, 3)
        k = jnp.dot(h, bp["wk"]).reshape(B, L, H, d // H).transpose(0, 2, 1, 3)
        v = jnp.dot(h, bp["wv"]).reshape(B, L, H, d // H).transpose(0, 2, 1, 3)
        o = chunked_attention(q, k, v, causal=True)
        o = o.transpose(0, 2, 1, 3).reshape(B, L, d)
        h = h + jnp.dot(o, bp["wo"]).astype(jnp.bfloat16)
        return (h + mlp_forward(bp["ffn"], h).astype(jnp.bfloat16)), None

    h, _ = jax.lax.scan(block, h, dense_hi["blocks"])
    return h.astype(jnp.float32)


def sasrec_loss_sum(dense_hi, emb_out, batch, s: SASRecSizes = SASRecSizes()):
    L = s.seq_len
    u = sasrec_user_rep(dense_hi, emb_out[:, :L], s)       # [B, L, E]
    pos, neg = emb_out[:, L:2 * L], emb_out[:, 2 * L:3 * L]
    sp = (u * pos).sum(-1)
    sn = (u * neg).sum(-1)
    m = batch["seq_mask"].astype(jnp.float32)              # [B, L]
    ls = bce_like = (jnp.log1p(jnp.exp(-sp)) + jnp.log1p(jnp.exp(sn))) * m
    return ls.sum() / jnp.maximum(1.0, 1.0)                # per-shard sum


def sasrec_score(dense_hi, emb_out, batch, s: SASRecSizes = SASRecSizes()):
    """Serve: dot(user rep at last position, target item) -- the target item
    embedding rides in the 'pos' slots' first column."""
    L = s.seq_len
    u = sasrec_user_rep(dense_hi, emb_out[:, :L], s)[:, -1]
    target = emb_out[:, L]                                 # slot L = target
    return (u * target).sum(-1)


def make_sasrec(item_vocab, batch=65536, **kw) -> HybridDef:
    s = SASRecSizes()
    spec = EmbeddingSpec((item_vocab,), s.emb_dim)   # ONE shared item table
    s2t = tuple([0] * (3 * s.seq_len))               # seq + pos + neg slots
    return HybridDef(
        name="sasrec", spec=spec, pooling=1, batch=batch,
        init_dense=lambda k: sasrec_dense_init(k, s),
        dense_loss=lambda hi, e, b: sasrec_loss_sum(hi, e, b, s),
        dense_score=lambda hi, e, b: sasrec_score(hi, e, b, s),
        extras={"seq_mask": ((s.seq_len,), jnp.float32)},
        slot_to_table=s2t, **kw)


# ---------------------------------------------------------------------------
# DIN — embed_dim=18, hist len=100, attention MLP 80-40, main MLP 200-80.
# Slots: [0..99]=history, [100]=target, [101..104]=context fields.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DINSizes:
    hist: int = 100
    emb_dim: int = 18
    n_ctx: int = 4
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)


def din_dense_init(key, s: DINSizes = DINSizes()):
    k1, k2 = jax.random.split(key)
    d = s.emb_dim
    return {"attn": init_mlp(k1, [4 * d, *s.attn_mlp, 1]),
            "mlp": init_mlp(k2, [(2 + s.n_ctx) * d, *s.mlp, 1])}


def din_score(dense_hi, emb_out, batch, s: DINSizes = DINSizes()):
    B = emb_out.shape[0]
    h = emb_out[:, :s.hist]                    # [B, T, E]
    t = emb_out[:, s.hist]                     # [B, E]
    ctx = emb_out[:, s.hist + 1:]              # [B, n_ctx, E]
    tt = jnp.broadcast_to(t[:, None, :], h.shape)
    a_in = jnp.concatenate([h, tt, h - tt, h * tt], axis=-1)
    a = mlp_forward(dense_hi["attn"], a_in.astype(jnp.bfloat16))[..., 0]
    mask = batch.get("hist_mask")
    if mask is not None:
        a = a * mask.astype(jnp.float32)
    pooled = (a[..., None] * h).sum(axis=1)    # [B, E]
    flat = jnp.concatenate([pooled, t, ctx.reshape(B, -1)], axis=-1)
    return mlp_forward(dense_hi["mlp"], flat.astype(jnp.bfloat16))[:, 0]


def make_din(item_vocab, ctx_rows, batch=65536, **kw) -> HybridDef:
    s = DINSizes()
    rows = (item_vocab,) + tuple(ctx_rows)           # ONE shared item table
    spec = EmbeddingSpec(rows, s.emb_dim)
    s2t = tuple([0] * (s.hist + 1)) + tuple(range(1, 1 + len(ctx_rows)))
    return HybridDef(
        name="din", spec=spec, pooling=1, batch=batch,
        init_dense=lambda k: din_dense_init(k, s),
        dense_loss=lambda hi, e, b: bce_sum(din_score(hi, e, b, s),
                                            b["labels"]),
        dense_score=lambda hi, e, b: din_score(hi, e, b, s),
        extras={"labels": ((), jnp.float32),
                "hist_mask": ((s.hist,), jnp.float32)},
        slot_to_table=s2t, **kw)


# ---------------------------------------------------------------------------
# Retrieval scoring (retrieval_cand shape): candidates sharded over the full
# mesh, per-shard scores + distributed top-k merge.
# ---------------------------------------------------------------------------

def make_retrieval_step(mdef: HybridDef, mesh, n_candidates: int,
                        emb_dim: int, topk: int = 128):
    """Generic candidate scoring: the caller passes per-candidate embedding
    rows (gathered from the item table) pre-sharded over the mesh, plus the
    query-side embedding output; scoring is a batched dot (sasrec) or the
    model's dense_score vmapped over candidate chunks.

    Returns scores' global top-k (values, indices)."""
    all_axes = tuple(mesh.axis_names)
    ns = int(np.prod(list(mesh.shape.values())))
    per = n_candidates // ns

    def local(urep, cand):                      # urep [E], cand [per, E]
        s = jnp.einsum("e,ce->c", urep.astype(jnp.float32),
                       cand.astype(jnp.float32))
        v, i = jax.lax.top_k(s, min(topk, per))
        base = jax.lax.axis_index(all_axes) * per
        i = i + base
        vg = jax.lax.all_gather(v, all_axes, axis=0, tiled=True)
        ig = jax.lax.all_gather(i, all_axes, axis=0, tiled=True)
        vv, pos = jax.lax.top_k(vg, topk)
        return vv, jnp.take(ig, pos)

    fn = compat.shard_map(local, mesh=mesh,
                       in_specs=(P(), P(all_axes, None)),
                       out_specs=(P(), P()), check_vma=False)
    return jax.jit(fn)
