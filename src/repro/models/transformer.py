"""Transformer LM family covering the five assigned architectures:

    qwen3-moe-30b-a3b   GQA + 128-expert top-8 MoE
    deepseek-v2-236b    MLA (latent KV) + 2-shared/160-routed top-6 MoE
    internlm2-1.8b      dense GQA
    gemma2-27b          dense GQA, alternating local/global attn, softcaps
    phi3-medium-14b     dense GQA

Design notes
------------
* Layers are scanned (stacked params) with full per-layer remat — compile
  size stays flat in depth, which is what makes the 512-device dry-run of a
  60-layer MoE tractable.
* TP follows Megatron: attention heads and FFN hidden sharded over 'model';
  vocab table row-sharded over 'model' (the paper's C1 embedding-sharding
  insight applied to the LM family); batch over the remaining axes.
  Sharding enters through constraints below + param specs in
  repro/dist/sharding.py, GSPMD inserts the collectives.
* MoE dispatch is per-sequence grouped (capacity C = ceil(L*k*cf/E)):
  one-hot slot assignment via cumsum, scatter into [B, E, C, d] buffers,
  batched expert GEMMs (TP over the expert hidden dim), gather+weighted
  combine.  No host-side or data-dependent shapes anywhere.
* MoE models follow the paper's hybrid-parallel pattern: the router's
  dispatch/combine is the same model<->data layout switch as DLRM's
  interaction all-to-all (DESIGN.md section 5).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.models.attention import (attention, decode_attention, rms_norm,
                                    repeat_kv, rope)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # MLA (deepseek)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # gemma2
    local_global: bool = False
    window: int = 4096
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    embed_scale: bool = False       # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = True
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    attn_impl: str = "chunked"      # 'chunked' | 'pallas'
    remat: bool = True
    # sequence parallelism: shard the token dim of activations over 'model'
    # between blocks (Megatron-SP); dp_axes are the mesh batch axes.
    seq_shard: bool = True
    dp_axes: tuple = ("data",)
    tp_size: int = 16               # 'model' axis width (set by the builder)
    loss_chunk: int = 1024          # token-chunked loss (never materializes
                                    # the full [B, L, V] logits)
    microbatch: int = 1             # grad-accumulation chunks per step
    prefill_microbatch: int = 1     # batch-chunked prefill (serving)
    attn_chunk: int = 256           # q-chunk for the XLA attention path
    # FSDP('data') on top of TP: required for 27B+ params, a PESSIMIZATION
    # for small models (per-layer weight all-gathers dominate; see
    # EXPERIMENTS.md section Perf HC1) — configs disable it when params fit.
    fsdp: bool = True
    # cost_mode: fully unroll the layer scans so compiled cost_analysis
    # counts every layer (XLA counts a while body ONCE regardless of trip
    # count).  Used ONLY by benchmarks/roofline.py on reduced-depth builds.
    cost_mode: bool = False

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_scale(self) -> float:
        if self.mla:
            return float((self.qk_nope + self.qk_rope) ** -0.5)
        return float(self.d_head ** -0.5)

    def layer_windows(self) -> list[int]:
        """Per-layer local window (0 = global).  gemma2 alternates
        local(window), global, local, ..."""
        if not self.local_global:
            return [0] * self.n_layers
        return [self.window if i % 2 == 0 else 0
                for i in range(self.n_layers)]

    def param_count(self) -> int:
        c = self
        d = c.d_model
        if c.mla:
            attn = (d * c.q_lora + c.q_lora * c.n_heads * (c.qk_nope + c.qk_rope)
                    + d * (c.kv_lora + c.qk_rope)
                    + c.kv_lora * c.n_heads * (c.qk_nope + c.v_head)
                    + c.n_heads * c.v_head * d)
        else:
            attn = d * c.n_heads * c.d_head + 2 * d * c.n_kv_heads * c.d_head \
                + c.n_heads * c.d_head * d
        dense_ffn = 3 * d * c.d_ff
        if c.moe:
            moe_ffn = c.n_experts * 3 * d * c.moe_d_ff + d * c.n_experts
            if c.n_shared_experts:
                moe_ffn += 3 * d * c.moe_d_ff * c.n_shared_experts
            n_moe = c.n_layers - c.first_dense_layers
            ffn_total = n_moe * moe_ffn + c.first_dense_layers * dense_ffn
        else:
            ffn_total = c.n_layers * dense_ffn
        total = c.n_layers * (attn + 2 * d) + ffn_total + c.vocab * d
        if not c.tie_embeddings:
            total += c.vocab * d
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        c = self
        d = c.d_model
        full = self.param_count()
        n_moe = c.n_layers - c.first_dense_layers
        routed_all = n_moe * c.n_experts * 3 * d * c.moe_d_ff
        routed_active = n_moe * c.top_k * 3 * d * c.moe_d_ff
        return full - routed_all + routed_active


# ---------------------------------------------------------------------------
# Parameter construction (fp32 host init for smoke configs; eval_shape for
# the dry-run)
# ---------------------------------------------------------------------------

def _dense(key, shape, scale=None):
    scale = scale if scale is not None else (shape[0] ** -0.5)
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_layer_params(key, cfg: TransformerConfig, moe_layer: bool) -> dict:
    ks = iter(jax.random.split(key, 24))
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p: dict[str, Any] = {"ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,))}
    if cfg.mla:
        p["attn"] = {
            "wq_a": _dense(next(ks), (d, cfg.q_lora)),
            "q_norm": jnp.zeros((cfg.q_lora,)),
            "wq_b": _dense(next(ks), (cfg.q_lora,
                                      H * (cfg.qk_nope + cfg.qk_rope))),
            "wkv_a": _dense(next(ks), (d, cfg.kv_lora + cfg.qk_rope)),
            "kv_norm": jnp.zeros((cfg.kv_lora,)),
            "wkv_b": _dense(next(ks), (cfg.kv_lora,
                                       H * (cfg.qk_nope + cfg.v_head))),
            "wo": _dense(next(ks), (H * cfg.v_head, d)),
        }
    else:
        p["attn"] = {
            "wq": _dense(next(ks), (d, H * dh)),
            "wk": _dense(next(ks), (d, Hkv * dh)),
            "wv": _dense(next(ks), (d, Hkv * dh)),
            "wo": _dense(next(ks), (H * dh, d)),
        }
    if moe_layer:
        f = cfg.moe_d_ff
        p["moe"] = {
            "router": _dense(next(ks), (d, cfg.n_experts)),
            "wg": _dense(next(ks), (cfg.n_experts, d, f)),
            "wu": _dense(next(ks), (cfg.n_experts, d, f)),
            "wd": _dense(next(ks), (cfg.n_experts, f, d)),
        }
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            p["moe"]["shared"] = {
                "wg": _dense(next(ks), (d, fs)),
                "wu": _dense(next(ks), (d, fs)),
                "wd": _dense(next(ks), (fs, d)),
            }
    else:
        p["mlp"] = {"wg": _dense(next(ks), (d, cfg.d_ff)),
                    "wu": _dense(next(ks), (d, cfg.d_ff)),
                    "wd": _dense(next(ks), (cfg.d_ff, d))}
    return p


def init_params(key, cfg: TransformerConfig) -> dict:
    """Stacked-layer fp32 params.  Structure:
    {embed, layers (stacked n_moe), dense_layers (stacked, optional),
     final_norm, unembed?}"""
    k0, k1, k2, k3 = jax.random.split(key, 4)
    n_dense_pre = cfg.first_dense_layers
    n_main = cfg.n_layers - n_dense_pre
    main_moe = cfg.moe

    def stack(key, n, moe_layer):
        keys = jax.random.split(key, n)
        layers = [init_layer_params(k, cfg, moe_layer) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    params = {
        "embed": _dense(k0, (cfg.vocab, cfg.d_model), scale=0.02),
        "layers": stack(k1, n_main, main_moe),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if n_dense_pre:
        params["dense_layers"] = stack(k2, n_dense_pre, False)
    if not cfg.tie_embeddings:
        params["unembed"] = _dense(k3, (cfg.d_model, cfg.vocab), scale=0.02)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _wsc(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh in context (single-device smoke tests)


def _expert_ffn(buf, wg, wu, wd, cfg: TransformerConfig):
    """Expert FFN with an EXPLICIT EP exchange.

    The model<->data layout switch (the paper's C3 all-to-all) is done with
    manual ``jax.lax.all_to_all`` inside a shard_map — GSPMD's automatic
    reshard of the [B, E, C, d] dispatch buffer falls into its
    replicate-fallback on the multi-pod mesh (observed 16 GiB/device), so
    we spell out the collective:

        fwd: all_to_all over EP axis (split E, concat B)  -> expert GEMMs
             (f sharded over 'model', fp32-accumulated, psum over 'model')
             -> all_to_all back
        bwd: the transposed collectives, for free via shard_map autodiff.
    """
    if not cfg.seq_shard:      # single-device / smoke path
        g = jnp.einsum("becd,edf->becf", buf, wg)
        u = jnp.einsum("becd,edf->becf", buf, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
        return jnp.einsum("becf,efd->becd", h, wd).astype(buf.dtype)

    from jax.sharding import PartitionSpec as P
    ep = cfg.dp_axes[-1]
    mesh = compat.get_abstract_mesh()

    def inner(buf_l, wg_l, wu_l, wd_l):
        # buf_l [B/ndp, E, C, d] -> a2a -> [B/ndp*ep, E/ep, C, d]
        bx = jax.lax.all_to_all(buf_l, ep, split_axis=1, concat_axis=0,
                                tiled=True)
        g = jnp.einsum("becd,edf->becf", bx, wg_l)
        u = jnp.einsum("becd,edf->becf", bx, wu_l)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(bx.dtype) * u
        o = jnp.einsum("becf,efd->becd", h, wd_l,
                       preferred_element_type=jnp.float32)
        o = jax.lax.psum(o, "model").astype(bx.dtype)  # TP reduce over f
        return jax.lax.all_to_all(o, ep, split_axis=0, concat_axis=1,
                                  tiled=True)

    return compat.shard_map(
        inner, mesh=mesh,
        in_specs=(P(cfg.dp_axes, None, None, None),
                  P(ep, None, "model"), P(ep, None, "model"),
                  P(ep, "model", None)),
        out_specs=P(cfg.dp_axes, None, None, None),
        check_vma=False)(buf, wg, wu, wd)


def _head_constraint(x, cfg: TransformerConfig):
    """[B, H, L, D] head-sharded over 'model' when divisible (GSPMD loses
    the head sharding through MLA's reshape chain — observed: deepseek
    attention scores with all 128 heads on every device)."""
    if cfg.tp_size <= 1 or not cfg.seq_shard or x.shape[1] % cfg.tp_size:
        return x
    from jax.sharding import PartitionSpec as P
    return _wsc(x, P(cfg.dp_axes, "model", None, None))


def _logit_constraint(x, cfg: TransformerConfig):
    """[B, c, V] vocab-sharded (the tied-embedding gradient otherwise
    materializes a replicated fp32 [V, d] — observed on gemma2).  Pure-DP:
    batch-sharded over both axes (an unconstrained CE scan otherwise
    replicates 90 GiB of chunk logits)."""
    if cfg.tp_size > 1 and not cfg.seq_shard:
        return x
    from jax.sharding import PartitionSpec as P
    spec = (P(cfg.dp_axes, None, "model") if cfg.tp_size > 1
            else P(cfg.dp_axes, None, None))
    return _wsc(x, spec)


def swiglu(x, wg, wu, wd):
    # bf16-stored outputs: the MXU still accumulates fp32 internally, but
    # fp32 *materialization* of [tokens, d_ff] transients doubles HBM for
    # nothing (observed on gemma2's 36864-wide FFN).
    g = jnp.dot(x, wg)
    u = jnp.dot(x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.dot(h, wd).astype(x.dtype)


# MoE dispatch/combine as custom-vjp GATHERS in both directions.  dispatch
# and combine are inverse permutations, so each one's backward is the
# other's forward gather — no batched scatter ever reaches GSPMD (whose
# scatter partitioner replicates operands; observed 16 GiB/device).

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_dispatch(k, x, tok, filled, dest):
    """x [B,L,d] -> buf [B, EC, d]; slot s reads token tok[b,s]."""
    buf = jnp.take_along_axis(x, tok[..., None], axis=1)
    return jnp.where(filled[..., None], buf, 0)


def _moe_dispatch_fwd(k, x, tok, filled, dest):
    return _moe_dispatch(k, x, tok, filled, dest), (x.shape, dest)


def _moe_dispatch_bwd(k, res, d_buf):
    (B, L, d), dest = res
    EC = d_buf.shape[1]
    safe = jnp.minimum(dest, EC - 1)
    dp = jnp.take_along_axis(d_buf, safe[..., None], axis=1)
    dp = jnp.where((dest < EC)[..., None], dp, 0)
    dx = dp.reshape(B, L, k, d).sum(axis=2).astype(d_buf.dtype)
    return dx, None, None, None


_moe_dispatch.defvjp(_moe_dispatch_fwd, _moe_dispatch_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_combine(k, out_flat, dest, src_pair):
    """out_flat [B, EC, d] -> per-pair rows [B, L*k, d] via dest."""
    EC = out_flat.shape[1]
    safe = jnp.minimum(dest, EC - 1)
    y = jnp.take_along_axis(out_flat, safe[..., None], axis=1)
    return jnp.where((dest < EC)[..., None], y, 0)


def _moe_combine_fwd(k, out_flat, dest, src_pair):
    return _moe_combine(k, out_flat, dest, src_pair), \
        (out_flat.shape, src_pair)


def _moe_combine_bwd(k, res, d_y):
    (B, EC, d), src_pair = res
    Lk = d_y.shape[1]
    safe = jnp.minimum(src_pair, Lk - 1)
    dout = jnp.take_along_axis(d_y, safe[..., None], axis=1)
    dout = jnp.where((src_pair < Lk)[..., None], dout, 0)
    return dout.astype(d_y.dtype), None, None


_moe_combine.defvjp(_moe_combine_fwd, _moe_combine_bwd)


def moe_block(x: jax.Array, p: dict, cfg: TransformerConfig) -> jax.Array:
    """Per-sequence grouped top-k dispatch.  x [B, L, d] -> [B, L, d]."""
    B, L, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(8, int(np.ceil(L * k * cfg.capacity_factor / E)))
    C = min(C, L * k)
    logits = jnp.einsum("bld,de->ble", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                 # [B, L, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    ef = eidx.reshape(B, L * k)
    oh = jax.nn.one_hot(ef, E, dtype=jnp.int32)          # [B, Lk, E]
    pos = jnp.cumsum(oh, axis=1) - oh
    slot = jnp.take_along_axis(pos, ef[..., None], -1)[..., 0]  # [B, Lk]
    keep = slot < C
    dest = jnp.where(keep, ef * C + slot, E * C)         # OOB -> dropped
    # Dispatch via an int32 id-scatter + feature GATHER: scattering the
    # feature tensor itself is replicated by GSPMD's scatter partitioner
    # (observed 390 GiB/device); scattering only pair ids keeps the scatter
    # tiny and the [B, E*C, d] buffer comes from a batched gather, which
    # partitions cleanly on the batch dim.
    sentinel = L * k
    pair_ids = jnp.broadcast_to(jnp.arange(L * k, dtype=jnp.int32)[None],
                                (B, L * k))
    src_pair = jnp.full((B, E * C), sentinel, jnp.int32)
    src_pair = src_pair.at[jnp.arange(B)[:, None], dest].set(pair_ids)
    tok = jnp.minimum(src_pair // k, L - 1)              # [B, E*C]
    filled = src_pair < sentinel
    buf = _moe_dispatch(k, x, tok, filled, dest).reshape(B, E, C, d)
    out = _expert_ffn(buf, p["wg"], p["wu"], p["wd"], cfg)
    out = out.reshape(B, E * C, d)
    y_pair = _moe_combine(k, out, dest, src_pair)
    y_pair = y_pair * (keep[..., None] *
                       gate.reshape(B, L * k)[..., None]).astype(y_pair.dtype)
    y = y_pair.reshape(B, L, k, d).sum(axis=2).astype(x.dtype)
    if cfg.seq_shard:
        from jax.sharding import PartitionSpec as P
        y = _wsc(y, P(cfg.dp_axes, None, None))
    if "shared" in p:
        sh = p["shared"]
        y = y + swiglu(x, sh["wg"], sh["wu"], sh["wd"])
    return y


def _gqa_qkv(x, ap, cfg, positions):
    B, L, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.dot(x, ap["wq"]).reshape(B, L, H, dh).transpose(0, 2, 1, 3)
    kk = jnp.dot(x, ap["wk"]).reshape(B, L, Hkv, dh).transpose(0, 2, 1, 3)
    vv = jnp.dot(x, ap["wv"]).reshape(B, L, Hkv, dh).transpose(0, 2, 1, 3)
    q = rope(q, positions[None, None, :], cfg.rope_theta)
    kk = rope(kk, positions[None, None, :], cfg.rope_theta)
    return q, kk, vv


def _mla_qkv(x, ap, cfg, positions):
    """MLA decompression path (train/prefill).  Returns q,k [B,H,L,nope+rope]
    and v [B,H,L,v_head], plus the latent cache entries."""
    B, L, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(jnp.dot(x, ap["wq_a"]), ap["q_norm"], cfg.norm_eps)
    q = jnp.dot(cq, ap["wq_b"]).reshape(B, L, H, cfg.qk_nope + cfg.qk_rope)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope], axis=-1)
    kv_a = jnp.dot(x, ap["wkv_a"])
    c_kv, k_rope = jnp.split(kv_a, [cfg.kv_lora], axis=-1)
    c_kv = rms_norm(c_kv, ap["kv_norm"], cfg.norm_eps)      # [B, L, kv_lora]
    kv = jnp.dot(c_kv, ap["wkv_b"]).reshape(B, L, H, cfg.qk_nope + cfg.v_head)
    k_nope, v = jnp.split(kv, [cfg.qk_nope], axis=-1)
    pos = positions[None, :]
    q_rope = rope(q_rope.transpose(0, 2, 1, 3), pos[:, None],
                  cfg.rope_theta).transpose(0, 2, 1, 3)
    k_rope = rope(k_rope, pos, cfg.rope_theta)               # [B, L, rope]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, L, H, cfg.qk_rope))
    q_full = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)
    k_full = jnp.concatenate([k_nope, k_rope_h], -1).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    return q_full, k_full, v, (c_kv, k_rope)


def attn_block(x, ap, cfg: TransformerConfig, positions, window: int):
    B, L, d = x.shape
    if cfg.mla:
        q, k, v, cache_entry = _mla_qkv(x, ap, cfg, positions)
        q = _head_constraint(q, cfg)
        k = _head_constraint(k, cfg)
        v = _head_constraint(v, cfg)
        o = attention(q, k, v, causal=True, softcap=cfg.attn_softcap,
                      window=window, scale=cfg.attn_scale,
                      impl=cfg.attn_impl, bq=cfg.attn_chunk,
                      unroll=cfg.cost_mode)
        o = _head_constraint(o, cfg)
        o = o.transpose(0, 2, 1, 3).reshape(B, L, cfg.n_heads * cfg.v_head)
    else:
        q, k, v = _gqa_qkv(x, ap, cfg, positions)
        cache_entry = (k, v)
        q = _head_constraint(q, cfg)
        o = attention(q, k, v, causal=True, softcap=cfg.attn_softcap,
                      window=window, scale=cfg.attn_scale,
                      impl=cfg.attn_impl, bq=cfg.attn_chunk,
                      unroll=cfg.cost_mode)
        o = _head_constraint(o, cfg)
        o = o.transpose(0, 2, 1, 3).reshape(B, L, cfg.n_heads * cfg.d_head)
    return jnp.dot(o, ap["wo"]).astype(x.dtype), cache_entry


def _sp_constraint(x, cfg: TransformerConfig):
    """Sequence-parallel activation sharding between blocks: tokens over
    'model', batch over the DP axes (pure-DP configs: batch only).  GSPMD
    derives the Megatron-SP all-gather/reduce-scatter pattern around the
    matmuls."""
    if cfg.tp_size > 1 and not cfg.seq_shard:
        return x
    from jax.sharding import PartitionSpec as P
    spec = (P(cfg.dp_axes, "model", None) if cfg.tp_size > 1
            else P(cfg.dp_axes, None, None))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, NameError):
        return x  # no mesh in context (single-device smoke tests)


def layer_fwd(x, lp, cfg: TransformerConfig, positions, window: int,
              moe_layer: bool, return_cache: bool = False):
    x = _sp_constraint(x, cfg)
    h, cache = attn_block(rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"],
                          cfg, positions, window)
    x = x + h
    z = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if moe_layer:
        x = x + moe_block(z, lp["moe"], cfg)
    else:
        x = x + swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    # constrain the OUTPUT too: the scan carry (what remat saves per layer)
    # must be sequence-sharded, or 40+ layers of replicated residuals blow
    # past HBM (observed: phi3 28 GiB -> fits after this).
    x = _sp_constraint(x, cfg)
    return (x, cache) if return_cache else (x, None)


# ---------------------------------------------------------------------------
# Full forward: train loss / prefill / decode
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), jnp.bfloat16)
    return x


def _unembed(params, x, cfg):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.dot(x, w.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def _scan_layers(x, params, cfg: TransformerConfig, positions,
                 collect_cache: bool = False):
    """Scan the stacked layers.  gemma2's alternating local/global pattern
    scans PAIRS (the stacked params were built with n_layers entries; we
    reindex as [n/2, 2, ...] so each scan step applies local then global)."""
    windows = cfg.layer_windows()

    def make_body(window, moe_layer):
        def body(h, lp):
            h2, cache = layer_fwd(h, lp, cfg, positions, window, moe_layer,
                                  return_cache=collect_cache)
            return h2, cache
        return jax.checkpoint(body) if cfg.remat else body

    caches = []
    if "dense_layers" in params:
        body = make_body(0, False)
        x, c = jax.lax.scan(body, x, params["dense_layers"],
                            unroll=True if cfg.cost_mode else 1)
        caches.append(c)
    if cfg.local_global:
        n = cfg.n_layers
        assert n % 2 == 0
        stacked = jax.tree.map(lambda a: a.reshape(n // 2, 2, *a.shape[1:]),
                               params["layers"])
        def pair_body(h, lp2):
            l0 = jax.tree.map(lambda a: a[0], lp2)
            l1 = jax.tree.map(lambda a: a[1], lp2)
            h, c0 = layer_fwd(h, l0, cfg, positions, windows[0], cfg.moe,
                              return_cache=collect_cache)
            h, c1 = layer_fwd(h, l1, cfg, positions, 0, cfg.moe,
                              return_cache=collect_cache)
            if collect_cache:
                c = jax.tree.map(lambda a, b: jnp.stack([a, b]), c0, c1)
            else:
                c = None
            return h, c
        pb = jax.checkpoint(pair_body) if cfg.remat else pair_body
        x, c = jax.lax.scan(pb, x, stacked,
                            unroll=True if cfg.cost_mode else 1)
        if collect_cache:
            c = jax.tree.map(
                lambda a: a.reshape(n, *a.shape[2:]), c)
        caches.append(c)
    else:
        body = make_body(0, cfg.moe)
        x, c = jax.lax.scan(body, x, params["layers"],
                            unroll=True if cfg.cost_mode else 1)
        caches.append(c)
    if not collect_cache:
        return x, None
    cache = jax.tree.map(lambda *xs: jnp.concatenate(xs) if len(xs) > 1
                         else xs[0], *caches)
    return x, cache


def _chunked_ce(params, x, labels, cfg: TransformerConfig) -> jax.Array:
    """Cross-entropy scanned over token chunks — the full [B, L, V] logits
    tensor is never materialized (V_chunk transients only)."""
    B, L, d = x.shape
    c = min(cfg.loss_chunk, L)
    while L % c:
        c -= 1
    n = L // c
    if n == 1:
        logits = _logit_constraint(_unembed(params, x, cfg), cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return (lse - lab).sum()

    def body(acc, inp):
        xc, lc = inp                                  # [B, c, d], [B, c]
        logits = _logit_constraint(_unembed(params, xc, cfg), cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, lc[..., None], -1)[..., 0]
        return acc + (lse - lab).sum(), None

    xs = (x.reshape(B, n, c, d).transpose(1, 0, 2, 3),
          labels.reshape(B, n, c).transpose(1, 0, 2))
    body = jax.checkpoint(body) if cfg.remat else body
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs,
                            unroll=True if cfg.cost_mode else 1)
    return total


def lm_loss(params, tokens, labels, cfg: TransformerConfig) -> jax.Array:
    """Causal LM cross-entropy (mean over tokens)."""
    B, L = tokens.shape
    x = _embed(params, tokens, cfg)
    positions = jnp.arange(L)
    x, _ = _scan_layers(x, params, cfg, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _chunked_ce(params, x, labels, cfg) / (B * L)


def prefill(params, tokens, cfg: TransformerConfig):
    """Serving prefill: last-token logits + KV cache.

    Cache layout: GQA {'k','v'} [n_layers, B, Hkv, L, dh];
    MLA {'c_kv' [n_layers, B, L, kv_lora], 'k_rope' [n_layers, B, L, rope]}.
    """
    B, L = tokens.shape
    x = _embed(params, tokens, cfg)
    positions = jnp.arange(L)
    x, cache = _scan_layers(x, params, cfg, positions, collect_cache=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x[:, -1:], cfg)[:, 0]
    if cfg.mla:
        cache = {"c_kv": cache[0], "k_rope": cache[1]}
    else:
        cache = {"k": cache[0], "v": cache[1]}
    return logits, cache


# -------------------------- decode ----------------------------------------

def _mla_decode_attn(z, ap, cfg, c_kv_cache, k_rope_cache, pos):
    """Absorbed-MLA decode: scores in latent space, no per-step K/V
    decompression (deepseek-v2's serving trick).  z [B, 1, d] normed input;
    caches [B, Lmax, kv_lora] / [B, Lmax, qk_rope]."""
    B = z.shape[0]
    H = cfg.n_heads
    cq = rms_norm(jnp.dot(z, ap["wq_a"]), ap["q_norm"], cfg.norm_eps)
    q = jnp.dot(cq, ap["wq_b"]).reshape(B, H, cfg.qk_nope + cfg.qk_rope)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope], axis=-1)   # [B,H,*]
    q_rope = rope(q_rope[:, :, None, :], pos[:, None, None],
                  cfg.rope_theta)[:, :, 0]                  # [B,H,rope]
    wkv_b = ap["wkv_b"].reshape(cfg.kv_lora, H, cfg.qk_nope + cfg.v_head)
    wk = wkv_b[:, :, :cfg.qk_nope]                          # [lora,H,nope]
    wv = wkv_b[:, :, cfg.qk_nope:]                          # [lora,H,v]
    q_eff = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))              # absorb
    s = jnp.einsum("bhl,btl->bht", q_eff, c_kv_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhr,btr->bht", q_rope.astype(jnp.float32),
                       k_rope_cache.astype(jnp.float32))
    s = s * cfg.attn_scale
    Lk = c_kv_cache.shape[1]
    valid = jnp.arange(Lk)[None, None, :] < pos[:, None, None] + 1
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bht,btl->bhl", p, c_kv_cache.astype(jnp.float32))
    o = jnp.einsum("bhl,lhv->bhv", ctx, wv.astype(jnp.float32))
    o = o.reshape(B, 1, H * cfg.v_head).astype(z.dtype)
    return jnp.dot(o, ap["wo"]).astype(z.dtype)


def _decode_layer(x, lp, cache_slice, cfg, pos, window, moe_layer):
    """One decode layer.  ``window`` is a TRACED per-layer scalar (Lmax for
    global layers) so the layer loop can be a lax.scan.  Returns
    (x, new cache slice)."""
    B = x.shape[0]
    z = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla:
        kv_a = jnp.dot(z, lp["attn"]["wkv_a"])
        c_new, kr_new = jnp.split(kv_a, [cfg.kv_lora], axis=-1)
        c_new = rms_norm(c_new, lp["attn"]["kv_norm"], cfg.norm_eps)
        kr_new = rope(kr_new, pos[:, None], cfg.rope_theta)
        ck = cache_slice["c_kv"].at[jnp.arange(B), pos].set(c_new[:, 0])
        kr = cache_slice["k_rope"].at[jnp.arange(B), pos].set(kr_new[:, 0])
        new_slice = {"c_kv": ck, "k_rope": kr}
        h = _mla_decode_attn(z, lp["attn"], cfg, ck, kr, pos)
    else:
        H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = jnp.dot(z, lp["attn"]["wq"]).reshape(B, 1, H, dh)
        kk = jnp.dot(z, lp["attn"]["wk"]).reshape(B, 1, Hkv, dh)
        vv = jnp.dot(z, lp["attn"]["wv"]).reshape(B, 1, Hkv, dh)
        q = rope(q.transpose(0, 2, 1, 3), pos[:, None, None], cfg.rope_theta)
        kk = rope(kk.transpose(0, 2, 1, 3), pos[:, None, None],
                  cfg.rope_theta)
        vv = vv.transpose(0, 2, 1, 3)

        def _align(t):
            # match q/new-KV sharding to the CACHE placement (HC2): a
            # mismatched einsum otherwise all-gathers the whole cache every
            # step (observed: ~52 GB/step on internlm2 decode).  The cache
            # placement is decided by Hkv (see lm_steps.cache_structs), so
            # EVERY attention operand follows that choice.
            if not cfg.seq_shard:
                return t
            from jax.sharding import PartitionSpec as P
            if Hkv % cfg.tp_size == 0 and t.shape[1] % cfg.tp_size == 0:
                return _wsc(t, P(cfg.dp_axes, "model", None, None))
            if dh % cfg.tp_size == 0:
                return _wsc(t, P(cfg.dp_axes, None, None, "model"))
            return t

        q = _align(q)
        kk = _align(kk)
        vv = _align(vv)
        ck = cache_slice["k"].at[jnp.arange(B), :, pos].set(kk[:, :, 0])
        cv = cache_slice["v"].at[jnp.arange(B), :, pos].set(vv[:, :, 0])
        new_slice = {"k": ck, "v": cv}
        o = decode_attention(q, ck, cv, softcap=cfg.attn_softcap,
                             window=window, scale=cfg.attn_scale,
                             kv_len=pos + 1)
        o = _align(o)
        h = o.transpose(0, 2, 1, 3).reshape(B, 1, H * dh)
        h = jnp.dot(h, lp["attn"]["wo"]).astype(x.dtype)
    x = x + h
    z2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if moe_layer:
        x = x + moe_block(z2, lp["moe"], cfg)
    else:
        x = x + swiglu(z2, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    return x, new_slice


def decode_step(params, cache, tokens, pos, cfg: TransformerConfig):
    """One serving decode step, layers scanned with the cache as ys.

    tokens [B] int32; pos [B] int32 = number of valid cache entries (the
    position this token is written at).  Returns (logits [B, V], cache').
    """
    B = tokens.shape[0]
    x = _embed(params, tokens[:, None], cfg)             # [B, 1, d]
    windows = np.array(
        [w if w > 0 else (1 << 30) for w in cfg.layer_windows()], np.int32)
    n_pre = params["dense_layers"]["ln1"].shape[0] \
        if "dense_layers" in params else 0

    def make_scan(moe_layer):
        def body(x, xs):
            lp, cache_slice, window = xs
            x, new_slice = _decode_layer(x, lp, cache_slice, cfg, pos,
                                         window, moe_layer)
            return x, new_slice
        return body

    new_cache_parts = []
    if n_pre:
        pre_cache = jax.tree.map(lambda a: a[:n_pre], cache)
        x, nc = jax.lax.scan(
            make_scan(False), x,
            (params["dense_layers"], pre_cache,
             jnp.asarray(windows[:n_pre])),
            unroll=True if cfg.cost_mode else 1)
        new_cache_parts.append(nc)
    main_cache = jax.tree.map(lambda a: a[n_pre:], cache)
    x, nc = jax.lax.scan(
        make_scan(cfg.moe), x,
        (params["layers"], main_cache, jnp.asarray(windows[n_pre:])),
        unroll=True if cfg.cost_mode else 1)
    new_cache_parts.append(nc)
    if len(new_cache_parts) > 1:
        new_cache = jax.tree.map(
            lambda *xs: jnp.concatenate(xs), *new_cache_parts)
    else:
        new_cache = new_cache_parts[0]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg)[:, 0]
    return logits, new_cache
