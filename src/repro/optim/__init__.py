from repro.optim import adamw, data_parallel, row, sgd, split_sgd  # noqa: F401
from repro.optim.row import RowOptimizer, SparseStream  # noqa: F401
from repro.optim.split_sgd import (combine_split, split_fp32,  # noqa: F401
                                   SplitParams)
