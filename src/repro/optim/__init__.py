from repro.optim import adamw, data_parallel, sgd, split_sgd  # noqa: F401
from repro.optim.split_sgd import (combine_split, split_fp32,  # noqa: F401
                                   SplitParams)
