"""AdamW with optional split-bf16 weight storage.

Standard AdamW keeps fp32 (m, v) moments; with ``split=True`` the weights
themselves use the paper's hi/lo representation (C5), so total state is
2+2(+4+4) bytes/param vs 4(+4+4) for fp32 — the bandwidth saving on fwd/bwd
is identical to Split-SGD's.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.split_sgd import SplitParams, combine_split, split_fp32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    params: Any           # SplitParams or fp32 tree
    m: Any
    v: Any
    count: jax.Array
    split: bool = dataclasses.field(metadata=dict(static=True), default=True)


def init(params_fp32: Any, split: bool = True) -> AdamWState:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), params_fp32)
    if split:
        hi_lo = jax.tree.map(split_fp32, params_fp32)
        leaf = lambda x: isinstance(x, tuple)
        params = SplitParams(
            jax.tree.map(lambda t: t[0], hi_lo, is_leaf=leaf),
            jax.tree.map(lambda t: t[1], hi_lo, is_leaf=leaf))
    else:
        params = params_fp32
    return AdamWState(params, zeros(), zeros(),
                      jnp.zeros((), jnp.int32), split)


def apply_updates(state: AdamWState, grads: Any, lr, *, b1=0.9, b2=0.999,
                  eps=1e-8, weight_decay=0.0) -> AdamWState:
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def leaf(w_or_hi, lo, g, m, v):
        w32 = combine_split(w_or_hi, lo) if state.split \
            else w_or_hi.astype(jnp.float32)
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        upd = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * w32
        w32 = w32 - lr * upd
        if state.split:
            nh, nl = split_fp32(w32)
            return nh, nl, m, v
        return w32.astype(w_or_hi.dtype), None, m, v

    if state.split:
        out = jax.tree.map(leaf, state.params.hi, state.params.lo, grads,
                           state.m, state.v)
    else:
        lo_tree = jax.tree.map(lambda _: None, state.params)
        out = jax.tree.map(lambda w, g, m, v: leaf(w, None, g, m, v),
                           state.params, grads, state.m, state.v)
    is4 = lambda x: isinstance(x, tuple)
    w = jax.tree.map(lambda t: t[0], out, is_leaf=is4)
    l = jax.tree.map(lambda t: t[1], out, is_leaf=is4)
    m = jax.tree.map(lambda t: t[2], out, is_leaf=is4)
    v = jax.tree.map(lambda t: t[3], out, is_leaf=is4)
    params = SplitParams(w, l) if state.split else w
    return AdamWState(params, m, v, count, state.split)
