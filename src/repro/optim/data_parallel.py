"""Data-parallel gradient path (paper contribution C4, Sect. IV-A).

The paper materializes the MLP weight-gradient allreduce as
**reduce-scatter + all-gather** and overlaps it with the backward GEMMs.
On TPU we keep the same decomposition — the optimizer runs on the gradient
*shard* (each device updates 1/ns of the flattened parameter vector, then
all-gathers the updated weights), which is ZeRO-1 and is bit-identical to
allreduce+replicated-update for SGD.  Overlap itself comes from XLA's
latency-hiding scheduler; what we control is the decomposition, the bucket
granularity, and the on-wire dtype.

``bf16 compression + error feedback``: gradients are cast to bf16 before the
reduce-scatter (2x wire volume saving — the distributed-optimization trick),
with the fp32 quantization residual carried to the next step so the scheme
stays unbiased (error-feedback SGD).

All functions run INSIDE shard_map; ``axis_name`` may be a tuple of mesh axes
(e.g. ('pod','data','model') when dense params are replicated everywhere).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro import compat
from repro.optim.split_sgd import combine_split, split_fp32


def _axis_size(axis_name) -> int:
    return compat.axis_size(axis_name)


def combined_axis_index(axis_name) -> jax.Array:
    """Flattened device index over a (tuple of) mesh axes, first axis
    major — the order a P(axes) sharding lays blocks out in.  Shared by
    the RS+AG optimizer below and the pipeline's table-mode index slice
    (repro/core/pipeline.py); the flattening rule must stay single-sourced
    or the two would silently disagree on block routing."""
    if isinstance(axis_name, (tuple, list)):
        idx = jnp.zeros((), jnp.int32)
        for a in axis_name:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        return idx
    return jax.lax.axis_index(axis_name)


_axis_index = combined_axis_index


def _pad_to(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.concatenate([x, jnp.zeros((pad,), x.dtype)]) if pad else x


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DPState:
    """Replicated dense-parameter state with RS+AG split-SGD update."""
    hi: Any                      # bf16 param tree (what fwd/bwd consume)
    lo_shard: jax.Array          # THIS device's uint16 lo shard [chunk]
    mom_shard: Optional[jax.Array]  # fp32 momentum shard or None
    err_shard: Optional[jax.Array]  # fp32 error-feedback residual (bf16 wire)


def init_dp_state(params_fp32: Any, num_shards: int, shard_id: int,
                  momentum: float = 0.0, compress: bool = False,
                  num_buckets: int = 4) -> DPState:
    """Host-side init.  The lo/momentum/error shards use the BUCKETED layout
    (concat over buckets of this shard's slice of each bucket) to match
    :func:`rs_ag_split_sgd`."""
    flat, _ = ravel_pytree(jax.tree.map(
        lambda p: p.astype(jnp.float32), params_fp32))
    n_real = flat.shape[0]
    flat = _pad_to(flat, num_shards * num_buckets)
    chunk = flat.shape[0] // num_shards
    bchunk = chunk // num_buckets
    hi_flat, lo_flat = split_fp32(flat)
    hi = unravel_like(hi_flat[:n_real], params_fp32)
    lo_shard = jnp.concatenate([
        jax.lax.dynamic_slice(
            lo_flat, (b * num_shards * bchunk + shard_id * bchunk,), (bchunk,))
        for b in range(num_buckets)])
    mom = jnp.zeros((chunk,), jnp.float32) if momentum else None
    err = jnp.zeros((chunk,), jnp.float32) if compress else None
    return DPState(hi, lo_shard, mom, err)


def ravel_size(tree: Any) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def unravel_like(flat: jax.Array, tree: Any) -> Any:
    leaves, treedef = jax.tree.flatten(tree)
    out, pos = [], 0
    for l in leaves:
        out.append(flat[pos:pos + l.size].reshape(l.shape))
        pos += l.size
    return jax.tree.unflatten(treedef, out)


def to_bucketed_layout(flat: jax.Array, ns: int, nb: int) -> jax.Array:
    """Natural flat layout -> bucket-major-within-shard global layout, so a
    plain P(axes) sharding of the result hands each device exactly the
    concat-over-buckets shard that :func:`rs_ag_split_sgd` maintains."""
    padded = _pad_to(flat, ns * nb)
    bchunk = padded.shape[0] // (ns * nb)
    return padded.reshape(nb, ns, bchunk).transpose(1, 0, 2).reshape(-1)


def dp_global_arrays(params_fp32: Any, ns: int, momentum: float = 0.0,
                     compress: bool = False, num_buckets: int = 4) -> dict:
    """GLOBAL (unsharded) state arrays for the dense data-parallel path:
    {'hi': param tree (bf16, replicated), 'lo': [padded] uint16 (shard over
    the DP axes), 'mom'/'err': fp32 or None}.  Shard 'lo'/'mom'/'err' with
    P(axes); their layout is bucket-major within each shard."""
    flat, _ = ravel_pytree(jax.tree.map(
        lambda p: p.astype(jnp.float32), params_fp32))
    hi_flat, lo_flat = split_fp32(flat)
    hi = unravel_like(hi_flat, params_fp32)
    lo = to_bucketed_layout(lo_flat, ns, num_buckets)
    mom = jnp.zeros_like(lo, jnp.float32) if momentum else None
    err = jnp.zeros_like(lo, jnp.float32) if compress else None
    return {"hi": hi, "lo": lo, "mom": mom, "err": err}


def rs_ag_split_sgd(state: DPState, grads: Any, lr, axis_name,
                    beta: float = 0.0, compress: bool = False,
                    num_buckets: int = 4, mean: bool = True,
                    wire_dtype: Optional[str] = None,
                    error_feedback: bool = True, seed=None) -> DPState:
    """One data-parallel step: bucketed reduce-scatter of grads, split-SGD on
    the local shard, all-gather of updated bf16 weights.

    Bucketing splits the flat gradient into ``num_buckets`` independent
    RS -> update -> AG chains so XLA can overlap bucket k's collectives with
    bucket k+1's compute (the paper's progression-thread overlap, as a
    schedule instead of threads).

    ``wire_dtype`` selects the reduce-scatter wire format
    (repro/dist/exchange.py): ``'fp32'`` the uncompressed wire, ``'bf16'``
    round-to-nearest truncation with the per-device fp32 residual carried
    in ``err_shard`` when ``error_feedback`` and the slab exist (exactly
    the legacy ``compress=True`` scheme), ``'bf16_sr'`` the seeded
    stochastic-rounding wire (``seed`` = the replicated per-step sr
    counter; unbiased with no error slab).  ``None`` (default) maps the
    legacy ``compress`` bool, bit-for-bit."""
    from repro.dist import exchange as exchange_cfg
    from repro.optim import stochastic
    if wire_dtype is None:
        wire_dtype = ("bf16" if compress and state.err_shard is not None
                      else "fp32")
    ef = (wire_dtype == "bf16" and error_feedback
          and state.err_shard is not None)
    ns = _axis_size(axis_name)
    g_flat, _ = ravel_pytree(jax.tree.map(
        lambda g: g.astype(jnp.float32), grads))
    n_real = g_flat.shape[0]
    g_flat = _pad_to(g_flat, ns * num_buckets)
    chunk = g_flat.shape[0] // ns
    bchunk = chunk // num_buckets
    shard = _axis_index(axis_name)

    hi_flat, _ = ravel_pytree(state.hi)
    hi_flat = _pad_to(jax.lax.bitcast_convert_type(
        hi_flat, jnp.uint16), ns * num_buckets)

    new_hi_buckets, new_lo, new_mom, new_err = [], [], [], []
    for b in range(num_buckets):
        gb = jax.lax.dynamic_slice(
            g_flat, (b * (g_flat.shape[0] // num_buckets),),
            (g_flat.shape[0] // num_buckets,))
        eb = None
        if ef:
            # error feedback lives on the *shard*; add it after the RS
            eb = jax.lax.dynamic_slice(state.err_shard, (b * bchunk,), (bchunk,))
        if wire_dtype == "bf16":
            gb_wire = gb.astype(jnp.bfloat16)
        elif wire_dtype == "bf16_sr":
            gb_wire = stochastic.sr_round_bf16_wire(
                gb, jnp.int32(0) if seed is None else seed,
                exchange_cfg.wire_tag(exchange_cfg.TAG_DENSE, b, shard))
        else:
            gb_wire = gb
        # reduce-scatter (mean over replicas unless grads are pre-scaled)
        gsh = jax.lax.psum_scatter(gb_wire, axis_name, scatter_dimension=0,
                                   tiled=True).astype(jnp.float32)
        if mean:
            gsh = gsh / ns
        if eb is not None:
            # residual of THIS device's contribution, carried forward
            own = jax.lax.dynamic_slice(gb, (shard * bchunk,), (bchunk,))
            resid = own - own.astype(jnp.bfloat16).astype(jnp.float32)
            if mean:
                resid = resid / ns
            gsh = gsh + eb
            new_err.append(resid)
        # split-SGD on the shard
        lob = jax.lax.dynamic_slice(state.lo_shard, (b * bchunk,), (bchunk,))
        hib = jax.lax.dynamic_slice(
            hi_flat, (b * ns * bchunk + shard * bchunk,), (bchunk,))
        w32 = combine_split(jax.lax.bitcast_convert_type(hib, jnp.bfloat16),
                            lob)
        if state.mom_shard is not None:
            mb = jax.lax.dynamic_slice(state.mom_shard, (b * bchunk,), (bchunk,))
            mb = beta * mb + gsh
            gsh = mb
            new_mom.append(mb)
        w32 = w32 - lr * gsh
        nh, nl = split_fp32(w32)
        new_lo.append(nl)
        # all-gather updated bf16 weights for this bucket
        full = jax.lax.all_gather(nh, axis_name, axis=0, tiled=True)
        new_hi_buckets.append(full)

    hi_full = jnp.concatenate(new_hi_buckets)[:n_real]
    return DPState(
        hi=unravel_like(hi_full, state.hi),
        lo_shard=jnp.concatenate(new_lo),
        mom_shard=jnp.concatenate(new_mom) if new_mom else None,
        err_shard=jnp.concatenate(new_err) if new_err else None,
    )


def allreduce_sgd(params: Any, grads: Any, lr, axis_name):
    """Baseline path (no RS+AG): psum-mean the grads, replicated SGD update.
    Used for A/B comparison in benchmarks."""
    ns = _axis_size(axis_name)
    def upd(p, g):
        g = jax.lax.psum(g.astype(jnp.float32), axis_name) / ns
        return (p.astype(jnp.float32) - lr * g).astype(p.dtype)
    return jax.tree.map(upd, params, grads)
