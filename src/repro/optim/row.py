"""Pluggable sparse RowOptimizer API — ONE update surface for the
embedding path (SGD / Split-SGD / momentum / Adagrad variants, fp32 or
compressed bf16-hi state).

The paper's Split-SGD trick (Sect. V) makes the sparse update O(unique
rows) per step; production DLRM training additionally wants momentum and
row-wise Adagrad on the embeddings (Naumov et al. 2019), and the optimizer
must stay FUSED and ROW-ADDRESSED — a dense optax-style update would
materialize the O(M x E) state/gradient the whole design avoids.  This
module is the plug-in point:

* A :class:`RowOptimizer` owns (a) an **EmbeddingStore** — a flat dict
  pytree of row-aligned slabs: the weight slab(s) (``hi``/``lo`` split
  bf16+uint16, or ``w`` fp32) plus zero or more per-row optimizer-state
  slabs (``mom``/``acc`` rows in fp32 or compressed bf16-hi), all sharded
  by the same ``ShardedEmbeddingLayout`` row partition — and (b) a single
  fused apply, :meth:`RowOptimizer.apply_sparse`, which every path
  (reference scan, fused Pallas kernel, host-pre-sorted stream) goes
  through.

* The per-optimizer MATH lives on the instance, as three hooks supplied
  at registration time (the ROADMAP "strategy registration" refactor):

  - ``kernel``          — the fused Pallas entry: called by
    ``kernels.ops`` on the (lane-aligned) sorted stream; owns which
    kernel body runs and how the hyperparameters/seed reach it.
  - ``reference``       — the reduced-stream reference transition
    (unique rows + per-row gradient sums), applied exactly once per row
    per step; the chunked scan path accumulates across chunks first.
  - ``flat_reference``  — optional per-lookup reference (the stateless
    kinds' legacy scatter semantics); defaults to dedup + ``reference``.

  ``kernels/ops.py``, ``core/sharded_embedding.py`` and
  ``core/pipeline.py`` contain NO per-optimizer dispatch (enforced by a
  source-scan test): :func:`register` alone — plus one Pallas kernel
  body — adds an optimizer end-to-end.

* The registry (:func:`register` / :func:`get` / :func:`make`) names the
  built-ins: ``sgd``, ``split_sgd``, ``momentum``, ``adagrad_rowwise``,
  ``adagrad``, and the compressed-state ``momentum_bf16`` /
  ``adagrad_bf16`` (bf16-hi state + seeded stochastic rounding,
  :mod:`repro.optim.stochastic` — half the state bytes per touched row).
  :func:`resolve` maps a model definition (``HybridDef``/``DLRMConfig``:
  ``sparse_optimizer=`` + optional ``opt_beta``/``opt_eps``, with the
  legacy ``split_sgd`` bool as fallback sugar) to an optimizer instance.

Determinism / parity contracts (tests/test_row_optim.py,
tests/test_stochastic.py):

* ``split_sgd``: fused == the jitted ``split_fp32``/``combine_split``
  reference, BITWISE (inherited from the PR-1 kernel, pinned).
* ``momentum(beta=0)``: bitwise == ``sgd`` on the fused path (both
  pre-reduce duplicates; ``0 * m + acc`` is an exact fp32 identity).
* ``adagrad`` / ``adagrad_rowwise`` first step from zero state == SGD
  scaled by ``1 / (sqrt(acc_1) + eps)`` (per element / per row) to fp32
  tolerance — one extra division per touched row vs the closed form.
* ``momentum_bf16`` / ``adagrad_bf16``: under one per-step ``seed`` the
  reference scan, fused device-sorted and host-pre-sorted paths are
  BITWISE identical (the stochastic dither is a counter-based pure
  function of (seed, row, lane), never of traversal order).
* State is touched ONLY for rows receiving at least one valid lookup —
  padding/masked streams never decay momentum or inflate accumulators.

The ``cnt`` slab key is RESERVED: it is the per-row touch counter.  A
store may carry it either as an AUXILIARY slab (``store_struct(...,
counters=True)`` — any optimizer; the hot-row embedding cache's
promotion policy reads it, see docs/cache.md) or as a declared STATE
slab (``adagrad_freq``).  In both cases :meth:`RowOptimizer
.apply_sparse` bumps it by +1 per VALID lookup (duplicates accumulate;
O(touched rows) scatter-add) before the optimizer math runs, so a
frequency-driven optimizer reads the post-bump count and an auxiliary
counter rides every path (reference / fused / presorted / chunked)
without the registered hooks knowing it exists.  Register-only toy
optimizers must therefore pick a different key for private counters.

Nothing outside this module calls the ``kernels.ops.fused_row_update*``
entry points; checkpointing, serving snapshots and elastic restarts all
see the store as an opaque dict of row-aligned slabs.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.split_sgd import combine_split, split_fp32
from repro.optim.stochastic import sr_noise, sr_round_bf16


# ---------------------------------------------------------------------------
# Reference helpers (the scan/oracle path; moved here from
# core.sharded_embedding so the optimizer owns BOTH implementations)
# ---------------------------------------------------------------------------

def dedup_rows(tgt: jax.Array, upd: jax.Array, num_rows: int
               ) -> tuple[jax.Array, jax.Array]:
    """Sum duplicate targets.  Returns (rep [n], summed [n, E]); positions
    for empty run segments get rep == num_rows (out of bounds -> the
    subsequent scatter DROPS them, JAX's default OOB-scatter mode)."""
    order = jnp.argsort(tgt)
    sg = jnp.take(tgt, order)
    su = jnp.take(upd, order, axis=0)
    newseg = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              (sg[1:] != sg[:-1]).astype(jnp.int32)])
    uid = jnp.cumsum(newseg)
    n = tgt.shape[0]
    summed = jax.ops.segment_sum(su, uid, num_segments=n)
    rep = jnp.full((n,), num_rows, dtype=sg.dtype).at[uid].min(sg)
    return rep, summed


def dedup_targets(tgt: jax.Array, num_rows: int) -> jax.Array:
    """Scalar-only half of :func:`dedup_rows`: the unique in-range targets
    of ``tgt`` (one per sorted run), padded with ``num_rows`` fillers that
    a subsequent scatter drops."""
    order = jnp.argsort(tgt)
    sg = jnp.take(tgt, order)
    newseg = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              (sg[1:] != sg[:-1]).astype(jnp.int32)])
    uid = jnp.cumsum(newseg)
    return jnp.full(tgt.shape, num_rows, dtype=sg.dtype).at[uid].min(sg)


def bump_counters(cnt: jax.Array, tgt: jax.Array, num_rows: int
                  ) -> jax.Array:
    """+1 per valid lookup on the reserved ``cnt`` touch-counter slab
    [rows, 1].  ``tgt`` [L] flat row targets; out-of-range entries (masked
    lookups keyed to ``num_rows``, other shards' rows in a local stream)
    are DROPPED — masked explicitly, because JAX wraps negative indices
    before ``mode="drop"`` can reject them.  Duplicates accumulate, so
    every update path (reference / fused / presorted / batch-chunked)
    produces identical integer counts regardless of traversal order."""
    ok = (tgt >= 0) & (tgt < num_rows)
    safe = jnp.where(ok, tgt, num_rows)
    return cnt.at[safe].add(jnp.asarray(1, cnt.dtype), mode="drop")


def apply_rows_sgd(W_local: jax.Array, tgt: jax.Array, grad: jax.Array,
                   lr) -> jax.Array:
    """Plain scatter-add SGD on local rows (duplicates accumulate) —
    Alg. 3 with XLA's deterministic scatter supplying the atomicity."""
    return W_local.at[tgt].add((-lr * grad).astype(W_local.dtype))


def apply_rows_split_sgd(hi: jax.Array, lo: jax.Array, tgt: jax.Array,
                         grad: jax.Array, lr, fused: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """Exact-fp32 sparse SGD on split-bf16 storage (see
    repro.optim.split_sgd).  ``tgt`` may contain duplicates.

    ``fused=False`` (reference): segment_sum the per-row gradients, gather
    the touched rows, combine/step/split, and scatter back — the functional
    scatter copies the whole shard.  ``fused=True``: one Pallas pass
    (:mod:`repro.kernels.embedding_update`) that pre-reduces duplicates in
    VMEM and rewrites only the touched rows in place; bit-identical output."""
    if fused:
        from repro.kernels import ops
        out = ops.fused_row_update(get("split_sgd"), {"hi": hi, "lo": lo},
                                   tgt, grad, lr, pooling=1)
        return out["hi"], out["lo"]
    rep, summed = dedup_rows(tgt, grad, hi.shape[0])
    safe = jnp.minimum(rep, hi.shape[0] - 1)   # gather side must be in-bounds
    h = jnp.take(hi, safe, axis=0)
    l = jnp.take(lo, safe, axis=0)
    w32 = combine_split(h, l)
    w32 = w32 - lr * summed
    nh, nl = split_fp32(w32)
    # rep == num_rows rows (empty segments) are dropped by the scatter.
    return hi.at[rep].set(nh), lo.at[rep].set(nl)


# ---------------------------------------------------------------------------
# The update stream
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseStream:
    """One sparse-update stream for :meth:`RowOptimizer.apply_sparse`.

    Either the UNSORTED shaped stream — ``idx`` [..., P] LOCAL row ids,
    ``dY`` [..., E] bag cotangents over the matching leading dims,
    optional ``valid``/``weights`` in the layout of ``idx`` — or the
    HOST-PRE-SORTED stream: ``presort = (sorted_rows, sorted_bags,
    sorted_msk, sorted_wgt)`` [L] arrays (``repro.data.pipeline
    .presort_batch`` / ``kernels.embedding_update.sort_lookups``) with
    ``dY`` whose flattened leading dims give the bag table."""

    idx: Optional[jax.Array] = None
    dY: Optional[jax.Array] = None
    valid: Optional[jax.Array] = None
    weights: Optional[jax.Array] = None
    presort: Optional[tuple] = None


# ---------------------------------------------------------------------------
# RowOptimizer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RowOptimizer:
    """A sparse embedding optimizer: store layout + one fused apply.

    The three callables are the REGISTRATION HOOKS — they carry the whole
    per-optimizer math, so nothing outside the instance dispatches on an
    optimizer kind:

    ``kernel(opt, store, srows, sbags, smsk, swgt, dY, lr, seed, e_real,
    interpret) -> store``
        fused Pallas entry on the sorted stream (slabs already
        lane-aligned by ``kernels.ops``; ``e_real`` is the unpadded E).
    ``reference(opt, store, rep, summed, lr, seed) -> store``
        reduced-stream reference transition — ``rep`` [n] unique touched
        rows (``num_rows`` fillers dropped by the scatter), ``summed``
        [n, E] per-row gradient sums; applied exactly ONCE per row per
        step.
    ``flat_reference(opt, store, tgt, grad, lr, seed) -> store``
        optional per-lookup reference (the stateless kinds' scatter
        semantics); ``None`` means dedup + ``reference``.

    ``split`` says whether the master weights live as (hi bf16, lo
    uint16) or one fp32 ``w`` slab; ``state`` lists the per-row state
    slabs as ``(key, width[, dtype])`` tuples — width 0 meaning the
    embedding dim E, any other value a fixed per-row lane count (1 = the
    row-wise Adagrad scalar), dtype defaulting to fp32 (``"bfloat16"``
    selects the compressed bf16-hi layout).  ``stochastic_round`` asks
    the step factory to thread a fresh int32 seed per step (the ``sr``
    counter in the train state).  Hashable and jit-static-friendly."""

    name: str
    split: bool = False
    state: tuple = ()        # ((slab_key, width[, dtype]), ...); width 0 => E
    beta: float = 0.0            # momentum coefficient
    eps: float = 1e-8            # adagrad denominator floor
    stochastic_round: bool = False
    kernel: Optional[Callable] = None
    reference: Optional[Callable] = None
    flat_reference: Optional[Callable] = None

    # ---------------------------------------------------------- store --
    @property
    def weight_keys(self) -> tuple:
        return ("hi", "lo") if self.split else ("w",)

    @property
    def state_keys(self) -> tuple:
        return tuple(s[0] for s in self.state)

    def state_slabs(self) -> tuple:
        """Normalized ``(key, width, dtype)`` per state slab."""
        return tuple((s[0], s[1],
                      jnp.dtype(s[2]) if len(s) > 2 else jnp.dtype("float32"))
                     for s in self.state)

    def store_struct(self, rows: int, E: int,
                     counters: bool = False) -> dict:
        """ShapeDtypeStructs of the EmbeddingStore for a [rows, E] slab —
        weights first, then state, all row-aligned (shard the leading dim
        by the embedding layout).  ``counters=True`` appends the reserved
        ``cnt`` touch-counter slab ([rows, 1] int32) unless the optimizer
        already declares it as state (``adagrad_freq``)."""
        out = ({"hi": jax.ShapeDtypeStruct((rows, E), jnp.bfloat16),
                "lo": jax.ShapeDtypeStruct((rows, E), jnp.uint16)}
               if self.split else
               {"w": jax.ShapeDtypeStruct((rows, E), jnp.float32)})
        for key, width, dtype in self.state_slabs():
            out[key] = jax.ShapeDtypeStruct((rows, width or E), dtype)
        if counters and "cnt" not in out:
            out["cnt"] = jax.ShapeDtypeStruct((rows, 1), jnp.int32)
        return out

    def init_store(self, W: jax.Array, counters: bool = False) -> dict:
        """EmbeddingStore from fp32 master weights [rows, E]; state slabs
        (and, with ``counters=True``, the reserved ``cnt`` touch-counter
        slab) zero-initialized."""
        rows, E = W.shape
        if self.split:
            hi, lo = split_fp32(W)
            out = {"hi": hi, "lo": lo}
        else:
            out = {"w": W.astype(jnp.float32)}
        for key, width, dtype in self.state_slabs():
            out[key] = jnp.zeros((rows, width or E), dtype)
        if counters and "cnt" not in out:
            out["cnt"] = jnp.zeros((rows, 1), jnp.int32)
        return out

    def fwd_weights(self, store: dict) -> jax.Array:
        """The slab the forward/backward passes read (bf16 hi or fp32 w)."""
        return store["hi"] if self.split else store["w"]

    def materialize_fp32(self, store: dict) -> jax.Array:
        """Exact fp32 master weights (eval / serving snapshots)."""
        if self.split:
            return combine_split(store["hi"], store["lo"])
        return store["w"]

    # ---------------------------------------------------------- apply --
    def apply_sparse(self, store: dict, stream: SparseStream, lr, *,
                     seed=None, fused: bool = False,
                     interpret: Optional[bool] = None) -> dict:
        """THE sparse update dispatcher: new store from one stream.

        ``fused=True`` (and always for pre-sorted streams) runs the Pallas
        fused kernel — per-row VMEM pre-reduction, weights AND state
        updated in place on the touched rows only.  ``fused=False`` runs
        the reference math (scatter / dedup + functional scatter) with
        identical optimizer semantics; the split path is bit-identical
        between the two, the fp32 paths match to the documented
        pre-reduction rounding, and the stochastic-rounding kinds are
        bit-identical across ALL paths for a given ``seed`` (the int32
        per-step stochastic-rounding counter; ignored by the
        deterministic kinds).

        The reserved ``cnt`` touch-counter slab, when present in
        ``store``, is bumped here — +1 per valid lookup, before the
        optimizer math — so a declared-state counter (``adagrad_freq``)
        reads the post-bump count and an auxiliary counter (the hot-row
        cache's promotion signal) is carried through unchanged by hooks
        that never see it."""
        from repro.kernels import ops
        seed = jnp.asarray(0 if seed is None else seed, jnp.int32)
        num_rows = self.fwd_weights(store).shape[0]
        # flat touch targets for the counter bump: valid in-range row ids,
        # everything else keyed out of range (dropped by bump_counters)
        if stream.presort is not None:
            srows, _, smsk, _ = stream.presort
            touch = jnp.where(smsk != 0, srows, num_rows)
        elif stream.valid is None:
            touch = stream.idx.reshape(-1)
        else:
            touch = jnp.where(stream.valid, stream.idx,
                              num_rows).reshape(-1)
        aux_cnt = None
        if "cnt" in self.state_keys:
            store = dict(store)
            store["cnt"] = bump_counters(store["cnt"], touch, num_rows)
        elif "cnt" in store:
            # auxiliary counter: the hooks (and the kernel lane padding in
            # kernels.ops, which drops unknown input keys) must not see it
            store = dict(store)
            aux_cnt = bump_counters(store.pop("cnt"), touch, num_rows)

        def _out(out):
            if aux_cnt is not None:
                out = dict(out)
                out["cnt"] = aux_cnt
            return out

        if stream.presort is not None:
            dY = stream.dY
            dYr = dY.reshape(-1, dY.shape[-1]) if dY.ndim != 2 else dY
            return _out(ops.fused_row_update_presorted(
                self, store, *stream.presort, dYr, lr, seed=seed,
                interpret=interpret))
        idx, dY = stream.idx, stream.dY
        P = idx.shape[-1]
        E = dY.shape[-1]
        if fused:
            tgt = idx.reshape(-1)
            val = None if stream.valid is None else stream.valid.reshape(-1)
            w = (None if stream.weights is None
                 else stream.weights.reshape(-1))
            dYr = dY.reshape(-1, E)
            return _out(ops.fused_row_update(self, store, tgt, dYr, lr,
                                             seed=seed, valid=val,
                                             weights=w, pooling=P,
                                             interpret=interpret))
        # reference: expand dY to per-lookup grads (the thing the fused
        # kernel never materializes), zero the masked entries, and apply
        # the instance's reference row math
        grad = jnp.broadcast_to(dY[..., None, :],
                                idx.shape + (E,)).astype(jnp.float32)
        if stream.weights is not None:
            grad = grad * stream.weights[..., None].astype(jnp.float32)
        valid = stream.valid
        if valid is not None:
            grad = jnp.where(valid[..., None], grad, 0.0)
        grad = grad.reshape(-1, E)
        if not self.state:
            # stateless contract: masked lookups become zero-grad entries
            # on row 0 (a bit-exact no-op for the stateless kinds)
            tgt = (idx if valid is None
                   else jnp.where(valid, idx, 0)).reshape(-1)
        else:
            # stateful kinds must DROP masked lookups entirely (a zero
            # gradient still decays momentum / rewrites the accumulator):
            # key them out of range so dedup's scatter drops the segment
            tgt = (idx if valid is None
                   else jnp.where(valid, idx, num_rows)).reshape(-1)
        if self.flat_reference is not None:
            return _out(self.flat_reference(self, store, tgt, grad, lr,
                                            seed))
        rep, summed = dedup_rows(tgt, grad, num_rows)
        return _out(self.apply_rows_reduced(store, rep, summed, lr,
                                            seed=seed))

    def apply_rows_reduced(self, store: dict, rep: jax.Array,
                           summed: jax.Array, lr, seed=None) -> dict:
        """Stateful reference transition on a PRE-REDUCED stream: ``rep``
        [n] unique touched rows (``num_rows`` fillers are dropped by the
        scatter), ``summed`` [n, E] their per-row gradient sums.  Applied
        exactly ONCE per row per step — the contract a batch-chunked
        caller must preserve by accumulating gradients across chunks
        first (``se.apply_update``) instead of re-running the momentum
        decay / Adagrad accumulate per chunk.  Dispatches to the
        instance's ``reference`` hook.

        An AUXILIARY ``cnt`` slab is carried through UNCHANGED — on this
        pre-reduced entry the caller owns the bump (``rep`` is
        deduplicated, so +1 per entry would undercount duplicates); a
        declared-state ``cnt`` (``adagrad_freq``) reaches the hook as-is
        and the caller must have bumped it already."""
        if self.reference is None:
            raise ValueError(
                f"row optimizer {self.name!r} registered no reduced "
                "reference transition (reference=) — required for "
                "stateful optimizers")
        seed = jnp.asarray(0 if seed is None else seed, jnp.int32)
        aux_cnt = None
        if "cnt" in store and "cnt" not in self.state_keys:
            store = dict(store)
            aux_cnt = store.pop("cnt")
        out = self.reference(self, store, rep, summed, lr, seed)
        if aux_cnt is not None:
            out = dict(out)
            out["cnt"] = aux_cnt
        return out


# ---------------------------------------------------------------------------
# Built-in hook implementations.  ``kernel`` hooks import the Pallas
# entries lazily (kernels.embedding_update) so the reference paths stay
# importable without the kernel stack; each one is a thin adapter from
# the generic hook signature to one kernel entry.
# ---------------------------------------------------------------------------

def _take_rows(store: dict, rep: jax.Array) -> tuple:
    """(safe gather index, fp32 weight rows) for a reduced stream."""
    W = store["w"]
    safe = jnp.minimum(rep, W.shape[0] - 1)
    return safe, jnp.take(W, safe, axis=0)


def _flatref_sgd(opt, store, tgt, grad, lr, seed):
    return {"w": apply_rows_sgd(store["w"], tgt, grad, lr)}


def _flatref_split_sgd(opt, store, tgt, grad, lr, seed):
    nh, nl = apply_rows_split_sgd(store["hi"], store["lo"], tgt, grad, lr)
    return {"hi": nh, "lo": nl}


def _ref_momentum(opt, store, rep, summed, lr, seed):
    safe, w_rows = _take_rows(store, rep)
    m_rows = jnp.take(store["mom"], safe, axis=0)
    m_new = opt.beta * m_rows + summed
    w_new = w_rows - lr * m_new
    return {"w": store["w"].at[rep].set(w_new),
            "mom": store["mom"].at[rep].set(m_new)}


def _ref_adagrad(opt, store, rep, summed, lr, seed):
    safe, w_rows = _take_rows(store, rep)
    s_rows = jnp.take(store["acc"], safe, axis=0)
    s_new = s_rows + summed * summed
    w_new = w_rows - lr * summed / (jnp.sqrt(s_new) + opt.eps)
    return {"w": store["w"].at[rep].set(w_new),
            "acc": store["acc"].at[rep].set(s_new)}


def _ref_adagrad_rowwise(opt, store, rep, summed, lr, seed):
    safe, w_rows = _take_rows(store, rep)
    s_rows = jnp.take(store["acc"], safe, axis=0)          # [n, 1]
    ms = jnp.mean(summed * summed, axis=1, keepdims=True)
    s_new = s_rows + ms
    w_new = w_rows - lr * summed / (jnp.sqrt(s_new) + opt.eps)
    return {"w": store["w"].at[rep].set(w_new),
            "acc": store["acc"].at[rep].set(s_new)}


def _ref_momentum_bf16(opt, store, rep, summed, lr, seed):
    # same expressions as _kernel_momentum_bf16: decode exact, fp32
    # transition, stochastically round ONLY the stored state — noise is a
    # pure function of (seed, row, lane), so this path is bitwise the
    # fused kernel on the same stream
    safe, w_rows = _take_rows(store, rep)
    m_rows = jnp.take(store["mom"], safe, axis=0).astype(jnp.float32)
    m_new = opt.beta * m_rows + summed
    w_new = w_rows - lr * m_new
    m_out = sr_round_bf16(m_new, sr_noise(seed, safe, m_new.shape[-1]))
    return {"w": store["w"].at[rep].set(w_new),
            "mom": store["mom"].at[rep].set(m_out)}


def _ref_adagrad_bf16(opt, store, rep, summed, lr, seed):
    safe, w_rows = _take_rows(store, rep)
    s_rows = jnp.take(store["acc"], safe, axis=0).astype(jnp.float32)
    s_new = s_rows + summed * summed
    w_new = w_rows - lr * summed / (jnp.sqrt(s_new) + opt.eps)
    s_out = sr_round_bf16(s_new, sr_noise(seed, safe, s_new.shape[-1]))
    return {"w": store["w"].at[rep].set(w_new),
            "acc": store["acc"].at[rep].set(s_out)}


def _ref_adagrad_freq(opt, store, rep, summed, lr, seed):
    # frequency-adaptive sparse LR: store["cnt"] is the POST-bump touch
    # counter (apply_sparse bumps the reserved slab before dispatch), so
    # hot rows — large counts — take proportionally smaller steps.  The
    # hook only READS the counter; the bump owns the transition.
    safe, w_rows = _take_rows(store, rep)
    c = jnp.take(store["cnt"], safe, axis=0).astype(jnp.float32)   # [n, 1]
    denom = jnp.sqrt(jnp.maximum(c, 1.0)) + opt.eps
    w_new = w_rows - lr * summed / denom
    return {"w": store["w"].at[rep].set(w_new), "cnt": store["cnt"]}


def _k_sgd(opt, store, srows, sbags, smsk, swgt, dY, lr, seed, e_real,
           interpret):
    from repro.kernels import embedding_update as ku
    return {"w": ku.fused_update_fp32_pallas(store["w"], srows, sbags, smsk,
                                             swgt, dY, lr,
                                             interpret=interpret)}


def _k_split_sgd(opt, store, srows, sbags, smsk, swgt, dY, lr, seed, e_real,
                 interpret):
    from repro.kernels import embedding_update as ku
    nh, nl = ku.fused_update_split_pallas(store["hi"], store["lo"], srows,
                                          sbags, smsk, swgt, dY, lr,
                                          interpret=interpret)
    return {"hi": nh, "lo": nl}


def _k_momentum(opt, store, srows, sbags, smsk, swgt, dY, lr, seed, e_real,
                interpret):
    from repro.kernels import embedding_update as ku
    nw, nm = ku.fused_update_momentum_pallas(store["w"], store["mom"],
                                             srows, sbags, smsk, swgt, dY,
                                             lr, opt.beta,
                                             interpret=interpret)
    return {"w": nw, "mom": nm}


def _k_adagrad(opt, store, srows, sbags, smsk, swgt, dY, lr, seed, e_real,
               interpret):
    from repro.kernels import embedding_update as ku
    nw, ns = ku.fused_update_adagrad_pallas(
        store["w"], store["acc"], srows, sbags, smsk, swgt, dY, lr,
        opt.eps, False, e_real, interpret=interpret)
    return {"w": nw, "acc": ns}


def _k_adagrad_rowwise(opt, store, srows, sbags, smsk, swgt, dY, lr, seed,
                       e_real, interpret):
    from repro.kernels import embedding_update as ku
    nw, ns = ku.fused_update_adagrad_pallas(
        store["w"], store["acc"], srows, sbags, smsk, swgt, dY, lr,
        opt.eps, True, e_real, interpret=interpret)
    return {"w": nw, "acc": ns}


def _k_momentum_bf16(opt, store, srows, sbags, smsk, swgt, dY, lr, seed,
                     e_real, interpret):
    from repro.kernels import embedding_update as ku
    nw, nm = ku.fused_update_momentum_bf16_pallas(
        store["w"], store["mom"], srows, sbags, smsk, swgt, dY, lr,
        opt.beta, seed, interpret=interpret)
    return {"w": nw, "mom": nm}


def _k_adagrad_bf16(opt, store, srows, sbags, smsk, swgt, dY, lr, seed,
                    e_real, interpret):
    from repro.kernels import embedding_update as ku
    nw, ns = ku.fused_update_adagrad_bf16_pallas(
        store["w"], store["acc"], srows, sbags, smsk, swgt, dY, lr,
        opt.eps, seed, interpret=interpret)
    return {"w": nw, "acc": ns}


def _k_adagrad_freq(opt, store, srows, sbags, smsk, swgt, dY, lr, seed,
                    e_real, interpret):
    from repro.kernels import embedding_update as ku
    nw, nc = ku.fused_update_freq_pallas(store["w"], store["cnt"], srows,
                                         sbags, smsk, swgt, dY, lr,
                                         opt.eps, interpret=interpret)
    return {"w": nw, "cnt": nc}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, RowOptimizer] = {}


def register(opt: RowOptimizer) -> RowOptimizer:
    if opt.name in _REGISTRY:
        raise ValueError(f"row optimizer {opt.name!r} already registered")
    if opt.kernel is None:
        raise ValueError(f"row optimizer {opt.name!r} registered no fused "
                         "kernel entry (kernel=)")
    if opt.reference is None and opt.flat_reference is None:
        raise ValueError(f"row optimizer {opt.name!r} registered no "
                         "reference transition (reference= or "
                         "flat_reference=)")
    _REGISTRY[opt.name] = opt
    return opt


def unregister(name: str) -> None:
    """Remove a registered optimizer (tests tearing down toy entries)."""
    _REGISTRY.pop(name, None)


def names() -> tuple:
    return tuple(_REGISTRY)


def get(name: str, *, beta: Optional[float] = None,
        eps: Optional[float] = None) -> RowOptimizer:
    """Look a registered optimizer up by name, optionally overriding its
    hyperparameters."""
    try:
        opt = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown sparse optimizer {name!r}; registered: "
                         f"{sorted(_REGISTRY)}") from None
    repl = {}
    if beta is not None:
        repl["beta"] = float(beta)
    if eps is not None:
        repl["eps"] = float(eps)
    return dataclasses.replace(opt, **repl) if repl else opt


def make(spec: Any, *, beta: Optional[float] = None,
         eps: Optional[float] = None) -> RowOptimizer:
    """Coerce a config value (name string or RowOptimizer) to an instance."""
    if isinstance(spec, RowOptimizer):
        repl = {}
        if beta is not None:
            repl["beta"] = float(beta)
        if eps is not None:
            repl["eps"] = float(eps)
        return dataclasses.replace(spec, **repl) if repl else spec
    return get(str(spec), beta=beta, eps=eps)


def resolve(mdef: Any) -> RowOptimizer:
    """RowOptimizer for a model definition (``HybridDef``, ``DLRMConfig``,
    or anything with the same fields).  ``sparse_optimizer`` (name or
    instance) wins; a falsy value falls back to the DEPRECATED
    ``split_sgd`` bool sugar (True -> 'split_sgd', False -> 'sgd'; an
    explicit bool warns — the unset ``None`` default resolves to
    'split_sgd' silently).  ``opt_beta``/``opt_eps`` override the
    registered defaults."""
    spec = getattr(mdef, "sparse_optimizer", None)
    if not spec:
        sugar = getattr(mdef, "split_sgd", None)
        if sugar is None:
            spec = "split_sgd"
        else:
            warnings.warn(
                "split_sgd=<bool> is deprecated sugar; pass "
                "sparse_optimizer='split_sgd' (or 'sgd') instead",
                DeprecationWarning, stacklevel=2)
            spec = "split_sgd" if sugar else "sgd"
    return make(spec, beta=getattr(mdef, "opt_beta", None),
                eps=getattr(mdef, "opt_eps", None))


register(RowOptimizer(name="sgd", split=False,
                      kernel=_k_sgd, flat_reference=_flatref_sgd))
register(RowOptimizer(name="split_sgd", split=True,
                      kernel=_k_split_sgd,
                      flat_reference=_flatref_split_sgd))
register(RowOptimizer(name="momentum", split=False,
                      state=(("mom", 0),), beta=0.9,
                      kernel=_k_momentum, reference=_ref_momentum))
register(RowOptimizer(name="adagrad_rowwise", split=False,
                      state=(("acc", 1),), eps=1e-8,
                      kernel=_k_adagrad_rowwise,
                      reference=_ref_adagrad_rowwise))
register(RowOptimizer(name="adagrad", split=False,
                      state=(("acc", 0),), eps=1e-8,
                      kernel=_k_adagrad, reference=_ref_adagrad))
# compressed bf16-hi state + seeded stochastic rounding: half the
# state-slab bytes per touched row (see docs/optim.md for when NOT to)
register(RowOptimizer(name="momentum_bf16", split=False,
                      state=(("mom", 0, "bfloat16"),), beta=0.9,
                      stochastic_round=True,
                      kernel=_k_momentum_bf16,
                      reference=_ref_momentum_bf16))
register(RowOptimizer(name="adagrad_bf16", split=False,
                      state=(("acc", 0, "bfloat16"),), eps=1e-8,
                      stochastic_round=True,
                      kernel=_k_adagrad_bf16,
                      reference=_ref_adagrad_bf16))
# frequency-adaptive sparse LR driven by the reserved touch-counter slab
# (hot rows — large counts — decay faster); the same counters feed the
# hot-row cache's promotion policy (docs/cache.md)
register(RowOptimizer(name="adagrad_freq", split=False,
                      state=(("cnt", 1, "int32"),), eps=1e-8,
                      kernel=_k_adagrad_freq,
                      reference=_ref_adagrad_freq))
