"""Plain fp32 SGD (DLRM's default optimizer) — the baseline Split-SGD must
match bit-for-bit on the update rule."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def init_momentum(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def apply_updates(params: Any, grads: Any, lr,
                  momentum: Optional[Any] = None, beta: float = 0.0):
    if momentum is None:
        return jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                          ).astype(p.dtype), params, grads)
    new_mom = jax.tree.map(
        lambda m, g: beta * m + g.astype(jnp.float32), momentum, grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, new_mom)
    return new_params, new_mom
