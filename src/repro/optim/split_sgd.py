"""Split-SGD-BF16 (paper contribution C5, Sect. VII).

FP32 master weights are stored as two 16-bit tensors:

* ``hi``  — the 16 MSBs of the fp32 bits.  This IS a valid BFLOAT16 number
  (bf16 aliases the upper half of IEEE754 fp32) and is the only thing the
  forward/backward passes ever touch: 2x bandwidth on 2 of the 3 training
  passes, zero extra capacity vs fp32.
* ``lo``  — the 16 LSBs, held as optimizer state (uint16).

The update reconstructs exact fp32, applies SGD (+ optional momentum), and
re-splits.  ``combine_split(split_fp32(x)) == x`` bit-exactly; the update is
bit-identical to an fp32 SGD update given the same gradients (property-tested
in tests/test_split_sgd.py).

The scheme is workload-independent (paper: "transferable to all other deep
learning topologies") — every architecture config in this framework can select
``optimizer: split_sgd``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


def split_fp32(w32: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp32 -> (hi: bf16, lo: uint16).  Pure bit partition (truncation)."""
    bits = jax.lax.bitcast_convert_type(w32.astype(jnp.float32), jnp.uint32)
    hi = jax.lax.bitcast_convert_type(
        (bits >> 16).astype(jnp.uint16), jnp.bfloat16)
    lo = (bits & jnp.uint32(0xFFFF)).astype(jnp.uint16)
    return hi, lo


def combine_split(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """(hi: bf16, lo: uint16) -> exact fp32."""
    hb = jax.lax.bitcast_convert_type(hi, jnp.uint16).astype(jnp.uint32)
    bits = (hb << 16) | lo.astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SplitParams:
    """A pytree-of-arrays pair mirroring the model parameter tree."""
    hi: Any   # bf16 tree — feed THIS to fwd/bwd
    lo: Any   # uint16 tree — optimizer state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SplitSGDState:
    params: SplitParams
    momentum: Optional[Any]  # fp32 tree or None


def init(params_fp32: Any, momentum: float = 0.0) -> SplitSGDState:
    hi_lo = jax.tree.map(split_fp32, params_fp32)
    hi = jax.tree.map(lambda t: t[0], hi_lo,
                      is_leaf=lambda x: isinstance(x, tuple))
    lo = jax.tree.map(lambda t: t[1], hi_lo,
                      is_leaf=lambda x: isinstance(x, tuple))
    mom = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params_fp32)
           if momentum else None)
    return SplitSGDState(SplitParams(hi, lo), mom)


def update_leaf(hi, lo, g, lr, mom=None, beta: float = 0.0):
    """One exact-fp32 SGD step on a split leaf.  Returns (hi, lo[, mom])."""
    w32 = combine_split(hi, lo)
    g32 = g.astype(jnp.float32)
    if mom is not None:
        mom = beta * mom + g32
        g32 = mom
    w32 = w32 - lr * g32
    nh, nl = split_fp32(w32)
    if mom is not None:
        return nh, nl, mom
    return nh, nl


def apply_updates(state: SplitSGDState, grads: Any, lr,
                  beta: float = 0.0) -> SplitSGDState:
    """Tree-wide split-SGD step (dense gradients)."""
    if state.momentum is None:
        out = jax.tree.map(lambda h, l, g: update_leaf(h, l, g, lr),
                           state.params.hi, state.params.lo, grads)
        hi = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        lo = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return SplitSGDState(SplitParams(hi, lo), None)
    out = jax.tree.map(
        lambda h, l, g, m: update_leaf(h, l, g, lr, m, beta),
        state.params.hi, state.params.lo, grads, state.momentum)
    leaf = lambda x: isinstance(x, tuple)
    hi = jax.tree.map(lambda t: t[0], out, is_leaf=leaf)
    lo = jax.tree.map(lambda t: t[1], out, is_leaf=leaf)
    mom = jax.tree.map(lambda t: t[2], out, is_leaf=leaf)
    return SplitSGDState(SplitParams(hi, lo), mom)


def materialize_fp32(state: SplitSGDState) -> Any:
    """Reconstruct the exact fp32 master weights (for checkpoints/eval)."""
    return jax.tree.map(combine_split, state.params.hi, state.params.lo)
