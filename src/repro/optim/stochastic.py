"""Seeded stochastic rounding to bf16-hi storage (compressed optimizer
state).

The Split-SGD trick (paper Sect. VII) keeps fp32 EXACT by bit-partitioning
each weight into a bf16 ``hi`` half and a uint16 ``lo`` carry half.  The
per-row optimizer-state slabs (momentum rows, Adagrad accumulators) do not
need exactness — they need UNBIASEDNESS: storing only the bf16 ``hi`` half
and rounding stochastically halves the state-slab bytes per touched row
while keeping the expected value of the stored state equal to the fp32
value (truncation would bias every row toward zero; round-to-nearest would
bias long accumulations toward the last rounding boundary).

Determinism contract (the reason this module exists instead of a PRNG
call): the dither is a COUNTER-BASED pure function of
``(seed, row id, lane)`` — no sampler state, no traversal order.  The
reference scan, the fused Pallas kernel (device-sorted) and the
host-pre-sorted path therefore add the exact same 16-bit dither to the
exact same fp32 value for every touched row, and the three paths stay
BITWISE identical for a given per-step seed (tests/test_stochastic.py).
``pltpu.prng_random_bits`` could not give this: its stream depends on the
core's sampler state and has no jnp twin for the reference path.

The hash is the 32-bit ``lowbias32`` finalizer (a Murmur3-style avalanche:
xor-shift / multiply rounds) — integer ops only, so the same expression
runs inside the Pallas kernel body (interpret AND compiled) and in plain
``jnp`` reference code with identical results.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# lowbias32 multipliers (Ellis: exact-bias-measured avalanche constants)
_MIX1 = 0x7FEB352D
_MIX2 = 0x846CA68B
# Weyl / stream constants decorrelating the (seed, row, lane) counters
_GOLD = 0x9E3779B1
_ROWC = 0x85EBCA6B
# wire-payload stream constant (PCG multiplier): keeps the collective
# wire dither of repro/dist/exchange.py off the row-state dither streams
# above even when a tag numerically equals a row id
_WIREC = 0xB5297A4D


def mix32(x: jax.Array) -> jax.Array:
    """lowbias32 avalanche on uint32 (xorshift-multiply finalizer)."""
    x = jnp.asarray(x, jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(_MIX1)
    x = (x ^ (x >> 15)) * jnp.uint32(_MIX2)
    return x ^ (x >> 16)


def sr_noise(seed: jax.Array, rows: jax.Array, width: int) -> jax.Array:
    """The dither stream: uint32 noise of shape ``rows.shape + (width,)``.

    Pure function of ``(seed, rows[...], lane)``; ``rows`` are (local) row
    ids of any integer shape/dtype.  The lane counter is a 2-D+
    ``broadcasted_iota`` (TPU-legal in kernel bodies).  Every path that
    rounds the same row under the same seed sees the same bits.
    """
    seed_u = jnp.asarray(seed).astype(jnp.uint32)
    rows_u = jnp.asarray(rows).astype(jnp.uint32)
    base = mix32(seed_u * jnp.uint32(_GOLD) ^ rows_u * jnp.uint32(_ROWC))
    lane = jax.lax.broadcasted_iota(jnp.uint32, rows_u.shape + (width,),
                                    rows_u.ndim)
    return mix32(base[..., None] ^ (lane * jnp.uint32(_GOLD) + jnp.uint32(1)))


def sr_round_bf16(x: jax.Array, noise_u32: jax.Array) -> jax.Array:
    """fp32 -> bf16 stochastic round: add a uniform 16-bit dither to the
    discarded mantissa half, truncate to the bf16-aliasing hi half.

    The two representable bf16 neighbours of ``x`` are hit with
    probabilities proportional to their distance, so ``E[sr(x)] == x``
    (exactly, over the uniform dither) — the property that keeps long
    state accumulations drift-free where truncation shrinks them ~0.2%
    per rewrite.  The uint32 add carries through the exponent boundary
    (IEEE754 bit patterns are magnitude-ordered), so rounding across a
    binade is handled for free; the sign bit is untouched for any finite
    ``x``.  ``bf16 -> fp32`` decode (``.astype``) is exact, so
    decode(round(x)) differs from ``x`` by at most one bf16 ulp.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    dithered = bits + (noise_u32 & jnp.uint32(0xFFFF))
    return jax.lax.bitcast_convert_type(
        (dithered >> 16).astype(jnp.uint16), jnp.bfloat16)


def wire_noise(seed: jax.Array, tag: jax.Array, shape: tuple) -> jax.Array:
    """Dither stream for one WIRE payload (a collective operand of
    repro/dist/exchange.py): uint32 noise of ``shape``, a pure function of
    ``(seed, tag, flat element index)``.

    Same determinism contract as :func:`sr_noise` — counter-based, no
    sampler state, no traversal order — so a run resumed from a
    checkpointed ``sr`` counter replays the exact wire dither.  ``tag``
    (see ``exchange.wire_tag``) positions the payload within the step
    (stream base, microbatch/bucket, sender rank); the ``_WIREC``
    multiplier keeps these streams disjoint from the row-state streams
    even when a tag numerically equals a row id.  The flat-iota element
    counter is plain XLA (this path never runs inside a Pallas body, so
    the 1-D iota restriction of kernel code does not apply)."""
    seed_u = jnp.asarray(seed).astype(jnp.uint32)
    tag_u = jnp.asarray(tag).astype(jnp.uint32)
    base = mix32(seed_u * jnp.uint32(_GOLD)
                 ^ (tag_u * jnp.uint32(_WIREC) + jnp.uint32(1)))
    n = 1
    for d in shape:
        n *= int(d)
    ctr = jax.lax.iota(jnp.uint32, n).reshape(shape)
    return mix32(base ^ (ctr * jnp.uint32(_ROWC) + jnp.uint32(_GOLD)))


def sr_round_bf16_wire(x: jax.Array, seed: jax.Array, tag) -> jax.Array:
    """fp32 -> bf16 stochastic round of a wire payload under the seeded
    counter dither.  Exactness guarantee (the degeneration contract of
    the compressed collectives): any value already representable in bf16
    — zeros included — passes through BITWISE, because its discarded
    mantissa half is zero and the <= 0xFFFF dither cannot carry into the
    kept half."""
    x = jnp.asarray(x, jnp.float32)
    return sr_round_bf16(x, wire_noise(seed, tag, x.shape))
