from repro.serve.loop import BatchingServer  # noqa: F401
