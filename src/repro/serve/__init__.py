"""Production serving subsystem (docs/serve.md).

* :mod:`repro.serve.server` — continuous batching over bucketed compiled
  shapes with a real ``max_wait_ms`` deadline (plus the legacy
  pad-and-drain :class:`BatchingServer`).
* :mod:`repro.serve.snapshot` — immutable read-only serving snapshots of
  the bf16-hi embedding slab, versioned publish/retire, and the
  bitwise-identical ``score_from_snapshot`` path.
* :mod:`repro.serve.publish` — online training wiring: a train-loop hook
  publishing fresh snapshots to a concurrently running server, with
  measured train-to-serve freshness.
"""

from repro.serve.server import (  # noqa: F401
    BatchingServer,
    ContinuousBatchingServer,
    ServerClosed,
    bucket_for,
)
from repro.serve.snapshot import (  # noqa: F401
    ServingSnapshot,
    SnapshotRegistry,
    make_bucket_scorers,
    make_snapshot_score_step,
    snapshot_from_state,
    snapshot_state,
)
from repro.serve.publish import SnapshotPublisher, combined_serve_stats  # noqa: F401
