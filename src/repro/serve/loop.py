"""Minimal batched serving loop (the serve_p99 path).

Requests queue up; the server pads them to the compiled batch size and runs
the jitted score step.  Request latencies land in a bounded-memory
log-bucketed histogram (:class:`repro.telemetry.LatencyHistogram`) so
:meth:`BatchingServer.percentiles` reports p50/p99 — the metric the
``serve_p99`` shape exists for — at O(1) memory however long the server
stays up.  Each drained chunk is also a ``serve/batch`` span on the
process tracer.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable

import numpy as np

from repro import telemetry


class BatchingServer:
    def __init__(self, score_fn: Callable[[dict], np.ndarray],
                 batch_size: int, pad_batch: Callable[[list], dict],
                 max_wait_ms: float = 2.0):
        self.score_fn = score_fn
        self.batch_size = batch_size
        self.pad_batch = pad_batch
        self.max_wait_ms = max_wait_ms
        self.queue: deque = deque()
        # 1us..100s in ms units, 2% relative quantile error
        self.latency = telemetry.LatencyHistogram(lo=1e-3, hi=1e5,
                                                  growth=1.02)

    def submit(self, request: Any):
        self.queue.append((time.perf_counter(), request))

    def drain(self):
        """Process the queue in compiled-batch chunks."""
        while self.queue:
            n = min(self.batch_size, len(self.queue))
            items = [self.queue.popleft() for _ in range(n)]
            t_in = [t for t, _ in items]
            reqs = [r for _, r in items]
            with telemetry.span("serve/batch", cat="serve", n=n):
                batch = self.pad_batch(reqs)
                scores = np.asarray(self.score_fn(batch))[:n]
            t_done = time.perf_counter()
            for t in t_in:
                self.latency.record((t_done - t) * 1e3)
            yield reqs, scores

    def percentiles(self) -> dict:
        """{p50_ms, p99_ms, mean_ms, n} (empty before any request) — the
        historical key contract, served from the bounded histogram."""
        s = self.latency.summary()
        if not s:
            return {}
        return {"p50_ms": s["p50"], "p99_ms": s["p99"],
                "mean_ms": s["mean"], "n": s["n"]}
