"""Minimal batched serving loop (the serve_p99 path).

Requests queue up; the server pads them to the compiled batch size and runs
the jitted score step.  Latency percentiles are tracked so the examples can
report p50/p99 — the metric the ``serve_p99`` shape exists for.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable

import numpy as np


class BatchingServer:
    def __init__(self, score_fn: Callable[[dict], np.ndarray],
                 batch_size: int, pad_batch: Callable[[list], dict],
                 max_wait_ms: float = 2.0):
        self.score_fn = score_fn
        self.batch_size = batch_size
        self.pad_batch = pad_batch
        self.max_wait_ms = max_wait_ms
        self.queue: deque = deque()
        self.latencies_ms: list[float] = []

    def submit(self, request: Any):
        self.queue.append((time.perf_counter(), request))

    def drain(self):
        """Process the queue in compiled-batch chunks."""
        while self.queue:
            n = min(self.batch_size, len(self.queue))
            items = [self.queue.popleft() for _ in range(n)]
            t_in = [t for t, _ in items]
            reqs = [r for _, r in items]
            batch = self.pad_batch(reqs)
            scores = np.asarray(self.score_fn(batch))[:n]
            t_done = time.perf_counter()
            self.latencies_ms += [(t_done - t) * 1e3 for t in t_in]
            yield reqs, scores

    def percentiles(self) -> dict:
        if not self.latencies_ms:
            return {}
        a = np.asarray(self.latencies_ms)
        return {"p50_ms": float(np.percentile(a, 50)),
                "p99_ms": float(np.percentile(a, 99)),
                "mean_ms": float(a.mean()), "n": int(a.size)}
