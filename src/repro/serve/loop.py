"""Back-compat shim: the pad-and-drain :class:`BatchingServer` moved to
:mod:`repro.serve.server` when serving grew into a subsystem (continuous
batching + snapshots + publish; see docs/serve.md)."""

from repro.serve.server import BatchingServer  # noqa: F401
