"""Train-to-serve publishing: fresh snapshots from a live training loop.

:class:`SnapshotPublisher` is a :class:`repro.train.TrainLoop`
``step_hook``: every ``publish_every`` completed steps it captures
:func:`repro.serve.snapshot.snapshot_state` with ``copy=True`` (the
forward slabs only — the train step donates its input buffers, so the
snapshot must own its tables) and publishes it to a
:class:`~repro.serve.snapshot.SnapshotRegistry` that a concurrently
running :class:`~repro.serve.server.ContinuousBatchingServer` reads per
batch.

Train-to-serve FRESHNESS is a measured number, not a hope:
``freshness()`` reports how far the serving tables trail the training
head — ``steps_behind`` (head step minus the published snapshot's step;
bounded by ``publish_every - 1`` plus in-flight time) and
``seconds_behind`` (wall time since publish).  ``stats()`` is
heartbeat-shaped: pass it (or :func:`combined_serve_stats`) as the train
loop's ``serve_stats`` so every heartbeat JSONL record carries snapshot
version + freshness next to the serve-path latency percentiles.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Optional

from repro import telemetry
from repro.serve import snapshot as snap_mod


class SnapshotPublisher:
    """Publishes a serving snapshot every ``publish_every`` steps.

    Use as a TrainLoop ``step_hook`` (called with ``(completed_step,
    state)``); ``registry`` defaults to a fresh
    :class:`~repro.serve.snapshot.SnapshotRegistry`."""

    def __init__(
        self,
        mdef,
        *,
        publish_every: int = 10,
        registry: Optional[snap_mod.SnapshotRegistry] = None,
        keep: int = 2,
    ):
        if publish_every < 1:
            raise ValueError(f"publish_every must be >= 1, got {publish_every}")
        self.mdef = mdef
        self.publish_every = publish_every
        self.registry = registry if registry is not None else snap_mod.SnapshotRegistry(keep=keep)
        self.head_step = 0
        self.publishes = 0

    def __call__(self, step: int, state: Any) -> Optional[snap_mod.ServingSnapshot]:
        """TrainLoop step hook: track the head, publish on cadence."""
        self.head_step = max(self.head_step, step)
        if step % self.publish_every == 0:
            return self.publish(step, state)
        return None

    def publish(self, step: int, state: Any) -> snap_mod.ServingSnapshot:
        """Publish now, regardless of cadence (e.g. version 1 at step 0 so
        the server has tables before training starts).  Always copies the
        forward slabs: the train step donates the previous state's buffers
        to XLA, so a by-reference snapshot would be deleted under the
        server as training moves on."""
        self.head_step = max(self.head_step, step)
        snap = self.registry.publish(
            snap_mod.snapshot_state(self.mdef, state, copy=True), step=step)
        self.publishes += 1
        telemetry.instant("serve/publish", cat="serve", step=step, version=snap.version)
        return snap

    def freshness(self, head_step: Optional[int] = None, now: Optional[float] = None) -> dict:
        """{version, steps_behind, seconds_behind} of the CURRENT snapshot
        vs the training head (empty before the first publish)."""
        cur = self.registry.current()
        if cur is None:
            return {}
        head = self.head_step if head_step is None else head_step
        return {
            "version": cur.version,
            "steps_behind": head - cur.step,
            "seconds_behind": (time.time() if now is None else now) - cur.published_t,
        }

    def stats(self) -> dict:
        """Heartbeat-shaped publisher summary."""
        out = {"publishes": self.publishes, "versions": self.registry.versions()}
        out.update(self.freshness())
        return out


def combined_serve_stats(publisher: Optional[SnapshotPublisher], server=None) -> Callable[[], dict]:
    """A ``TrainLoop(serve_stats=...)`` callable merging publisher
    freshness with the server's queue/latency stats (either side
    optional)."""

    def stats() -> dict:
        rec: dict = {}
        if publisher is not None:
            rec["snapshot"] = publisher.stats()
        if server is not None:
            rec.update(server.stats())
        return rec

    return stats
