"""Continuous-batching serving over a ladder of compiled batch shapes.

Two servers, one contract (per-request latency lands in bounded-memory
:class:`repro.telemetry.LatencyHistogram` buckets; every scored batch is a
``serve/batch`` tracer span):

* :class:`BatchingServer` — the synchronous pad-and-drain loop (one
  compiled batch size, caller-driven ``drain()``).  Its ``max_wait_ms``
  deadline is REAL: a partial batch waits up to the deadline of its oldest
  request for stragglers to join before padding-and-flushing — padding a
  nearly-empty batch the instant one request shows up wastes a full
  compiled-shape execution per request.
* :class:`ContinuousBatchingServer` — the production shape: a worker
  thread drains a bounded request queue into the smallest compiled bucket
  that fits (e.g. 8/32/128), waiting at most ``max_wait_ms`` past the
  oldest request before flushing partial.  ``submit`` returns a handle;
  ``result()`` blocks on completion.  The worker reuses the poisoned-queue
  idiom of :class:`repro.data.pipeline.ThreadedIterator`: a scorer
  exception POISONS the server — every pending and future request fails
  promptly with the original error instead of hanging, and the server goes
  sticky-dead.

The per-bucket score fns are typically the donated-batch compiled steps of
:func:`repro.serve.snapshot.make_bucket_scorers`, reading the newest
published :class:`~repro.serve.snapshot.ServingSnapshot` per batch.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

from repro import telemetry


class ServerClosed(RuntimeError):
    """Raised by submit/result when the server is closed or poisoned."""


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= n (buckets sorted ascending; n must fit)."""
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"{n} requests exceed the largest bucket {buckets[-1]}")


class _Request:
    """Submit handle: a tiny future resolved by the worker thread."""

    __slots__ = ("payload", "t_submit", "t_done", "_done", "score", "error")

    def __init__(self, payload: Any):
        self.payload = payload
        self.t_submit = time.perf_counter()
        self.t_done: Optional[float] = None
        self._done = threading.Event()
        self.score = None
        self.error: Optional[BaseException] = None

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError("request not scored within timeout")
        if self.error is not None:
            if isinstance(self.error, ServerClosed):
                raise self.error
            raise ServerClosed("serving worker died") from self.error
        return self.score

    def done(self) -> bool:
        return self._done.is_set()

    def _resolve(self, score=None, error: Optional[BaseException] = None) -> None:
        self.score = score
        self.error = error
        self.t_done = time.perf_counter()
        self._done.set()


class ContinuousBatchingServer:
    """Worker-thread continuous batching over bucketed compiled shapes.

    ``score_fns``: ``{bucket_size: fn(batch) -> [bucket] scores}`` — one
    compiled step per bucket.  ``pad_batch(payloads, bucket)``: stack +
    zero-pad ``len(payloads) <= bucket`` request payloads into that
    bucket's batch.  ``max_wait_ms``: how long past the OLDEST queued
    request a partial batch may wait for more arrivals; a full largest
    bucket never waits.  ``queue_depth`` bounds the submit queue
    (backpressure: ``submit`` blocks when the server is that far behind).
    """

    def __init__(
        self,
        score_fns: dict[int, Callable[[dict], Any]],
        pad_batch: Callable[[list, int], dict],
        *,
        max_wait_ms: float = 2.0,
        queue_depth: int = 4096,
        name: str = "serve_worker",
    ):
        if not score_fns:
            raise ValueError("need at least one bucket score fn")
        self.buckets = tuple(sorted(score_fns))
        self.score_fns = dict(score_fns)
        self.pad_batch = pad_batch
        self.max_wait_ms = max_wait_ms
        # per-bucket latency: 1us..100s in ms units, 2% relative error
        self.hist = {
            b: telemetry.LatencyHistogram(lo=1e-3, hi=1e5, growth=1.02) for b in self.buckets
        }
        self.batches = {b: 0 for b in self.buckets}
        self.requests = 0
        self.padded = 0  # dummy rows executed (bucket - n summed)
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth)
        self._stop = threading.Event()
        self._dead: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, daemon=True, name=name)
        self._started = False
        self._lock = threading.Lock()

    # ---------------------------------------------------------- submit --
    def submit(self, payload: Any) -> _Request:
        """Enqueue one request; returns a handle whose ``result()`` blocks
        until the worker scores it.  Raises :class:`ServerClosed` once the
        server is closed or poisoned (sticky-dead, like a poisoned
        ThreadedIterator)."""
        if self._dead is not None:
            raise ServerClosed("serving worker died") from self._dead
        if self._stop.is_set():
            raise ServerClosed("server is closed")
        with self._lock:
            if not self._started:
                self._thread.start()
                self._started = True
        req = _Request(payload)
        self._q.put(req)
        return req

    def score(self, payload: Any, timeout: Optional[float] = None):
        """Blocking convenience: submit + result."""
        return self.submit(payload).result(timeout)

    # ---------------------------------------------------------- worker --
    def _collect(self, first: _Request) -> list[_Request]:
        """One batch: the first request plus everything that arrives before
        its ``max_wait_ms`` deadline, capped at the largest bucket.  Queued
        backlog is taken without waiting — the deadline only ever delays a
        PARTIAL batch."""
        reqs = [first]
        deadline = first.t_submit + self.max_wait_ms * 1e-3
        while len(reqs) < self.buckets[-1]:
            try:
                reqs.append(self._q.get_nowait())
                continue
            except queue.Empty:
                pass
            remaining = deadline - time.perf_counter()
            if remaining <= 0 or self._stop.is_set():
                break
            try:
                reqs.append(self._q.get(timeout=min(remaining, 0.05)))
            except queue.Empty:
                continue
        return reqs

    def _run_batch(self, reqs: list[_Request]) -> None:
        n = len(reqs)
        bucket = bucket_for(n, self.buckets)
        with telemetry.span(
            "serve/batch", cat="serve", bucket=bucket, n=n, queue_depth=self._q.qsize()
        ):
            batch = self.pad_batch([r.payload for r in reqs], bucket)
            scores = np.asarray(self.score_fns[bucket](batch))[:n]
        t_done = time.perf_counter()
        hist = self.hist[bucket]
        for r, s in zip(reqs, scores):
            r._resolve(score=s)
            hist.record((t_done - r.t_submit) * 1e3)
        self.batches[bucket] += 1
        self.requests += n
        self.padded += bucket - n

    def _work(self) -> None:
        reqs: list[_Request] = []
        try:
            while not self._stop.is_set():
                try:
                    first = self._q.get(timeout=0.05)
                except queue.Empty:
                    continue
                reqs = self._collect(first)
                self._run_batch(reqs)
                reqs = []
        except BaseException as e:  # noqa: BLE001 — poison, don't hang
            # the ThreadedIterator poison idiom, future-shaped: mark the
            # server sticky-dead and deliver the error to every request in
            # hand, queued, or submitted later — callers FAIL, never hang
            self._dead = e
            for r in reqs:
                r._resolve(error=e)
            self._fail_queued(e)

    def _fail_queued(self, exc: Optional[BaseException]) -> None:
        while True:
            try:
                r = self._q.get_nowait()
            except queue.Empty:
                return
            if exc is not None:
                r._resolve(error=exc)
            else:
                r._resolve(error=ServerClosed("server closed before scoring"))

    # ----------------------------------------------------------- stats --
    def percentiles(self) -> dict:
        """Per-bucket ``{p50_ms, p99_ms, mean_ms, n}`` (buckets with no
        traffic are omitted, matching the histogram's empty contract)."""
        out = {}
        for b, h in self.hist.items():
            s = h.summary()
            if s:
                out[b] = {"p50_ms": s["p50"], "p99_ms": s["p99"], "mean_ms": s["mean"], "n": s["n"]}
        return out

    def stats(self) -> dict:
        """Heartbeat-shaped summary: queue depth, totals, per-bucket
        batch counts and latency percentiles."""
        return {
            "queue_depth": self._q.qsize(),
            "requests": self.requests,
            "padded": self.padded,
            "batches": dict(self.batches),
            "buckets": self.percentiles(),
        }

    # ----------------------------------------------------------- close --
    def close(self) -> None:
        """Stop the worker, fail anything still queued (ServerClosed), and
        join.  Idempotent."""
        self._stop.set()
        if self._started:
            self._thread.join(timeout=5.0)
        self._fail_queued(self._dead)

    def __enter__(self) -> "ContinuousBatchingServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BatchingServer:
    """Synchronous pad-and-drain serving loop (single compiled shape).

    ``drain()`` processes the queue in compiled-batch chunks.  Partial
    batches honor ``max_wait_ms``: they wait until the oldest queued
    request has aged that long before padding-and-flushing, so requests
    submitted concurrently (another thread) can still join the chunk.
    """

    def __init__(
        self,
        score_fn: Callable[[dict], np.ndarray],
        batch_size: int,
        pad_batch: Callable[[list], dict],
        max_wait_ms: float = 2.0,
    ):
        self.score_fn = score_fn
        self.batch_size = batch_size
        self.pad_batch = pad_batch
        self.max_wait_ms = max_wait_ms
        self.queue: deque = deque()
        # 1us..100s in ms units, 2% relative quantile error
        self.latency = telemetry.LatencyHistogram(lo=1e-3, hi=1e5, growth=1.02)

    def submit(self, request: Any):
        self.queue.append((time.perf_counter(), request))

    def _await_deadline(self) -> None:
        """Block until the queue fills a whole batch or the OLDEST queued
        request reaches its ``max_wait_ms`` deadline (the dead-parameter
        fix: a sub-batch-size queue is no longer flushed immediately)."""
        deadline = self.queue[0][0] + self.max_wait_ms * 1e-3
        while len(self.queue) < self.batch_size:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                return
            time.sleep(min(remaining, 0.0005))

    def drain(self):
        """Process the queue in compiled-batch chunks."""
        while self.queue:
            if len(self.queue) < self.batch_size:
                self._await_deadline()
            n = min(self.batch_size, len(self.queue))
            items = [self.queue.popleft() for _ in range(n)]
            t_in = [t for t, _ in items]
            reqs = [r for _, r in items]
            with telemetry.span("serve/batch", cat="serve", n=n):
                batch = self.pad_batch(reqs)
                scores = np.asarray(self.score_fn(batch))[:n]
            t_done = time.perf_counter()
            for t in t_in:
                self.latency.record((t_done - t) * 1e3)
            yield reqs, scores

    def percentiles(self) -> dict:
        """{p50_ms, p99_ms, mean_ms, n} (empty before any request) — the
        historical key contract, served from the bounded histogram."""
        s = self.latency.summary()
        if not s:
            return {}
        return {"p50_ms": s["p50"], "p99_ms": s["p99"], "mean_ms": s["mean"], "n": s["n"]}
