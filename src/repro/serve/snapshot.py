"""Read-only serving snapshots of the training state.

The Split-SGD store already keeps a bf16 hi-half of every embedding row —
that slab IS a read-optimized serving table at zero conversion cost (half
the bytes of an fp32 table).  A :class:`ServingSnapshot` captures exactly
the slabs the forward pass reads:

* ``emb_w``   — ``opt.fwd_weights(state["emb"])``: the bf16 ``hi`` slab for
  split optimizers, the fp32 ``w`` slab otherwise.  Never the ``lo`` half,
  never ``mom``/``acc``/``cnt`` optimizer state.
* ``dense_hi`` — the bf16 dense parameters.
* ``hot_w`` / ``hot_pos`` — the replicated hot-row cache slab, when the
  model def enables it (``hot_rows > 0``); it rides along so a serving
  tier can answer hot-row reads without touching the sharded cold store.

JAX arrays are immutable, but the train step DONATES its input state
buffers — so a snapshot taken mid-training must own copies of its slabs
(``snapshot_state(..., copy=True)``, what the publisher does), while a
post-training snapshot can hold zero-cost references.  Either way a
published snapshot keeps scoring the weights it captured while training
moves on.

Determinism contract (pinned in tests/test_serve.py): scoring through
:func:`make_snapshot_score_step` is BITWISE identical to
``repro.core.hybrid.make_score_step`` on the same weights — both run the
same ``index_exchange``/``embedding_fwd`` stages and the same dense
scorer; the snapshot path merely enters at the post-``fwd_weights`` slab.

:class:`SnapshotRegistry` is the versioned publish/retire surface between
one training loop and any number of serving threads: ``publish`` assigns
monotonically increasing versions and auto-retires all but the newest
``keep`` snapshots; ``current()`` is what a server reads per batch.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import hybrid, pipeline
from repro.optim import row as row_optim


def snapshot_state(mdef, state: dict, *, copy: bool = False) -> dict:
    """The forward-only view of a train state: ``{emb_w, dense_hi}`` plus
    ``{hot_w, hot_pos}`` when the hot-row cache is enabled.  Never any
    optimizer-state slab.

    ``copy=False`` returns references — right for scoring a state that
    will not train further.  ``copy=True`` materializes owned buffers:
    REQUIRED when training continues, because the train step DONATES the
    previous state's buffers to XLA and a by-reference snapshot would be
    deleted out from under the server two steps later
    (:class:`repro.serve.publish.SnapshotPublisher` always copies)."""
    opt = row_optim.resolve(mdef)
    snap = {"emb_w": opt.fwd_weights(state["emb"]), "dense_hi": state["dense"]["hi"]}
    if getattr(mdef, "hot_rows", 0) > 0:
        snap["hot_w"] = state["cache"]["hot_w"]
        snap["hot_pos"] = state["cache"]["hot_pos"]
    if copy:
        snap = jax.tree.map(jnp.copy, snap)
    return snap


def snapshot_specs(mdef, mesh) -> dict:
    """PartitionSpecs of the snapshot pytree (the embedding slab keeps the
    store's row sharding; everything else is replicated)."""
    emb_ax, _ = pipeline.emb_axes(mdef, mesh)
    specs: dict = {"emb_w": P(emb_ax, None), "dense_hi": None}
    structs, _, _, _ = hybrid.state_struct(mdef, mesh)
    specs["dense_hi"] = jax.tree.map(lambda _: P(), structs["dense"]["hi"])
    if getattr(mdef, "hot_rows", 0) > 0:
        specs["hot_w"] = P()
        specs["hot_pos"] = P()
    return specs


def _tree_bytes(tree) -> int:
    return int(sum(np.dtype(leaf.dtype).itemsize * leaf.size for leaf in jax.tree.leaves(tree)))


@dataclasses.dataclass(frozen=True)
class ServingSnapshot:
    """One immutable published version of the serving tables."""

    version: int
    step: int
    published_t: float  # wall time of publish (time.time())
    state: dict  # {emb_w, dense_hi[, hot_w, hot_pos]} — jax arrays

    @property
    def emb_bytes(self) -> int:
        """Bytes of the serving embedding table as stored (bf16 hi slab for
        split optimizers: half the fp32 table)."""
        return _tree_bytes(self.state["emb_w"])

    @property
    def fp32_emb_bytes(self) -> int:
        """Bytes the same table would cost at fp32 (the comparison point
        for the bf16-hi serving-bytes claim)."""
        return int(self.state["emb_w"].size) * 4

    @property
    def total_bytes(self) -> int:
        return _tree_bytes(self.state)

    def seconds_behind(self, now: Optional[float] = None) -> float:
        return (time.time() if now is None else now) - self.published_t


def snapshot_from_state(
    mdef, state: dict, *, version: int = 1, step: int = 0, now: Optional[float] = None
) -> ServingSnapshot:
    """Build an immutable snapshot straight from a train state."""
    return ServingSnapshot(
        version=version,
        step=step,
        published_t=time.time() if now is None else now,
        state=snapshot_state(mdef, state),
    )


class SnapshotRegistry:
    """Versioned publish/retire store between ONE publisher and many
    serving readers.  Thread-safe; ``publish`` assigns monotonically
    increasing versions and auto-retires all but the newest ``keep``."""

    def __init__(self, keep: int = 2):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.keep = keep
        self._lock = threading.Lock()
        self._snaps: dict[int, ServingSnapshot] = {}
        self._next_version = 1

    def publish(self, snap_state: dict, *, step: int = 0) -> ServingSnapshot:
        """Publish a snapshot-state pytree (:func:`snapshot_state`) as the
        next version; snapshots beyond ``keep`` are retired."""
        with self._lock:
            snap = ServingSnapshot(
                version=self._next_version,
                step=step,
                published_t=time.time(),
                state=snap_state,
            )
            self._next_version += 1
            self._snaps[snap.version] = snap
            for v in sorted(self._snaps)[: -self.keep]:
                del self._snaps[v]
            return snap

    def current(self) -> Optional[ServingSnapshot]:
        """Newest published snapshot (None before the first publish)."""
        with self._lock:
            if not self._snaps:
                return None
            return self._snaps[max(self._snaps)]

    def get(self, version: int) -> Optional[ServingSnapshot]:
        with self._lock:
            return self._snaps.get(version)

    def retire(self, version: int) -> bool:
        """Drop one version (readers holding the object keep it alive —
        retirement only stops new lookups).  Returns whether it existed."""
        with self._lock:
            return self._snaps.pop(version, None) is not None

    def versions(self) -> list[int]:
        with self._lock:
            return sorted(self._snaps)


def make_snapshot_score_step(
    mdef, mesh, batch: Optional[int] = None, *, donate_batch: bool = True
):
    """Forward-only scoring from a snapshot-state pytree.

    Same stage composition as ``hybrid.make_score_step`` —
    ``index_exchange(fwd_only=True)`` then ``embedding_fwd`` then
    ``mdef.dense_score`` — entered at the post-``fwd_weights`` slab, so the
    scores are bitwise identical to the full-state path on the same
    weights.  The BATCH argument is donated by default (each serving batch
    is scored once; XLA may reuse its buffers for the outputs) — the
    snapshot argument never is, so one snapshot serves many batches.

    Returns ``(fn, snap_shardings, bstructs, bspecs)``; call as
    ``scores = fn(snapshot.state, batch)``.
    """
    layout = hybrid.make_layout(mdef, mesh)
    bstructs, bspecs = hybrid.batch_struct(mdef, mesh, layout, batch, include_presort=False)
    all_axes, _, _ = pipeline.mesh_axes(mesh)
    stages = pipeline.build_stages(mdef, mesh, layout)
    specs = snapshot_specs(mdef, mesh)

    def score_local(snap, batch_d):
        idx_fwd, _ = stages.index_exchange(batch_d["idx"], fwd_only=True)
        wgt_fwd = None
        if mdef.weighted:
            wgt_fwd, _ = stages.index_exchange(batch_d["weights"], fwd_only=True)
        emb_out = stages.embedding_fwd(snap["emb_w"], idx_fwd, wgt_fwd)
        return mdef.dense_score(snap["dense_hi"], emb_out, batch_d)

    sc = compat.shard_map(
        score_local, mesh=mesh, in_specs=(specs, bspecs), out_specs=P(all_axes), check_vma=False
    )
    fn = jax.jit(sc, donate_argnums=(1,) if donate_batch else ())
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    return fn, shardings, bstructs, bspecs


def make_bucket_scorers(
    mdef,
    mesh,
    buckets: tuple[int, ...],
    source: Callable[[], Any],
    *,
    donate_batch: bool = True,
):
    """Per-bucket compiled score fns over a snapshot source.

    ``source`` returns the snapshot-state pytree to score against (e.g.
    ``lambda: registry.current().state`` — read per batch, so a publish
    between batches is picked up immediately).  Returns ``(score_fns,
    pad_batch)`` in the shape :class:`repro.serve.server
    .ContinuousBatchingServer` consumes: ``score_fns[bucket](batch)`` and
    ``pad_batch(payloads, bucket)`` (zero-padded to the bucket's compiled
    shape, dtypes from the batch struct)."""
    steps = {}
    structs_by = {}
    for b in sorted(buckets):
        fn, _, bstructs, _ = make_snapshot_score_step(mdef, mesh, batch=b, donate_batch=donate_batch)
        steps[b] = fn
        structs_by[b] = bstructs

    def _score(bucket):
        def run(batch):
            return steps[bucket](source(), batch)

        return run

    def pad_batch(payloads: list, bucket: int) -> dict:
        import jax.numpy as jnp

        structs = structs_by[bucket]
        out = {}
        for k, sds in structs.items():
            np_dtype = np.float32 if sds.dtype == jnp.bfloat16 else np.dtype(sds.dtype)
            base = np.zeros(sds.shape, np_dtype)
            for i, p in enumerate(payloads):
                base[i] = np.asarray(p[k])
            out[k] = jnp.asarray(base, sds.dtype)
        return out

    return {b: _score(b) for b in sorted(buckets)}, pad_batch
