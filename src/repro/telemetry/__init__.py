"""Unified telemetry: host-side tracing, in-graph step metrics, latency
histograms, per-stage pipeline attribution (docs/telemetry.md).

This package top level is STDLIB-ONLY (tracer + histogram) so the hot
integration points — the loader worker, the checkpoint writer, the
failure log — can import it without pulling jax.  The jax-adjacent
pieces stay behind their submodules and import lazily:

* :mod:`repro.telemetry.metrics` — the replicated in-graph metrics
  vector threaded through the pipelined train step;
* :mod:`repro.telemetry.stages` — per-stage profiler for the pipeline's
  Stage objects (spans + modeled bytes/flops);
* :mod:`repro.telemetry.summarize` — offline trace analysis, also the
  ``python -m repro.telemetry summarize`` CLI.
"""

from repro.telemetry.hist import LatencyHistogram
from repro.telemetry.tracer import (
    Tracer,
    configure,
    counter,
    export,
    get_tracer,
    instant,
    set_track,
    span,
)

__all__ = [
    "LatencyHistogram",
    "Tracer",
    "configure",
    "counter",
    "export",
    "get_tracer",
    "instant",
    "set_track",
    "span",
]
