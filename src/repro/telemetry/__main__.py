"""CLI entry: ``python -m repro.telemetry summarize <trace.json>``."""

import sys

from repro.telemetry.summarize import main

if __name__ == "__main__":
    sys.exit(main())
