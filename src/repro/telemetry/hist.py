"""Bounded-memory latency histogram with quantile readout.

The serve loop's p50/p99 report used to keep every latency sample in an
unbounded Python list — fine for a bench, wrong for a server meant to
stay up under heavy traffic.  :class:`LatencyHistogram` keeps
log-spaced buckets instead: O(1) record, O(buckets) quantile, memory
fixed regardless of request count, relative quantile error bounded by
the bucket growth factor (2% by default).

Units are caller-defined (the serve loop records milliseconds); the
histogram only assumes positive values.  Thread-safe: ``record`` may be
called from multiple serving threads.
"""

from __future__ import annotations

import math
import threading


class LatencyHistogram:
    """Log-bucketed histogram over ``[lo, hi)`` with ``growth``-factor
    bucket widths.  Values below ``lo`` land in the first bucket, above
    ``hi`` in the last (and are still exact in min/max/mean)."""

    def __init__(self, lo: float = 1e-3, hi: float = 1e5, growth: float = 1.02):
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError(f"need 0 < lo < hi and growth > 1, got {lo}, {hi}, {growth}")
        self.lo = lo
        self.growth = growth
        self._log_lo = math.log(lo)
        self._log_g = math.log(growth)
        self.nbuckets = int(math.ceil((math.log(hi) - self._log_lo) / self._log_g)) + 1
        self.counts = [0] * self.nbuckets
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._lock = threading.Lock()

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        b = int((math.log(v) - self._log_lo) / self._log_g)
        return min(b, self.nbuckets - 1)

    def record(self, v: float) -> None:
        b = self._bucket(v)
        with self._lock:
            self.counts[b] += 1
            self.n += 1
            self.total += v
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (geometric bucket midpoint; clamped to
        the exact observed min/max so q=0/1 are honest)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self.n == 0:
                return 0.0
            # rank of the q-quantile under the 'lower' convention
            rank = min(self.n - 1, int(q * self.n))
            seen = 0
            for b, c in enumerate(self.counts):
                seen += c
                if seen > rank:
                    mid = math.exp(self._log_lo + (b + 0.5) * self._log_g)
                    return min(max(mid, self.vmin), self.vmax)
            return self.vmax

    def summary(self) -> dict:
        """{p50, p99, mean, min, max, n} — empty dict when no samples
        (matches the serve loop's historical contract)."""
        with self._lock:
            n, total = self.n, self.total
            vmin, vmax = self.vmin, self.vmax
        if n == 0:
            return {}
        return {
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
            "mean": total / n,
            "min": vmin,
            "max": vmax,
            "n": n,
        }
