"""In-graph step metrics: a replicated float32 vector in the train state.

The pipelined train step (repro/core/pipeline.py) can answer "how many
bags did the cache absorb, how many rows did the update touch, how many
bytes rode the layout-switch collective" — but reading those numbers out
per step would add a host sync to the hot path.  Instead the step
ACCUMULATES them on device into a small replicated ``state["metrics"]``
vector (the same compute-always discipline the hot-row cache epilogue
uses: no data-dependent control flow, no extra host round-trips), and
the host drains the cumulative vector every ``metrics_every`` steps —
one small device->host copy per window, zero extra syncs between.

Slots (cumulative since init; all float32, integer-valued except bytes):

====================  ======================================================
``steps``             steps accumulated (the window normalizer)
``hit_lookups``       lookups served from the hot-row slab (cache bypass)
``skipped_bags``      bags served entirely from the slab — the bags that
                      shipped NO all-to-all payload
``bags``              total bags (batch rows x slots)
``rows_touched``      valid row reads by the embedding forward (lookups
                      with an in-range index; duplicates included — this
                      is row TRAFFIC, not unique-row count)
``exchange_payload_bytes``  effective fwd layout-switch payload:
                      ``(bags - skipped_bags) * E * 4``
====================  ======================================================

Contract: the vector is **bitwise invisible** to training.  Metric
contributions only READ the index stream and the cache hit mask and
WRITE the separate metrics slot; with ``step_metrics=False`` (the
default) the state has no ``metrics`` entry and the lowered step is
bit-identical to a build without this module.  ``hit_rate(drained) ==
skipped_bags / bags`` reproduces the cache bench's ``jnp.mean(hit)``
exactly (both are an exact small-integer f32 sum followed by one f32
divide).
"""

from __future__ import annotations

import numpy as np

METRIC_NAMES = (
    "steps",
    "hit_lookups",
    "skipped_bags",
    "bags",
    "rows_touched",
    "exchange_payload_bytes",
)
NUM_METRICS = len(METRIC_NAMES)


def metrics_struct():
    import jax
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct((NUM_METRICS,), jnp.float32)


def init_metrics():
    import jax.numpy as jnp

    return jnp.zeros((NUM_METRICS,), jnp.float32)


def pack(**slots):
    """Metrics vector from named slot values (unnamed slots are 0)."""
    import jax.numpy as jnp

    vals = [slots.pop(name, 0.0) for name in METRIC_NAMES]
    if slots:
        raise ValueError(f"unknown metric slots {sorted(slots)}; have {METRIC_NAMES}")
    return jnp.stack([jnp.asarray(v, jnp.float32) for v in vals])


# ---------------------------------------------------------------------------
# In-graph counting helpers (called inside shard_map by the step)
# ---------------------------------------------------------------------------


def valid_lookups(layout, idx):
    """f32 count of in-range lookups in an ORIGINAL-SLOT index block
    [..., S, P] — each valid lookup reads exactly one embedding row, so
    this is the step's row traffic (duplicates included)."""
    import jax.numpy as jnp

    spec = layout.spec
    rows_per_slot = np.asarray(spec.table_rows, np.int32)[np.asarray(layout.slot_to_table)]
    cap = jnp.asarray(rows_per_slot)[None, :, None]
    ok = (idx >= 0) & (idx < cap)
    return jnp.sum(ok, dtype=jnp.float32)


def valid_lookups_padded(layout, idx_local, model_axis):
    """f32 count of in-range lookups in THIS model shard's PADDED-SLOT
    index block [b, slots_per_shard, P] (the paper-loader layout: slots
    pre-sharded over the model axis, dummy pad slots carry -1)."""
    import jax
    import jax.numpy as jnp

    spec = layout.spec
    ps = np.asarray(layout.padded_slots)
    s2t = np.asarray(layout.slot_to_table)
    rows_pad = np.where(
        ps >= 0,
        np.asarray(spec.table_rows, np.int64)[s2t[np.clip(ps, 0, None)]],
        0,
    ).astype(np.int32)
    K = layout.slots_per_shard
    m = jax.lax.axis_index(model_axis)
    cap = jax.lax.dynamic_slice_in_dim(jnp.asarray(rows_pad), m * K, K)
    ok = (idx_local >= 0) & (idx_local < cap[None, :, None])
    return jnp.sum(ok, dtype=jnp.float32)


def cache_hit_counts(layout, hot_pos, idx):
    """(hit_lookups, hit_bags) f32 for one local index block [b, S, P],
    mirroring :func:`repro.core.cache.hot_bag_local`'s hit definition: a
    lookup hits when its spec-global row is in the hot set; a bag counts
    as skipped only when ALL P of its lookups hit."""
    import jax.numpy as jnp

    spec = layout.spec
    off = jnp.asarray(spec.row_offsets[layout.slot_to_table], jnp.int32)
    gid = idx + off[None, :, None]
    ok = (gid >= 0) & (gid < spec.total_rows)
    pos = jnp.take(hot_pos, jnp.clip(gid, 0, spec.total_rows - 1))
    lk_hit = ok & (pos >= 0)
    return (
        jnp.sum(lk_hit, dtype=jnp.float32),
        jnp.sum(jnp.all(lk_hit, axis=2), dtype=jnp.float32),
    )


# ---------------------------------------------------------------------------
# Host-side drain
# ---------------------------------------------------------------------------


def drain(state) -> dict | None:
    """Cumulative metrics as a name->float dict (one device->host copy);
    None when the state carries no metrics vector."""
    m = state.get("metrics") if isinstance(state, dict) else None
    if m is None:
        return None
    vals = np.asarray(m, np.float32)
    return {name: float(vals[i]) for i, name in enumerate(METRIC_NAMES)}


def window(cur: dict, prev: dict | None) -> dict:
    """Per-window deltas between two drains (prev=None means since init)."""
    if prev is None:
        return dict(cur)
    return {k: cur[k] - prev.get(k, 0.0) for k in cur}


def hit_rate(m: dict) -> float:
    """skipped_bags / bags in float32, mirroring the cache bench's
    ``jnp.mean(hit)`` (f32 sum of bools, one f32 division).  The two agree
    bit-for-bit whenever ``bags`` is a power of two — the bench windows
    are (batch 64 x 8 slots = 512) — because a power-of-two divide and
    XLA mean's multiply-by-reciprocal are both exact there; for other bag
    counts they can differ by one ulp."""
    bags = np.float32(m.get("bags", 0.0))
    if bags == 0:
        return 0.0
    return float(np.float32(m.get("skipped_bags", 0.0)) / bags)


def emit(tracer, m: dict, name: str = "repro.metrics") -> None:
    """Record a drained metrics dict as a counter event on the trace
    (``summarize`` reads these back; cumulative values, one per drain)."""
    tracer.counter(name, m)
