"""Per-stage attribution for the staged pipeline (spans + modeled costs).

The pipelined step compiles all six stages into ONE XLA program — great
for overlap, useless for attribution: a wall clock around the jitted call
says nothing about where the step spent its time and bytes.  This module
profiles the :class:`repro.core.pipeline.Stage` objects INDIVIDUALLY:
each stage is wrapped in its own ``jit(shard_map(...))``, dispatched in
sequence on real data, and timed with host spans on a dedicated
``pipeline_stages`` trace track.  Two timing modes:

* ``barrier=True`` (default) — ``jax.block_until_ready`` between stages,
  so each span is honest device time for that stage alone;
* ``barrier=False`` — dispatch-only spans (what the host pays to issue
  the work; useful for spotting host-side serialization).

Because the per-stage programs break the fused schedule, the measured
numbers are an attribution PROFILE, not the end-to-end step time — the
fused step is faster than the sum of stages by exactly the overlap the
pipeline buys.  Each span also carries the stage's MODELED bytes/flops
on the target chip at the target scale (``ranks_model``), from the same
analytic formulas the comm-model bench uses — so a trace viewed in
Perfetto shows both what the local run measured and what the paper-scale
system would move.

On a single-device mesh the collectives inside the stages are no-ops;
the modeled bytes are then the ONLY cross-rank cost signal.  That is the
intended reading: measure compute locally, model communication.
"""

from __future__ import annotations

import time

import numpy as np

from repro import hw


def _median_ms(durs: list) -> float:
    return float(np.median(np.asarray(durs))) * 1e3


def modeled_stage_costs(mdef, layout=None, ranks: int = 64,
                        chip: hw.ChipSpec = hw.TPU_V5E) -> dict:
    """Analytic per-rank bytes/flops per stage at ``ranks`` sockets.

    Volumes mirror the paper's cost model: the index streams are int32,
    bag rows fp32, dense params bf16.  ``bytes`` is what THIS rank moves
    (fabric for comm stages, HBM for local stages); ``modeled_us`` is the
    max of the bandwidth and compute terms on ``chip``.
    """
    import jax

    B, Pq, E = mdef.batch, mdef.pooling, mdef.spec.dim
    S = layout.num_orig_slots if layout is not None else mdef.spec.num_tables
    n_dense = _dense_param_count(mdef)
    r = max(int(ranks), 1)
    shrink = (r - 1) / r            # the self-shard never crosses the fabric
    idx_bytes = B * S * Pq * 4      # global int32 index stream
    bag_bytes = B * S * E * 4       # global fp32 bag activations
    row_bytes = B * S * Pq * E * 4  # row reads (duplicates included)
    costs = {
        "index_exchange": dict(
            bytes=idx_bytes * shrink, flops=0.0, comm="all_gather(idx)"),
        "embedding_fwd": dict(
            bytes=row_bytes / r + bag_bytes / r * shrink,
            flops=2.0 * B * S * Pq * E / r, comm="all_to_all"),
        "dense_fwd_bwd": dict(
            bytes=3.0 * n_dense * 2, flops=6.0 * n_dense * B / r,
            comm="none"),
        "dY_exchange": dict(
            bytes=bag_bytes / r * shrink, flops=0.0, comm="all_to_all(dY)"),
        "sparse_update": dict(
            bytes=2.0 * row_bytes / r, flops=2.0 * B * S * Pq * E / r,
            comm="none"),
        "dense_update": dict(
            bytes=(4.0 + 2.0) * n_dense * shrink, flops=2.0 * n_dense / r,
            comm="rs+ag"),
    }
    for c in costs.values():
        bw = chip.ici_bw_per_link * chip.ici_links if c["comm"] != "none" \
            else chip.hbm_bw
        c["modeled_us"] = max(c["bytes"] / bw,
                              c["flops"] / chip.peak_flops_bf16) * 1e6
    return costs


def _dense_param_count(mdef) -> int:
    import jax

    from repro.optim import data_parallel as dp

    tree = jax.eval_shape(lambda: mdef.init_dense(jax.random.PRNGKey(0)))
    return dp.ravel_size(tree)


def profile_stages(mdef, mesh=None, *, steps: int = 3, warmup: int = 1,
                   barrier: bool = True, tracer=None, ranks_model: int = 64,
                   chip: hw.ChipSpec = hw.TPU_V5E, seed: int = 0) -> dict:
    """Run each pipeline stage as its own jitted program and time it.

    Returns ``{"stages": {name: {"ms", "bytes", "flops", "modeled_us",
    "comm"}}, ...}`` and (when ``tracer`` is enabled) emits one span per
    timed dispatch on the ``pipeline_stages`` track, modeled costs in the
    span args.  ``mesh`` defaults to a (1, 1) data/model mesh — the
    profile needs no multi-device setup; collectives no-op and the
    modeled columns carry the cross-rank story (see module docstring).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import hybrid, pipeline
    from repro.data.pipeline import PSORT_KEYS
    from repro.optim import row as row_optim

    if tracer is None:
        from repro.telemetry import tracer as tr_mod
        tracer = tr_mod.get_tracer()
    if mesh is None:
        mesh = compat.make_mesh((1, 1), ("data", "model"))
    pipeline.validate_pipeline(mdef, mesh, 1)
    state, layout = hybrid.init_state(jax.random.PRNGKey(seed), mdef, mesh)
    bstructs, _ = hybrid.batch_struct(mdef, mesh, layout)
    batch = synthetic_batch(mdef, bstructs, seed)
    stages = pipeline.build_stages(mdef, mesh, layout)
    opt = row_optim.resolve(mdef)
    costs = modeled_stage_costs(mdef, layout, ranks=ranks_model, chip=chip)

    def sm(fn, n_in):
        # per-stage program: replicated specs are trivially correct on the
        # single-device profile mesh (P() is a valid pytree prefix for
        # dict/tuple arguments)
        return jax.jit(compat.shard_map(fn, mesh=mesh,
                                        in_specs=(P(),) * n_in,
                                        out_specs=P(), check_vma=False))

    result = {}

    def timed(name, fn, *args):
        out = fn(*args)                     # compile
        out = jax.block_until_ready(out)
        for _ in range(max(warmup - 1, 0)):
            out = jax.block_until_ready(fn(*args))
        durs = []
        c = costs[name]
        for _ in range(max(steps, 1)):
            t0 = time.perf_counter()
            with tracer.span(f"stage/{name}", cat="pipeline",
                             track="pipeline_stages", comm=c["comm"],
                             modeled_bytes=c["bytes"],
                             modeled_flops=c["flops"],
                             modeled_us=c["modeled_us"],
                             ranks_model=ranks_model, chip=chip.name):
                out = fn(*args)
                if barrier:
                    out = jax.block_until_ready(out)
            durs.append(time.perf_counter() - t0)
        result[name] = {"ms": _median_ms(durs), "bytes": c["bytes"],
                        "flops": c["flops"], "modeled_us": c["modeled_us"],
                        "comm": c["comm"]}
        return out

    weighted = bool(getattr(mdef, "weighted", False))
    fwd_w = jax.jit(compat.shard_map(opt.fwd_weights, mesh=mesh,
                                     in_specs=(P(),), out_specs=P(),
                                     check_vma=False))(state["emb"])
    idx_fwd, idx_upd = timed("index_exchange",
                             sm(lambda i: stages.index_exchange(i), 1),
                             batch["idx"])
    wgt_fwd = wgt_upd = None
    if weighted:
        wgt_fwd, wgt_upd = sm(lambda w: stages.index_exchange(w), 1)(
            batch["weights"])
    emb_out = timed(
        "embedding_fwd",
        sm(lambda W, i: stages.embedding_fwd(W, i, wgt_fwd), 2),
        fwd_w, idx_fwd)
    mb = {k: v for k, v in batch.items() if k not in PSORT_KEYS}
    loss, g_dense, d_emb = timed("dense_fwd_bwd",
                                 sm(stages.dense_fwd_bwd, 3),
                                 state["dense"]["hi"], emb_out, mb)
    dY = timed("dY_exchange", sm(stages.dY_exchange, 1), d_emb)
    sr = state.get("sr")
    if sr is not None:
        sp_fn = sm(lambda e, i, d, s: stages.sparse_update(
            e, i, d, weights=wgt_upd, seed=s), 4)
        timed("sparse_update", sp_fn, state["emb"], idx_upd, dY, sr)
    else:
        sp_fn = sm(lambda e, i, d: stages.sparse_update(
            e, i, d, weights=wgt_upd), 3)
        timed("sparse_update", sp_fn, state["emb"], idx_upd, dY)
    timed("dense_update", sm(stages.dense_update, 2), state["dense"],
          g_dense)
    return {
        "stages": result,
        "mesh": dict(mesh.shape),
        "barrier": barrier,
        "steps": steps,
        "ranks_model": ranks_model,
        "chip": chip.name,
        "dense_params": _dense_param_count(mdef),
    }


def synthetic_batch(mdef, bstructs: dict, seed: int = 0) -> dict:
    """Random host batch matching a ``hybrid.batch_struct`` tree: int
    fields draw valid row indices (smallest table bounds them for every
    slot), float fields draw uniform [0, 1)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    rows_cap = int(min(mdef.spec.table_rows))
    out = {}
    for name, s in bstructs.items():
        if np.issubdtype(np.dtype(s.dtype), np.integer):
            out[name] = jnp.asarray(
                rng.integers(0, rows_cap, size=s.shape, dtype=np.int64),
                s.dtype)
        else:
            out[name] = jnp.asarray(
                rng.random(size=s.shape, dtype=np.float64), s.dtype)
    return out
