"""Offline trace analysis: per-track/per-span time+bytes table.

``python -m repro.telemetry summarize <trace.json>`` reads a trace
exported by :mod:`repro.telemetry.tracer` and prints, per track, every
span name with its count, total/mean wall time, and (for the pipeline
stage spans) the modeled bytes/flops the spans carry in their args.  The
in-graph metrics counter samples ("repro.metrics") are folded into a
metrics section: cumulative totals, the last drain window, and the
derived cache hit rate — computed with the exact float32 arithmetic of
the cache bench, so the summarized ``hit_rate`` reproduces
``BENCH_pipeline.json["cache"]`` bit-for-bit on the same step window
(see repro/telemetry/metrics.py).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.telemetry import metrics as _metrics

METRICS_COUNTER = "repro.metrics"


def load_events(path) -> list[dict]:
    doc = json.loads(Path(path).read_text())
    if isinstance(doc, dict):
        return doc.get("traceEvents", [])
    return doc  # bare-array form is also valid Chrome trace JSON


def summarize(path) -> dict:
    """Aggregate a trace file into ``{"tracks", "metrics", "instants",
    "serve"}``.

    tracks:   track name -> span name -> {count, total_ms, mean_ms,
              modeled_bytes, modeled_flops} (byte/flop columns only when
              the spans carried them)
    metrics:  {"cumulative", "last_window", "hit_rate",
               "last_window_hit_rate", "drains"} from the
              ``repro.metrics`` counter samples (empty when none)
    instants: event name -> count (failure-log events etc.)
    serve:    aggregate over ``serve/*`` spans — total batches/requests/
              time plus a per-bucket breakdown of the serve/batch spans
              (empty when the trace has no serving traffic)
    """
    events = load_events(path)
    track_of: dict[int, str] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            track_of[ev["tid"]] = ev.get("args", {}).get("name", str(ev["tid"]))

    tracks: dict[str, dict] = {}
    instants: dict[str, int] = {}
    drains: list[dict] = []
    serve: dict = {}
    for ev in events:
        ph = ev.get("ph")
        track = track_of.get(ev.get("tid"), str(ev.get("tid")))
        if ph == "X":
            row = tracks.setdefault(track, {}).setdefault(
                ev["name"], {"count": 0, "total_ms": 0.0})
            row["count"] += 1
            row["total_ms"] += ev.get("dur", 0.0) / 1e3
            args = ev.get("args", {})
            for k in ("modeled_bytes", "modeled_flops", "modeled_us"):
                if k in args:
                    row[k] = float(args[k])   # per-dispatch model, not summed
            if ev["name"].startswith("serve/"):
                _fold_serve(serve, ev["name"], ev.get("dur", 0.0) / 1e3, args)
        elif ph == "i":
            instants[ev["name"]] = instants.get(ev["name"], 0) + 1
        elif ph == "C" and ev.get("name") == METRICS_COUNTER:
            drains.append((ev.get("ts", 0.0), ev.get("args", {})))
    for spans in tracks.values():
        for row in spans.values():
            row["mean_ms"] = row["total_ms"] / row["count"]

    drains.sort(key=lambda t: t[0])
    samples = [d for _, d in drains]
    metrics: dict = {}
    if samples:
        cum = samples[-1]
        win = _metrics.window(cum, samples[-2] if len(samples) > 1 else None)
        metrics = {
            "cumulative": cum,
            "last_window": win,
            "hit_rate": _metrics.hit_rate(cum),
            "last_window_hit_rate": _metrics.hit_rate(win),
            "drains": len(samples),
        }
    for row in serve.values():
        row["mean_ms"] = row["total_ms"] / row["count"]
        for b in row.get("by_bucket", {}).values():
            b["mean_ms"] = b["total_ms"] / b["count"]
    return {"tracks": tracks, "metrics": metrics, "instants": instants,
            "serve": serve}


def _fold_serve(serve: dict, name: str, dur_ms: float, args: dict) -> None:
    """Fold one ``serve/*`` span into the serve aggregate: batch/request
    counts and wall time, split per compiled bucket when the span says
    which bucket it ran (serve/batch spans from the continuous server)."""
    row = serve.setdefault(name, {"count": 0, "total_ms": 0.0, "requests": 0})
    row["count"] += 1
    row["total_ms"] += dur_ms
    row["requests"] += int(args.get("n", 0))
    if "bucket" in args:
        b = row.setdefault("by_bucket", {}).setdefault(
            str(args["bucket"]), {"count": 0, "total_ms": 0.0, "requests": 0})
        b["count"] += 1
        b["total_ms"] += dur_ms
        b["requests"] += int(args.get("n", 0))


def _fmt_qty(v: float) -> str:
    for unit, div in (("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if abs(v) >= div:
            return f"{v / div:.2f}{unit}"
    return f"{v:.0f}"


def format_summary(s: dict) -> str:
    lines = []
    for track in sorted(s["tracks"]):
        lines.append(f"track: {track}")
        lines.append(f"  {'span':<28} {'count':>7} {'total_ms':>10} "
                     f"{'mean_ms':>9} {'bytes':>9} {'flops':>9}")
        spans = s["tracks"][track]
        for name in sorted(spans, key=lambda n: -spans[n]["total_ms"]):
            r = spans[name]
            b = _fmt_qty(r["modeled_bytes"]) if "modeled_bytes" in r else "-"
            f = _fmt_qty(r["modeled_flops"]) if "modeled_flops" in r else "-"
            lines.append(f"  {name:<28} {r['count']:>7} "
                         f"{r['total_ms']:>10.3f} {r['mean_ms']:>9.3f} "
                         f"{b:>9} {f:>9}")
    if s.get("serve"):
        lines.append("serve spans:")
        lines.append(f"  {'span / bucket':<28} {'count':>7} {'reqs':>7} "
                     f"{'total_ms':>10} {'mean_ms':>9}")
        for name in sorted(s["serve"]):
            r = s["serve"][name]
            lines.append(f"  {name:<28} {r['count']:>7} {r['requests']:>7} "
                         f"{r['total_ms']:>10.3f} {r['mean_ms']:>9.3f}")
            for bk in sorted(r.get("by_bucket", {}), key=int):
                b = r["by_bucket"][bk]
                lines.append(f"    bucket {bk:<19} {b['count']:>7} "
                             f"{b['requests']:>7} {b['total_ms']:>10.3f} "
                             f"{b['mean_ms']:>9.3f}")
    if s["instants"]:
        lines.append("instant events:")
        for name in sorted(s["instants"]):
            lines.append(f"  {name:<28} {s['instants'][name]:>7}")
    m = s["metrics"]
    if m:
        lines.append(f"in-graph metrics ({m['drains']} drains):")
        lines.append(f"  {'slot':<24} {'cumulative':>14} {'last_window':>14}")
        for k in m["cumulative"]:
            lines.append(f"  {k:<24} {m['cumulative'][k]:>14.0f} "
                         f"{m['last_window'].get(k, 0.0):>14.0f}")
        lines.append(f"  {'hit_rate':<24} {m['hit_rate']:>14.9f} "
                     f"{m['last_window_hit_rate']:>14.9f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="offline analysis of exported telemetry traces")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summarize",
                        help="per-track/per-span time+bytes table")
    ps.add_argument("trace", help="trace.json exported by the tracer")
    ps.add_argument("--json", action="store_true",
                    help="emit the aggregate as JSON instead of a table")
    args = ap.parse_args(argv)
    s = summarize(args.trace)
    if args.json:
        print(json.dumps(s, indent=2, sort_keys=True))
    else:
        print(format_summary(s))
    return 0
