"""Host-side tracer: nestable spans, instants, counters -> Chrome trace JSON.

One :class:`Tracer` collects timing events from every thread of the
process — the train loop, the ``HostPipeline`` / ``ThreadedIterator``
ingestion workers, the async checkpoint writer — and exports them as
Chrome trace-event JSON (the ``{"traceEvents": [...]}`` format), loadable
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  Each
thread gets its own track (named after the thread, overridable with
:meth:`Tracer.set_track`); spans emitted with an explicit ``track=`` land
on a named VIRTUAL track instead (used for the per-stage pipeline
profile, which runs on the main thread but reads as its own timeline).

Design constraints, in order:

1. **Near-zero cost when disabled.**  The hot path (one span per train
   step, one per loader pull) must survive being compiled in permanently.
   ``span()`` on a disabled tracer returns a shared no-op context manager
   after a single attribute check; nothing is allocated, no clock is read.
2. **Thread-safe.**  Events append to one list under a lock; spans carry
   their own start time on the stack frame (the context-manager object),
   so nesting needs no per-thread state.
3. **Stdlib only.**  This module is imported by the loader, the
   checkpoint writer and the failure log — it must not pull jax.

Timestamps are microseconds on the ``perf_counter`` clock, zeroed at
tracer construction (Chrome trace viewers only care about relative time).
The wall-clock epoch is recorded in the exported metadata for
cross-referencing heartbeat / failure-log records.
"""

from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Optional


class _NoopSpan:
    """Shared do-nothing context manager for the disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class _Span:
    """One live span: records its own start, emits a complete ('X') event
    on exit.  Created only when the tracer is enabled."""

    __slots__ = ("_tracer", "name", "cat", "args", "_tid", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, tid: int, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._tid = tid
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        ev = {
            "name": self.name,
            "ph": "X",
            "ts": (self._t0 - tr._epoch) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": tr._pid,
            "tid": self._tid,
        }
        if self.cat:
            ev["cat"] = self.cat
        if self.args:
            ev["args"] = self.args
        with tr._lock:
            tr._events.append(ev)
        return False


class Tracer:
    """Collects spans/instants/counters; exports Chrome trace JSON.

    ``enabled=False`` (the default) makes every emit call a cheap no-op;
    flip with :meth:`enable` / :meth:`disable`.  ``trace_dir`` (optional)
    is where :meth:`export` writes ``trace.json`` when called without an
    explicit path.
    """

    def __init__(self, enabled: bool = False, trace_dir: Optional[str] = None):
        self.enabled = enabled
        self.trace_dir = Path(trace_dir) if trace_dir else None
        self._epoch = time.perf_counter()
        self._epoch_unix = time.time()
        self._pid = os.getpid()
        self._lock = threading.Lock()
        self._events: list[dict] = []
        # thread ident -> track name override; virtual track name -> tid
        self._thread_tracks: dict[int, str] = {}
        self._virtual_tids: dict[str, int] = {}
        self._named_tids: set[int] = set()

    # ------------------------------------------------------------ config
    def enable(self, trace_dir: Optional[str] = None) -> "Tracer":
        if trace_dir is not None:
            self.trace_dir = Path(trace_dir)
        self.enabled = True
        return self

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop all collected events (tests / reuse across runs)."""
        with self._lock:
            self._events = []
            self._named_tids = set()
            self._virtual_tids = {}

    # ------------------------------------------------------------ tracks
    def set_track(self, name: str) -> None:
        """Name the CURRENT thread's track (overrides the thread name)."""
        if not self.enabled:
            return
        tid = threading.get_ident()
        self._thread_tracks[tid] = name
        with self._lock:
            self._named_tids.discard(tid)  # re-emit metadata with new name

    def _tid_for(self, track: Optional[str]) -> int:
        if track is not None:
            with self._lock:
                tid = self._virtual_tids.get(track)
                if tid is None:
                    # virtual tracks get small negative-range ids well away
                    # from real thread idents
                    tid = 1_000_000 + len(self._virtual_tids)
                    self._virtual_tids[track] = tid
                    self._events.append(_thread_name(self._pid, tid, track))
                    self._named_tids.add(tid)
            return tid
        tid = threading.get_ident()
        if tid not in self._named_tids:
            name = self._thread_tracks.get(tid) or threading.current_thread().name
            with self._lock:
                if tid not in self._named_tids:
                    self._events.append(_thread_name(self._pid, tid, name))
                    self._named_tids.add(tid)
        return tid

    # ------------------------------------------------------------- emits
    def span(self, name: str, cat: str = "", track: Optional[str] = None, **args):
        """Context manager timing the enclosed block.  ``args`` are
        attached to the event (visible in the Perfetto side panel);
        ``track`` places the span on a named virtual track instead of the
        calling thread's."""
        if not self.enabled:
            return _NOOP_SPAN
        return _Span(self, name, cat, self._tid_for(track), args)

    def instant(self, name: str, cat: str = "", track: Optional[str] = None, **args) -> None:
        """Zero-duration marker (failure-log events, preemptions, ...)."""
        if not self.enabled:
            return
        ev = {
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "pid": self._pid,
            "tid": self._tid_for(track),
        }
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def counter(self, name: str, values: dict, track: Optional[str] = None) -> None:
        """Counter sample: ``values`` is a dict of series -> number.  The
        drained in-graph metrics vector lands here (one event per drain,
        cumulative values; see repro/telemetry/metrics.py)."""
        if not self.enabled:
            return
        ev = {
            "name": name,
            "ph": "C",
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "pid": self._pid,
            "tid": self._tid_for(track),
            "args": {k: float(v) for k, v in values.items()},
        }
        with self._lock:
            self._events.append(ev)

    # ------------------------------------------------------------ export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def export(self, path: Optional[str] = None) -> Optional[Path]:
        """Write ``{"traceEvents": [...]}`` JSON.  ``path`` overrides the
        configured ``trace_dir/trace.json``.  Returns the written path,
        or None when there is nowhere to write."""
        if path is None:
            if self.trace_dir is None:
                return None
            self.trace_dir.mkdir(parents=True, exist_ok=True)
            path = self.trace_dir / "trace.json"
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        doc = {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"epoch_unix_s": self._epoch_unix, "pid": self._pid},
        }
        p.write_text(json.dumps(doc))
        return p


def _thread_name(pid: int, tid: int, name: str) -> dict:
    return {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid, "args": {"name": name}}


# ---------------------------------------------------------------------------
# Process-global tracer: the integration points (train loop, loader
# workers, checkpoint writer, failure log, serve loop) all emit here, so
# enabling tracing is one configure() call — no tracer threading through
# every constructor.
# ---------------------------------------------------------------------------

_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def configure(enabled: bool = True, trace_dir: Optional[str] = None) -> Tracer:
    """Enable (or disable) the process-global tracer.  With ``trace_dir``
    set, :func:`export` writes ``<trace_dir>/trace.json``."""
    if enabled:
        _GLOBAL.enable(trace_dir)
    else:
        _GLOBAL.disable()
    return _GLOBAL


def span(name: str, cat: str = "", track: Optional[str] = None, **args):
    return _GLOBAL.span(name, cat, track, **args)


def instant(name: str, cat: str = "", track: Optional[str] = None, **args) -> None:
    _GLOBAL.instant(name, cat, track, **args)


def counter(name: str, values: dict, track: Optional[str] = None) -> None:
    _GLOBAL.counter(name, values, track)


def set_track(name: str) -> None:
    _GLOBAL.set_track(name)


def export(path: Optional[str] = None) -> Optional[Path]:
    return _GLOBAL.export(path)
