from repro.train.loop import (TrainLoop, TrainLoopConfig, StragglerMonitor,  # noqa: F401
                              prefetch_to_device)
