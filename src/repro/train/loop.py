"""Fault-tolerant training loop.

Scale features (designed for 1000+ node SPMD jobs, exercised here on the
local device set):

* checkpoint/restart — periodic async checkpoints (atomic commit), restore
  on startup, final checkpoint on SIGTERM/KeyboardInterrupt (preemption
  safety);
* straggler mitigation — a per-step timing ring buffer flags steps slower
  than ``threshold x`` the running median; in synchronous SPMD you cannot
  drop a worker, so the mitigation hook rebalances DATA: the elastic
  sampler shrinks the slow host's shard (callback-based so deployments can
  plug in their own telemetry);
* elastic restart — on device-count change, states are restored through
  CheckpointManager with the NEW mesh's shardings (global-array format; see
  repro/checkpoint/manager.py), embeddings re-laid-out via
  ``reshard_embedding``;
* host-side prefetch — :func:`prefetch_to_device` runs a worker thread
  keeping ``size`` batches submitted to the devices (``jax.device_put``
  is async), so the loader's host work AND the H2D transfer of batch n+1
  overlap step n's device compute — the host-side leg of the staged
  pipeline's comm/compute overlap (repro/core/pipeline.py; the shard
  decode + pre-sort leg lives in repro/data/pipeline.py).  Worker
  failures poison the queue and re-raise at the consumer — a dead loader
  fails the loop instead of hanging it.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Any, Callable, Iterator, Optional

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import ThreadedIterator


def prefetch_to_device(batches: Iterator[Any], size: int = 2,
                       shardings: Any = None) -> Iterator[Any]:
    """Wrap a host batch iterator so the next ``size`` batches are already
    submitted to the devices (``jax.device_put`` returns immediately with
    the transfer in flight) while the current step runs.

    A :class:`repro.data.pipeline.ThreadedIterator` worker pulls from
    ``batches`` and device_puts into a bounded queue, so the HOST-side
    cost of ``next(batches)`` (shard decode, pre-sort) also overlaps
    device compute, not just the H2D transfer.  The worker stays at most
    ``size`` batches ahead of the consumer (bounded-queue backpressure);
    order is preserved exactly.  If the source iterator raises, the
    exception is delivered through the queue as a poison sentinel and
    re-raised to the consumer promptly — a loader failure fails the
    training loop, it does not hang it.  Dropping the iterator (consumer
    stops early, e.g. a step-bounded loop over an infinite stream)
    closes the worker and releases its queued batches instead of leaking
    a blocked thread.

    ``shardings``: optional pytree of shardings matching each batch (the
    ``bspecs``-derived NamedShardings of the step factory); None keeps the
    default placement."""
    import jax

    if size < 1:
        raise ValueError(f"prefetch size must be >= 1, got {size}")

    def put(b):
        return jax.device_put(b, shardings) if shardings is not None \
            else jax.device_put(b)

    tit = ThreadedIterator(batches, transform=put, depth=size,
                           name="prefetch_to_device")

    def gen():
        try:
            yield from tit
        finally:
            tit.close()       # early exit / GC: unblock + drain the worker

    return gen()


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_threshold: float = 2.0   # step > thr x median -> straggler
    straggler_window: int = 50
    prefetch: int = 0                  # >0: device_put-ahead window


class StragglerMonitor:
    """Ring-buffer step timer; flags outliers vs the running median."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.events: list[tuple[int, float, float]] = []
        self.on_straggler = on_straggler

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 10:
            med = float(np.median(self.times))
            if dt > self.threshold * med:
                is_straggler = True
                self.events.append((step, dt, med))
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler


class DataRebalancer:
    """Elastic per-host batch shares.  Synchronous SPMD keeps the global
    batch fixed; when host h straggles we shift a fraction of its rows to
    the fastest hosts (the sampler consults ``shares`` when building the
    next global batch)."""

    def __init__(self, n_hosts: int, min_share: float = 0.5):
        self.shares = np.ones(n_hosts) / n_hosts
        self.min_share = min_share / n_hosts

    def penalize(self, host: int, factor: float = 0.9):
        moved = self.shares[host] * (1 - factor)
        floor = self.min_share
        if self.shares[host] - moved < floor:
            moved = max(0.0, self.shares[host] - floor)
        self.shares[host] -= moved
        others = [i for i in range(len(self.shares)) if i != host]
        self.shares[others] += moved / len(others)

    def rows_per_host(self, global_batch: int) -> np.ndarray:
        raw = np.floor(self.shares * global_batch).astype(int)
        raw[0] += global_batch - raw.sum()
        return raw


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, step_fn: Callable,
                 state: Any, batches: Iterator[Any],
                 state_shardings: Any = None, batch_shardings: Any = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        if cfg.prefetch > 0:
            batches = prefetch_to_device(batches, size=cfg.prefetch,
                                         shardings=batch_shardings)
        self.batches = batches
        self.monitor = StragglerMonitor(cfg.straggler_window,
                                        cfg.straggler_threshold)
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, cfg.keep)
                     if cfg.ckpt_dir else None)
        self.state_shardings = state_shardings
        self.start_step = 0
        self.losses: list[float] = []
        self._stop = False
        if self.ckpt and self.ckpt.latest_step() is not None:
            self.start_step, self.state = self.ckpt.restore(
                self.state, shardings=state_shardings)
            print(f"[train] restored checkpoint at step {self.start_step}")

    def _sigterm(self, *_):
        self._stop = True

    def run(self) -> Any:
        old = signal.signal(signal.SIGTERM, self._sigterm)
        completed = self.start_step
        try:
            for step in range(self.start_step, self.cfg.steps):
                if self._stop:
                    print(f"[train] preemption at step {step}; checkpointing")
                    break
                batch = next(self.batches)
                t0 = time.perf_counter()
                self.state, loss = self.step_fn(self.state, batch)
                loss = float(loss)
                dt = time.perf_counter() - t0
                self.losses.append(loss)
                completed = step + 1
                if self.monitor.record(step, dt):
                    print(f"[train] straggler step {step}: {dt*1e3:.1f} ms")
                if step % self.cfg.log_every == 0:
                    print(f"[train] step {step} loss {loss:.4f} "
                          f"{dt*1e3:.1f} ms")
                if (self.ckpt and completed % self.cfg.ckpt_every == 0):
                    self.ckpt.save(completed, self.state)
            if self.ckpt:
                self.ckpt.save(completed, self.state, blocking=True)
        finally:
            signal.signal(signal.SIGTERM, old)
        return self.state
