"""Fault-tolerant training loop.

Scale features (designed for 1000+ node SPMD jobs, exercised here on the
local device set):

* checkpoint/restart — periodic async checkpoints (atomic commit, verified
  on restore: ``repro/checkpoint/manager.py``), restore on startup from the
  newest VALID checkpoint (corrupt ones are skipped), final checkpoint on
  SIGTERM / KeyboardInterrupt / any in-loop failure (the save lives in a
  ``finally``, so preemption safety is not lost to an exception) — except a
  simulated process death (:class:`repro.faults.InjectedCrash`), which dies
  checkpoint-less like a real ``kill -9``;
* straggler mitigation — a per-step timing ring buffer flags steps slower
  than ``threshold x`` the running median; in synchronous SPMD you cannot
  drop a worker, so the mitigation hook rebalances DATA: the elastic
  sampler shrinks the slow host's shard (callback-based so deployments can
  plug in their own telemetry);
* loader fault containment — a counted skip-batch budget
  (``TrainLoopConfig.skip_batch_budget``) absorbs transient loader
  exceptions: each one is logged and the pull retried, up to the budget;
  beyond it the failure propagates (and the final checkpoint still
  commits).  A source that ends (``StopIteration``) ends the run cleanly
  at the last completed step;
* elastic restart — on device-count change, states are restored through
  CheckpointManager with the NEW mesh's shardings (global-array format),
  embeddings re-laid-out via ``reshard_embedding`` / ``reshard_store``;
* host-side prefetch — :func:`prefetch_to_device` runs a worker thread
  keeping ``size`` batches submitted to the devices (``jax.device_put``
  is async), so the loader's host work AND the H2D transfer of batch n+1
  overlap step n's device compute.  Worker failures poison the queue and
  re-raise at the consumer — a dead loader fails the loop instead of
  hanging it.

SIGTERM handling degrades gracefully off the main thread (Python only
allows signal handlers there): preemption is then requested via the
``_stop`` flag — ``FaultPlan`` preemption drills use exactly that path.
Fault-injection hook point: ``train.step`` (inside the timed window, so
injected stalls register as stragglers).  Recovery actions record
structured events on the optional :class:`repro.faults.FailureLog`.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import sys
import threading
import time
import warnings
from collections import deque
from pathlib import Path
from typing import Any, Callable, Iterator, Optional

import numpy as np

from repro import telemetry
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import ThreadedIterator
from repro.faults.plan import NO_FAULTS, InjectedCrash

_EXHAUSTED = object()


class PrefetchIterator:
    """The iterator :func:`prefetch_to_device` returns: forwards one
    :class:`ThreadedIterator` and exposes its ``stats``/``close`` (the
    train-loop heartbeat reads ``stats``; a bare generator would hide
    them).  Dropping it closes the worker, same as the generator did."""

    def __init__(self, tit: ThreadedIterator):
        self._tit = tit

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return next(self._tit)

    @property
    def stats(self) -> dict:
        return self._tit.stats

    def close(self) -> None:
        self._tit.close()

    def __del__(self):
        try:
            self._tit.close()
        except Exception:  # noqa: BLE001 — interpreter teardown
            pass


def prefetch_to_device(batches: Iterator[Any], size: int = 2, shardings: Any = None,
                       faults=None) -> Iterator[Any]:
    """Wrap a host batch iterator so the next ``size`` batches are already
    submitted to the devices (``jax.device_put`` returns immediately with
    the transfer in flight) while the current step runs.

    A :class:`repro.data.pipeline.ThreadedIterator` worker pulls from
    ``batches`` and device_puts into a bounded queue, so the HOST-side
    cost of ``next(batches)`` (shard decode, pre-sort) also overlaps
    device compute, not just the H2D transfer.  The worker stays at most
    ``size`` batches ahead of the consumer (bounded-queue backpressure);
    order is preserved exactly.  If the source iterator raises, the
    exception is delivered through the queue as a poison sentinel and
    re-raised to the consumer promptly — a loader failure fails the
    training loop, it does not hang it.  Dropping the iterator (consumer
    stops early, e.g. a step-bounded loop over an infinite stream)
    closes the worker and releases its queued batches instead of leaking
    a blocked thread.

    ``shardings``: optional pytree of shardings matching each batch (the
    ``bspecs``-derived NamedShardings of the step factory); None keeps the
    default placement.  ``faults``: optional
    :class:`repro.faults.FaultPlan` — the worker fires ``loader.next``
    per pull (drills inject loader deaths and stalls here)."""
    import jax

    if size < 1:
        raise ValueError(f"prefetch size must be >= 1, got {size}")

    def put(b):
        return jax.device_put(b, shardings) if shardings is not None else jax.device_put(b)

    tit = ThreadedIterator(batches, transform=put, depth=size,
                           name="prefetch_to_device", faults=faults)
    return PrefetchIterator(tit)


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_threshold: float = 2.0  # step > thr x median -> straggler
    straggler_window: int = 50
    prefetch: int = 0  # >0: device_put-ahead window
    skip_batch_budget: int = 0  # transient loader errors absorbed per run
    # heartbeat: one JSONL record per ``heartbeat_every``-step window
    # (step-time percentiles, straggler snapshot, ingest stats, cache hit
    # rate, checkpoint save durations); None = off
    heartbeat_path: Optional[str] = None
    heartbeat_every: int = 10
    # in-graph metrics drain cadence (steps): how often state["metrics"]
    # is copied to host and emitted as a trace counter.  Only meaningful
    # when the model def set step_metrics=True.
    metrics_every: int = 10


class StragglerMonitor:
    """Ring-buffer step timer; flags outliers vs the running median."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.events: list[tuple[int, float, float]] = []
        self.on_straggler = on_straggler

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 10:
            med = float(np.median(self.times))
            if dt > self.threshold * med:
                is_straggler = True
                self.events.append((step, dt, med))
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler

    def snapshot(self) -> dict:
        """Summary over the current ring-buffer window: {n, median_ms,
        p99_ms, max_ms, outliers} (outliers = flagged stragglers over the
        whole run, not just the window)."""
        if not self.times:
            return {"n": 0, "outliers": len(self.events)}
        a = np.asarray(self.times, np.float64) * 1e3
        return {"n": int(a.size), "median_ms": float(np.median(a)),
                "p99_ms": float(np.percentile(a, 99)),
                "max_ms": float(a.max()), "outliers": len(self.events)}


class DataRebalancer:
    """Elastic per-host batch shares.  Synchronous SPMD keeps the global
    batch fixed; when host h straggles we shift a fraction of its rows to
    the fastest hosts (the sampler consults ``shares`` when building the
    next global batch).  ``min_share`` floors every host's share (as a
    fraction of the uniform 1/n share) so repeated penalties never starve
    a host to zero."""

    def __init__(self, n_hosts: int, min_share: float = 0.5):
        self.shares = np.ones(n_hosts) / n_hosts
        self.min_share = min_share / n_hosts

    def penalize(self, host: int, factor: float = 0.9):
        moved = self.shares[host] * (1 - factor)
        floor = self.min_share
        if self.shares[host] - moved < floor:
            moved = max(0.0, self.shares[host] - floor)
        self.shares[host] -= moved
        others = [i for i in range(len(self.shares)) if i != host]
        self.shares[others] += moved / len(others)

    def rows_per_host(self, global_batch: int) -> np.ndarray:
        raw = np.floor(self.shares * global_batch).astype(int)
        raw[0] += global_batch - raw.sum()
        return raw


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, step_fn: Callable, state: Any,
                 batches: Iterator[Any], state_shardings: Any = None,
                 batch_shardings: Any = None, faults=None, event_log=None,
                 step_hook: Optional[Callable[[int, Any], Any]] = None,
                 serve_stats: Optional[Callable[[], dict]] = None):
        # step_hook(completed_step, state) runs after every completed step
        # (the serve snapshot publisher: repro/serve/publish.py);
        # serve_stats() is folded into each heartbeat record as rec["serve"]
        # (per-bucket latency percentiles, queue depth, snapshot freshness)
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        self.step_hook = step_hook
        self.serve_stats = serve_stats
        self.faults = faults if faults is not None else NO_FAULTS
        self.events = event_log
        if cfg.prefetch > 0:
            batches = prefetch_to_device(batches, size=cfg.prefetch,
                                         shardings=batch_shardings, faults=faults)
        self.batches = batches
        self.monitor = StragglerMonitor(cfg.straggler_window, cfg.straggler_threshold)
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, cfg.keep, faults=self.faults,
                                       event_log=event_log)
                     if cfg.ckpt_dir else None)
        self.state_shardings = state_shardings
        self.start_step = 0
        self.losses: list[float] = []
        self.skipped_batches = 0
        self._stop = False
        self._owns_batches = cfg.prefetch > 0
        self._metrics_prev: Optional[dict] = None
        self._metrics_window: Optional[dict] = None
        if self.ckpt and self.ckpt.latest_valid_step() is not None:
            self.start_step, self.state = self.ckpt.restore(
                self.state, shardings=state_shardings)
            print(f"[train] restored checkpoint at step {self.start_step}")

    def _record(self, kind: str, **fields) -> None:
        if self.events is not None:
            self.events.record(kind, **fields)

    def _sigterm(self, *_):
        self._stop = True

    def _next_batch(self):
        """Pull the next batch; transient loader exceptions consume the
        skip-batch budget (each one logged) before propagating.  A source
        that ends — including a loader that died and went sticky-dead —
        returns the exhaustion sentinel so the loop can finish cleanly."""
        while True:
            try:
                return next(self.batches)
            except StopIteration:
                return _EXHAUSTED
            except InjectedCrash:
                raise  # simulated process death: never absorbed
            except Exception as e:  # noqa: BLE001 — budgeted containment
                if self.skipped_batches < self.cfg.skip_batch_budget:
                    self.skipped_batches += 1
                    self._record("batch_skipped", error=repr(e),
                                 skipped=self.skipped_batches,
                                 budget=self.cfg.skip_batch_budget)
                    print(f"[train] skipping failed batch "
                          f"({self.skipped_batches}/{self.cfg.skip_batch_budget}): {e!r}")
                    continue
                raise

    def _drain_metrics(self) -> Optional[dict]:
        """Copy the cumulative in-graph metrics vector to host (one small
        device->host transfer), emit it as a trace counter, and remember
        the per-window delta for the next heartbeat.  No-op (None) when the
        model def did not enable ``step_metrics``."""
        from repro.telemetry import metrics as step_mx

        cur = step_mx.drain(self.state)
        if cur is None:
            return None
        self._metrics_window = step_mx.window(cur, self._metrics_prev)
        self._metrics_prev = cur
        step_mx.emit(telemetry.get_tracer(), cur)
        return self._metrics_window

    def _heartbeat(self, step: int, window: list[float]) -> dict:
        """One JSONL record summarizing the window since the last
        heartbeat: step-time percentiles, straggler snapshot, ingest
        stats, drained metrics (+ cache hit rate), checkpoint save
        durations.  Appended + flushed per record so a dying process
        leaves the tail on disk."""
        from repro.telemetry import metrics as step_mx

        rec: dict = {"step": step, "t": time.time(),
                     "skipped_batches": self.skipped_batches}
        if window:
            a = np.asarray(window, np.float64) * 1e3
            rec["window_steps"] = int(a.size)
            rec["step_ms_p50"] = float(np.percentile(a, 50))
            rec["step_ms_p99"] = float(np.percentile(a, 99))
            rec["step_ms_mean"] = float(a.mean())
        rec["straggler"] = self.monitor.snapshot()
        ingest = getattr(self.batches, "stats", None)
        if ingest is not None:
            rec["ingest"] = dict(ingest)
        if self._metrics_window is not None:
            rec["metrics_window"] = self._metrics_window
            rec["cache_hit_rate"] = step_mx.hit_rate(self._metrics_window)
        if self.ckpt is not None and self.ckpt.save_durations:
            rec["ckpt_save_s"] = [round(d, 6) for d in self.ckpt.save_durations[-8:]]
        if self.serve_stats is not None:
            try:
                rec["serve"] = self.serve_stats()
            except Exception as e:  # noqa: BLE001 — telemetry must not kill the run
                rec["serve"] = {"error": repr(e)}
        path = Path(self.cfg.heartbeat_path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
        telemetry.instant("train/heartbeat", cat="train", step=step)
        return rec

    def run(self) -> Any:
        """Run to ``cfg.steps``, checkpointing every ``cfg.ckpt_every``
        completed steps.  The FINAL checkpoint is written in a ``finally``:
        SIGTERM preemption, KeyboardInterrupt, a dead loader or a failing
        step all leave the last completed state on disk (only a simulated
        hard crash skips it).  Off the main thread, SIGTERM installation is
        skipped with a warning and preemption degrades to the ``_stop``
        flag."""
        on_main = threading.current_thread() is threading.main_thread()
        old = None
        if on_main:
            old = signal.signal(signal.SIGTERM, self._sigterm)
        else:
            warnings.warn(
                "TrainLoop.run outside the main thread: SIGTERM handler not "
                "installed (Python restricts signal handling to the main "
                "thread); preemption degrades to the _stop flag",
                RuntimeWarning, stacklevel=2)
        tr = telemetry.get_tracer()
        tr.set_track("train_loop")
        hb_on = self.cfg.heartbeat_path is not None
        window: list[float] = []
        completed = self.start_step
        crashed = False
        try:
            for step in range(self.start_step, self.cfg.steps):
                if self._stop:
                    print(f"[train] preemption at step {step}; checkpointing")
                    self._record("preempted", step=step)
                    break
                batch = self._next_batch()
                if batch is _EXHAUSTED:
                    print(f"[train] batch stream ended at step {step}")
                    self._record("stream_exhausted", step=step)
                    break
                t0 = time.perf_counter()
                fault = self.faults.fire("train.step", step=step)
                if fault is not None and fault.action in ("preempt", "sigterm"):
                    if fault.action == "sigterm" and on_main:
                        os.kill(os.getpid(), signal.SIGTERM)  # handler sets _stop
                    else:
                        self._stop = True
                with tr.span("train/step", cat="train", step=step):
                    self.state, loss = self.step_fn(self.state, batch)
                    loss = float(loss)
                dt = time.perf_counter() - t0
                self.losses.append(loss)
                window.append(dt)
                completed = step + 1
                if self.monitor.record(step, dt):
                    print(f"[train] straggler step {step}: {dt * 1e3:.1f} ms")
                if self.step_hook is not None:
                    self.step_hook(completed, self.state)
                if step % self.cfg.log_every == 0:
                    print(f"[train] step {step} loss {loss:.4f} {dt * 1e3:.1f} ms")
                if self.ckpt and completed % self.cfg.ckpt_every == 0:
                    self.ckpt.save(completed, self.state)
                if completed % self.cfg.metrics_every == 0:
                    self._drain_metrics()
                if hb_on and completed % self.cfg.heartbeat_every == 0:
                    self._heartbeat(completed, window)
                    window.clear()
        except InjectedCrash:
            crashed = True  # simulated kill -9: no final checkpoint
            raise
        finally:
            unwinding = sys.exc_info()[1] is not None
            try:
                if self.ckpt and not crashed:
                    self.ckpt.save(completed, self.state, blocking=True)
            except Exception as e:  # noqa: BLE001 — don't mask the in-flight error
                self._record("final_checkpoint_failed", step=completed, error=repr(e))
                if not unwinding:
                    raise
            finally:
                try:
                    if not crashed:
                        self._drain_metrics()
                        if hb_on:
                            self._heartbeat(completed, window)
                except Exception:  # noqa: BLE001 — telemetry must not mask the run
                    pass
                if self._owns_batches:
                    try:
                        self.batches.close()
                    except Exception:  # noqa: BLE001 — worker already dead is fine
                        pass
                if old is not None:
                    signal.signal(signal.SIGTERM, old)
        return self.state
