# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device (the
# 512-device override belongs ONLY to repro.launch.dryrun).  Distributed
# behaviour is tested via subprocesses in test_distributed.py.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
