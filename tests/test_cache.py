"""Frequency-tiered hot-row embedding cache (repro/core/cache.py).

Contracts under test:

* ``hot_sync='allreduce'`` is BITWISE invisible: with ``hot_rows > 0`` the
  trained weights (every slab) AND the stochastic-rounding ``sr`` counter
  equal the ``hot_rows=0`` run for {sgd, split_sgd, momentum_bf16} x
  M in {1, 2} x host_presort on/off — while the cache demonstrably serves
  a nonzero fraction of bags.
* Promotion is deterministic and layout-independent: the same counters
  (keyed by spec-global gid) and seed select the identical hot set on a
  4-shard row layout and a 3-shard table layout, under count ties.
* Save/restore mid-run resumes bitwise INCLUDING the cache subtree
  (hot_ids / hot_w / tick) and the counter slab.
* ``hot_sync='deferred:N'`` drifts (the cache is really serving stale
  rows) but stays under a pinned bound over a 50-step zipf stream.
* The reserved ``cnt`` touch-counter slab counts identically on every
  update path (reference, fused kernel, host-presorted, batch-chunked)
  and equals the per-lookup bincount oracle.
* ``adagrad_freq`` (frequency-adaptive LR off the same counters) matches
  its closed-form oracle on all three paths.
* Misconfigurations (bad hot_sync, promote_every < 1, hot_rows < 0 or
  larger than the row space) fail loudly at validate_pipeline time.
"""

import dataclasses
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import cache as hot_cache
from repro.core import sharded_embedding as se
from repro.core.embedding import EmbeddingSpec
from repro.optim import row

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TABLES = (50, 30, 20, 10)


def _cfg(**kw):
    from repro.core.dlrm import DLRMConfig
    base = dict(name="t", num_dense=4, bottom=(8, 8), top=(8,),
                table_rows=TABLES, emb_dim=8, pooling=3, batch=16,
                emb_mode="table", idx_input="sharded", lr=0.05)
    base.update(kw)
    return DLRMConfig(**base)


def _mesh():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def _zipf_batch(i, batch=16):
    """Zipf-ish multi-hot batch: heavy repeat mass on each table's head."""
    r = np.random.default_rng(500 + i)
    hi = np.array([m - 1 for m in TABLES])[None, :, None]
    idx = np.minimum(r.zipf(1.5, size=(batch, len(TABLES), 3)) - 1,
                     hi).astype(np.int32)
    return {"idx": jnp.asarray(idx),
            "dense_x": jnp.asarray(r.normal(size=(batch, 4)), jnp.bfloat16),
            "labels": jnp.asarray(r.integers(0, 2, batch), jnp.float32)}


def _emb_bits(state):
    return {k: np.asarray(v).view(np.uint8).copy()
            for k, v in state["emb"].items()}


# ---------------------------------------------------------------------------
# Units: parsing, positions, layout-independent promotion
# ---------------------------------------------------------------------------

def test_parse_hot_sync():
    assert hot_cache.parse_hot_sync("allreduce") == 1
    assert hot_cache.parse_hot_sync("deferred:4") == 4
    for bad in ("deferred:0", "deferred:x", "psum", "deferred:-2"):
        with pytest.raises(ValueError, match="hot_sync"):
            hot_cache.parse_hot_sync(bad)


def test_validate_rejects_bad_cache_config():
    from repro.core import dlrm as D
    mesh = _mesh()
    for kw, match in ((dict(hot_rows=-1), "hot_rows"),
                      (dict(hot_rows=8, promote_every=0), "promote_every"),
                      (dict(hot_rows=8, hot_sync="bogus"), "hot_sync"),
                      (dict(hot_rows=10**6), "row space")):
        with pytest.raises(ValueError, match=match):
            D.make_train_step(_cfg(**kw), mesh)


def test_hot_positions_inverts_ids_and_drops_empties():
    ids = jnp.asarray([7, -1, 0, 12], jnp.int32)
    pos = hot_cache.hot_positions(16, ids)
    assert pos.shape == (16,)
    assert int(pos[7]) == 0 and int(pos[0]) == 2 and int(pos[12]) == 3
    # every other gid is cold; -1 must NOT wrap to the last entry
    assert int((pos >= 0).sum()) == 3 and int(pos[15]) == -1


def test_select_hot_layout_independent_under_ties():
    """The same per-gid counts select the identical hot set (ids AND
    order) on a 4-shard row layout and a 3-shard table layout — count
    ties broken by the seeded gid hash, never by shard position."""
    spec = EmbeddingSpec(TABLES, dim=4)
    rng = np.random.default_rng(7)
    counts = np.zeros(spec.total_rows, np.int32)
    for t, rows_t in enumerate(TABLES):
        base = int(spec.row_offsets[t])
        # few distinct count values => plenty of ties
        counts[base:base + rows_t] = rng.integers(0, 4, rows_t)
    got = {}
    for name, layout in (("row4", se.make_layout(spec, 4, "row")),
                         ("tab3", se.make_layout(spec, 3, "table"))):
        l2g, g2l = se.layout_gid_maps(layout)
        cnt_full = np.zeros(layout.total_rows, np.int32)
        owned = l2g >= 0
        cnt_full[owned] = counts[l2g[owned]]
        got[name] = np.asarray(hot_cache.select_hot(
            layout, jnp.asarray(cnt_full), 6, seed=5))
    np.testing.assert_array_equal(got["row4"], got["tab3"])
    # per-table chunks hold gids of that table (or -1), counts descending
    ids = got["row4"].reshape(len(TABLES), 6)
    tab = hot_cache.spec_gid_to_table(spec)
    for t in range(len(TABLES)):
        live = ids[t][ids[t] >= 0]
        assert np.all(tab[live] == t)
        c = counts[live]
        assert np.all(np.diff(c) <= 0) and np.all(c > 0)
    # a different seed reorders ties
    other = np.asarray(hot_cache.select_hot(
        se.make_layout(spec, 4, "row"),
        jnp.asarray(np.where(se.layout_gid_maps(
            se.make_layout(spec, 4, "row"))[0] >= 0,
            counts[np.clip(se.layout_gid_maps(
                se.make_layout(spec, 4, "row"))[0], 0, None)], 0)
            .astype(np.int32)), 6, seed=6))
    assert not np.array_equal(got["row4"], other)


def test_gid_maps_row_and_table_agree():
    spec = EmbeddingSpec(TABLES, dim=4)
    for layout in (se.make_layout(spec, 4, "row"),
                   se.make_layout(spec, 3, "table")):
        l2g, g2l = se.layout_gid_maps(layout)
        owned = np.nonzero(l2g >= 0)[0]
        # bijection between owned layout rows and real gids
        np.testing.assert_array_equal(g2l[l2g[owned]], owned)
        assert len(np.unique(l2g[owned])) == sum(TABLES)


# ---------------------------------------------------------------------------
# Bitwise matrix: allreduce cache on == cache off
# ---------------------------------------------------------------------------

def _run(cfg, mesh, steps, presort_layout=None):
    from repro.core import dlrm as D
    step, _, _, layout = D.make_train_step(cfg, mesh)
    state, _ = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    for i in range(steps):
        batch = _zipf_batch(i)
        if presort_layout is not None:
            from repro.data.pipeline import presort_batch
            batch.update({k: jnp.asarray(v) for k, v in presort_batch(
                presort_layout, np.asarray(batch["idx"])).items()})
        state, loss = step(state, batch)
    return state, float(loss), layout


@pytest.mark.parametrize("optimizer", ["sgd", "split_sgd", "momentum_bf16"])
@pytest.mark.parametrize("M", [1, 2])
@pytest.mark.parametrize("presort", [False, True])
def test_allreduce_cache_is_bitwise_invisible(optimizer, M, presort):
    """hot_rows=8 + hot_sync='allreduce' must be bit-identical to
    hot_rows=0 on every weight/state slab and the sr counter — while the
    hot slab serves a substantial fraction of bags (zipf head)."""
    mesh = _mesh()
    base = _cfg(sparse_optimizer=optimizer, microbatches=M,
                host_presort=presort, sr_seed=3)
    layout = None
    if presort:
        from repro.core import dlrm as D
        layout = D.make_layout(base, mesh)
    off, loss_off, _ = _run(base, mesh, 4, presort_layout=layout)
    on, loss_on, _ = _run(
        dataclasses.replace(base, hot_rows=8, promote_every=2), mesh, 4,
        presort_layout=layout)
    assert loss_off == loss_on
    bits_off, bits_on = _emb_bits(off), _emb_bits(on)
    for k in bits_off:      # cache-on additionally carries the cnt slab
        np.testing.assert_array_equal(bits_on[k], bits_off[k]), k
    if "sr" in off:
        assert int(off["sr"]) == int(on["sr"])
    # the identity must not be vacuous: the final hot set really hits
    from repro.core import dlrm as D
    hit, _ = hot_cache.hot_bag_local(
        D.make_layout(base, mesh), on["cache"]["hot_w"],
        on["cache"]["hot_pos"], _zipf_batch(3)["idx"])
    assert float(jnp.mean(hit)) > 0.3


def test_cache_save_restore_resume_bitwise(tmp_path):
    """Mid-run save/restore with the cache on: counters, hot set, mirror
    and tick all persist, and the resumed run is bitwise the
    uninterrupted one (promotion replays identically)."""
    from repro.checkpoint import CheckpointManager
    from repro.core import dlrm as D
    mesh = _mesh()
    cfg = _cfg(sparse_optimizer="momentum_bf16", sr_seed=3, hot_rows=8,
               promote_every=2)
    step, shardings, _, _ = D.make_train_step(cfg, mesh)

    def fresh():
        return D.init_state(jax.random.PRNGKey(0), cfg, mesh)[0]

    want = fresh()
    for i in range(6):
        want, _ = step(want, _zipf_batch(i))

    mid = fresh()
    for i in range(3):
        mid, _ = step(mid, _zipf_batch(i))
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, mid, blocking=True)
    structs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), mid)
    got_step, got = mgr.restore(structs, shardings=shardings)
    assert got_step == 3
    assert int(got["cache"]["tick"]) == 3
    for i in range(3, 6):
        got, _ = step(got, _zipf_batch(i))
    for k, v in _emb_bits(want).items():
        np.testing.assert_array_equal(_emb_bits(got)[k], v), k
    assert int(got["sr"]) == int(want["sr"])
    for k in ("hot_ids", "tick"):
        np.testing.assert_array_equal(np.asarray(got["cache"][k]),
                                      np.asarray(want["cache"][k])), k
    np.testing.assert_array_equal(
        np.asarray(got["cache"]["hot_w"]).view(np.uint8),
        np.asarray(want["cache"]["hot_w"]).view(np.uint8))


def test_deferred_sync_drift_is_real_and_bounded():
    """deferred:8 over 50 zipf steps: the run must DIFFER from cache-off
    (stale rows really served) but the weight drift stays pinned — the
    cold store is authoritative and absorbs every update."""
    mesh = _mesh()
    base = _cfg(sparse_optimizer="sgd", split_sgd=False)
    off, _, _ = _run(base, mesh, 50)
    on, _, _ = _run(dataclasses.replace(
        base, hot_rows=8, promote_every=5, hot_sync="deferred:8"),
        mesh, 50)
    w_off = np.asarray(off["emb"]["w"])
    w_on = np.asarray(on["emb"]["w"])
    drift = float(np.max(np.abs(w_off - w_on)))
    assert drift > 0.0, "deferred run identical: the cache never served"
    assert drift < 5e-3, f"deferred drift {drift} above the pinned bound"


# ---------------------------------------------------------------------------
# Multi-rank: cross-rank hot-set identity + bitwise invisibility
# ---------------------------------------------------------------------------

def test_cache_multirank_bitwise_and_hotset_identity():
    from test_row_optim import run_sub
    out = run_sub("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core.dlrm import DLRMConfig, make_train_step, init_state

    mesh = compat.make_mesh((2, 4), ('data', 'model'))
    TABLES = (100, 60, 40, 30)
    base = DLRMConfig(name='t', num_dense=8, bottom=(16, 8), top=(16,),
                      table_rows=TABLES, emb_dim=8, pooling=3, batch=16,
                      emb_mode='table', idx_input='sharded',
                      sparse_optimizer='split_sgd', lr=0.05)

    def batch(i):
        r = np.random.default_rng(300 + i)
        hi = np.array([m - 1 for m in TABLES])[None, :, None]
        idx = np.minimum(r.zipf(1.5, size=(16, 4, 3)) - 1,
                         hi).astype(np.int32)
        return {'idx': jnp.asarray(idx),
                'dense_x': jnp.asarray(r.normal(size=(16, 8)),
                                       jnp.bfloat16),
                'labels': jnp.asarray(r.integers(0, 2, 16), jnp.float32)}

    def run(cfg):
        step, _, _, _ = make_train_step(cfg, mesh)
        state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
        for i in range(4):
            state, loss = step(state, batch(i))
        return state, float(loss)

    s0, l0 = run(base)
    s1, l1 = run(dataclasses.replace(base, hot_rows=8, promote_every=2))
    assert l0 == l1, (l0, l1)
    for k in s0['emb']:
        a = np.asarray(s0['emb'][k]).view(np.uint8)
        b = np.asarray(s1['emb'][k]).view(np.uint8)
        assert np.array_equal(a, b), k
    # the replicated cache must hold the SAME hot set on every device
    for k in ('hot_ids', 'hot_w', 'hot_pos'):
        shards = [np.asarray(sh.data)
                  for sh in s1['cache'][k].addressable_shards]
        assert len(shards) == 8, k
        for sh in shards[1:]:
            assert np.array_equal(
                sh.view(np.uint8), shards[0].view(np.uint8)), k
    hot = np.asarray(s1['cache']['hot_ids'])
    assert (hot >= 0).sum() > 0
    print('MULTI_OK')
    """)
    assert out.count("MULTI_OK") == 1


# ---------------------------------------------------------------------------
# Counter slab: path identity + bincount oracle
# ---------------------------------------------------------------------------

def _count_oracle(idx, valid, num_rows):
    tgt = np.asarray(idx).reshape(-1)
    if valid is not None:
        tgt = tgt[np.asarray(valid).reshape(-1)]
    tgt = tgt[(tgt >= 0) & (tgt < num_rows)]
    return np.bincount(tgt, minlength=num_rows).astype(np.int32)[:, None]


def test_counter_bump_identical_on_every_path():
    """The cnt slab advances by exactly the per-lookup bincount on the
    reference, fused-kernel and host-presorted paths — counting happens
    once, before optimizer dispatch, regardless of path."""
    from repro.kernels.embedding_update import sort_lookups
    rng = np.random.default_rng(9)
    M, E, B, S, P = 40, 8, 6, 2, 3
    W = jnp.asarray(rng.standard_normal((M, E)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 12, (B, S, P)), jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, (B, S, P)), bool)
    dY = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    opt = row.get("sgd")
    store = opt.init_store(W, counters=True)
    start = np.asarray(store["cnt"])
    want = start + _count_oracle(idx, valid, M)

    ref = opt.apply_sparse(store, row.SparseStream(idx=idx, dY=dY,
                                                   valid=valid), 0.05,
                           fused=False)
    fus = opt.apply_sparse(store, row.SparseStream(idx=idx, dY=dY,
                                                   valid=valid), 0.05,
                           fused=True, interpret=True)
    srows, sbags, smsk, swgt = sort_lookups(idx.reshape(-1),
                                            valid.reshape(-1), M, P, None)
    pre = opt.apply_sparse(
        store, row.SparseStream(idx=idx, dY=dY,
                                presort=(srows, sbags, smsk, swgt)),
        0.05, fused=True, interpret=True)
    for name, out in (("reference", ref), ("fused", fus),
                      ("presorted", pre)):
        np.testing.assert_array_equal(np.asarray(out["cnt"]), want), name


def test_counter_bump_chunked_matches(monkeypatch):
    """The batch-chunked apply_update branches (stateless scan AND the
    stateful chunked path) bump once per valid lookup, same as the
    unchunked paths."""
    from jax.sharding import PartitionSpec as P_
    from repro import compat
    layout = se.make_layout(EmbeddingSpec((40, 24), 8), 1, "row")
    rng = np.random.default_rng(4)
    idx = jnp.asarray(rng.integers(0, 6, (8, 2, 3)), jnp.int32)
    dY = jnp.asarray(rng.standard_normal((8, 2, 8)), jnp.float32)
    g = np.asarray(idx) + np.asarray(layout.row_offsets,
                                     np.int32)[None, :, None]
    mesh = _mesh()
    axes = ("data", "model")

    def run(opt, store):
        def f(st, i, d):
            return se.apply_update(layout, st, opt, i, d, 0.05, axes,
                                   fused=False)
        sm = jax.jit(compat.shard_map(
            f, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P_(axes, None), store),
                      P_(None, None, None), P_(None, None, None)),
            out_specs=jax.tree.map(lambda _: P_(axes, None), store),
            check_vma=False))
        return {k: np.asarray(v) for k, v in sm(store, idx, dY).items()}

    for name in ("sgd", "momentum"):     # stateless scan / stateful chunk
        opt = row.get(name)
        W = jnp.asarray(rng.standard_normal((layout.total_rows, 8)),
                        jnp.float32)
        store = opt.init_store(W, counters=True)
        want = _count_oracle(g, None, layout.total_rows)
        # per-row bytes = S*P*E*4 = 192; 200-byte budget forces 8 chunks
        monkeypatch.setenv("REPRO_EMB_CHUNK_BUDGET", "200")
        chunked = run(opt, store)
        monkeypatch.delenv("REPRO_EMB_CHUNK_BUDGET")
        plain = run(opt, store)
        np.testing.assert_array_equal(chunked["cnt"], want), name
        np.testing.assert_array_equal(plain["cnt"], want), name


def test_adagrad_freq_matches_oracle_on_all_paths():
    """w -= lr * g_summed / (sqrt(max(cnt, 1)) + eps) with cnt counted
    BEFORE the step; reference / fused kernel / presorted agree with the
    numpy oracle to fp32 tolerance and count identically."""
    from repro.kernels.embedding_update import sort_lookups
    rng = np.random.default_rng(12)
    M, E, B, S, P = 30, 8, 6, 2, 3
    W = jnp.asarray(rng.standard_normal((M, E)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 9, (B, S, P)), jnp.int32)
    dY = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    opt = row.get("adagrad_freq")
    assert opt.state_keys == ("cnt",)
    store = opt.init_store(W)
    store = dict(store, cnt=jnp.asarray(
        rng.integers(0, 50, (M, 1)), jnp.int32))

    cnt1 = np.asarray(store["cnt"]) + _count_oracle(idx, None, M)
    g = np.repeat(np.asarray(dY, np.float64).reshape(-1, E), P, axis=0)
    tgt = np.asarray(idx).reshape(-1)
    want_w = np.asarray(W, np.float64).copy()
    for r in np.unique(tgt):
        Gr = g[tgt == r].sum(axis=0)
        denom = np.sqrt(max(float(cnt1[r, 0]), 1.0)) + opt.eps
        want_w[r] -= 0.05 * Gr / denom

    ref = jax.jit(lambda s, t: opt.apply_sparse(s, t, 0.05, fused=False))(
        store, row.SparseStream(idx=idx, dY=dY))
    fus = opt.apply_sparse(store, row.SparseStream(idx=idx, dY=dY), 0.05,
                           fused=True, interpret=True)
    srows, sbags, smsk, swgt = sort_lookups(idx.reshape(-1), None, M, P,
                                            None)
    pre = opt.apply_sparse(
        store, row.SparseStream(idx=idx, dY=dY,
                                presort=(srows, sbags, smsk, swgt)),
        0.05, fused=True, interpret=True)
    for name, out in (("reference", ref), ("fused", fus),
                      ("presorted", pre)):
        np.testing.assert_array_equal(np.asarray(out["cnt"]), cnt1), name
        np.testing.assert_allclose(np.asarray(out["w"]), want_w,
                                   rtol=1e-5, atol=1e-6), name
