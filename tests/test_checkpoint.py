"""Checkpoint manager: atomic commit, retention, roundtrip, elastic
embedding re-layout."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import reshard_embedding
from repro.core.embedding import EmbeddingSpec
from repro.core import sharded_embedding as se


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": [jnp.ones(3), jnp.zeros(2)]}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = make_state()
    mgr.save(7, state, blocking=True)
    step, restored = mgr.restore(jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = make_state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, make_state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, make_state(), blocking=True)
    names = os.listdir(tmp_path)
    assert "step_5" in names
    assert not any(n.endswith(".tmp") for n in names)


@pytest.mark.parametrize("mode_pair", [("row", "row"), ("row", "table"),
                                       ("table", "row")])
def test_elastic_embedding_reshard(mode_pair):
    """Changing shard count (and placement mode) across a restart preserves
    every table's rows."""
    spec = EmbeddingSpec((100, 30, 70, 20), dim=4)
    m_old, m_new = mode_pair
    old = se.make_layout(spec, 4, m_old)
    new = se.make_layout(spec, 8 if m_new == "row" else 4, m_new)
    rng = np.random.default_rng(0)
    W_old = rng.standard_normal((old.total_rows, 4)).astype(np.float32)
    W_new = reshard_embedding(old, new, W_old)

    def base(layout, t):
        if layout.mode == "row":
            return int(spec.row_offsets[t])
        for pos, s in enumerate(layout.padded_slots):
            if s >= 0 and layout.slot_to_table[s] == t:
                return (pos // layout.slots_per_shard) * layout.rows_per_shard \
                    + int(layout.slot_local_offsets[pos])
        raise KeyError

    for t, rows in enumerate(spec.table_rows):
        np.testing.assert_array_equal(
            W_new[base(new, t):base(new, t) + rows],
            W_old[base(old, t):base(old, t) + rows])


def test_reshard_store_preserves_slab_dtypes():
    """Satellite regression: an elastic reshard must keep every slab's
    dtype — the split-weight bf16 ``hi`` half, the uint16 ``lo`` bits and
    fp32 state must NOT silently promote to float64 (np.zeros default) or
    reinterpret across the hop."""
    import ml_dtypes

    from repro.checkpoint.manager import reshard_store

    spec = EmbeddingSpec((100, 30, 70, 20), dim=4)
    old = se.make_layout(spec, 4, "row")
    new = se.make_layout(spec, 2, "row")  # shrink: N -> N-k
    rng = np.random.default_rng(3)
    R = old.total_rows
    store = {
        "hi": jnp.asarray(rng.standard_normal((R, 4)), jnp.bfloat16),
        "lo": jnp.asarray(rng.integers(0, 2**16, (R, 4)), jnp.uint16),
        "acc": jnp.asarray(rng.standard_normal((R, 1)) ** 2, jnp.float32),
        "mom": jnp.asarray(rng.standard_normal((R, 4)), jnp.bfloat16),
        # the reserved touch-counter slab of the hot-row cache: int32
        # counts must reshard as counts, not float-promote
        "cnt": jnp.asarray(rng.integers(0, 1000, (R, 1)), jnp.int32),
    }
    out = reshard_store(old, new, store)
    want_dtypes = {"hi": ml_dtypes.bfloat16, "lo": np.uint16,
                   "acc": np.float32, "mom": ml_dtypes.bfloat16,
                   "cnt": np.int32}
    for k, dt in want_dtypes.items():
        assert np.asarray(out[k]).dtype == dt, k
    # content: every real table row survives bitwise (compare raw bits so
    # bf16 NaN payloads can't hide behind NaN != NaN)
    for t, rows in enumerate(spec.table_rows):
        src, dst = int(spec.row_offsets[t]), int(spec.row_offsets[t])
        for k in store:
            a = np.asarray(out[k])[dst:dst + rows]
            b = np.asarray(store[k])[src:src + rows]
            np.testing.assert_array_equal(
                a.view(np.uint8), b.view(np.uint8)), (k, t)
