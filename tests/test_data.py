"""Data pipeline: index-skew generator and the fanout neighbor sampler."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.graph import NeighborSampler, random_powerlaw_graph
from repro.data.synthetic import SparseBatchSpec, sparse_batch, zipf_indices


@settings(max_examples=20, deadline=None)
@given(st.integers(10, 10_000), st.floats(0.0, 2.0))
def test_zipf_in_range(vocab, alpha):
    rng = np.random.default_rng(0)
    idx = zipf_indices(rng, vocab, (256,), alpha)
    assert idx.min() >= 0 and idx.max() < vocab


def test_zipf_skew_increases_contention():
    rng = np.random.default_rng(0)
    flat = zipf_indices(rng, 10_000, (20_000,), 0.0)
    skew = zipf_indices(np.random.default_rng(0), 10_000, (20_000,), 1.2)
    assert len(np.unique(skew)) < len(np.unique(flat)) * 0.5


def test_sparse_batch_shapes():
    spec = SparseBatchSpec((100, 50, 20), None, pooling=4, batch=32,
                           num_dense=8)
    b = sparse_batch(np.random.default_rng(0), spec)
    assert b["idx"].shape == (32, 3, 4)
    assert b["dense_x"].shape == (32, 8)
    assert b["labels"].shape == (32,)
    for s, rows in enumerate((100, 50, 20)):
        assert b["idx"][:, s].max() < rows


def test_sparse_batch_slot_sharing():
    spec = SparseBatchSpec((1000, 7), (0, 0, 0, 1), pooling=1, batch=16)
    b = sparse_batch(np.random.default_rng(0), spec)
    assert b["idx"].shape == (16, 4, 1)
    assert b["idx"][:, :3].max() < 1000
    assert b["idx"][:, 3].max() < 7


def test_neighbor_sampler_validity():
    g = random_powerlaw_graph(5000, 60_000, seed=1)
    s = NeighborSampler(g, fanout=(5, 3), n_pad=32, e_pad=32, seed=0)
    sub = s.sample(42)
    n_real, e_real = sub["n_real"], int(sub["edge_mask"].sum())
    assert sub["nodes"][0] == 42                      # target is node 0
    assert 1 <= n_real <= 32
    # every real edge uses only relabeled local ids < n_real
    assert sub["src"][:e_real].max(initial=0) < n_real
    assert sub["dst"][:e_real].max(initial=0) < n_real
    # all sampled neighbors are true graph neighbors of their parent
    for i in range(e_real):
        child = sub["nodes"][sub["src"][i]]
        parent = sub["nodes"][sub["dst"][i]]
        lo, hi = g.indptr[parent], g.indptr[parent + 1]
        assert child in g.indices[lo:hi]


def test_neighbor_sampler_batch():
    g = random_powerlaw_graph(2000, 20_000, seed=2)
    s = NeighborSampler(g, fanout=(4, 2), n_pad=16, e_pad=16, seed=0)
    feats = np.random.default_rng(0).standard_normal((2000, 6)).astype(
        np.float32)
    labels = np.random.default_rng(1).integers(0, 5, 2000)
    batch = s.sample_batch(np.array([1, 2, 3, 4]), feats, labels)
    assert batch["feats"].shape == (4, 16, 6)
    assert batch["src"].shape == (4, 16)
    assert batch["labels"].shape == (4,)
