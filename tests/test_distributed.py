"""Multi-device integration tests.

These spawn SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax initializes, and the main test process must
keep seeing 1 device per the smoke-test contract)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_row_sharded_bag_matches_reference():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.embedding import EmbeddingSpec, bag_lookup, globalize
        from repro.core import sharded_embedding as se
        mesh = compat.make_mesh((2, 4), ('data', 'model'))
        spec = EmbeddingSpec((1000, 50, 333, 20), dim=16)
        layout = se.make_layout(spec, 8, 'row')
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (layout.total_rows, 16), jnp.float32)
        rng = np.random.default_rng(0)
        idx = np.stack([rng.integers(0, m, (16, 4))
                        for m in spec.table_rows], 1).astype(np.int32)
        AX = ('data', 'model')
        fwd = jax.jit(compat.shard_map(
            lambda Wl, i: se.row_sharded_bag_fwd(layout, Wl, i, AX),
            mesh=mesh, in_specs=(P(AX, None), P(None, None, None)),
            out_specs=P(AX, None, None)))
        out = fwd(W, jnp.asarray(idx))
        ref = bag_lookup(W, globalize(spec, jnp.asarray(idx)))
        # bf16 collective wire (HC3): ~2^-8 relative on the reduce
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-2, atol=2e-2)
        print('ROW_OK')
    """)
    assert "ROW_OK" in out


def test_dlrm_hybrid_trains_both_modes():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.core.dlrm import DLRMConfig, make_train_step, init_state
        from repro.core import sharded_embedding as se
        mesh = compat.make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        for mode in ('row', 'table'):
            cfg = DLRMConfig(name='t', num_dense=16, bottom=(32, 8),
                             top=(32,), table_rows=(100, 60, 40, 30, 20,
                             200, 51, 77), emb_dim=8, pooling=3, batch=32,
                             emb_mode=mode)
            state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
            step, _, _, _ = make_train_step(cfg, mesh)
            idx = np.stack([rng.integers(0, m, (32, 3))
                            for m in cfg.table_rows], 1).astype(np.int32)
            if mode == 'table':
                idx = np.asarray(se.permute_indices(layout,
                                                    jnp.asarray(idx)))
            batch = {'idx': jnp.asarray(idx),
                     'dense_x': jnp.asarray(
                         rng.standard_normal((32, 16)), jnp.bfloat16),
                     'labels': jnp.asarray(rng.integers(0, 2, 32),
                                           jnp.float32)}
            losses = []
            for _ in range(5):
                state, loss = step(state, batch)
                losses.append(float(loss))
            assert losses[-1] < losses[0], (mode, losses)
            print(mode, 'OK')
    """)
    assert "row OK" in out and "table OK" in out


def test_rs_ag_equals_allreduce():
    """The paper's RS+AG decomposition (C4) == plain allreduce SGD."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.optim import data_parallel as dp
        from repro.optim.split_sgd import combine_split
        mesh = compat.make_mesh((8,), ('d',))
        rng = np.random.default_rng(0)
        params = {'w': jnp.asarray(rng.standard_normal((33, 7)),
                                   jnp.float32),
                  'b': jnp.asarray(rng.standard_normal(13), jnp.float32)}
        arrays = dp.dp_global_arrays(params, 8, num_buckets=2)
        grads = jax.tree.map(
            lambda p: jnp.asarray(rng.standard_normal(p.shape) * 0 + 1.0,
                                  jnp.float32), params)

        def step(hi, lo, g):
            st = dp.DPState(hi, lo, None, None)
            st2 = dp.rs_ag_split_sgd(st, g, 0.1, 'd', num_buckets=2)
            return st2.hi, st2.lo_shard

        f = jax.jit(compat.shard_map(
            step, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), arrays['hi']), P('d'),
                      jax.tree.map(lambda _: P(), grads)),
            out_specs=(jax.tree.map(lambda _: P(), arrays['hi']), P('d')),
            check_vma=False))
        hi2, lo2 = f(arrays['hi'], arrays['lo'], grads)
        # reference: every replica contributes g=1 -> mean 1 -> w - 0.1
        want = jax.tree.map(lambda p: np.asarray(p) - 0.1, params)
        got_w = np.asarray(hi2['w'], np.float32)
        np.testing.assert_allclose(got_w, want['w'], rtol=1e-2)
        print('RSAG_OK')
    """)
    assert "RSAG_OK" in out


def test_lm_train_step_small_mesh():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models import lm_steps
        from repro.models.transformer import TransformerConfig
        mesh = make_mesh((2, 4), ('data', 'model'))
        cfg = TransformerConfig('t', n_layers=2, d_model=64, n_heads=8,
                                n_kv_heads=8, d_head=8, d_ff=128, vocab=256,
                                dp_axes=('data',), tp_size=4,
                                tie_embeddings=False, microbatch=2)
        state = lm_steps.init_lm_state(jax.random.PRNGKey(0), cfg, mesh)
        step, structs, shardings = lm_steps.make_lm_train_step(
            cfg, mesh, B=16, L=32, lr=0.1)
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, 256, (16, 32)),
                                       jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, 256, (16, 32)),
                                       jnp.int32)}
        losses = []
        for _ in range(4):
            state, loss = step(state, batch)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        print('LM_OK', losses[0], '->', losses[-1])
    """)
    assert "LM_OK" in out


def test_egnn_fullgraph_distributed():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models.egnn import EGNNConfig
        from repro.models import egnn_steps
        mesh = make_mesh((2, 4), ('data', 'model'))
        cfg = EGNNConfig('t', n_layers=2, d_hidden=16, d_feat=12,
                         n_classes=5)
        state = egnn_steps.init_egnn_state(jax.random.PRNGKey(0), cfg, mesh)
        step, (ss, bs), _ = egnn_steps.make_fullgraph_train_step(
            cfg, mesh, n_nodes=200, n_edges=800, lr=0.005)
        rng = np.random.default_rng(0)
        N, E = bs['feats'].shape[0], bs['src'].shape[0]
        batch = {
            'feats': jnp.asarray(rng.standard_normal((N, 12)),
                                 jnp.bfloat16),
            'coords': jnp.asarray(rng.standard_normal((N, 3)), jnp.float32),
            'src': jnp.asarray(rng.integers(0, 200, E), jnp.int32),
            'dst': jnp.asarray(rng.integers(0, 200, E), jnp.int32),
            'edge_mask': jnp.asarray(
                (np.arange(E) < 800).astype(np.float32)),
            'labels': jnp.asarray(rng.integers(0, 5, N), jnp.int32),
            'label_mask': jnp.asarray(
                (np.arange(N) < 200).astype(np.float32)),
        }
        losses = []
        for _ in range(5):
            state, loss = step(state, batch)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        print('EGNN_OK', losses[0], '->', losses[-1])
    """)
    assert "EGNN_OK" in out


def test_sharded_idx_input_matches_replicated():
    """Beyond-paper data-loader fix: batch-sharded index input + on-chip
    all-gather == the paper's replicated loader, trajectory-identical."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro import compat
        from repro.core.dlrm import DLRMConfig, make_train_step, init_state
        mesh = compat.make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        base = DLRMConfig(name='t', num_dense=16, bottom=(32, 8), top=(32,),
                          table_rows=(100, 60, 40, 30, 20, 200, 51, 77),
                          emb_dim=8, pooling=3, batch=32)
        idx = np.stack([rng.integers(0, m, (32, 3))
                        for m in base.table_rows], 1).astype(np.int32)
        batch = {'idx': jnp.asarray(idx),
                 'dense_x': jnp.asarray(rng.standard_normal((32, 16)),
                                        jnp.bfloat16),
                 'labels': jnp.asarray(rng.integers(0, 2, 32), jnp.float32)}
        traj = {}
        for mode in ('replicated', 'sharded'):
            cfg = dataclasses.replace(base, idx_input=mode)
            state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
            step, _, _, _ = make_train_step(cfg, mesh)
            ls = []
            for _ in range(4):
                state, loss = step(state, batch)
                ls.append(float(loss))
            traj[mode] = ls
        assert np.allclose(traj['replicated'], traj['sharded'], rtol=1e-4)
        print('IDX_OK')
    """)
    assert "IDX_OK" in out
