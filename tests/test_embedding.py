"""Unified embedding engine: bags, fused updates, dedup, interaction, and
the FM sum-square identity (hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import embedding as E
from repro.core.interaction import dot_interaction, interaction_output_dim
from repro.optim.row import dedup_rows

RNG = np.random.default_rng(0)


def test_spec_offsets():
    spec = E.EmbeddingSpec((100, 7, 33), dim=16)
    off = spec.row_offsets
    assert off[0] == 0 and off[1] == 104 and off[2] == 112  # row_pad=8
    assert spec.total_rows == 152


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 50), st.integers(1, 6), st.integers(1, 8),
       st.integers(1, 24))
def test_bag_is_sum_of_lookups(rows, s, p, b):
    W = jnp.asarray(RNG.standard_normal((rows * s + 8 * s, 8)), jnp.float32)
    spec = E.EmbeddingSpec(tuple([rows] * s), 8)
    idx = jnp.asarray(RNG.integers(0, rows, (b, s, p)), jnp.int32)
    g = E.globalize(spec, idx)
    out = E.bag_lookup(W[:spec.total_rows], g)
    naive = np.zeros((b, s, 8), np.float32)
    Wn = np.asarray(W[:spec.total_rows])
    gn = np.asarray(g)
    for bi in range(b):
        for si in range(s):
            for pi in range(p):
                naive[bi, si] += Wn[gn[bi, si, pi]]
    np.testing.assert_allclose(np.asarray(out), naive, rtol=1e-4, atol=1e-5)


def test_bag_linearity():
    """bag(W1+W2) == bag(W1) + bag(W2) — linearity in the table.  Local rng
    (the module RNG's position depends on hypothesis draws) and fp32
    accumulation-order tolerance."""
    rng = np.random.default_rng(42)
    spec = E.EmbeddingSpec((50, 20), 8)
    W1 = jnp.asarray(rng.standard_normal((spec.total_rows, 8)), jnp.float32)
    W2 = jnp.asarray(rng.standard_normal((spec.total_rows, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 20, (6, 2, 3)), jnp.int32)
    g = E.globalize(spec, idx)
    np.testing.assert_allclose(
        np.asarray(E.bag_lookup(W1 + W2, g)),
        np.asarray(E.bag_lookup(W1, g) + E.bag_lookup(W2, g)),
        rtol=1e-4, atol=1e-5)


def test_fused_update_equals_dense_grad_path():
    """bag_update (C1 fused bwd+update) == materializing the dense dW and
    applying SGD — the 1.6x fusion changes nothing numerically."""
    spec = E.EmbeddingSpec((30, 11), 4)
    W = jnp.asarray(RNG.standard_normal((spec.total_rows, 4)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 11, (5, 2, 3)), jnp.int32)
    g = E.globalize(spec, idx)
    dY = jnp.asarray(RNG.standard_normal((5, 2, 4)), jnp.float32)
    fused = E.bag_update(W, g, dY, 0.1)
    dW = E.bag_grad_rows(g, dY, spec.total_rows)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(W - 0.1 * dW),
                               rtol=1e-4, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 100), st.integers(1, 64))
def test_dedup_rows_sums_duplicates(n, rows):
    tgt = jnp.asarray(RNG.integers(0, rows, (n,)), jnp.int32)
    upd = jnp.asarray(RNG.standard_normal((n, 3)), jnp.float32)
    rep, summed = dedup_rows(tgt, upd, rows)
    acc = np.zeros((rows, 3), np.float32)
    for i in range(n):
        acc[int(tgt[i])] += np.asarray(upd)[i]
    got = np.zeros((rows, 3), np.float32)
    for i in range(n):
        r = int(rep[i])
        if r < rows:
            got[r] = np.asarray(summed)[i]
    np.testing.assert_allclose(got, acc, rtol=1e-4, atol=1e-5)
    # every in-range rep is unique
    reps = [int(r) for r in np.asarray(rep) if r < rows]
    assert len(reps) == len(set(reps))


def test_dot_interaction_matches_naive():
    dense = jnp.asarray(RNG.standard_normal((4, 8)), jnp.float32)
    emb = jnp.asarray(RNG.standard_normal((4, 3, 8)), jnp.float32)
    out = dot_interaction(dense, emb)
    assert out.shape == (4, interaction_output_dim(4, 8))
    Z = np.concatenate([np.asarray(dense)[:, None], np.asarray(emb)], 1)
    for b in range(4):
        zz = Z[b] @ Z[b].T
        pairs = [zz[i, j] for i in range(4) for j in range(i)]
        np.testing.assert_allclose(np.asarray(out)[b, 8:], pairs, rtol=2e-5,
                                   atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 12), st.integers(1, 8))
def test_fm_sum_square_trick(n_fields, k):
    """FM identity: sum_{i<j} <v_i, v_j> == 0.5 ((sum v)^2 - sum v^2)."""
    v = RNG.standard_normal((n_fields, k)).astype(np.float32)
    explicit = sum(float(v[i] @ v[j]) for i in range(n_fields)
                   for j in range(i + 1, n_fields))
    sv = v.sum(0)
    trick = 0.5 * float((sv * sv).sum() - (v * v).sum())
    np.testing.assert_allclose(trick, explicit, rtol=1e-4, atol=1e-4)
