"""Fused sparse-backward + Split-SGD embedding update (paper Alg. 3 + C5):
bit-exactness vs the segment_sum + combine_split reference, duplicate
accumulation, ragged/padded bags, untouched-row preservation, and the
blocked forward kernel."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import embedding as E
from repro.kernels import ops, ref
from repro.kernels import embedding_update as EU
from repro.optim.row import apply_rows_split_sgd
from repro.optim.split_sgd import combine_split, split_fp32

RNG = np.random.default_rng(7)

# jitted reference: the fused kernel matches the REFERENCE AS COMPILED
# (XLA contracts the mul+sub of the update identically in both paths;
# the eager op-by-op dispatch of the same expression does not contract)
_ref_split = jax.jit(apply_rows_split_sgd)


def _fused_split(hi, lo, tgt, dY, lr, valid=None, weights=None, pooling=1):
    """Kernel-level helper: the split_sgd kind of the collapsed
    ``fused_row_update`` surface (the former fused_embedding_update)."""
    out = ops.fused_row_update("split_sgd", {"hi": hi, "lo": lo}, tgt, dY,
                               lr, valid=valid, weights=weights,
                               pooling=pooling, interpret=True)
    return out["hi"], out["lo"]


def _fused_fp32(W, tgt, dY, lr, valid=None, weights=None, pooling=1):
    return ops.fused_row_update("sgd", {"w": W}, tgt, dY, lr, valid=valid,
                                weights=weights, pooling=pooling,
                                interpret=True)["w"]


def _mk(M, E_, L, P, dup_vocab=None, seed=0):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.standard_normal((M, E_)), jnp.float32)
    hi, lo = split_fp32(W)
    tgt = jnp.asarray(rng.integers(0, dup_vocab or M, (L,)), jnp.int32)
    dY = jnp.asarray(rng.standard_normal((L // P, E_)), jnp.float32)
    return W, hi, lo, tgt, dY


@pytest.mark.parametrize("M,E_,L,P", [(50, 16, 24, 3), (200, 8, 300, 5),
                                      (8, 4, 64, 4), (1000, 32, 128, 1),
                                      (16, 128, 160, 8), (60, 17, 40, 2)])
def test_fused_split_bit_exact_duplicate_heavy(M, E_, L, P):
    """Duplicate-heavy zipf-like targets: fused == jitted reference, bitwise."""
    W, hi, lo, tgt, dY = _mk(M, E_, L, P, dup_vocab=max(2, M // 10))
    nh, nl = _fused_split(hi, lo, tgt, dY, 0.05, pooling=P)
    grad = jnp.take(dY, jnp.arange(L) // P, axis=0)
    rh, rl = _ref_split(hi, lo, tgt, grad, 0.05)
    np.testing.assert_array_equal(np.asarray(combine_split(nh, nl)),
                                  np.asarray(combine_split(rh, rl)))


def test_fused_split_flag_on_reference_entrypoint():
    """apply_rows_split_sgd(fused=True) is the same kernel behind the
    reference signature (A/B flag of the acceptance criteria)."""
    W, hi, lo, tgt, dY = _mk(100, 8, 64, 1, dup_vocab=9)
    nh, nl = jax.jit(apply_rows_split_sgd, static_argnames=("fused",))(
        hi, lo, tgt, dY, 0.1, fused=True)
    rh, rl = _ref_split(hi, lo, tgt, dY, 0.1)
    np.testing.assert_array_equal(np.asarray(combine_split(nh, nl)),
                                  np.asarray(combine_split(rh, rl)))


def test_duplicate_accumulation_explicit():
    """All lookups hit ONE row: update must be w - lr * sum(all grads)."""
    E_ = 8
    W = jnp.asarray(RNG.standard_normal((10, E_)), jnp.float32)
    hi, lo = split_fp32(W)
    tgt = jnp.full((12,), 3, jnp.int32)
    dY = jnp.asarray(RNG.standard_normal((12, E_)), jnp.float32)
    nh, nl = _fused_split(hi, lo, tgt, dY, 0.5, pooling=1)
    got = np.asarray(combine_split(nh, nl))
    want = np.asarray(W).copy()
    acc = np.zeros(E_, np.float32)
    for i in range(12):
        acc = (acc + np.asarray(dY)[i]).astype(np.float32)
    want[3] = want[3] - np.float32(0.5) * acc
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    # every other row untouched, bitwise
    rest = np.setdiff1d(np.arange(10), [3])
    np.testing.assert_array_equal(got[rest], np.asarray(W)[rest])


def test_untouched_rows_never_modified():
    W, hi, lo, tgt, dY = _mk(500, 16, 32, 1, dup_vocab=20)
    nh, nl = _fused_split(hi, lo, tgt, dY, 0.1)
    got = np.asarray(combine_split(nh, nl))
    untouched = np.setdiff1d(np.arange(500), np.asarray(tgt))
    np.testing.assert_array_equal(got[untouched], np.asarray(W)[untouched])


def test_ragged_padded_bags_masked_out():
    """Invalid (padding) lookups — valid=False or out-of-range targets —
    contribute nothing and corrupt no row."""
    M, E_, L = 40, 8, 30
    W = jnp.asarray(RNG.standard_normal((M, E_)), jnp.float32)
    hi, lo = split_fp32(W)
    tgt = jnp.asarray(RNG.integers(0, M, (L,)), jnp.int32)
    dY = jnp.asarray(RNG.standard_normal((L, E_)), jnp.float32)
    valid = jnp.asarray(RNG.integers(0, 2, (L,)).astype(bool))
    nh, nl = _fused_split(hi, lo, tgt, dY, 0.1, valid=valid)
    # reference on the VALID subset only (invalid -> zero grads at tgt 0)
    grad = jnp.where(valid[:, None], dY, 0.0)
    rh, rl = _ref_split(hi, lo, jnp.where(valid, tgt, 0), grad, 0.1)
    np.testing.assert_array_equal(np.asarray(combine_split(nh, nl)),
                                  np.asarray(combine_split(rh, rl)))
    # out-of-range targets are dropped, not clamped into real rows
    tgt_oob = jnp.where(valid, tgt, M + 1000)
    nh2, nl2 = _fused_split(hi, lo, tgt_oob, dY, 0.1)
    np.testing.assert_array_equal(np.asarray(combine_split(nh2, nl2)),
                                  np.asarray(combine_split(rh, rl)))


def test_all_invalid_is_noop():
    W, hi, lo, tgt, dY = _mk(30, 8, 16, 1)
    valid = jnp.zeros((16,), bool)
    nh, nl = _fused_split(hi, lo, tgt, dY, 0.1, valid=valid)
    np.testing.assert_array_equal(np.asarray(combine_split(nh, nl)),
                                  np.asarray(W))


def test_fused_fp32_variant_matches_dedup_semantics():
    M, E_, L, P = 80, 8, 60, 3
    W, _, _, tgt, dY = _mk(M, E_, L, P, dup_vocab=11)
    out = _fused_fp32(W, tgt, dY, 0.1, pooling=P)
    want = np.asarray(W).copy()
    dyn = np.asarray(dY)
    for r in np.unique(np.asarray(tgt)):
        acc = np.zeros(E_, np.float32)
        for i in range(L):
            if int(tgt[i]) == r:
                acc = (acc + dyn[i // P]).astype(np.float32)
        want[r] = want[r] - np.float32(0.1) * acc
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)


def test_bag_update_dispatch():
    """core.embedding.bag_update(method='fused') and bag_update_split."""
    B, S, P, E_, M = 4, 3, 2, 16, 50
    W = jnp.asarray(RNG.standard_normal((M, E_)), jnp.float32)
    g = jnp.asarray(RNG.integers(0, M, (B, S, P)), jnp.int32)
    dY = jnp.asarray(RNG.standard_normal((B, S, E_)), jnp.float32)
    w_f = E.bag_update(W, g, dY, 0.1, method="fused")
    w_s = E.bag_update(W, g, dY, 0.1, method="scatter")
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_s),
                               rtol=1e-5, atol=1e-6)
    hi, lo = split_fp32(W)
    nh, nl = E.bag_update_split(hi, lo, g, dY, 0.1)
    rh, rl = _ref_split(hi, lo, g.reshape(-1),
                        jnp.broadcast_to(dY[:, :, None, :],
                                         (B, S, P, E_)).reshape(-1, E_), 0.1)
    np.testing.assert_array_equal(np.asarray(combine_split(nh, nl)),
                                  np.asarray(combine_split(rh, rl)))


def test_sort_lookups_properties():
    tgt = jnp.asarray([5, 2, 9, 2, 100, -1, 5], jnp.int32)
    w = jnp.asarray([0.5, 1.5, 2.0, 3.0, 4.0, 5.0, 6.0], jnp.float32)
    rows, bags, msk, wgt = EU.sort_lookups(tgt, None, 10, 1, weights=w)
    rn = np.asarray(rows)
    assert (np.diff(rn) >= 0).all()                 # sorted
    assert np.asarray(msk).sum() == 5               # 100 and -1 dropped
    assert (rn < 10).all() and (rn >= 0).all()      # in-range (tail clamped)
    # bag ids of the valid positions point at the original flat slots
    mb = np.asarray(bags)[np.asarray(msk) == 1]
    assert set(mb.tolist()) == {0, 1, 2, 3, 6}
    # weights ride the same permutation as the bag ids
    np.testing.assert_array_equal(np.asarray(wgt),
                                  np.asarray(w)[np.asarray(bags)])
    # no weights -> exact ones
    _, _, _, w1 = EU.sort_lookups(tgt, None, 10, 1)
    np.testing.assert_array_equal(np.asarray(w1), np.ones(7, np.float32))


# ---------------------------------------------------------------------------
# Weighted bags (per-lookup weights) on the fused path
# ---------------------------------------------------------------------------

def test_weighted_split_matches_scaled_reference():
    """Fused weighted update vs jitted reference on pre-scaled grads: the
    kernel scales each lookup's dY row by its weight inside the sorted-
    order pre-reduction.  The compiler contracts scale+accumulate into an
    FMA (one rounding instead of two per lookup), so the weighted result
    is within 1 ulp/step of the pre-scaled reference — NOT bitwise (the
    unweighted path multiplies by exactly 1.0 and keeps its bit-identity
    contract, enforced by the tests above).  Untouched rows stay bitwise
    intact."""
    M, E_, L = 60, 16, 48
    W, hi, lo, tgt, dY = _mk(M, E_, L, 1, dup_vocab=7, seed=3)
    w = jnp.asarray(RNG.standard_normal(L).astype(np.float32))
    nh, nl = _fused_split(hi, lo, tgt, dY, 0.05, weights=w, pooling=1)
    rh, rl = _ref_split(hi, lo, tgt, dY * w[:, None], 0.05)
    np.testing.assert_allclose(np.asarray(combine_split(nh, nl)),
                               np.asarray(combine_split(rh, rl)),
                               rtol=1e-6, atol=1e-6)
    untouched = np.setdiff1d(np.arange(M), np.asarray(tgt))
    np.testing.assert_array_equal(
        np.asarray(combine_split(nh, nl))[untouched],
        np.asarray(W)[untouched])


def test_weighted_fused_bag_update_matches_scatter():
    """bag_update(method='fused') now accepts per-lookup weights and
    matches the weighted scatter-add reference."""
    B, S, P, E_, M = 5, 3, 4, 8, 40
    W = jnp.asarray(RNG.standard_normal((M, E_)), jnp.float32)
    g = jnp.asarray(RNG.integers(0, M // 4, (B, S, P)), jnp.int32)
    dY = jnp.asarray(RNG.standard_normal((B, S, E_)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((B, S, P)), jnp.float32)
    w_f = E.bag_update(W, g, dY, 0.1, weights=w, method="fused")
    w_s = E.bag_update(W, g, dY, 0.1, weights=w, method="scatter")
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_s),
                               rtol=1e-5, atol=1e-6)
    # rows untouched by any lookup stay bitwise intact
    untouched = np.setdiff1d(np.arange(M), np.asarray(g).ravel())
    np.testing.assert_array_equal(np.asarray(w_f)[untouched],
                                  np.asarray(W)[untouched])


def test_weighted_split_bag_update():
    """bag_update_split with weights: pooled (P>1) weighted bags, fused vs
    reference on the weighted grad expansion (1-ulp FMA tolerance)."""
    B, S, P, E_, M = 4, 2, 3, 8, 30
    W = jnp.asarray(RNG.standard_normal((M, E_)), jnp.float32)
    hi, lo = split_fp32(W)
    g = jnp.asarray(RNG.integers(0, M // 3, (B, S, P)), jnp.int32)
    dY = jnp.asarray(RNG.standard_normal((B, S, E_)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((B, S, P)), jnp.float32)
    nh, nl = E.bag_update_split(hi, lo, g, dY, 0.1, weights=w)
    grad = jnp.broadcast_to(dY[:, :, None, :], (B, S, P, E_)) \
        * w[..., None]
    rh, rl = _ref_split(hi, lo, g.reshape(-1), grad.reshape(-1, E_), 0.1)
    np.testing.assert_allclose(np.asarray(combine_split(nh, nl)),
                               np.asarray(combine_split(rh, rl)),
                               rtol=1e-6, atol=1e-6)
    untouched = np.setdiff1d(np.arange(M), np.asarray(g).ravel())
    np.testing.assert_array_equal(
        np.asarray(combine_split(nh, nl))[untouched],
        np.asarray(W)[untouched])


# ---------------------------------------------------------------------------
# Blocked forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,e,n,p", [(500, 96, 40, 7), (64, 64, 13, 3),
                                        (200, 17, 8, 4), (100, 130, 33, 5)])
@pytest.mark.parametrize("bpb", [1, 4, 8])
def test_blocked_forward_matches_ref(rows, e, n, p, bpb):
    W = jnp.asarray(RNG.standard_normal((rows, e)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, rows, (n, p)), jnp.int32)
    out = ops.embedding_bag(W, idx, bags_per_block=bpb, interpret=True)
    r = ref.embedding_bag(W, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=1e-5, atol=1e-5)


def test_blocked_forward_bf16_hi_path():
    """Forward off the bf16 hi half (2 bytes/elem): fp32-accumulated, close
    to the fp32 table within bf16 storage error."""
    W = jnp.asarray(RNG.standard_normal((300, 64)), jnp.float32)
    hi, _ = split_fp32(W)
    idx = jnp.asarray(RNG.integers(0, 300, (24, 6)), jnp.int32)
    out = ops.embedding_bag(hi, idx, interpret=True)
    exact = ref.embedding_bag(hi, idx)     # same storage, jnp oracle
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                               rtol=1e-5, atol=1e-5)
    full = ref.embedding_bag(W, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# End-to-end: train step trajectories identical with fused on/off
# ---------------------------------------------------------------------------

def test_dlrm_step_fused_trajectory_identical():
    from repro.core import dlrm as D
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    base = D.DLRMConfig(name="t", num_dense=8, bottom=(16, 8), top=(16,),
                        table_rows=(50, 30, 20, 10), emb_dim=8, pooling=3,
                        batch=16)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(np.stack([rng.integers(0, m, (16, 3))
                                for m in base.table_rows], 1), jnp.int32)
    batch = {"idx": idx,
             "dense_x": jnp.asarray(rng.standard_normal((16, 8)),
                                    jnp.bfloat16),
             "labels": jnp.asarray(rng.integers(0, 2, (16,)), jnp.float32)}
    out = {}
    for fused in (False, True):
        cfg = dataclasses.replace(base, fused_update=fused)
        state, _ = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
        step, _, _, _ = D.make_train_step(cfg, mesh)
        for _ in range(3):
            state, loss = step(state, batch)
        out[fused] = (float(loss), np.asarray(state["emb"]["hi"], np.float32),
                      np.asarray(state["emb"]["lo"]))
    assert out[False][0] == out[True][0]
    np.testing.assert_array_equal(out[False][1], out[True][1])
    np.testing.assert_array_equal(out[False][2], out[True][2])
