"""Compressed exchange collectives behind the typed ExchangeConfig API
(repro/dist/exchange.py).

Contracts under test:
* API: ExchangeConfig validation; resolve_exchange coercion of the
  deprecated flat kwargs (DeprecationWarning) and the exchange_dtype
  sugar; typed-config/flat-kwarg conflicts rejected; the legacy
  ``split_sgd`` bool sugar warns through the same deprecation path;
  ``parse_hot_sync`` rejects malformed strings.
* ``exchange_dtype='fp32'`` is BIT-IDENTICAL to the pre-config step
  across M in {1,2} x row/table x exchange_impl fused/ring (the default
  config's step is itself pinned against the pre-refactor monolithic
  step in tests/test_pipeline.py, so equality here closes the chain back
  to the pre-PR step).
* ``bf16_sr`` is deterministic: two identical runs agree bitwise, a
  different ``sr_seed`` diverges, and a checkpoint-resume replays the
  exact wire dither (state incl. the ``sr`` counter is bitwise equal to
  the uninterrupted run).
* Degenerations: zero cotangents / zero gradients survive EVERY wire
  format bitwise (state unchanged), and bf16-representable payloads are
  wire-format-invariant.
* The dense error-feedback ``err`` slab round-trips through a
  checkpoint (save -> restore -> continue == uninterrupted, bitwise).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


COMMON = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core.dlrm import DLRMConfig, make_train_step, init_state
    from repro.core import sharded_embedding as se
    from repro.dist.exchange import ExchangeConfig

    mesh = compat.make_mesh((2, 4), ('data', 'model'))
    BASE = DLRMConfig(name='t', num_dense=16, bottom=(32, 8), top=(32,),
                      table_rows=(100, 60, 40, 30, 20, 200, 51, 77),
                      emb_dim=8, pooling=3, batch=32, fused_update=False)

    def mk_batch(seed, cfg, layout):
        rng = np.random.default_rng(seed)
        idx = np.stack([rng.integers(0, max(2, m // 8), (32, 3))
                        for m in cfg.table_rows], 1).astype(np.int32)
        if cfg.emb_mode == 'table' and cfg.idx_input == 'replicated':
            idx = np.asarray(se.permute_indices(layout, jnp.asarray(idx)))
        return {'idx': jnp.asarray(idx),
                'dense_x': jnp.asarray(rng.standard_normal((32, 16)),
                                       jnp.bfloat16),
                'labels': jnp.asarray(rng.integers(0, 2, 32), jnp.float32)}

    def snap(state):
        flat, _ = jax.flatten_util.ravel_pytree(jax.tree.map(
            lambda x: np.asarray(x, np.float32), state))
        return np.asarray(flat)
"""


# ---------------------------------------------------------------------------
# API surface (no mesh needed)
# ---------------------------------------------------------------------------

def test_exchange_config_validation():
    from repro.dist.exchange import ExchangeConfig
    cfg = ExchangeConfig()
    assert (cfg.impl, cfg.dY_dtype, cfg.dense_dtype) == ("fused", "fp32",
                                                         "fp32")
    assert not cfg.needs_sr and not cfg.needs_err
    assert ExchangeConfig(dense_dtype="bf16").needs_err
    assert not ExchangeConfig(dense_dtype="bf16",
                              error_feedback=False).needs_err
    assert ExchangeConfig(dY_dtype="bf16_sr").needs_sr
    assert ExchangeConfig(dense_dtype="bf16_sr").needs_sr
    with pytest.raises(ValueError, match="exchange_impl"):
        ExchangeConfig(impl="smoke")
    with pytest.raises(ValueError, match="dY_dtype"):
        ExchangeConfig(dY_dtype="fp16")
    with pytest.raises(ValueError, match="dense_dtype"):
        ExchangeConfig(dense_dtype="int8")
    with pytest.raises(ValueError, match="num_buckets"):
        ExchangeConfig(num_buckets=0)


def test_resolve_exchange_coercion_and_conflicts():
    import dataclasses as dc
    from repro.dist.exchange import ExchangeConfig, resolve_exchange

    @dc.dataclass
    class M:
        exchange: object = None
        exchange_dtype: object = None
        exchange_impl: object = None
        compress_grads: object = None
        num_buckets: object = None

    # unset flats resolve to the defaults, silently
    assert resolve_exchange(M()) == ExchangeConfig()
    # exchange_dtype is supported sugar (no warning): sets BOTH dtypes
    got = resolve_exchange(M(exchange_dtype="bf16_sr"))
    assert got.dY_dtype == got.dense_dtype == "bf16_sr"
    # deprecated flat kwargs coerce with a DeprecationWarning
    with pytest.warns(DeprecationWarning, match="compress_grads"):
        got = resolve_exchange(M(exchange_impl="ring", compress_grads=True,
                                 num_buckets=2))
    assert got == ExchangeConfig(impl="ring", dense_dtype="bf16",
                                 num_buckets=2)
    with pytest.warns(DeprecationWarning):
        got = resolve_exchange(M(compress_grads=False))
    assert got.dense_dtype == "fp32"
    # typed config + any flat kwarg is a hard error, not a silent pick
    with pytest.raises(ValueError, match="not both"):
        resolve_exchange(M(exchange=ExchangeConfig(), exchange_impl="ring"))
    with pytest.raises(ValueError, match="not both"):
        resolve_exchange(M(exchange=ExchangeConfig(),
                           exchange_dtype="bf16"))
    # the two dense-wire spellings conflict (the deprecation warning for
    # compress_grads still fires first — hence the warns wrapper)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="compress_grads"):
            resolve_exchange(M(exchange_dtype="bf16_sr",
                               compress_grads=True))
    with pytest.raises(TypeError, match="ExchangeConfig"):
        resolve_exchange(M(exchange="bf16"))
    # bad values surface through resolution too
    with pytest.raises(ValueError, match="dY_dtype"):
        resolve_exchange(M(exchange_dtype="fp16"))


def test_split_sgd_sugar_deprecated():
    import dataclasses as dc
    from repro.optim import row as row_optim

    @dc.dataclass
    class M:
        sparse_optimizer: object = None
        split_sgd: object = None
        opt_beta: object = None
        opt_eps: object = None

    # unset -> the split_sgd default, silently
    assert row_optim.resolve(M()).name == "split_sgd"
    with pytest.warns(DeprecationWarning, match="split_sgd"):
        assert row_optim.resolve(M(split_sgd=True)).name == "split_sgd"
    with pytest.warns(DeprecationWarning, match="split_sgd"):
        assert row_optim.resolve(M(split_sgd=False)).name == "sgd"
    # an explicit sparse_optimizer wins and silences the sugar
    assert row_optim.resolve(
        M(sparse_optimizer="sgd", split_sgd=False)).name == "sgd"


def test_parse_hot_sync_validation():
    from repro.core.cache import parse_hot_sync
    assert parse_hot_sync("allreduce") == 1
    assert parse_hot_sync("deferred:3") == 3
    for bad in ("deferred:", "deferred:-1", "deferred:0", "deferred:x",
                "psum", ""):
        with pytest.raises(ValueError, match="hot_sync"):
            parse_hot_sync(bad)


# ---------------------------------------------------------------------------
# Degeneration / identity contracts (subprocess, 8 devices)
# ---------------------------------------------------------------------------

def test_fp32_bit_identity_matrix():
    """exchange_dtype='fp32' == the default-config step, bitwise, across
    M x mode x impl; the typed ExchangeConfig spelling matches the flat
    exchange_impl spelling bitwise too."""
    out = run_sub(COMMON + """
    import warnings
    warnings.simplefilter('ignore', DeprecationWarning)
    for mode in ('row', 'table'):
        for M in (1, 2):
            for impl in ('fused', 'ring'):
                base = dataclasses.replace(BASE, emb_mode=mode,
                                           idx_input='sharded',
                                           microbatches=M)
                variants = {
                    'default': dataclasses.replace(base, exchange_impl=impl),
                    'fp32': dataclasses.replace(base, exchange_impl=impl,
                                                exchange_dtype='fp32'),
                    'typed': dataclasses.replace(
                        base, exchange=ExchangeConfig(impl=impl)),
                }
                res = {}
                for tag, cfg in variants.items():
                    state, layout = init_state(jax.random.PRNGKey(0), cfg,
                                               mesh)
                    step, _, _, _ = make_train_step(cfg, mesh)
                    batch = mk_batch(0, cfg, layout)
                    for _ in range(2):
                        state, loss = step(state, batch)
                    res[tag] = (float(loss), snap(state))
                for tag in ('fp32', 'typed'):
                    assert res['default'][0] == res[tag][0], (mode, M, impl,
                                                              tag)
                    assert np.array_equal(res['default'][1], res[tag][1]), (
                        mode, M, impl, tag)
                print(mode, M, impl, 'FP32_EQ')
    """)
    assert out.count("FP32_EQ") == 8


def test_wire_degenerations_bitwise():
    """Zero cotangents / zero gradients survive every wire format bitwise,
    and bf16-representable payloads are wire-format-invariant (unit-level,
    inside shard_map, both modes)."""
    out = run_sub(COMMON + """
    from jax.sharding import PartitionSpec as P
    from repro.core.dlrm import as_hybrid_def
    from repro.core import hybrid as H
    from repro.optim import data_parallel as dp

    for mode in ('row', 'table'):
        cfg = dataclasses.replace(BASE, emb_mode=mode)
        mdef = as_hybrid_def(cfg)
        layout = H.make_layout(mdef, mesh)
        emb_ax, replica_ax = H._emb_axes(mdef, mesh)
        S = layout.num_orig_slots

        def gd(dY, dt):
            f = compat.shard_map(
                lambda v: se.gather_dY(layout, v, emb_ax, replica_ax,
                                       wire_dtype=dt, seed=jnp.int32(5),
                                       tag=1),
                mesh=mesh, in_specs=P(('data', 'model'), None, None),
                out_specs=(P(None, None, None) if mode == 'row'
                           else P(None, 'model', None)),
                check_vma=False)
            return np.asarray(jax.jit(f)(dY))

        zeros = jnp.zeros((32, S, 8), jnp.float32)
        # bf16-representable payload: small integers are exact in bf16
        rng = np.random.default_rng(3)
        exact = jnp.asarray(rng.integers(-8, 9, (32, S, 8)), jnp.float32)
        for dt in ('fp32', 'bf16', 'bf16_sr'):
            assert (gd(zeros, dt) == 0).all(), (mode, dt)
            assert np.array_equal(gd(exact, dt), gd(exact, 'fp32')), (
                mode, dt)
        print(mode, 'GATHER_DEGEN_OK')

    # dense RS+AG: zero grads leave (hi, lo, err) bitwise unchanged under
    # every wire format
    params = {'w': jnp.arange(64, dtype=jnp.float32) / 7.0,
              'b': jnp.ones((16,), jnp.float32) / 3.0}
    for dt, with_err in (('fp32', False), ('bf16', True), ('bf16', False),
                         ('bf16_sr', False)):
        arrays = dp.dp_global_arrays(params, 8, compress=with_err,
                                     num_buckets=2)
        def one(dense, grads):
            st = dp.DPState(hi=dense['hi'], lo_shard=dense['lo'],
                            mom_shard=None, err_shard=dense['err'])
            st2 = dp.rs_ag_split_sgd(st, grads, 0.1, ('data', 'model'),
                                     num_buckets=2, mean=False,
                                     wire_dtype=dt, seed=jnp.int32(3))
            return {'hi': st2.hi, 'lo': st2.lo_shard, 'err': st2.err_shard}
        specs = {'hi': jax.tree.map(lambda _: P(), arrays['hi']),
                 'lo': P(('data', 'model')),
                 'err': P(('data', 'model')) if with_err else None}
        f = jax.jit(compat.shard_map(
            one, mesh=mesh,
            in_specs=(specs, jax.tree.map(lambda _: P(), params)),
            out_specs=specs, check_vma=False))
        dense = {'hi': arrays['hi'], 'lo': arrays['lo'],
                 'err': arrays['err']}
        out = f(dense, jax.tree.map(jnp.zeros_like, params))
        for k in ('w', 'b'):
            assert np.array_equal(np.asarray(out['hi'][k]),
                                  np.asarray(dense['hi'][k])), (dt, k)
        assert np.array_equal(np.asarray(out['lo']),
                              np.asarray(dense['lo'])), dt
        if with_err:
            assert (np.asarray(out['err']) == 0).all(), dt
        print(dt, with_err, 'RS_DEGEN_OK')
    """)
    assert out.count("GATHER_DEGEN_OK") == 2
    assert out.count("RS_DEGEN_OK") == 4


# ---------------------------------------------------------------------------
# bf16_sr determinism + checkpoint resume (subprocess, 8 devices)
# ---------------------------------------------------------------------------

def test_bf16_sr_deterministic_and_seeded():
    out = run_sub(COMMON + """
    for mode in ('row', 'table'):
        res = {}
        for tag, seed in (('a', 0), ('b', 0), ('c', 11)):
            cfg = dataclasses.replace(BASE, emb_mode=mode,
                                      exchange_dtype='bf16_sr',
                                      microbatches=2, sr_seed=seed)
            state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
            step, _, _, _ = make_train_step(cfg, mesh)
            batch = mk_batch(0, cfg, layout)
            for _ in range(3):
                state, loss = step(state, batch)
            res[tag] = (float(loss), snap(state))
        assert res['a'][0] == res['b'][0], mode
        assert np.array_equal(res['a'][1], res['b'][1]), mode
        # a different sr_seed dithers differently (the wire is live)
        assert not np.array_equal(res['a'][1], res['c'][1]), mode
        print(mode, 'SR_DET_OK')
    """)
    assert out.count("SR_DET_OK") == 2


def test_bf16_sr_checkpoint_resume_replays_wire_dither():
    out = run_sub(COMMON + """
    import tempfile
    from repro.checkpoint import CheckpointManager

    cfg = dataclasses.replace(BASE, emb_mode='table', idx_input='sharded',
                              exchange_dtype='bf16_sr', microbatches=2)
    step, shardings, _, _ = make_train_step(cfg, mesh)

    state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
    structs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    batch = mk_batch(0, cfg, layout)
    straight = state
    for _ in range(4):
        straight, loss_s = step(straight, batch)

    state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
    for _ in range(2):
        state, _ = step(state, batch)
    assert int(state['sr']) == 2
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(2, state, blocking=True)
        got_step, restored = mgr.restore(structs)
        assert got_step == 2 and int(restored['sr']) == 2
        resumed = jax.device_put(restored, shardings)
    for _ in range(2):
        resumed, loss_r = step(resumed, batch)

    assert float(loss_s) == float(loss_r)
    assert int(resumed['sr']) == int(straight['sr']) == 4
    assert np.array_equal(snap(straight), snap(resumed))
    print('SR_RESUME_OK')
    """)
    assert "SR_RESUME_OK" in out


def test_err_slab_checkpoint_roundtrip():
    """The dense error-feedback residual is step-dependent state: dropping
    it on restore would silently change the next update.  (The repo's
    dense grads are natively bf16 — the bf16 wire is lossless for them —
    so a fresh run keeps the slab at zero; a deterministic nonzero slab is
    injected to make the round-trip non-vacuous.)  save -> restore ->
    continue == uninterrupted, bitwise, err slab included; and the
    injected slab demonstrably changes the next update."""
    out = run_sub(COMMON + """
    import tempfile
    from repro.checkpoint import CheckpointManager

    cfg = dataclasses.replace(
        BASE, emb_mode='table',
        exchange=ExchangeConfig(dense_dtype='bf16'))
    step, shardings, _, _ = make_train_step(cfg, mesh)

    state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
    structs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    batch = mk_batch(0, cfg, layout)
    err0 = np.asarray(state['dense']['err'])
    assert (err0 == 0).all()
    rng = np.random.default_rng(7)
    inj = jnp.asarray(rng.standard_normal(err0.shape) * 1e-2, jnp.float32)
    state['dense']['err'] = inj
    state = jax.device_put(state, shardings)

    with tempfile.TemporaryDirectory() as d:
        # save FIRST: the jitted step donates its input state buffers
        mgr = CheckpointManager(d)
        mgr.save(0, state, blocking=True)

        straight = state
        for _ in range(3):
            straight, loss_s = step(straight, batch)

        _, restored = mgr.restore(structs)
        # the slab survived the round-trip bit-for-bit (and is nonzero)
        assert np.array_equal(np.asarray(restored['dense']['err']),
                              np.asarray(inj))
        resumed = jax.device_put(restored, shardings)
    for _ in range(3):
        resumed, loss_r = step(resumed, batch)

    assert float(loss_s) == float(loss_r)
    assert np.array_equal(snap(straight), snap(resumed))

    # the slab is LIVE state: a zeroed slab yields a different trajectory
    clean, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
    for _ in range(3):
        clean, _ = step(clean, batch)
    assert not np.array_equal(snap(clean), snap(straight))
    print('ERR_RESUME_OK')
    """)
    assert "ERR_RESUME_OK" in out
