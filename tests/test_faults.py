"""Fault-injection harness + kill-matrix resilience drills.

The contract under test (docs/resilience.md): for EVERY injected fault —
each checkpoint write phase, a corrupted latest checkpoint, loader death,
SIGTERM mid-run, an elastic shard-count change — training resumes BITWISE
from the newest *verified* checkpoint, never from a corrupt one.

Layers covered here:

* ``repro/faults/plan.py``  — deterministic seeded/step-indexed FaultPlan,
  action semantics, the explicit hook-point protocol;
* ``repro/faults/log.py``   — structured failure-event log;
* ``repro/checkpoint``      — checksums + format version, verified restore,
  ``latest_valid_step`` fallback, bounded retry, async-failure surfacing;
* ``repro/data/pipeline.py``— loader fault hook, bounded worker retry,
  sticky-dead-after-poison;
* ``repro/train/loop.py``   — preemption drills, skip-batch budget,
  final-checkpoint-in-finally, hard-crash semantics;
* the DLRM integration     — the real pipelined step + momentum_bf16 (the
  stochastic-rounding ``sr`` counter must survive recovery) and the
  elastic ``reshard_store`` N->N±k drill.
"""

import itertools
import json
import threading
import time
import warnings

import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, CheckpointError,
                              CheckpointManager)
from repro.data.pipeline import ThreadedIterator
from repro.faults import (Fault, FaultPlan, FailureLog, InjectedCrash,
                          corrupt_checkpoint)
from repro.train import TrainLoop, TrainLoopConfig, prefetch_to_device

# ---------------------------------------------------------------------------
# FaultPlan / FailureLog unit behaviour
# ---------------------------------------------------------------------------


def test_fault_plan_step_indexed_and_counted():
    plan = FaultPlan([Fault("train.step", step=3, times=2)])
    for s in (0, 1, 2):
        assert plan.fire("train.step", step=s) is None
    with pytest.raises(RuntimeError, match="injected fault"):
        plan.fire("train.step", step=3)
    # times=2: the same step-match fires again, then disarms
    with pytest.raises(RuntimeError):
        plan.fire("train.step", step=3)
    assert plan.fire("train.step", step=3) is None
    assert plan.count("train.step") == 2
    assert plan.fired == [("train.step", 3, "raise"), ("train.step", 3, "raise")]


def test_fault_plan_auto_counter_and_unknown_site():
    # with step=None at the hook, firing is indexed by per-site call count
    plan = FaultPlan([Fault("loader.next", step=2)])
    assert plan.fire("loader.next") is None
    assert plan.fire("loader.next") is None
    with pytest.raises(RuntimeError):
        plan.fire("loader.next")
    # un-armed sites are free
    assert plan.fire("ckpt.commit") is None
    with pytest.raises(ValueError, match="unknown fault action"):
        Fault("x", action="explode")


def test_fault_plan_actions():
    with pytest.raises(InjectedCrash):
        FaultPlan.single("ckpt.commit", action="crash").fire("ckpt.commit")
    assert isinstance(InjectedCrash("x"), BaseException)
    assert not isinstance(InjectedCrash("x"), Exception)  # retries can't eat it
    t0 = time.perf_counter()
    f = FaultPlan.single("train.step", action="stall", delay_s=0.05).fire("train.step")
    assert f.action == "stall" and time.perf_counter() - t0 >= 0.045
    # marker actions return the fault for the site to interpret
    f = FaultPlan.single("train.step", action="preempt").fire("train.step")
    assert f.action == "preempt"
    exc = OSError(28, "No space left on device")
    with pytest.raises(OSError, match="No space left"):
        FaultPlan.single("ckpt.write.arrays", exc=exc).fire("ckpt.write.arrays")


def test_fault_plan_random_is_seed_deterministic():
    a = FaultPlan.random(7, ["train.step", "loader.next"], steps=50, rate=0.2)
    b = FaultPlan.random(7, ["train.step", "loader.next"], steps=50, rate=0.2)
    sched_a = [(f.site, f.step) for f in a._faults]
    sched_b = [(f.site, f.step) for f in b._faults]
    assert sched_a == sched_b and len(sched_a) > 0
    c = FaultPlan.random(8, ["train.step", "loader.next"], steps=50, rate=0.2)
    assert sched_a != [(f.site, f.step) for f in c._faults]


def test_failure_log_records_and_jsonl(tmp_path):
    log = FailureLog(tmp_path / "events.jsonl")
    log.record("ckpt_write_retry", step=3, attempt=0)
    log.record("ckpt_write_retry", step=3, attempt=1)
    log.record("preempted", step=9)
    assert log.counts() == {"ckpt_write_retry": 2, "preempted": 1}
    assert [e["attempt"] for e in log.of_kind("ckpt_write_retry")] == [0, 1]
    lines = [json.loads(ln) for ln in
             (tmp_path / "events.jsonl").read_text().splitlines()]
    assert [ln["kind"] for ln in lines] == ["ckpt_write_retry",
                                           "ckpt_write_retry", "preempted"]
    # a plan wired to the log records its injections too
    plan = FaultPlan([Fault("train.step", action="preempt", step=0)], log=log)
    plan.fire("train.step", step=0)
    assert log.counts()["fault_injected"] == 1


def test_failure_log_survives_kill_after_event(tmp_path):
    """The jsonl mirror flushes AND fsyncs per event: a process killed via
    os._exit immediately after record() — no interpreter shutdown, no
    atexit, no buffered-file flushing — must still leave the event on
    disk.  This is the post-mortem contract the log exists for."""
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    path = tmp_path / "events.jsonl"
    code = (
        f"import os, sys\n"
        f"sys.path.insert(0, {str(src)!r})\n"
        f"from repro.faults import FailureLog\n"
        f"log = FailureLog({str(path)!r})\n"
        f"log.record('ckpt_write_retry', step=7, attempt=1)\n"
        f"os._exit(86)\n"
    )
    r = subprocess.run([sys.executable, "-c", code], timeout=120)
    assert r.returncode == 86
    events = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(events) == 1
    assert events[0]["kind"] == "ckpt_write_retry"
    assert events[0]["step"] == 7 and events[0]["attempt"] == 1


def test_failure_log_mirrors_trace_instants(tmp_path):
    from repro import telemetry

    tr = telemetry.configure(enabled=True)
    try:
        log = FailureLog(tmp_path / "events.jsonl")
        log.record("batch_skipped", step=4, error="OSError")
        inst = [e for e in tr.events() if e.get("ph") == "i"]
        assert [e["name"] for e in inst] == ["fault/batch_skipped"]
        assert inst[0]["args"] == {"step": "4", "error": "OSError"}
        tracks = {e["args"]["name"] for e in tr.events()
                  if e.get("ph") == "M"}
        assert "faults" in tracks
    finally:
        telemetry.configure(enabled=False)
        tr.reset()


# ---------------------------------------------------------------------------
# Checkpoint layer: verification, fallback, retry, async surfacing
# ---------------------------------------------------------------------------


def _np_state(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((64, 8)).astype(np.float32),
            "sr": np.int32(seed)}


def test_checkpoint_meta_carries_version_and_checksums(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _np_state(), blocking=True)
    meta = json.loads((tmp_path / "step_1" / "meta.json").read_text())
    assert meta["format_version"] == 2
    assert set(meta["checksums"]) == set(meta["keys"]) == {"sr", "w"}
    mgr.verify(1)  # round-trips
    # future format versions refuse instead of misreading
    meta["format_version"] = 99
    (tmp_path / "step_1" / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(CheckpointCorruptError, match="newer than this reader"):
        mgr.verify(1)


@pytest.mark.parametrize("mode", ["flip", "truncate", "no_meta", "meta_garbage"])
def test_latest_valid_step_skips_corruption(tmp_path, mode):
    log = FailureLog()
    mgr = CheckpointManager(tmp_path, event_log=log)
    for s in (2, 4, 6):
        mgr.save(s, _np_state(s), blocking=True)
    corrupt_checkpoint(tmp_path, 6, mode)
    assert mgr.latest_step() == 6          # the naive scan still sees it
    assert mgr.latest_valid_step() == 4    # the verified scan does not
    step, got = mgr.restore(_np_state())
    assert step == 4
    np.testing.assert_array_equal(got["w"], _np_state(4)["w"])
    assert log.counts()["ckpt_corrupt_skipped"] >= 1
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(_np_state(), step=6)   # explicitly asking for it refuses


def test_restore_treedef_mismatch_refuses(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _np_state(), blocking=True)
    with pytest.raises(CheckpointError, match="tree structure"):
        mgr.restore({"w": _np_state()["w"]})  # missing the "sr" leaf


def test_transient_write_retries_then_succeeds(tmp_path):
    log = FailureLog()
    plan = FaultPlan([Fault("ckpt.write.arrays", times=2,
                            exc=lambda: OSError(28, "No space left on device"))])
    mgr = CheckpointManager(tmp_path, retries=2, backoff_s=0.001,
                            faults=plan, event_log=log)
    mgr.save(5, _np_state(), blocking=True)   # 2 ENOSPC hits, 3rd attempt lands
    assert mgr.latest_valid_step() == 5
    assert log.counts()["ckpt_write_retry"] == 2


def test_exhausted_write_retries_raise(tmp_path):
    plan = FaultPlan([Fault("ckpt.write.meta", times=10,
                            exc=lambda: OSError(28, "No space left on device"))])
    mgr = CheckpointManager(tmp_path, retries=1, backoff_s=0.001, faults=plan)
    with pytest.raises(CheckpointError, match="failed after 2 attempts"):
        mgr.save(5, _np_state(), blocking=True)
    assert mgr.latest_valid_step() is None


def test_async_save_failure_surfaces_at_next_save_and_wait(tmp_path):
    """Satellite regression: a background-thread save failure used to die
    silently with the daemon thread; it must re-raise at the next save()
    or wait()."""
    plan = FaultPlan([Fault("ckpt.write.arrays", times=10,
                            exc=lambda: OSError(5, "Input/output error"))])
    mgr = CheckpointManager(tmp_path, retries=0, faults=plan)
    mgr.save(1, _np_state(), blocking=False)
    with pytest.raises(CheckpointError, match="background checkpoint save failed"):
        mgr.wait()
    # the pending error is one-shot: surfaced once, then cleared
    mgr.wait()
    mgr.save(2, _np_state(), blocking=False)
    with pytest.raises(CheckpointError, match="background checkpoint save failed"):
        mgr.save(3, _np_state(), blocking=False)


def test_torn_commit_is_detected(tmp_path):
    """The 'partial' action commits a torn arrays.npz then crashes — the
    case atomic rename cannot catch and checksums must."""
    plan = FaultPlan([Fault("ckpt.write.arrays", action="partial", step=4)])
    mgr = CheckpointManager(tmp_path, faults=plan)
    mgr.save(2, _np_state(2), blocking=True)
    with pytest.raises(InjectedCrash):
        mgr.save(4, _np_state(4), blocking=True)
    assert 4 in mgr.steps()                # it LOOKS committed...
    assert not mgr.is_valid(4)             # ...but does not verify
    assert mgr.latest_valid_step() == 2
    step, got = mgr.restore(_np_state())
    assert step == 2
    np.testing.assert_array_equal(got["w"], _np_state(2)["w"])


def test_crash_before_replace_leaves_tmp_only(tmp_path):
    plan = FaultPlan([Fault("ckpt.commit", action="crash")])
    mgr = CheckpointManager(tmp_path, faults=plan)
    with pytest.raises(InjectedCrash):
        mgr.save(3, _np_state(), blocking=True)
    assert (tmp_path / "step_3.tmp").exists()
    assert mgr.steps() == []               # tmp dirs are never scanned
    mgr.save(3, _np_state(), blocking=True)  # re-save cleans the tmp
    assert mgr.latest_valid_step() == 3 and not (tmp_path / "step_3.tmp").exists()


# ---------------------------------------------------------------------------
# Loader layer: fault hook, bounded retry, sticky-dead
# ---------------------------------------------------------------------------


class _RetryableSource:
    """__next__ can be called again after a failure (mmap-style reader).
    Failures are transient: pull index ``i`` fails once, then succeeds."""

    def __init__(self, n, fail_pulls=(), exc=None):
        self.n = n
        self.i = 0
        self.fail_pulls = set(fail_pulls)
        self.exc = exc or RuntimeError("shard read failed")

    def __iter__(self):
        return self

    def __next__(self):
        if self.i in self.fail_pulls:
            self.fail_pulls.discard(self.i)
            raise self.exc
        if self.i >= self.n:
            raise StopIteration
        self.i += 1
        return {"x": np.full((8,), self.i - 1, np.float32)}


def test_threaded_iterator_retries_transient_faults():
    src = _RetryableSource(6, fail_pulls=(1, 3))
    it = ThreadedIterator(src, retries=2, retry_backoff_s=0.001)
    got = [int(b["x"][0]) for b in it]
    assert got == list(range(6))           # nothing lost, order kept
    # full stats contract after retry-then-recover: the heartbeat reads
    # this dict verbatim, so its keys and counters are pinned
    st = it.stats
    assert set(st) == {"prep_s", "wait_s", "batches", "retries"}
    assert st["batches"] == 6
    assert st["retries"] == 2
    assert st["prep_s"] > 0.0 and st["wait_s"] >= 0.0


def test_threaded_iterator_exhausted_retries_poison():
    class AlwaysFails:
        def __init__(self):
            self.i = 0

        def __iter__(self):
            return self

        def __next__(self):
            if self.i >= 2:
                raise RuntimeError("permanent decode failure")
            self.i += 1
            return {"x": np.full((8,), self.i - 1, np.float32)}

    it = ThreadedIterator(AlwaysFails(), retries=2, retry_backoff_s=0.001)
    assert int(next(it)["x"][0]) == 0
    assert int(next(it)["x"][0]) == 1
    with pytest.raises(RuntimeError, match="permanent decode failure"):
        for _ in range(10):
            next(it)
    assert it.stats["retries"] == 2


def test_threaded_iterator_sticky_dead_after_poison():
    """A consumer that absorbs the poison exception (skip-batch budget)
    and pulls again must get StopIteration, not a hang."""

    class Dies:
        def __init__(self):
            self.i = 0

        def __iter__(self):
            return self

        def __next__(self):
            if self.i >= 2:
                raise RuntimeError("loader died")
            self.i += 1
            return self.i

    it = ThreadedIterator(Dies())
    assert next(it) == 1 and next(it) == 2
    with pytest.raises(RuntimeError, match="loader died"):
        next(it)
    with pytest.raises(StopIteration):
        next(it)                            # sticky-dead, no deadlock


def test_loader_fault_hook_injects_death_and_stall():
    # death on the 3rd pull
    plan = FaultPlan([Fault("loader.next", step=2)])
    it = ThreadedIterator(({"x": i} for i in range(10)), faults=plan)
    assert next(it)["x"] == 0 and next(it)["x"] == 1
    with pytest.raises(RuntimeError, match="injected fault"):
        next(it)
    # a stall delays but loses nothing
    plan = FaultPlan([Fault("loader.next", step=1, action="stall", delay_s=0.05)])
    it = ThreadedIterator(({"x": i} for i in range(4)), faults=plan)
    assert [b["x"] for b in it] == [0, 1, 2, 3]
    assert plan.count("loader.next") == 1


def test_prefetch_to_device_forwards_faults():
    plan = FaultPlan([Fault("loader.next", step=1)])
    it = prefetch_to_device(({"x": np.int32(i)} for i in range(8)), size=2,
                            faults=plan)
    assert int(np.asarray(next(it)["x"])) == 0
    with pytest.raises(RuntimeError, match="injected fault"):
        for _ in range(8):
            next(it)


# ---------------------------------------------------------------------------
# Train-loop drills on a deterministic toy model
# ---------------------------------------------------------------------------


def _toy_step(state, batch):
    new = {"w": state["w"] * np.float32(0.999) + batch["x"],
           "sr": state["sr"] + np.int32(1)}
    return new, float(np.sum(new["w"]))


def _toy_init():
    return {"w": np.arange(8, dtype=np.float32), "sr": np.int32(0)}


def _toy_stream(start=0):
    def batch(i):
        rng = np.random.default_rng(1000 + i)  # pure function of the step
        return {"x": rng.standard_normal(8).astype(np.float32)}

    return (batch(i) for i in itertools.count(start))


def _toy_reference(steps=12):
    state = _toy_init()
    stream = _toy_stream()
    for _ in range(steps):
        state, _ = _toy_step(state, next(stream))
    return state


def _resume_and_finish(ckpt_dir, steps=12, **loop_kw):
    """Restart from whatever is on disk and run to completion."""
    loop = TrainLoop(TrainLoopConfig(steps=steps, ckpt_dir=str(ckpt_dir),
                                     ckpt_every=3, log_every=1000),
                     _toy_step, _toy_init(), iter(()), **loop_kw)
    loop.batches = _toy_stream(loop.start_step)
    return loop.run(), loop


KILL_MATRIX = [
    ("arrays_crash", [Fault("ckpt.write.arrays", action="crash")]),
    ("arrays_torn_commit", [Fault("ckpt.write.arrays", action="partial")]),
    ("meta_crash", [Fault("ckpt.write.meta", action="crash")]),
    ("commit_crash", [Fault("ckpt.commit", action="crash")]),
    ("enospc_exhausted", [Fault("ckpt.write.arrays", times=10,
                                exc=lambda: OSError(28, "No space left"))]),
    ("loader_death", [Fault("loader.next", step=7)]),
    ("sigterm_mid_run", [Fault("train.step", action="sigterm", step=7)]),
    ("preempt_flag", [Fault("train.step", action="preempt", step=5)]),
]


@pytest.mark.parametrize("name,faults", KILL_MATRIX, ids=[k[0] for k in KILL_MATRIX])
def test_kill_matrix_resumes_bitwise(tmp_path, name, faults):
    """THE acceptance drill: inject the fault, let the run die (or stop),
    restart from disk, and require the final state to be BITWISE equal to
    an uninterrupted run — the resume must come from the newest VERIFIED
    checkpoint and replay the exact missing steps."""
    want = _toy_reference(12)
    log = FailureLog()
    plan = FaultPlan(faults, log=log)
    batches = (ThreadedIterator(_toy_stream(), faults=plan)
               if name == "loader_death" else _toy_stream())
    loop = TrainLoop(TrainLoopConfig(steps=12, ckpt_dir=str(tmp_path),
                                     ckpt_every=3, log_every=1000),
                     _toy_step, _toy_init(), batches, faults=plan,
                     event_log=log)
    died = None
    try:
        loop.run()
    except BaseException as e:  # noqa: BLE001 — drills die in many ways
        died = e
    assert plan.count() >= 1, "the drill must actually fire"
    if name in ("sigterm_mid_run", "preempt_flag"):
        assert died is None                 # preemption is a clean stop

    got, loop2 = _resume_and_finish(tmp_path, event_log=log)
    assert 0 <= loop2.start_step <= 12
    np.testing.assert_array_equal(got["w"], want["w"])
    assert got["sr"] == want["sr"]
    # and whatever checkpoint it resumed from verifies
    if loop2.start_step:
        CheckpointManager(tmp_path).verify(loop2.start_step)


def test_corrupt_latest_checkpoint_drill(tmp_path):
    """Bit-rot after commit: run to step 9, corrupt the newest checkpoint,
    restart — the resume must fall back to the older verified one and
    still reach the bitwise-identical final state."""
    want = _toy_reference(12)
    loop = TrainLoop(TrainLoopConfig(steps=9, ckpt_dir=str(tmp_path),
                                     ckpt_every=3, log_every=1000),
                     _toy_step, _toy_init(), _toy_stream())
    loop.run()
    assert CheckpointManager(tmp_path).latest_step() == 9
    corrupt_checkpoint(tmp_path, 9, "flip")
    log = FailureLog()
    got, loop2 = _resume_and_finish(tmp_path, event_log=log)
    assert loop2.start_step == 6           # fell back past the corrupt 9
    assert log.counts()["ckpt_corrupt_skipped"] >= 1
    np.testing.assert_array_equal(got["w"], want["w"])
    assert got["sr"] == want["sr"]


def test_sigterm_preemption_checkpoints_and_resumes(tmp_path):
    """Preemption drill with a REAL signal: SIGTERM delivered mid-run
    stops the loop at a step boundary and commits a final checkpoint
    (nothing lost beyond the configured cadence)."""
    plan = FaultPlan([Fault("train.step", action="sigterm", step=7)])
    loop = TrainLoop(TrainLoopConfig(steps=100, ckpt_dir=str(tmp_path),
                                     ckpt_every=50, log_every=1000),
                     _toy_step, _toy_init(), _toy_stream(), faults=plan)
    loop.run()
    assert len(loop.losses) == 8           # step 7 completed, then stopped
    assert CheckpointManager(tmp_path).latest_valid_step() == 8


def test_run_off_main_thread_degrades_gracefully(tmp_path):
    """Satellite regression: signal.signal raises ValueError off the main
    thread; the loop must warn and still run (preemption via _stop)."""
    result = {}

    def target():
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            plan = FaultPlan([Fault("train.step", action="preempt", step=2)])
            loop = TrainLoop(TrainLoopConfig(steps=10, ckpt_dir=str(tmp_path),
                                             ckpt_every=100, log_every=1000),
                             _toy_step, _toy_init(), _toy_stream(),
                             faults=plan)
            loop.run()
            result["warned"] = any("main thread" in str(w.message)
                                   for w in caught)
            result["losses"] = len(loop.losses)

    t = threading.Thread(target=target)
    t.start()
    t.join(timeout=60)
    assert not t.is_alive()
    assert result["warned"]
    assert result["losses"] == 3           # preempted after step 2 completed
    assert CheckpointManager(tmp_path).latest_valid_step() == 3


def test_skip_batch_budget_counts_and_bounds():
    log = FailureLog()
    loop = TrainLoop(TrainLoopConfig(steps=8, log_every=1000,
                                     skip_batch_budget=2),
                     _toy_step, _toy_init(),
                     _RetryableSource(50, fail_pulls=(2, 5)), event_log=log)
    loop.run()
    assert loop.skipped_batches == 2
    assert len(loop.losses) == 8
    assert log.counts()["batch_skipped"] == 2
    # budget exhausted -> the third transient failure propagates
    loop = TrainLoop(TrainLoopConfig(steps=8, log_every=1000,
                                     skip_batch_budget=2),
                     _toy_step, _toy_init(),
                     _RetryableSource(50, fail_pulls=(1, 2, 3)))
    with pytest.raises(RuntimeError, match="shard read failed"):
        loop.run()
    assert loop.skipped_batches == 2


def test_dead_prefetch_loader_within_budget_ends_cleanly(tmp_path):
    """A loader that dies permanently under a skip budget: the poison is
    absorbed, the sticky-dead stream reports exhaustion, and the loop ends
    at the last completed step WITH a final checkpoint — no hang."""

    class DiesAt:
        def __init__(self, n):
            self.n = n
            self.i = 0

        def __iter__(self):
            return self

        def __next__(self):
            if self.i >= self.n:
                raise RuntimeError("loader died for good")
            self.i += 1
            return {"x": np.full((8,), 0.01, np.float32)}

    loop = TrainLoop(TrainLoopConfig(steps=50, ckpt_dir=str(tmp_path),
                                     ckpt_every=100, log_every=1000,
                                     prefetch=2, skip_batch_budget=1),
                     _toy_step, _toy_init(), DiesAt(5))
    loop.run()                              # must not raise or hang
    assert len(loop.losses) == 5
    assert loop.skipped_batches == 1
    assert CheckpointManager(tmp_path).latest_valid_step() == 5


def test_injected_stall_registers_as_straggler():
    plan = FaultPlan([Fault("train.step", action="stall", step=12,
                            delay_s=0.05)])
    loop = TrainLoop(TrainLoopConfig(steps=15, log_every=1000),
                     _toy_step, _toy_init(), _toy_stream(), faults=plan)
    loop.run()
    assert 12 in [e[0] for e in loop.monitor.events]


# ---------------------------------------------------------------------------
# DLRM integration: pipelined step + sr counter + elastic reshard drill
# ---------------------------------------------------------------------------


def _dlrm_cfg():
    from repro.core.dlrm import DLRMConfig
    return DLRMConfig(name="drill", num_dense=8, bottom=(16, 8), top=(16,),
                      table_rows=(50, 30, 20, 10), emb_dim=8, pooling=3,
                      batch=16, sparse_optimizer="momentum_bf16", sr_seed=5)


def _dlrm_batch(i):
    import jax.numpy as jnp
    rng = np.random.default_rng(2000 + i)
    idx = np.stack([rng.integers(0, max(2, m // 6), (16, 3))
                    for m in (50, 30, 20, 10)], 1).astype(np.int32)
    return {"idx": jnp.asarray(idx),
            "dense_x": jnp.asarray(rng.standard_normal((16, 8)), jnp.bfloat16),
            "labels": jnp.asarray(rng.integers(0, 2, (16,)), jnp.float32)}


def _dlrm_setup():
    """The step donates its input state buffers, so every run chain needs
    a FRESH initial state — ``fresh()`` re-inits from the same PRNG key
    (bitwise identical every time)."""
    import jax
    from repro.core import dlrm as D
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = _dlrm_cfg()
    step, shardings, _, _ = D.make_train_step(cfg, mesh)

    def fresh():
        state, _ = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
        return state

    _, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    return cfg, step, shardings, fresh, layout


def _dlrm_stream(start=0):
    return (_dlrm_batch(i) for i in itertools.count(start))


def test_dlrm_crash_resume_bitwise_including_sr(tmp_path):
    """Kill-matrix on the REAL pipelined DLRM step with the compressed
    momentum_bf16 optimizer: a crash while writing a checkpoint must
    resume bitwise — including the stochastic-rounding ``sr`` counter, or
    the dither replays wrong and every later step drifts."""
    cfg, step, shardings, fresh, _ = _dlrm_setup()

    # uninterrupted reference: 6 steps, snapshotting step 2 (the resume point)
    want = fresh()
    ref2_sr = None
    s = _dlrm_stream()
    for i in range(6):
        want, _ = step(want, next(s))
        if i == 1:
            ref2_sr = int(want["sr"])
    want_emb = {k: np.asarray(v) for k, v in want["emb"].items()}
    want_sr = int(want["sr"])

    # drilled run: hard crash while writing the step-4 checkpoint
    plan = FaultPlan([Fault("ckpt.write.arrays", action="crash", step=4)])
    loop = TrainLoop(TrainLoopConfig(steps=6, ckpt_dir=str(tmp_path),
                                     ckpt_every=2, log_every=1000),
                     step, fresh(), _dlrm_stream(), faults=plan)
    with pytest.raises(InjectedCrash):
        loop.run()

    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_valid_step() == 2    # the step-4 save died mid-write
    loop2 = TrainLoop(TrainLoopConfig(steps=6, ckpt_dir=str(tmp_path),
                                      ckpt_every=2, log_every=1000),
                      step, fresh(), iter(()), state_shardings=shardings)
    assert loop2.start_step == 2
    assert int(loop2.state["sr"]) == ref2_sr
    loop2.batches = _dlrm_stream(loop2.start_step)
    got = loop2.run()
    assert int(got["sr"]) == want_sr
    for k, v in want_emb.items():
        np.testing.assert_array_equal(np.asarray(got["emb"][k]), v), k


def test_dlrm_elastic_reshard_restart_bitwise(tmp_path):
    """Elastic N->N±k drill: checkpoint, re-lay-out the embedding store
    through reshard_store onto a different shard count and back (the row
    padding / bin packing changes both ways), resume — bitwise equal to
    the uninterrupted run.  Every slab (weight halves AND per-row
    optimizer state) must survive the hops with dtype and content intact."""
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import reshard_store
    from repro.core import sharded_embedding as se
    cfg, step, shardings, fresh, layout1 = _dlrm_setup()

    want = fresh()
    s = _dlrm_stream()
    for _ in range(6):
        want, _ = step(want, next(s))

    # run 3 steps, checkpoint, "restart" through a 3-shard layout and back
    mid = fresh()
    s = _dlrm_stream()
    for _ in range(3):
        mid, _ = step(mid, next(s))
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, mid, blocking=True)

    structs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), mid)
    got_step, restored = mgr.restore(structs)
    assert got_step == 3
    layout3 = se.make_layout(cfg.spec, 3, "row")  # the grown cluster's layout
    store3 = reshard_store(layout1, layout3, restored["emb"])
    for k, v in restored["emb"].items():          # dtypes survive the hop
        assert np.asarray(store3[k]).dtype == np.asarray(v).dtype, k
    back = reshard_store(layout3, layout1, store3)
    restored["emb"] = {k: jnp.asarray(v) for k, v in back.items()}
    restored = jax.device_put(restored, shardings)

    s = _dlrm_stream(3)
    got = restored
    for _ in range(3):
        got, _ = step(got, next(s))
    assert int(got["sr"]) == int(want["sr"])
    # compare the REAL table rows: reshard_embedding zero-fills the layout's
    # padding rows (they carry no state), so a whole-slab compare would
    # diff init garbage in rows the model never reads
    spec = cfg.spec
    for k in want["emb"]:
        for t, rows_t in enumerate(spec.table_rows):
            off = int(spec.row_offsets[t])
            np.testing.assert_array_equal(
                np.asarray(got["emb"][k])[off:off + rows_t],
                np.asarray(want["emb"][k])[off:off + rows_t]), (k, t)


def test_dlrm_elastic_reshard_with_hot_cache_bitwise(tmp_path):
    """Elastic drill with the frequency-tiered hot-row cache ON
    (table mode, allreduce sync): the touch-counter slab reshards with
    the store, the cache subtree (spec-global gids — layout-independent
    by construction) passes through the restart untouched, and the
    resumed run stays bitwise — weights, sr, counters AND the promoted
    hot set."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import reshard_store
    from repro.core import dlrm as D
    from repro.core import sharded_embedding as se
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = dataclasses.replace(_dlrm_cfg(), emb_mode="table",
                              idx_input="sharded", hot_rows=8,
                              promote_every=2)
    step, shardings, _, layout1 = D.make_train_step(cfg, mesh)

    def fresh():
        state, _ = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
        return state

    want = fresh()
    s = _dlrm_stream()
    for _ in range(6):
        want, _ = step(want, next(s))

    mid = fresh()
    s = _dlrm_stream()
    for _ in range(3):
        mid, _ = step(mid, next(s))
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, mid, blocking=True)

    structs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                           mid)
    got_step, restored = mgr.restore(structs)
    assert got_step == 3
    assert int(restored["cache"]["tick"]) == 3
    layout3 = se.make_layout(cfg.spec, 3, "table")
    store3 = reshard_store(layout1, layout3, restored["emb"])
    assert np.asarray(store3["cnt"]).dtype == np.int32
    back = reshard_store(layout3, layout1, store3)
    restored["emb"] = {k: jnp.asarray(v) for k, v in back.items()}
    restored = jax.device_put(restored, shardings)

    s = _dlrm_stream(3)
    got = restored
    for _ in range(3):
        got, _ = step(got, next(s))
    assert int(got["sr"]) == int(want["sr"])
    spec = cfg.spec
    for k in want["emb"]:
        for t, rows_t in enumerate(spec.table_rows):
            off = int(spec.row_offsets[t])
            np.testing.assert_array_equal(
                np.asarray(got["emb"][k])[off:off + rows_t].view(np.uint8),
                np.asarray(want["emb"][k])[off:off + rows_t].view(np.uint8)
            ), (k, t)
    for k in ("hot_ids", "tick"):
        np.testing.assert_array_equal(np.asarray(got["cache"][k]),
                                      np.asarray(want["cache"][k])), k
    np.testing.assert_array_equal(
        np.asarray(got["cache"]["hot_w"]).view(np.uint8),
        np.asarray(want["cache"]["hot_w"]).view(np.uint8))
    assert (np.asarray(got["cache"]["hot_ids"]) >= 0).any()
