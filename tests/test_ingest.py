"""Streaming ingestion subsystem (repro/data/{format,reader,pipeline}.py).

Contracts under test:
* Packed round-trip: synthetic stream -> shards -> ShardedReader yields
  the ORIGINAL batches bit-for-bit (idx/dense/labels/weights).
* Reader determinism: the global epoch order is rank-count-invariant
  (concat of rank slices == the single-reader stream), seeded (same seed
  => same order, different epoch/seed => different), and — with an
  explicit shuffle window — invariant to how the dataset was re-sharded
  on disk.
* Host pre-sort == device sort_lookups, bitwise, per shard.
* THE round-trip property (acceptance): synthetic stream -> packed
  shards -> ShardedReader -> pipelined train step with the host
  pre-sorted index path is BIT-IDENTICAL (Split-SGD embedding state and
  loss) to training directly on the in-process stream, for M in {1, 2}
  microbatches.  The non-split fp32 path matches to tolerance (the
  documented fused-kernel pre-reduction vs reference scatter-add gap).
* HostPipeline worker failures poison the queue and re-raise promptly.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import sharded_embedding as se
from repro.core.embedding import EmbeddingSpec
from repro.data.format import (DatasetSpec, ShardWriter, load_manifest,
                               write_shards)
from repro.data.pipeline import HostPipeline, presort_batch
from repro.data.reader import ShardedReader
from repro.data.synthetic import SparseBatchSpec, sparse_batch

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TABLES = (100, 60, 40, 30, 20, 200, 51, 77)


def _stream(seed, batch=32, weighted=False, alpha=0.6):
    rng = np.random.default_rng(seed)
    spec = SparseBatchSpec(TABLES, None, 3, batch, num_dense=16, alpha=alpha)
    while True:
        b = sparse_batch(rng, spec)
        if weighted:
            b["weights"] = rng.uniform(0.5, 1.5, b["idx"].shape).astype(
                np.float32)
        yield b


def _pack(tmp_path, n=192, per_shard=40, weighted=False, seed=0):
    out = str(tmp_path / f"ds{'w' if weighted else ''}{n}_{per_shard}")
    spec = DatasetSpec(table_rows=TABLES, pooling=3, num_dense=16,
                       weighted=weighted)
    write_shards(_stream(seed, weighted=weighted), out, spec, n,
                 samples_per_shard=per_shard)
    return out


# ---------------------------------------------------------------------------
# Format + reader
# ---------------------------------------------------------------------------

def test_packed_round_trip_bitwise(tmp_path):
    d = _pack(tmp_path, n=192, per_shard=40)   # batches cross shard edges
    ref = _stream(0)
    got = 0
    for mine, orig in zip(ShardedReader(d, batch=32, shuffle=False)
                          .batches(epochs=1), ref):
        for k in ("idx", "dense_x", "labels"):
            assert np.array_equal(mine[k], orig[k]), k
        got += 1
    assert got == 192 // 32


def test_weighted_round_trip_bitwise(tmp_path):
    d = _pack(tmp_path, n=96, per_shard=48, weighted=True)
    ref = _stream(0, weighted=True)
    for mine, orig in zip(ShardedReader(d, batch=32, shuffle=False)
                          .batches(epochs=1), ref):
        assert np.array_equal(mine["weights"], orig["weights"])
        assert np.array_equal(mine["idx"], orig["idx"])


def test_manifest_and_spec_check(tmp_path):
    d = _pack(tmp_path, n=64, per_shard=64)
    spec, manifest = load_manifest(d)
    assert spec.table_rows == TABLES and spec.pooling == 3
    assert manifest["num_samples"] == 64
    spec.check(TABLES, 3, num_dense=16)              # compatible
    with pytest.raises(ValueError, match="pooling"):
        spec.check(TABLES, 5, num_dense=16)
    with pytest.raises(ValueError, match="table_rows"):
        spec.check((10,) * 8, 3, num_dense=16)
    with pytest.raises(ValueError, match="weights"):
        spec.check(TABLES, 3, num_dense=16, weighted=True)


def test_writer_rejects_bad_batches(tmp_path):
    w = ShardWriter(str(tmp_path / "bad"), DatasetSpec(TABLES, 3), 16)
    with pytest.raises(ValueError, match="does not match spec"):
        w.append_batch({"idx": np.zeros((4, 2, 3), np.int32),
                        "labels": np.zeros(4, np.float32)})
    with pytest.raises(ValueError, match="out of range"):
        bad = np.zeros((4, 8, 3), np.int32)
        bad[0, 0, 0] = 1_000_000
        w.append_batch({"idx": bad, "labels": np.zeros(4, np.float32)})


def test_reader_rank_invariance(tmp_path):
    """Same seed => identical GLOBAL epoch order across rank counts."""
    d = _pack(tmp_path, n=192, per_shard=40)
    whole = list(ShardedReader(d, batch=48, shuffle=True, seed=3)
                 .batches(epochs=2))
    for R in (2, 4):
        parts = [list(ShardedReader(d, batch=48, shuffle=True, seed=3,
                                    rank=r, num_ranks=R).batches(epochs=2))
                 for r in range(R)]
        for i, ref in enumerate(whole):
            cat = {k: np.concatenate([parts[r][i][k] for r in range(R)])
                   for k in ref}
            for k in ref:
                assert np.array_equal(cat[k], ref[k]), (R, i, k)


def test_reader_reshard_invariance(tmp_path):
    """Identical batch contents no matter how the dataset was sharded on
    disk — sequential always; shuffled with an explicit window."""
    d_small = _pack(tmp_path, n=192, per_shard=24)
    d_large = _pack(tmp_path, n=192, per_shard=96)
    for kw in (dict(shuffle=False), dict(shuffle=True, window=48, seed=5)):
        a = list(ShardedReader(d_small, batch=32, **kw).batches(epochs=1))
        b = list(ShardedReader(d_large, batch=32, **kw).batches(epochs=1))
        for x, y in zip(a, b):
            for k in x:
                assert np.array_equal(x[k], y[k]), (kw, k)


def test_reader_shuffle_seeded_and_epoch_varies(tmp_path):
    d = _pack(tmp_path, n=128, per_shard=32)
    r = ShardedReader(d, batch=32, shuffle=True, seed=1)
    o0, o0b = r.epoch_order(0), r.epoch_order(0)
    assert np.array_equal(o0, o0b)                     # deterministic
    assert sorted(o0.tolist()) == list(range(128))     # a permutation
    assert not np.array_equal(o0, r.epoch_order(1))    # epoch decorrelates
    r2 = ShardedReader(d, batch=32, shuffle=True, seed=2)
    assert not np.array_equal(o0, r2.epoch_order(0))   # seed decorrelates
    # two-level structure: with window == samples_per_shard, every window
    # stays contiguous in id space (shard permutation + intra-shard)
    win = o0.reshape(-1, 32)
    assert sorted(set(w.min() // 32 for w in win)) == [0, 1, 2, 3]
    for w in win:
        assert w.max() - w.min() < 32


def test_reader_validation(tmp_path):
    d = _pack(tmp_path, n=64, per_shard=32)
    with pytest.raises(ValueError, match="divisible"):
        ShardedReader(d, batch=30, num_ranks=4)
    with pytest.raises(ValueError, match="rank"):
        ShardedReader(d, batch=32, rank=4, num_ranks=4)
    with pytest.raises(FileNotFoundError):
        ShardedReader(str(tmp_path / "nope"), batch=8)


# ---------------------------------------------------------------------------
# Host pipeline
# ---------------------------------------------------------------------------

def _layout(ns=4):
    return se.make_layout(EmbeddingSpec(TABLES, 8), ns, "row")


def test_presort_matches_device_sort_lookups():
    """Host presort_batch == kernels.embedding_update.sort_lookups, bitwise
    per shard (stable-sort permutations are unique)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels.embedding_update import sort_lookups
    layout = _layout(4)
    rng = np.random.default_rng(0)
    idx = np.stack([rng.integers(0, m, (16, 3)) for m in TABLES],
                   1).astype(np.int32)
    wgt = rng.uniform(0.5, 1.5, idx.shape).astype(np.float32)
    ps = presort_batch(layout, idx, wgt)
    g = idx + np.asarray(layout.row_offsets, np.int32)[None, :, None]
    R = layout.rows_per_shard
    for s in range(4):
        local = jnp.asarray((g - np.int32(s * R)).reshape(-1))
        sr, sb, sm, sw = sort_lookups(local, None, R, 3,
                                      jnp.asarray(wgt.reshape(-1)))
        assert np.array_equal(np.asarray(sr), ps["psort_rows"][s])
        assert np.array_equal(np.asarray(sb), ps["psort_bags"][s])
        assert np.array_equal(np.asarray(sm), ps["psort_msk"][s])
        assert np.array_equal(np.asarray(sw), ps["psort_wgt"][s])


def test_presort_table_mode_folds_padded_permute():
    """Table-mode host pre-sort (ROADMAP leftover): presort_batch folds
    the padded-slot permute in and matches the device-side
    permute_indices + sort_lookups stream, bitwise, per model shard."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels.embedding_update import sort_lookups
    layout = se.make_layout(EmbeddingSpec(TABLES, 8), 4, "table")
    rng = np.random.default_rng(1)
    idx = np.stack([rng.integers(0, m, (16, 3)) for m in TABLES],
                   1).astype(np.int32)
    wgt = rng.uniform(0.5, 1.5, idx.shape).astype(np.float32)
    ps = presort_batch(layout, idx, wgt)
    K, R = layout.slots_per_shard, layout.rows_per_shard
    assert ps["psort_rows"].shape == (4, 16 * K * 3)
    # device side: permute to padded order (dummy slots -> idx 0 / wgt 0),
    # slice this shard's slots, add the slot offsets, sort
    padded = np.asarray(se.permute_indices(layout, jnp.asarray(idx)))
    wp = wgt[:, np.where(layout.padded_slots >= 0, layout.padded_slots, 0)]
    wp[:, layout.padded_slots < 0] = 0.0
    off = np.asarray(layout.slot_local_offsets, np.int32).reshape(4, K)
    for s in range(4):
        local = (padded[:, s * K:(s + 1) * K] + off[s][None, :, None])
        sr, sb, sm, sw = sort_lookups(
            jnp.asarray(local.reshape(-1)), None, R, 3,
            jnp.asarray(wp[:, s * K:(s + 1) * K].reshape(-1)))
        assert np.array_equal(np.asarray(sr), ps["psort_rows"][s])
        assert np.array_equal(np.asarray(sb), ps["psort_bags"][s])
        assert np.array_equal(np.asarray(sm), ps["psort_msk"][s])
        assert np.array_equal(np.asarray(sw), ps["psort_wgt"][s])


def test_presort_rejects_unknown_mode():
    import dataclasses as dc
    layout = dc.replace(_layout(4), mode="diagonal")
    with pytest.raises(ValueError, match="mode"):
        presort_batch(layout, np.zeros((4, 8, 3), np.int32))


def test_hostpipeline_attaches_psort_and_preserves_stream(tmp_path):
    d = _pack(tmp_path, n=96, per_shard=48)
    layout = _layout(4)
    plain = list(ShardedReader(d, batch=32, shuffle=False).batches(epochs=1))
    hp = HostPipeline(ShardedReader(d, batch=32, shuffle=False)
                      .batches(epochs=1), layout=layout, presort=True)
    piped = list(hp)
    assert len(piped) == len(plain)
    L = 32 * 8 * 3
    for a, b in zip(piped, plain):
        for k in b:
            assert np.array_equal(a[k], b[k]), k
        for k in ("psort_rows", "psort_bags", "psort_msk", "psort_wgt"):
            assert a[k].shape == (4, L)
        ref = presort_batch(layout, b["idx"])
        assert np.array_equal(a["psort_rows"], ref["psort_rows"])
    assert hp.stats["batches"] == len(plain)


def test_hostpipeline_stats_pinned_after_drain(tmp_path):
    """Full ``stats`` contract after a clean drain — the train-loop
    heartbeat serializes this dict verbatim, so keys and values are
    pinned: every batch counted, no retries, worker prep time observed."""
    d = _pack(tmp_path, n=96, per_shard=48)
    hp = HostPipeline(ShardedReader(d, batch=32, shuffle=False)
                      .batches(epochs=1))
    n = sum(1 for _ in hp)
    st = hp.stats
    assert set(st) == {"prep_s", "wait_s", "batches", "retries"}
    assert n == 3 and st["batches"] == 3
    assert st["retries"] == 0
    assert st["prep_s"] > 0.0 and st["wait_s"] >= 0.0


def test_hostpipeline_poisons_on_worker_failure():
    def bad():
        yield {"idx": np.zeros((2, 8, 3), np.int32)}
        raise OSError("shard vanished")

    hp = HostPipeline(bad())
    next(hp)
    with pytest.raises(OSError, match="shard vanished"):
        next(hp)


def test_chained_pipeline_prefetch_close_does_not_strand(tmp_path):
    """launch/train.py chains HostPipeline -> prefetch_to_device and closes
    the INNER pipeline first; the outer worker must observe the sticky
    end-of-stream sentinel and finish instead of blocking forever."""
    import threading
    pytest.importorskip("jax")
    from repro.train import prefetch_to_device
    d = _pack(tmp_path, n=64, per_shard=32)
    hp = HostPipeline(ShardedReader(d, batch=32, shuffle=False))  # infinite
    it = prefetch_to_device(hp, size=2)
    next(it)
    hp.close()

    done = threading.Event()

    def drain():
        for _ in it:        # must terminate via the sticky _DONE
            pass
        done.set()

    t = threading.Thread(target=drain, daemon=True)
    t.start()
    assert done.wait(timeout=10.0), "outer prefetch worker stranded"
    it.close()


def test_hostpipeline_validation_and_close(tmp_path):
    with pytest.raises(ValueError, match="layout"):
        HostPipeline(iter(()), presort=True)
    with pytest.raises(ValueError, match="depth"):
        HostPipeline(iter(()), depth=0)
    d = _pack(tmp_path, n=64, per_shard=32)
    hp = HostPipeline(ShardedReader(d, batch=32, shuffle=False))  # infinite
    next(hp)
    hp.close()                                          # no hang


def test_batch_struct_from_spec(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.core import dlrm as D, hybrid as H
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = D.DLRMConfig(name="t", num_dense=16, bottom=(16, 8), top=(16,),
                       table_rows=TABLES, emb_dim=8, pooling=3, batch=16)
    mdef = D.as_hybrid_def(cfg)
    layout = H.make_layout(mdef, mesh)
    spec, _ = load_manifest(_pack(tmp_path, n=32, per_shard=32))
    structs, specs = H.batch_struct_from_spec(mdef, mesh, layout, spec)
    assert structs["idx"].shape == (16, 8, 3)
    bad = DatasetSpec(table_rows=TABLES, pooling=5, num_dense=16)
    with pytest.raises(ValueError, match="pooling"):
        H.batch_struct_from_spec(mdef, mesh, layout, bad)
    wspec = DatasetSpec(table_rows=TABLES, pooling=3, num_dense=16,
                        weighted=True)
    with pytest.raises(ValueError, match="weighted"):
        H.batch_struct_from_spec(mdef, mesh, layout, wspec)
    # extras the format cannot carry are rejected at wiring time, not as
    # a pytree mismatch inside shard_map
    from repro.models import recsys as R
    sas = R.make_sasrec(64, batch=16)
    with pytest.raises(ValueError, match="seq_mask"):
        spec.check_model(sas)


# ---------------------------------------------------------------------------
# THE round-trip property (acceptance criterion) — 8-device subprocess
# ---------------------------------------------------------------------------

def run_sub(code: str, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_packed_presorted_train_round_trip(tmp_path):
    """Acceptance: synthetic stream -> packed shards -> ShardedReader ->
    pipelined train step with host pre-sort is bit-identical (Split-SGD
    state + loss) to training directly on the in-process stream, for
    M in {1, 2}; the non-split fp32 path matches to tolerance."""
    pytest.importorskip("jax")
    out = run_sub(f"""
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    import sys; sys.path.insert(0, {os.path.dirname(__file__)!r})
    from test_ingest import TABLES, _pack, _stream
    from pathlib import Path
    from repro import compat
    from repro.core.dlrm import DLRMConfig, make_train_step, init_state
    from repro.data.pipeline import HostPipeline
    from repro.data.reader import ShardedReader

    tmp = Path({str(tmp_path)!r})
    mesh = compat.make_mesh((2, 4), ('data', 'model'))
    BASE = DLRMConfig(name='t', num_dense=16, bottom=(32, 8), top=(32,),
                      table_rows=TABLES, emb_dim=8, pooling=3, batch=32)
    d = _pack(tmp, n=96, per_shard=40)   # 3 steps, batches cross shards

    def emb_np(state):
        return tuple(np.asarray(v) for v in state['emb'].values())

    for split in (True, False):
        for M in (1, 2):
            res = {{}}
            for tag in ('inproc', 'packed'):
                cfg = dataclasses.replace(
                    BASE, emb_mode='row', split_sgd=split, microbatches=M,
                    host_presort=(tag == 'packed'))
                state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
                step, _, _, _ = make_train_step(cfg, mesh)
                if tag == 'packed':
                    stream = HostPipeline(
                        ShardedReader(d, batch=32, shuffle=False)
                        .batches(epochs=1), layout=layout, presort=True)
                else:
                    stream = _stream(0)
                for _ in range(3):
                    b = {{k: jnp.asarray(v) for k, v in next(stream).items()}}
                    state, loss = step(state, b)
                res[tag] = (float(loss), emb_np(state))
            if split:
                assert res['inproc'][0] == res['packed'][0], ('loss', M)
                for a, b in zip(res['inproc'][1], res['packed'][1]):
                    assert np.array_equal(a, b), ('emb', M)
                print(f'split M={{M}} BITWISE_OK')
            else:
                # fp32 non-split: presorted path always uses the fused
                # kernel (per-row pre-reduction); the reference scatter-add
                # differs by documented rounding only
                assert abs(res['inproc'][0] - res['packed'][0]) < 1e-5
                for a, b in zip(res['inproc'][1], res['packed'][1]):
                    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
                print(f'fp32 M={{M}} CLOSE_OK')
    """)
    assert out.count("BITWISE_OK") == 2
    assert out.count("CLOSE_OK") == 2
