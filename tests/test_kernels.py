"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the pure-jnp
oracles in repro.kernels.ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (100, 300, 120),
                                   (256, 512, 256), (33, 77, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["relu", "none", "sigmoid"])
def test_fused_mlp(m, k, n, dtype, act):
    x = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    w = jnp.asarray(RNG.standard_normal((k, n)) * 0.05, dtype)
    b = jnp.asarray(RNG.standard_normal((n,)), jnp.float32)
    out = ops.fused_mlp_layer(x, w, b, act, interpret=True)
    r = ref.fused_mlp_layer(x, w, b, act)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("rows,e,n,p", [(500, 96, 40, 7), (1000, 128, 16, 1),
                                        (64, 64, 128, 33), (200, 17, 8, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bpb", [1, 8])
def test_embedding_bag(rows, e, n, p, dtype, bpb):
    W = jnp.asarray(RNG.standard_normal((rows, e)), dtype)
    idx = jnp.asarray(RNG.integers(0, rows, (n, p)), jnp.int32)
    out = ops.embedding_bag(W, idx, bags_per_block=bpb, interpret=True)
    r = ref.embedding_bag(W, idx)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("b,f,e", [(20, 9, 64), (8, 27, 128), (5, 65, 32)])
def test_interaction(b, f, e):
    z = jnp.asarray(RNG.standard_normal((b, f, e)), jnp.bfloat16)
    out = ops.interaction_self_dot(z, interpret=True)
    r = ref.interaction_self_dot(z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=3e-2,
                               atol=3e-2)


@pytest.mark.parametrize("shape", [(333, 17), (1024,), (8, 128, 3)])
def test_split_sgd_kernel(shape):
    from repro.optim.split_sgd import combine_split, split_fp32
    w = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    g = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    hi, lo = split_fp32(w)
    nh, nl = ops.split_sgd_update(hi, lo, g, 0.05, interpret=True)
    rh, rl = ref.split_sgd_update(hi, lo, g, 0.05)
    # FMA-contraction differences (amplified by cancellation in w - lr*g)
    # stay below 1e-8 absolute — the kernel performs the same fp32 update
    a = np.asarray(combine_split(nh, nl), np.float32)
    b = np.asarray(combine_split(rh, rl), np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("cfg", [
    dict(B=2, H=8, Hkv=2, Lq=100, Lk=100, D=64, causal=True),
    dict(B=1, H=4, Hkv=4, Lq=1, Lk=300, D=64, causal=True, window=128,
         softcap=50.0),
    dict(B=1, H=2, Hkv=2, Lq=64, Lk=64, D=128, causal=False),
    dict(B=2, H=4, Hkv=1, Lq=33, Lk=65, D=32, causal=True, window=16),
])
def test_flash_attention(cfg):
    B, H, Hkv = cfg["B"], cfg["H"], cfg["Hkv"]
    Lq, Lk, D = cfg["Lq"], cfg["Lk"], cfg["D"]
    kw = dict(causal=cfg.get("causal", True), window=cfg.get("window", 0),
              softcap=cfg.get("softcap", 0.0))
    q = jnp.asarray(RNG.standard_normal((B, H, Lq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Lk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Lk, D)), jnp.float32)
    out = ops.flash_attention(q, k, v, interpret=True, **kw)
    r = ref.flash_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-3,
                               atol=2e-3)


def test_chunked_attention_matches_ref():
    from repro.models.attention import chunked_attention
    q = jnp.asarray(RNG.standard_normal((2, 4, 96, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 96, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 96, 32)), jnp.float32)
    for kw in (dict(causal=True), dict(causal=True, window=24),
               dict(causal=True, softcap=30.0)):
        out = chunked_attention(q, k, v, bq=32, **kw)
        r = ref.flash_attention(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                   rtol=2e-3, atol=2e-3, err_msg=str(kw))
