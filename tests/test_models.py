"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned architecture family runs one forward/train step on CPU; output
shapes + finite values asserted."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (TransformerConfig, decode_step,
                                      init_params, lm_loss, prefill)

RNG = np.random.default_rng(0)


def reduced(name) -> TransformerConfig:
    base = dict(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                d_ff=128, vocab=256, seq_shard=False, tp_size=1,
                tie_embeddings=False)
    # capacity_factor high enough that the tiny test sequences never DROP
    # tokens — capacity dropping is sequence-length-dependent by design and
    # would make prefill-vs-decode comparisons approximate
    if name == "qwen3-moe-30b-a3b":
        base.update(n_experts=8, top_k=2, moe_d_ff=32, capacity_factor=8.0)
    if name == "deepseek-v2-236b":
        base.update(n_experts=8, top_k=2, moe_d_ff=32, n_shared_experts=1,
                    first_dense_layers=1, mla=True, q_lora=32, kv_lora=32,
                    qk_nope=16, qk_rope=8, v_head=16, n_kv_heads=4,
                    capacity_factor=8.0)
    if name == "gemma2-27b":
        base.update(local_global=True, window=16, attn_softcap=50.0,
                    final_softcap=30.0, embed_scale=True,
                    tie_embeddings=True)
    if name == "phi3-medium-14b":
        base.update(n_heads=8, n_kv_heads=2)
    return TransformerConfig(name=name, **base)


LM_ARCHS = ["qwen3-moe-30b-a3b", "deepseek-v2-236b", "internlm2-1.8b",
            "gemma2-27b", "phi3-medium-14b"]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke(name):
    cfg = reduced(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 32
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, L)), jnp.int32)
    labs = jnp.asarray(RNG.integers(0, cfg.vocab, (B, L)), jnp.int32)
    loss, g = jax.jit(jax.value_and_grad(
        lambda p: lm_loss(p, toks, labs, cfg)))(params)
    assert np.isfinite(float(loss))
    gn = jax.tree.reduce(lambda a, x: a + float(jnp.abs(x).sum()), g, 0.0)
    assert np.isfinite(gn) and gn > 0

    logits, cache = jax.jit(lambda p, t: prefill(p, t, cfg))(params, toks)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    Lmax = L + 8
    cache = jax.tree.map(
        lambda a: jnp.zeros(a.shape[:-2] + (Lmax, a.shape[-1]), a.dtype
                            ).at[..., :L, :].set(a), cache)
    nt = jnp.asarray(RNG.integers(0, cfg.vocab, (B,)), jnp.int32)
    pos = jnp.full((B,), L, jnp.int32)
    lg, cache2 = jax.jit(lambda p, c, t, q: decode_step(p, c, t, q, cfg))(
        params, cache, nt, pos)
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_decode_matches_prefill():
    """Next-token logits from (prefill L, decode 1) must match prefill of
    L+1 tokens — the KV cache path is consistent with the parallel path."""
    cfg = reduced("internlm2-1.8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 16
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, L + 1)), jnp.int32)
    lg_full, _ = prefill(params, toks, cfg)

    lg_pre, cache = prefill(params, toks[:, :L], cfg)
    cache = jax.tree.map(
        lambda a: jnp.zeros(a.shape[:-2] + (L + 1, a.shape[-1]), a.dtype
                            ).at[..., :L, :].set(a), cache)
    pos = jnp.full((B,), L, jnp.int32)
    lg_dec, _ = decode_step(params, cache, toks[:, L], pos, cfg)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=5e-2, atol=5e-2)


def test_decode_matches_prefill_mla():
    cfg = reduced("deepseek-v2-236b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, L + 1)), jnp.int32)
    lg_full, _ = prefill(params, toks, cfg)
    lg_pre, cache = prefill(params, toks[:, :L], cfg)
    cache = jax.tree.map(
        lambda a: jnp.zeros(a.shape[:-2] + (L + 1, a.shape[-1]), a.dtype
                            ).at[..., :L, :].set(a), cache)
    pos = jnp.full((B,), L, jnp.int32)
    lg_dec, _ = decode_step(params, cache, toks[:, L], pos, cfg)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=6e-2, atol=6e-2)


def test_moe_block_matches_per_token_reference():
    """Capacity-dispatch MoE == naive per-token top-k expert mix (no drops
    at cf high enough)."""
    from repro.models.transformer import moe_block
    cfg = TransformerConfig("m", n_layers=1, d_model=16, n_heads=2,
                            n_kv_heads=2, d_head=8, d_ff=32, vocab=64,
                            n_experts=4, top_k=2, moe_d_ff=16,
                            capacity_factor=4.0, seq_shard=False, tp_size=1)
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    B, L, d = 2, 8, 16
    p = {"router": jax.random.normal(ks[0], (d, 4)) * 0.5,
         "wg": jax.random.normal(ks[1], (4, d, 16)) * 0.2,
         "wu": jax.random.normal(ks[2], (4, d, 16)) * 0.2,
         "wd": jax.random.normal(ks[3], (4, 16, d)) * 0.2}
    x = jax.random.normal(ks[4], (B, L, d), jnp.float32)
    out = moe_block(x, p, cfg)

    probs = jax.nn.softmax(x @ p["router"], axis=-1)
    gate, eidx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    want = np.zeros((B, L, d), np.float32)
    for b in range(B):
        for t in range(L):
            for j in range(2):
                e = int(eidx[b, t, j])
                xe = np.asarray(x)[b, t].astype(np.float32)
                g = np.asarray(xe @ np.asarray(p["wg"])[e])
                u = np.asarray(xe @ np.asarray(p["wu"])[e])
                h = (g / (1 + np.exp(-g))) * u
                want[b, t] += float(gate[b, t, j]) * (
                    h @ np.asarray(p["wd"])[e])
    np.testing.assert_allclose(np.asarray(out, np.float32), want, rtol=4e-2,
                               atol=4e-2)


def test_egnn_smoke_and_equivariance():
    from repro.models.egnn import EGNNConfig, egnn_forward, init_egnn_params
    cfg = EGNNConfig("t", n_layers=2, d_hidden=16, d_feat=8, n_classes=3)
    params = init_egnn_params(jax.random.PRNGKey(0), cfg)
    N, Ed = 20, 60
    feats = jnp.asarray(RNG.standard_normal((N, 8)), jnp.float32)
    coords = jnp.asarray(RNG.standard_normal((N, 3)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, N, (Ed,)), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, (Ed,)), jnp.int32)
    out = egnn_forward(params, feats, coords, src, dst, cfg)
    assert out.shape == (N, 3) and np.isfinite(np.asarray(out)).all()
    # E(n) invariance of h-outputs: rotate+translate coords -> same logits
    theta = 0.7
    R = jnp.asarray([[np.cos(theta), -np.sin(theta), 0],
                     [np.sin(theta), np.cos(theta), 0], [0, 0, 1]],
                    jnp.float32)
    out2 = egnn_forward(params, feats, coords @ R.T + 5.0, src, dst, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=2e-2,
                               atol=2e-2)


@pytest.mark.parametrize("name", ["fm", "bst", "sasrec", "din"])
def test_recsys_smoke(name):
    """Reduced config, one train step on a (1,1) mesh: loss finite, state
    updates, score step works."""
    from repro.core import hybrid as H
    from repro.launch.mesh import make_mesh
    from repro.models import recsys as R

    mesh = make_mesh((1, 1), ("data", "model"))
    B = 16
    if name == "fm":
        mdef = R.make_fm((50,) * 39, batch=B)
        extras = {"labels": jnp.asarray(RNG.integers(0, 2, (B,)),
                                        jnp.float32)}
    elif name == "bst":
        mdef = R.make_bst(100, (20,) * 8, batch=B)
        extras = {"labels": jnp.asarray(RNG.integers(0, 2, (B,)),
                                        jnp.float32)}
    elif name == "sasrec":
        mdef = R.make_sasrec(100, batch=B)
        extras = {"seq_mask": jnp.ones((B, 50), jnp.float32)}
    else:
        mdef = R.make_din(100, (20,) * 4, batch=B)
        extras = {"labels": jnp.asarray(RNG.integers(0, 2, (B,)),
                                        jnp.float32),
                  "hist_mask": jnp.ones((B, 100), jnp.float32)}
    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    step, _, _, _ = H.make_train_step(mdef, mesh)
    rows = [mdef.spec.table_rows[t] for t in layout.slot_to_table]
    idx = jnp.asarray(np.stack(
        [RNG.integers(0, m, (B, 1)) for m in rows], axis=1), jnp.int32)
    batch = {"idx": idx, **extras}
    s2, loss = step(state, batch)
    assert np.isfinite(float(loss))
    hi0 = jax.tree.leaves(state["emb"])[0] if "w" not in state["emb"] \
        else state["emb"]["w"]
    score, _, _, _ = H.make_score_step(mdef, mesh, batch=B)
    sc = score(s2, batch)
    assert sc.shape == (B,) and np.isfinite(np.asarray(sc)).all()


def test_dlrm_smoke():
    from repro.core import dlrm as D
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = D.DLRMConfig(name="t", num_dense=8, bottom=(16, 8), top=(16,),
                       table_rows=(50, 30, 20, 10), emb_dim=8, pooling=3,
                       batch=16)
    state, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    step, _, _, _ = D.make_train_step(cfg, mesh)
    idx = jnp.asarray(np.stack(
        [RNG.integers(0, m, (16, 3)) for m in cfg.table_rows], 1), jnp.int32)
    batch = {"idx": idx,
             "dense_x": jnp.asarray(RNG.standard_normal((16, 8)),
                                    jnp.bfloat16),
             "labels": jnp.asarray(RNG.integers(0, 2, (16,)), jnp.float32)}
    losses = []
    for _ in range(3):
        state, loss = step(state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
