"""Staged microbatch pipeline (repro/core/pipeline.py).

Contracts under test:
* M=1 is bit-identical to the legacy monolithic hybrid step (re-implemented
  inline here as the pinned reference — the pre-refactor ``step_local``).
* M in {1,2,4} produce IDENTICAL embedding state after a step on
  duplicate-heavy index streams (split and non-split SGD): every microbatch
  runs against the step's initial weights and the concatenated update
  stream is restored to full-batch order, so the single sparse update sees
  exactly the M=1 stream.  The accumulated DENSE gradient sums
  per-microbatch partial sums — a reassociation of the same reduction —
  so dense state matches to fp32 reassociation tolerance, not bitwise
  (that tolerance, not exactness, is the documented dense semantics).
* The ppermute-chunked ring exchange == the fused all_gather, bitwise.
* table-mode idx_input='sharded' (on-chip permute) == the replicated
  padded loader, trajectory-identical.
* Unsupported combinations are rejected with clear errors.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


COMMON = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core.dlrm import DLRMConfig, make_train_step, init_state
    from repro.core import sharded_embedding as se

    mesh = compat.make_mesh((2, 4), ('data', 'model'))
    BASE = DLRMConfig(name='t', num_dense=16, bottom=(32, 8), top=(32,),
                      table_rows=(100, 60, 40, 30, 20, 200, 51, 77),
                      emb_dim=8, pooling=3, batch=32)

    def mk_batch(seed, cfg, layout):
        rng = np.random.default_rng(seed)
        # duplicate-heavy: draw from a tiny sub-vocabulary per table
        idx = np.stack([rng.integers(0, max(2, m // 8), (32, 3))
                        for m in cfg.table_rows], 1).astype(np.int32)
        if cfg.emb_mode == 'table' and cfg.idx_input == 'replicated':
            idx = np.asarray(se.permute_indices(layout, jnp.asarray(idx)))
        return {'idx': jnp.asarray(idx),
                'dense_x': jnp.asarray(rng.standard_normal((32, 16)),
                                       jnp.bfloat16),
                'labels': jnp.asarray(rng.integers(0, 2, 32), jnp.float32)}

    def emb_np(state):
        if 'w' in state['emb']:
            return (np.asarray(state['emb']['w']),)
        return (np.asarray(state['emb']['hi'], np.float32),
                np.asarray(state['emb']['lo']))

    def dense_np(state):
        return np.asarray(jax.flatten_util.ravel_pytree(jax.tree.map(
            lambda x: np.asarray(x, np.float32), state['dense']['hi']))[0])
"""


def test_microbatch_state_identity_property():
    """Property over (mode x idx_input x split_sgd x seed): one pipelined
    step at M in {2,4} leaves the embedding state BIT-IDENTICAL to M=1 and
    the dense state within reassociation tolerance."""
    out = run_sub(COMMON + """
    for mode, inp in (('row', 'replicated'), ('row', 'sharded'),
                      ('table', 'replicated'), ('table', 'sharded')):
        for split in (True, False):
            for seed in (0, 7):
                res = {}
                for M in (1, 2, 4):
                    cfg = dataclasses.replace(
                        BASE, emb_mode=mode, idx_input=inp,
                        split_sgd=split, microbatches=M)
                    state, layout = init_state(jax.random.PRNGKey(seed),
                                               cfg, mesh)
                    step, _, _, _ = make_train_step(cfg, mesh)
                    batch = mk_batch(seed, cfg, layout)
                    state, loss = step(state, batch)
                    res[M] = (emb_np(state), dense_np(state), float(loss))
                for M in (2, 4):
                    for a, b in zip(res[1][0], res[M][0]):
                        assert np.array_equal(a, b), (mode, inp, split, M)
                    np.testing.assert_allclose(res[1][1], res[M][1],
                                               rtol=0, atol=4e-3)
                    assert abs(res[1][2] - res[M][2]) < 1e-4
    print('MB_PROP_OK')
    """)
    assert "MB_PROP_OK" in out


def test_m1_bit_identical_to_legacy_monolithic_step():
    """The M=1 pipeline == the pre-refactor monolithic step_local (pinned
    here verbatim), bitwise over a 3-step trajectory (split-SGD path)."""
    out = run_sub(COMMON + """
    from jax.sharding import PartitionSpec as P
    from repro.core import hybrid as H, dlrm as D
    from repro.optim import data_parallel as dp
    from repro.optim import row as row_optim

    def legacy_train_step(cfg, mesh):
        mdef = D.as_hybrid_def(cfg)
        structs, specs, shardings, layout = H.state_struct(mdef, mesh)
        bstructs, bspecs = H.batch_struct(mdef, mesh, layout)
        all_axes, model, batch_axes = H._mesh_axes(mesh)
        emb_ax, replica_ax = H._emb_axes(mdef, mesh)
        B = cfg.batch

        def step_local(state, batch):
            emb_store = state['emb']
            W_fwd = emb_store['hi']
            idx = batch['idx']
            if cfg.emb_mode == 'row' and cfg.idx_input == 'sharded':
                idx = jax.lax.all_gather(idx, emb_ax, axis=0, tiled=True)
            emb_out = se.sharded_bag_fwd(layout, W_fwd, idx, emb_ax)

            def loss_fn(dense_hi, emb_out):
                return mdef.dense_loss(dense_hi, emb_out, batch) / B

            (loss, (g_dense, d_emb)) = jax.value_and_grad(
                loss_fn, argnums=(0, 1))(state['dense']['hi'], emb_out)
            dY = se.gather_dY(layout, d_emb, emb_ax, replica_ax)
            new_emb = se.apply_update(
                layout, {'hi': emb_store['hi'], 'lo': emb_store['lo']},
                row_optim.get('split_sgd'), idx, dY, cfg.lr, emb_ax,
                replica_axes=replica_ax, fused=False)
            hi2, lo2 = new_emb['hi'], new_emb['lo']
            st = dp.DPState(hi=state['dense']['hi'],
                            lo_shard=state['dense']['lo'],
                            mom_shard=None, err_shard=state['dense']['err'])
            st2 = dp.rs_ag_split_sgd(st, g_dense, cfg.lr, all_axes,
                                     num_buckets=4, mean=False)
            return ({'emb': {'hi': hi2, 'lo': lo2},
                     'dense': {'hi': st2.hi, 'lo': st2.lo_shard,
                               'err': st2.err_shard}},
                    jax.lax.psum(loss, all_axes))

        step = compat.shard_map(step_local, mesh=mesh,
                                in_specs=(specs, bspecs),
                                out_specs=(specs, P()), check_vma=False)
        return jax.jit(step, donate_argnums=(0,))

    for mode, inp in (('row', 'replicated'), ('row', 'sharded'),
                      ('table', 'replicated')):
        cfg = dataclasses.replace(BASE, emb_mode=mode, idx_input=inp,
                                  fused_update=False)
        outs = {}
        for tag in ('legacy', 'pipeline'):
            state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
            step = (legacy_train_step(cfg, mesh) if tag == 'legacy'
                    else make_train_step(cfg, mesh)[0])
            batch = mk_batch(0, cfg, layout)
            for _ in range(3):
                state, loss = step(state, batch)
            outs[tag] = (float(loss), emb_np(state), dense_np(state),
                         np.asarray(state['dense']['lo']))
        l, p = outs['legacy'], outs['pipeline']
        assert l[0] == p[0], (mode, inp)
        for a, b in zip(l[1], p[1]):
            assert np.array_equal(a, b), (mode, inp)
        assert np.array_equal(l[2], p[2]), (mode, inp)
        assert np.array_equal(l[3], p[3]), (mode, inp)
        print(mode, inp, 'LEGACY_EQ')
    """)
    assert out.count("LEGACY_EQ") == 3


def test_ring_exchange_bit_identical():
    """ppermute-chunked ring all_gather == lax.all_gather (unit), and the
    end-to-end ring-exchange step == the fused-exchange step, bitwise."""
    out = run_sub(COMMON + """
    from jax.sharding import PartitionSpec as P
    from repro.core import pipeline

    x = jnp.arange(48 * 3, dtype=jnp.int32).reshape(48, 3)
    for axes in ('model', ('data', 'model')):
        f1 = jax.jit(compat.shard_map(
            lambda v: pipeline.ring_all_gather(v, axes), mesh=mesh,
            in_specs=P(axes, None), out_specs=P(None, None),
            check_vma=False))
        f2 = jax.jit(compat.shard_map(
            lambda v: jax.lax.all_gather(v, axes, axis=0, tiled=True),
            mesh=mesh, in_specs=P(axes, None), out_specs=P(None, None),
            check_vma=False))
        assert np.array_equal(np.asarray(f1(x)), np.asarray(f2(x))), axes

    for mode in ('row', 'table'):
        outs = {}
        for impl in ('fused', 'ring'):
            cfg = dataclasses.replace(BASE, emb_mode=mode,
                                      idx_input='sharded', microbatches=2,
                                      exchange_impl=impl)
            state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
            step, _, _, _ = make_train_step(cfg, mesh)
            batch = mk_batch(0, cfg, layout)
            for _ in range(2):
                state, loss = step(state, batch)
            outs[impl] = (float(loss), emb_np(state))
        assert outs['fused'][0] == outs['ring'][0], mode
        for a, b in zip(outs['fused'][1], outs['ring'][1]):
            assert np.array_equal(a, b), mode
    print('RING_OK')
    """)
    assert "RING_OK" in out


def test_table_sharded_idx_matches_replicated():
    """Satellite: table-mode idx_input='sharded' (original-slot stream +
    on-chip permute/slice) == the paper's replicated padded loader,
    trajectory-identical."""
    out = run_sub(COMMON + """
    traj = {}
    for inp in ('replicated', 'sharded'):
        cfg = dataclasses.replace(BASE, emb_mode='table', idx_input=inp)
        state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
        step, _, _, _ = make_train_step(cfg, mesh)
        batch = mk_batch(0, cfg, layout)
        ls = []
        for _ in range(4):
            state, loss = step(state, batch)
            ls.append(float(loss))
        traj[inp] = (ls, emb_np(state))
    assert np.allclose(traj['replicated'][0], traj['sharded'][0],
                       rtol=1e-5), traj
    for a, b in zip(traj['replicated'][1], traj['sharded'][1]):
        assert np.array_equal(a, b)
    print('TABLE_SHARDED_OK')
    """)
    assert "TABLE_SHARDED_OK" in out


def test_score_step_sharded_inputs():
    """Serve path reuses the exchange stage: scores identical between
    replicated and sharded index input, row and table mode."""
    out = run_sub(COMMON + """
    from repro.core import dlrm as D
    for mode in ('row', 'table'):
        sc = {}
        for inp in ('replicated', 'sharded'):
            cfg = dataclasses.replace(BASE, emb_mode=mode, idx_input=inp)
            state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
            ev, _, _, _ = D.make_eval_step(cfg, mesh)
            batch = mk_batch(3, cfg, layout)
            sc[inp] = np.asarray(ev(state, batch))
        np.testing.assert_allclose(sc['replicated'], sc['sharded'],
                                   rtol=1e-5, atol=1e-6)
    print('SCORE_OK')
    """)
    assert "SCORE_OK" in out


# ---------------------------------------------------------------------------
# Single-device: validation errors + retrieval extras normalization
# ---------------------------------------------------------------------------

def test_unsupported_combinations_rejected():
    import dataclasses

    import jax
    from repro.core import pipeline
    from repro.core.dlrm import DLRMConfig, make_train_step
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    base = DLRMConfig(name="t", num_dense=8, bottom=(16, 8), top=(16,),
                      table_rows=(50, 30, 20, 10), emb_dim=8, pooling=3,
                      batch=16)
    with pytest.raises(ValueError, match="idx_input"):
        make_train_step(dataclasses.replace(base, idx_input="banana"), mesh)
    with pytest.raises(ValueError, match="emb_mode"):
        pipeline.validate_pipeline(
            dataclasses.replace(base, emb_mode="diagonal"), mesh, 1)
    with pytest.raises(ValueError, match="microbatches"):
        make_train_step(base, mesh, microbatches=0)
    with pytest.raises(ValueError, match="divisible"):
        make_train_step(base, mesh, microbatches=5)
    with pytest.raises(ValueError, match="exchange_impl"):
        make_train_step(dataclasses.replace(base, exchange_impl="smoke"),
                        mesh)


def test_retrieval_rejects_sharded_and_normalizes_extras():
    """Satellite: make_retrieval_step broadcasts extras via the schema —
    a rank-1 (B-squeezed) extra is normalized, not dropped — and rejects a
    sharded index stream with a clear error."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    from repro.core import hybrid as H
    from repro.launch.mesh import make_mesh
    from repro.models import recsys as R

    mesh = make_mesh((1, 1), ("data", "model"))
    mdef = R.make_sasrec(64, batch=1)
    with pytest.raises(ValueError, match="sharded"):
        H.make_retrieval_step(dc.replace(mdef, idx_input="sharded"),
                              mesh, n_candidates=16, target_slot=50)

    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    retr, arg_structs, _, _ = H.make_retrieval_step(
        mdef, mesh, n_candidates=16, target_slot=50, topk=4)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 64, (1, 150, 1)), jnp.int32)
    cand = jnp.asarray(rng.standard_normal((16, 50)), jnp.bfloat16)
    batch_2d = {"idx": idx, "seq_mask": jnp.ones((1, 50), jnp.float32)}
    batch_1d = {"idx": idx, "seq_mask": jnp.ones((50,), jnp.float32)}
    v2, i2 = retr(state, batch_2d, cand)
    v1, i1 = retr(state, batch_1d, cand)
    # rank-1 extra is normalized via the extras schema -> same result
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1, np.float32),
                                  np.asarray(v2, np.float32))
    assert np.asarray(v1).shape == (4,)
