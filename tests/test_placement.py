"""Placement-policy unit tests for the perf-log features (EXPERIMENTS.md
section Perf): FSDP/TP/pure-DP param specs, decode cache sharding choice,
windowed-KV slicing equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.kernels import ref
from repro.models.attention import chunked_attention


def _leaf_spec(tree_specs, *keys):
    node = tree_specs
    for k in keys:
        node = node[k]
    return node


def _example_tree():
    return {
        "embed": jnp.zeros((64, 8)),
        "layers": {
            "ln1": jnp.zeros((4, 8)),
            "attn": {"wq": jnp.zeros((4, 8, 16)),
                     "wo": jnp.zeros((4, 16, 8))},
            "mlp": {"wg": jnp.zeros((4, 8, 32)),
                    "wd": jnp.zeros((4, 32, 8))},
            "moe": {"router": jnp.zeros((4, 8, 4)),
                    "wg": jnp.zeros((4, 4, 8, 16)),
                    "wd": jnp.zeros((4, 4, 16, 8))},
        },
        "final_norm": jnp.zeros((8,)),
    }


def test_fsdp_tp_specs():
    specs = shd.lm_param_specs(_example_tree(), fsdp=True, tp=True)
    assert _leaf_spec(specs, "embed") == P("model", "data")
    assert _leaf_spec(specs, "layers", "attn", "wq") == \
        P(None, "data", "model")
    assert _leaf_spec(specs, "layers", "attn", "wo") == \
        P(None, "model", "data")
    assert _leaf_spec(specs, "layers", "moe", "wg") == \
        P(None, "data", None, "model")
    assert _leaf_spec(specs, "layers", "ln1") == P(None, None)


def test_tp_only_specs():
    specs = shd.lm_param_specs(_example_tree(), fsdp=False, tp=True)
    assert _leaf_spec(specs, "layers", "attn", "wq") == \
        P(None, None, "model")
    # EP kept for MoE regardless
    assert _leaf_spec(specs, "layers", "moe", "wg") == \
        P(None, "data", None, "model")


def test_pure_dp_zero3_specs():
    specs = shd.lm_param_specs(_example_tree(), fsdp=True, tp=False)
    # no 'model'-only sharding anywhere outside moe; FSDP spans the mesh
    assert _leaf_spec(specs, "layers", "attn", "wq") == \
        P(None, ("data", "model"), None)
    assert _leaf_spec(specs, "embed") == P(None, ("data", "model"))


def test_decode_cache_sharding_choice():
    """HC2: heads when divisible, else head-dim, never seq for batch_ok."""
    from repro.models import lm_steps
    from repro.models.transformer import TransformerConfig
    # AbstractMesh: sharding decisions are testable without 8 real devices
    try:
        mesh = jax.sharding.AbstractMesh((2, 4), ("data", "model"))
    except TypeError:   # jax<0.5: AbstractMesh(((name, size), ...))
        mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    # Hkv=4 % 4 == 0 -> heads sharded
    cfg = TransformerConfig("a", n_layers=2, d_model=32, n_heads=4,
                            n_kv_heads=4, d_head=8, d_ff=64, vocab=64)
    _, spec, _ = lm_steps.cache_structs(cfg, mesh, B=8, Lmax=16)
    assert spec["k"] == P(None, ("data",), "model", None, None)
    # Hkv=2 % 4 != 0, d_head=8 % 4 == 0 -> head-dim sharded
    cfg2 = TransformerConfig("b", n_layers=2, d_model=32, n_heads=4,
                             n_kv_heads=2, d_head=8, d_ff=64, vocab=64)
    _, spec2, _ = lm_steps.cache_structs(cfg2, mesh, B=8, Lmax=16)
    assert spec2["k"] == P(None, ("data",), None, None, "model")
    # B=1 (long-context): sequence sharding over the full mesh
    _, spec3, _ = lm_steps.cache_structs(cfg2, mesh, B=1, Lmax=64)
    assert spec3["k"] == P(None, None, None, ("data", "model"), None)


def test_windowed_slicing_matches_full():
    """Iter. 4: the sliced local-attention path == the masked full path."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 2, 128, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 128, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 128, 16)), jnp.float32)
    # window + bq = 16+16 < Lk=128 -> sliced path active
    out = chunked_attention(q, k, v, causal=True, window=16, bq=16)
    want = ref.flash_attention(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
