"""Pluggable sparse RowOptimizer API (repro/optim/row.py).

Contracts under test:
* Registry/resolve: the five built-ins resolve by name, hyperparameter
  overrides apply, the legacy ``split_sgd`` bool maps to
  'split_sgd'/'sgd', unknown names fail loudly.
* Degeneration properties: ``momentum(beta=0)`` is BITWISE ``sgd`` on the
  fused path; ``split_sgd`` matches the jitted ``split_fp32``/
  ``combine_split`` reference bitwise; a zero-initialized Adagrad first
  step equals SGD scaled by ``1/(sqrt(acc_1)+eps)`` to fp32 tolerance.
* Pinned legacy kernel: the new ``apply_sparse`` split path is bitwise
  the PRE-REFACTOR ``fused_embedding_update`` wrapper (re-implemented
  here verbatim against the unchanged Pallas kernel).
* State hygiene: masked/padding streams never decay momentum or inflate
  accumulators; untouched rows keep weights AND state bitwise.
* Acceptance (subprocess, 8 devices): all registered optimizers —
  including the compressed-state ``momentum_bf16``/``adagrad_bf16``,
  whose per-step seed rides the replicated ``state["sr"]`` counter — run
  through ``make_pipelined_train_step`` for M in {1, 2} with
  ``host_presort`` on and off — embedding stores bit-identical across M,
  and the host-pre-sorted path bitwise matches the fused device-sort
  path (row AND table mode).
* Checkpoint round-trip: save/restore/resume is bit-identical to an
  uninterrupted run for every optimizer (state slabs persist and restore
  next to the weights), and ``reshard_store`` relays every slab across
  an elastic shard-count change.
* No caller outside optim/row.py touches the kernels.ops fused update
  entry points (source scan).
"""

import dataclasses
import os
import re
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.optim import row
from repro.optim.split_sgd import combine_split, split_fp32

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
RNG = np.random.default_rng(11)


def _mk(M=60, E=16, B=8, S=2, P=3, vocab=None, seed=0):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.standard_normal((M, E)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, vocab or M, (B, S, P)), jnp.int32)
    dY = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    return W, idx, dY


# ---------------------------------------------------------------------------
# Registry / resolve
# ---------------------------------------------------------------------------

def test_registry_names_and_overrides():
    assert set(row.names()) >= {"sgd", "split_sgd", "momentum",
                                "adagrad_rowwise", "adagrad",
                                "momentum_bf16", "adagrad_bf16",
                                "adagrad_freq"}
    # compressed-state layout: bf16 slabs + the stochastic_round flag
    bf = row.get("momentum_bf16")
    assert bf.stochastic_round and not row.get("momentum").stochastic_round
    assert bf.store_struct(32, 8)["mom"].dtype == jnp.bfloat16
    assert row.get("momentum").beta == 0.9
    assert row.get("momentum", beta=0.5).beta == 0.5
    assert row.get("adagrad", eps=1e-4).eps == 1e-4
    with pytest.raises(ValueError, match="unknown sparse optimizer"):
        row.get("rmsprop")
    # store layout ownership
    assert row.get("split_sgd").weight_keys == ("hi", "lo")
    assert row.get("momentum").state_keys == ("mom",)
    st = row.get("adagrad_rowwise").store_struct(32, 8)
    assert st["acc"].shape == (32, 1) and st["w"].shape == (32, 8)


def test_resolve_legacy_and_explicit():
    class Obj:
        sparse_optimizer = None
        split_sgd = True
    assert row.resolve(Obj()).name == "split_sgd"
    Obj.split_sgd = False
    assert row.resolve(Obj()).name == "sgd"
    Obj.sparse_optimizer = "momentum"
    Obj.opt_beta = 0.25
    assert row.resolve(Obj()).beta == 0.25
    Obj.sparse_optimizer = row.get("adagrad")
    del Obj.opt_beta
    assert row.resolve(Obj()).name == "adagrad"


def test_ops_entry_points_only_called_from_row():
    """Acceptance: no production caller outside optim/row.py invokes the
    kernels.ops fused update entry points (the model-facing surface is
    RowOptimizer.apply_sparse); the pre-refactor names are gone."""
    from repro.kernels import ops
    for legacy in ("fused_embedding_update", "fused_embedding_update_fp32",
                   "fused_embedding_update_presorted",
                   "fused_embedding_update_fp32_presorted"):
        assert not hasattr(ops, legacy), legacy
    # ops.fused_row_update* calls (the _split-suffixed jnp oracle in
    # kernels/ref.py is a pure reference, not a kernel invocation)
    pat = re.compile(r"fused_row_update(?!_split)|fused_embedding_update")
    offenders = []
    for root, _, files in os.walk(os.path.join(SRC, "repro")):
        for f in files:
            if not f.endswith(".py"):
                continue
            path = os.path.join(root, f)
            rel = os.path.relpath(path, SRC)
            if rel in (os.path.join("repro", "optim", "row.py"),
                       os.path.join("repro", "kernels", "ops.py")):
                continue
            if pat.search(open(path).read()):
                offenders.append(rel)
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# Degeneration properties
# ---------------------------------------------------------------------------

def test_momentum_beta0_bitwise_sgd_fused():
    """momentum(beta=0) == sgd, bitwise, on the fused path (both
    pre-reduce duplicates; 0*m + acc is an exact fp32 identity) — over a
    duplicate-heavy stream and several steps of carried state."""
    W, idx, dY = _mk(vocab=7, seed=3)
    sgd, mom0 = row.get("sgd"), row.get("momentum", beta=0.0)
    s_sgd = {"w": W}
    s_mom = mom0.init_store(W)
    for i in range(3):
        stream = row.SparseStream(idx=idx, dY=dY * (i + 1))
        s_sgd = sgd.apply_sparse(s_sgd, stream, 0.05, fused=True,
                                 interpret=True)
        s_mom = mom0.apply_sparse(s_mom, stream, 0.05, fused=True,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(s_sgd["w"]),
                                  np.asarray(s_mom["w"]))


def test_split_sgd_matches_jitted_split_reference():
    """split_sgd.apply_sparse(fused=True) == the jitted split_fp32/
    combine_split dedup reference, bitwise."""
    W, idx, dY = _mk(vocab=9, seed=4)
    ss = row.get("split_sgd")
    store = ss.init_store(W)
    out = ss.apply_sparse(store, row.SparseStream(idx=idx, dY=dY), 0.05,
                          fused=True, interpret=True)
    B, S, P = idx.shape
    E = dY.shape[-1]
    grad = jnp.broadcast_to(dY[:, :, None, :],
                            (B, S, P, E)).reshape(-1, E)
    rh, rl = jax.jit(row.apply_rows_split_sgd)(store["hi"], store["lo"],
                                               idx.reshape(-1), grad, 0.05)
    np.testing.assert_array_equal(
        np.asarray(combine_split(out["hi"], out["lo"])),
        np.asarray(combine_split(rh, rl)))


@pytest.mark.parametrize("name", ["adagrad_rowwise", "adagrad"])
@pytest.mark.parametrize("fused", [True, False])
def test_adagrad_first_step_is_scaled_sgd(name, fused):
    """Zero-initialized Adagrad's first step == SGD with the per-row
    (rowwise) / per-element (adagrad) scale ``1/(sqrt(acc_1)+eps)``
    computed from the deduped gradient — documented tolerance 1e-6
    (one extra fp32 division vs the closed form)."""
    W, idx, dY = _mk(vocab=11, seed=5)
    opt = row.get(name)
    out = (opt.apply_sparse(opt.init_store(W),
                            row.SparseStream(idx=idx, dY=dY), 0.05,
                            fused=True, interpret=True)
           if fused else
           jax.jit(lambda s, t: opt.apply_sparse(s, t, 0.05, fused=False)
                   )(opt.init_store(W), row.SparseStream(idx=idx, dY=dY)))
    # numpy oracle: dedup, scale, step
    B, S, P = idx.shape
    E = dY.shape[-1]
    g = np.repeat(np.asarray(dY, np.float32).reshape(-1, E), P, axis=0)
    tgt = np.asarray(idx).reshape(-1)
    want_w = np.asarray(W, np.float64).copy()
    acc1 = np.zeros((W.shape[0], E))
    for r in np.unique(tgt):
        Gr = g[tgt == r].sum(axis=0, dtype=np.float64)
        s1 = (np.mean(Gr * Gr) if name == "adagrad_rowwise" else Gr * Gr)
        scale = 1.0 / (np.sqrt(s1) + opt.eps)
        want_w[r] = want_w[r] - 0.05 * Gr * scale    # scaled SGD
        acc1[r] = s1
    np.testing.assert_allclose(np.asarray(out["w"]), want_w,
                               rtol=1e-5, atol=1e-6)
    got_acc = np.asarray(out["acc"])
    want_acc = (acc1[:, :1] if name == "adagrad_rowwise" else acc1)
    np.testing.assert_allclose(got_acc, want_acc, rtol=1e-5, atol=1e-6)


def test_momentum_reference_matches_fused_and_state_hygiene():
    """Reference (dedup) momentum == fused momentum to fp32 tolerance over
    a trajectory; masked lookups never decay state on either path."""
    W, idx, dY = _mk(vocab=6, seed=6)
    mom = row.get("momentum")
    st_f = mom.init_store(W)
    st_r = mom.init_store(W)
    ref = jax.jit(lambda s, t: mom.apply_sparse(s, t, 0.02, fused=False))
    for i in range(4):
        stream = row.SparseStream(idx=idx, dY=dY * ((-1.0) ** i))
        st_f = mom.apply_sparse(st_f, stream, 0.02, fused=True,
                                interpret=True)
        st_r = ref(st_r, stream)
    np.testing.assert_allclose(np.asarray(st_f["w"]), np.asarray(st_r["w"]),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(st_f["mom"]),
                               np.asarray(st_r["mom"]),
                               rtol=1e-6, atol=1e-7)
    untouched = np.setdiff1d(np.arange(W.shape[0]), np.asarray(idx))
    assert np.all(np.asarray(st_f["mom"])[untouched] == 0)
    np.testing.assert_array_equal(np.asarray(st_f["w"])[untouched],
                                  np.asarray(W)[untouched])
    # all-masked stream: exact no-op on weights AND state, both paths
    stm = {**mom.init_store(W), "mom": jnp.ones_like(st_f["mom"])}
    masked = row.SparseStream(idx=idx, dY=dY,
                              valid=jnp.zeros(idx.shape, bool))
    for out in (mom.apply_sparse(stm, masked, 0.02, fused=True,
                                 interpret=True),
                jax.jit(lambda s, t: mom.apply_sparse(s, t, 0.02,
                                                      fused=False)
                        )(stm, masked)):
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(W))
        assert np.all(np.asarray(out["mom"]) == 1.0)


def test_pinned_legacy_split_kernel_bit_identity():
    """The split_sgd path through the NEW RowOptimizer surface is bitwise
    the PRE-REFACTOR ``ops.fused_embedding_update`` wrapper — pinned here
    verbatim against the unchanged Pallas kernel."""
    from repro.kernels.embedding_update import (fused_update_split_pallas,
                                                sort_lookups)

    def legacy_fused_embedding_update(hi, lo, tgt, dY, lr, valid=None,
                                      weights=None, pooling=1):
        # pre-refactor ops.py wrapper, interpret branch (CPU), verbatim
        M = hi.shape[0]
        srows, sbags, smsk, swgt = sort_lookups(tgt, valid, M, pooling,
                                                weights)
        return fused_update_split_pallas(hi, lo, srows, sbags, smsk, swgt,
                                         dY, lr, interpret=True)

    W, idx, dY = _mk(vocab=8, seed=7)
    B, S, P = idx.shape
    ss = row.get("split_sgd")
    store = ss.init_store(W)
    new = ss.apply_sparse(store, row.SparseStream(idx=idx, dY=dY), 0.05,
                          fused=True, interpret=True)
    lh, ll = jax.jit(legacy_fused_embedding_update,
                     static_argnames=("pooling",))(
        store["hi"], store["lo"], idx.reshape(-1),
        dY.reshape(B * S, -1), 0.05, pooling=P)
    np.testing.assert_array_equal(np.asarray(new["hi"], np.float32),
                                  np.asarray(lh, np.float32))
    np.testing.assert_array_equal(np.asarray(new["lo"]), np.asarray(ll))


def test_chunked_stateful_reference_single_transition(monkeypatch):
    """Batch-chunking the stateful reference path (tiny
    REPRO_EMB_CHUNK_BUDGET) must NOT re-run the optimizer transition per
    chunk: the chunked result matches the unchunked reference to fp32
    accumulation tolerance, i.e. the momentum decay fires once per step,
    not beta^n-compounded across n chunks."""
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import sharded_embedding as se
    from repro.core.embedding import EmbeddingSpec
    from repro.launch.mesh import make_mesh

    layout = se.make_layout(EmbeddingSpec((40, 30), 8), 1, "row")
    mom = row.get("momentum", beta=0.9)
    rng = np.random.default_rng(2)
    W = jnp.asarray(rng.standard_normal((layout.total_rows, 8)),
                    jnp.float32)
    idx = jnp.asarray(rng.integers(0, 5, (8, 2, 3)), jnp.int32)
    dY = jnp.asarray(rng.standard_normal((8, 2, 8)), jnp.float32)
    store = {**mom.init_store(W), "mom": jnp.ones((layout.total_rows, 8),
                                                  jnp.float32)}
    mesh = make_mesh((1, 1), ("data", "model"))
    axes = ("data", "model")

    def run():
        def f(st, idxj, dYj):
            return se.apply_update(layout, st, mom, idxj, dYj, 0.05, axes,
                                   fused=False)
        sm = jax.jit(compat.shard_map(
            f, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(axes, None), store),
                      P(None, None, None), P(None, None, None)),
            out_specs=jax.tree.map(lambda _: P(axes, None), store),
            check_vma=False))
        return {k: np.asarray(v) for k, v in sm(store, idx, dY).items()}

    # per-row bytes = S*P*E*4 = 192; a 200-byte budget forces 8 chunks
    monkeypatch.setenv("REPRO_EMB_CHUNK_BUDGET", "200")
    chunked = run()
    monkeypatch.delenv("REPRO_EMB_CHUNK_BUDGET")
    unchunked = run()
    for k in store:
        np.testing.assert_allclose(chunked[k], unchunked[k],
                                   rtol=1e-5, atol=1e-6)
    # single decay: touched rows carry ~0.9*1 + sum(g), never 0.9^n
    g = np.asarray(idx) + np.asarray(layout.row_offsets,
                                     np.int32)[None, :, None]
    touched = np.unique(g)
    assert not np.array_equal(chunked["mom"][touched],
                              np.ones_like(chunked["mom"][touched]))
    untouched = np.setdiff1d(np.arange(layout.total_rows), touched)
    np.testing.assert_array_equal(chunked["mom"][untouched], 1.0)


# ---------------------------------------------------------------------------
# Checkpoint round-trip + elastic reshard (per optimizer)
# ---------------------------------------------------------------------------

def _small_cfg(optimizer):
    from repro.core.dlrm import DLRMConfig
    return DLRMConfig(name="t", num_dense=8, bottom=(16, 8), top=(16,),
                      table_rows=(50, 30, 20, 10), emb_dim=8, pooling=3,
                      batch=16, sparse_optimizer=optimizer)


def _small_batch(seed):
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.integers(0, max(2, m // 6), (16, 3))
                    for m in (50, 30, 20, 10)], 1).astype(np.int32)
    return {"idx": jnp.asarray(idx),
            "dense_x": jnp.asarray(rng.standard_normal((16, 8)),
                                   jnp.bfloat16),
            "labels": jnp.asarray(rng.integers(0, 2, (16,)), jnp.float32)}


@pytest.mark.parametrize("optimizer", ["sgd", "split_sgd", "momentum",
                                       "adagrad_rowwise", "adagrad"])
def test_checkpoint_roundtrip_resume_bit_identity(optimizer, tmp_path):
    """Save at step 2 / restore / resume == uninterrupted 3-step run,
    bitwise, for every registered optimizer — per-row state slabs persist
    and restore next to the weights (satellite: checkpoint/manager.py)."""
    from repro.checkpoint import CheckpointManager
    from repro.core import dlrm as D
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = _small_cfg(optimizer)
    step, shardings, _, _ = D.make_train_step(cfg, mesh)

    state, _ = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
    mgr = CheckpointManager(tmp_path, keep=2)
    for i in range(2):
        state, _ = step(state, _small_batch(i))
    mgr.save(2, state, blocking=True)
    state, _ = step(state, _small_batch(2))
    want = {k: np.asarray(v) for k, v in state["emb"].items()}

    # restore into the struct tree and resume
    structs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    got_step, restored = mgr.restore(structs, shardings=shardings)
    assert got_step == 2
    opt = row.resolve(cfg)
    assert set(restored["emb"]) == set(opt.weight_keys) | set(opt.state_keys)
    restored, _ = step(restored, _small_batch(2))
    for k, v in want.items():
        np.testing.assert_array_equal(np.asarray(restored["emb"][k]), v), k


def test_reshard_store_preserves_every_slab():
    """reshard_store relays weights AND optimizer-state slabs across a
    shard-count change (elastic restart) table-for-table."""
    from repro.checkpoint.manager import reshard_store
    from repro.core import sharded_embedding as se
    from repro.core.embedding import EmbeddingSpec
    spec = EmbeddingSpec((100, 30, 70, 20), dim=4)
    old = se.make_layout(spec, 4, "row")
    new = se.make_layout(spec, 8, "row")
    rng = np.random.default_rng(0)
    opt = row.get("adagrad_rowwise")
    W = jnp.asarray(rng.standard_normal((old.total_rows, 4)), jnp.float32)
    store = opt.init_store(W)
    store["acc"] = jnp.asarray(
        rng.standard_normal((old.total_rows, 1)) ** 2, jnp.float32)
    out = reshard_store(old, new, store)
    assert set(out) == set(store)
    for t, rows_t in enumerate(spec.table_rows):
        src = int(spec.row_offsets[t])
        for k in store:
            np.testing.assert_array_equal(
                np.asarray(out[k])[src:src + rows_t],
                np.asarray(store[k])[src:src + rows_t])


# ---------------------------------------------------------------------------
# Acceptance (subprocess, 8 devices): all five optimizers through the
# pipelined step, M in {1, 2}, host_presort on and off, row + table mode
# ---------------------------------------------------------------------------

def run_sub(code: str, timeout=1200):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_all_optimizers_through_pipeline():
    """Every registered optimizer x M in {1, 2} x host_presort on/off runs
    the pipelined hybrid step: finite loss, weights move, state slabs
    move, embedding store BIT-IDENTICAL across M, and the host-pre-sorted
    stream bitwise matches the fused device-sort path."""
    out = run_sub("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core.dlrm import DLRMConfig, make_train_step, init_state
    from repro.data.pipeline import presort_batch
    from repro.optim import row

    mesh = compat.make_mesh((2, 4), ('data', 'model'))
    TABLES = (100, 60, 40, 30)
    BASE = DLRMConfig(name='t', num_dense=8, bottom=(16, 8), top=(16,),
                      table_rows=TABLES, emb_dim=8, pooling=3, batch=16)
    rng = np.random.default_rng(0)
    idx = np.stack([rng.integers(0, max(2, m // 8), (16, 3))
                    for m in TABLES], 1).astype(np.int32)
    base_batch = {'idx': jnp.asarray(idx),
                  'dense_x': jnp.asarray(rng.standard_normal((16, 8)),
                                         jnp.bfloat16),
                  'labels': jnp.asarray(rng.integers(0, 2, 16),
                                        jnp.float32)}

    def emb_np(state):
        return {k: np.asarray(v, np.float32) if v.dtype == jnp.bfloat16
                else np.asarray(v) for k, v in state['emb'].items()}

    for name in row.names():
        opt = row.get(name)
        res = {}
        for presort in (False, True):
            for M in (1, 2):
                cfg = dataclasses.replace(
                    BASE, sparse_optimizer=name, microbatches=M,
                    host_presort=presort,
                    # presort always runs the fused kernel; run the
                    # device-sort path fused too so the two are the SAME
                    # kernel on host- vs device-sorted streams (stable
                    # sorts agree => bitwise).  The reference path's
                    # parity with the kernel is unit-tested in
                    # test_row_optim / test_embedding_update.
                    fused_update=True)
                state, layout = init_state(jax.random.PRNGKey(0), cfg,
                                           mesh)
                init = emb_np(state)
                step, _, _, _ = make_train_step(cfg, mesh)
                batch = dict(base_batch)
                if presort:
                    batch.update({k: jnp.asarray(v) for k, v in
                                  presort_batch(layout, idx).items()})
                state, loss = step(state, batch)
                emb1 = emb_np(state)
                state, loss2 = step(state, batch)
                assert np.isfinite(float(loss2)), (name, M, presort)
                got = emb_np(state)
                wk = 'hi' if opt.split else 'w'
                assert not np.array_equal(got[wk], init[wk]), \\
                    (name, M, presort, 'weights did not move')
                for k in opt.state_keys:
                    assert not np.array_equal(got[k], init[k]), \\
                        (name, M, presort, k, 'state did not move')
                res[(presort, M)] = (float(loss), emb1, got)
        for presort in (False, True):
            a, b = res[(presort, 1)], res[(presort, 2)]
            # loss sums per-microbatch partial sums (reassociation), and
            # the ACCUMULATED DENSE grad reassociates too — so the
            # bitwise M-identity contract covers the embedding store
            # after the FIRST step (step 2 sees M-dependent dense nets)
            assert abs(a[0] - b[0]) < 1e-4, (name, presort,
                                             'loss across M')
            for k in a[1]:
                assert np.array_equal(a[1][k], b[1][k]), \\
                    (name, presort, k, 'M-identity')
        # host presort (fused kernel, host-sorted) == device sort (same
        # kernel, device-sorted): stable sorts agree => bitwise, over
        # the full 2-step trajectory
        for M in (1, 2):
            a, b = res[(False, M)], res[(True, M)]
            assert a[0] == b[0], (name, M, 'loss presort vs device')
            for emb_a, emb_b in ((a[1], b[1]), (a[2], b[2])):
                for k in emb_a:
                    assert np.array_equal(emb_a[k], emb_b[k]), \\
                        (name, M, k, 'presort parity')
        print(name, 'ROW_OK')

    # TABLE mode: padded-slot permute folded into the host sort.  The
    # device-sort side runs the reference fallback on CPU (documented
    # XLA-CPU interpret limitation in se.apply_update), so parity is
    # BITWISE for split_sgd (reference == kernel by contract) and
    # tolerance-close for the stateful fp32 kinds.
    for name in ('split_sgd', 'adagrad_rowwise'):
        opt = row.get(name)
        res = {}
        for presort in (False, True):
            cfg = dataclasses.replace(
                BASE, sparse_optimizer=name, emb_mode='table',
                idx_input='sharded', host_presort=presort,
                fused_update=True)
            state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
            step, _, _, _ = make_train_step(cfg, mesh)
            batch = dict(base_batch)
            if presort:
                batch.update({k: jnp.asarray(v) for k, v in
                              presort_batch(layout, idx).items()})
            for _ in range(2):
                state, loss = step(state, batch)
            res[presort] = (float(loss), emb_np(state))
        if name == 'split_sgd':
            assert res[False][0] == res[True][0], (name, 'table loss')
            for k in res[False][1]:
                assert np.array_equal(res[False][1][k], res[True][1][k]), \\
                    (name, k, 'table presort parity')
        else:
            assert abs(res[False][0] - res[True][0]) < 1e-5, (name,
                                                             'table loss')
            for k in res[False][1]:
                np.testing.assert_allclose(res[False][1][k],
                                           res[True][1][k],
                                           rtol=1e-5, atol=1e-6)
        print(name, 'TABLE_OK')
    """)
    assert out.count("ROW_OK") == 8
    assert out.count("TABLE_OK") == 2
