"""Serving subsystem (docs/serve.md): snapshot scoring is BITWISE equal
to the full-state score step, snapshots never leak optimizer state, the
bf16-hi serving table is half the fp32 bytes, versioned publish/retire,
continuous batching over bucketed compiled shapes with a REAL max_wait
deadline, poisoned-worker fail-fast, and train-to-serve freshness."""

import dataclasses
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid as H
from repro.launch.mesh import make_mesh
from repro.models import recsys as R
from repro.serve import (BatchingServer, ContinuousBatchingServer,
                         ServerClosed, SnapshotPublisher, SnapshotRegistry,
                         bucket_for, combined_serve_stats,
                         make_bucket_scorers, make_snapshot_score_step,
                         snapshot_from_state, snapshot_state)
from repro.train import TrainLoop, TrainLoopConfig

RNG = np.random.default_rng(0)


def small_fm(optimizer="split_sgd", B=8):
    return dataclasses.replace(R.make_fm((50,) * 6, batch=B),
                               sparse_optimizer=optimizer)


def fm_batch(mdef, layout, B):
    rows = [mdef.spec.table_rows[t] for t in layout.slot_to_table]
    idx = np.stack([RNG.integers(0, m, (B, 1)) for m in rows], axis=1)
    return {"idx": jnp.asarray(idx, jnp.int32),
            "labels": jnp.asarray(RNG.integers(0, 2, (B,)), jnp.float32)}


# ------------------------------------------------------------ snapshots --

@pytest.mark.parametrize("opt", ["split_sgd", "sgd"])
def test_snapshot_scoring_bitwise_equals_score_step(opt):
    """The acceptance pin: scoring from a ServingSnapshot is bitwise
    identical to hybrid.make_score_step on the same weights — for the
    bf16-hi (split_sgd) AND fp32 (sgd) stores."""
    mesh = make_mesh((1, 1), ("data", "model"))
    mdef = small_fm(opt)
    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    step, _, _, _ = H.make_train_step(mdef, mesh)
    batch = fm_batch(mdef, layout, mdef.batch)
    for _ in range(2):
        state, _ = step(state, batch)

    ref_fn, _, _, _ = H.make_score_step(mdef, mesh)
    ref = np.asarray(ref_fn(state, batch))
    snap = snapshot_from_state(mdef, state, step=2)

    fn, _, _, _ = make_snapshot_score_step(mdef, mesh, donate_batch=False)
    got = np.asarray(fn(snap.state, batch))
    assert got.dtype == ref.dtype and got.tobytes() == ref.tobytes()

    # the donated-batch production path scores the same bits (fresh batch
    # copy: donation consumes the argument buffers)
    fn_d, _, _, _ = make_snapshot_score_step(mdef, mesh, donate_batch=True)
    copy = {k: jnp.array(v) for k, v in batch.items()}
    got_d = np.asarray(fn_d(snap.state, copy))
    assert got_d.tobytes() == ref.tobytes()


def test_snapshot_excludes_optimizer_state():
    """A snapshot holds only forward slabs — never momentum/accumulator
    state, never the Split-SGD lo half — and holds them by REFERENCE."""
    mesh = make_mesh((1, 1), ("data", "model"))
    mdef = small_fm("momentum")
    state, _ = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    assert "mom" in state["emb"]            # the store does carry it
    snap = snapshot_state(mdef, state)
    assert set(snap) == {"emb_w", "dense_hi"}
    assert snap["emb_w"] is state["emb"]["w"]   # default: zero-cost view
    # copy=True (what the publisher uses) owns its buffers, so a train
    # step donating `state` later cannot delete the snapshot's tables
    owned = snapshot_state(mdef, state, copy=True)
    assert owned["emb_w"] is not state["emb"]["w"]
    assert np.array_equal(np.asarray(owned["emb_w"]),
                          np.asarray(state["emb"]["w"]))

    mdef_s = small_fm("split_sgd")
    state_s, _ = H.init_state(jax.random.PRNGKey(1), mdef_s, mesh)
    snap_s = snapshot_state(mdef_s, state_s)
    assert snap_s["emb_w"] is state_s["emb"]["hi"]
    assert snap_s["emb_w"].dtype == jnp.bfloat16


def test_snapshot_bf16_hi_serving_bytes_half_of_fp32():
    mesh = make_mesh((1, 1), ("data", "model"))
    state, _ = H.init_state(jax.random.PRNGKey(0), small_fm("split_sgd"), mesh)
    snap = snapshot_from_state(small_fm("split_sgd"), state)
    assert snap.emb_bytes * 2 == snap.fp32_emb_bytes

    state32, _ = H.init_state(jax.random.PRNGKey(0), small_fm("sgd"), mesh)
    snap32 = snapshot_from_state(small_fm("sgd"), state32)
    assert snap32.emb_bytes == snap32.fp32_emb_bytes


def test_registry_publish_retire_versions():
    reg = SnapshotRegistry(keep=2)
    assert reg.current() is None
    for step in (0, 5, 10):
        reg.publish({"emb_w": np.zeros(1)}, step=step)
    assert reg.versions() == [2, 3]         # keep=2 auto-retired v1
    assert reg.current().version == 3 and reg.current().step == 10
    assert reg.get(1) is None and reg.get(2).step == 5
    assert reg.retire(2) and not reg.retire(2)
    assert reg.versions() == [3]
    with pytest.raises(ValueError):
        SnapshotRegistry(keep=0)


# --------------------------------------------------------------- server --

def test_bucket_for_picks_smallest_fit():
    assert bucket_for(1, (4, 16)) == 4
    assert bucket_for(4, (4, 16)) == 4
    assert bucket_for(5, (4, 16)) == 16
    with pytest.raises(ValueError):
        bucket_for(17, (4, 16))


def _echo_server(**kw):
    """Buckets 4/16; scores payload*2 via a padded 'vals' batch."""
    fns = {b: (lambda batch: batch["vals"] * 2) for b in (4, 16)}
    pad = lambda ps, b: {"vals": np.array(ps + [0] * (b - len(ps)))}  # noqa: E731
    return ContinuousBatchingServer(fns, pad, **kw)


def test_continuous_server_scores_and_batches():
    with _echo_server(max_wait_ms=20.0) as srv:
        handles = [srv.submit(i) for i in range(10)]
        assert [h.result(timeout=10.0) for h in handles] == \
            [2 * i for i in range(10)]
        stats = srv.stats()
    assert stats["requests"] == 10 and stats["queue_depth"] == 0
    # 10 requests coalesce within the wait window: a 16-batch (or a 4 + a
    # 16 if the worker won the race) — never ten 4-batches
    assert sum(stats["batches"].values()) <= 2
    for b, p in stats["buckets"].items():
        assert p["n"] > 0 and p["p50_ms"] <= p["p99_ms"]


def test_continuous_server_partial_batch_waits_for_deadline():
    """A sub-bucket queue is NOT flushed immediately: a request submitted
    30 ms after the first still joins the same compiled batch when
    max_wait_ms covers the gap."""
    with _echo_server(max_wait_ms=300.0) as srv:
        h1 = srv.submit(1)
        t = threading.Timer(0.03, lambda: srv.submit(2))
        t.start()
        assert h1.result(timeout=10.0) == 2
        t.join()
        # both requests rode one batch: the worker waited for the joiner
        deadline = time.perf_counter() + 5.0
        while srv.requests < 2 and time.perf_counter() < deadline:
            time.sleep(0.005)
        assert sum(srv.batches.values()) == 1
        assert srv.requests == 2


def test_continuous_server_poisoned_by_scorer_error():
    fns = {4: lambda batch: (_ for _ in ()).throw(RuntimeError("boom"))}
    pad = lambda ps, b: {}  # noqa: E731
    srv = ContinuousBatchingServer(fns, pad, max_wait_ms=1.0)
    h = srv.submit(0)
    with pytest.raises(ServerClosed) as ei:
        h.result(timeout=10.0)
    assert isinstance(ei.value.__cause__, RuntimeError)
    # sticky-dead: later submits fail promptly instead of hanging
    with pytest.raises(ServerClosed):
        srv.submit(1)
    srv.close()


def test_continuous_server_close_fails_queued():
    srv = _echo_server(max_wait_ms=1.0)
    srv.close()
    with pytest.raises(ServerClosed):
        srv.submit(0)


def test_server_over_snapshots_picks_up_publish():
    """End-to-end: the server reads the registry per batch, so a publish
    between batches serves the NEW tables with no restart."""
    mesh = make_mesh((1, 1), ("data", "model"))
    mdef = small_fm("split_sgd", B=4)
    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    step, _, _, _ = H.make_train_step(mdef, mesh)
    batch = fm_batch(mdef, layout, 4)
    reg = SnapshotRegistry()
    reg.publish(snapshot_state(mdef, state), step=0)
    fns, pad = make_bucket_scorers(mdef, mesh, (4,),
                                   lambda: reg.current().state)
    payloads = [{k: np.asarray(v)[i] for k, v in batch.items()}
                for i in range(4)]
    with ContinuousBatchingServer(fns, pad, max_wait_ms=10.0) as srv:
        r1 = np.array([h.result(60.0) for h in
                       [srv.submit(p) for p in payloads]])
        state2, _ = step(state, batch)
        reg.publish(snapshot_state(mdef, state2), step=1)
        r2 = np.array([h.result(60.0) for h in
                       [srv.submit(p) for p in payloads]])
    assert np.isfinite(r1).all() and np.isfinite(r2).all()
    assert not np.array_equal(r1, r2)       # trained tables are live


# ------------------------------------------------- BatchingServer (sync) --

def test_batching_server_max_wait_is_not_dead():
    """Regression for the dead-parameter bug: a sub-batch-size queue must
    wait for max_wait_ms, not pad-and-flush immediately — a straggler
    submitted from another thread 30 ms in still joins the chunk."""
    srv = BatchingServer(lambda b: np.zeros(4), batch_size=4,
                         pad_batch=lambda reqs: {"n": len(reqs)},
                         max_wait_ms=500.0)
    srv.submit("a")
    srv.submit("b")
    joined = threading.Timer(0.03, lambda: (srv.submit("c"),
                                            srv.submit("d")))
    joined.start()
    t0 = time.perf_counter()
    chunks = [len(reqs) for reqs, _ in srv.drain()]
    dt = time.perf_counter() - t0
    joined.join()
    assert chunks == [4]                    # one full chunk, no early flush
    assert dt < 0.45                        # returned at fill, not deadline


def test_batching_server_flushes_partial_at_deadline():
    srv = BatchingServer(lambda b: np.zeros(4), batch_size=4,
                         pad_batch=lambda reqs: {"n": len(reqs)},
                         max_wait_ms=60.0)
    srv.submit("only")
    t0 = time.perf_counter()
    chunks = [len(reqs) for reqs, _ in srv.drain()]
    dt = time.perf_counter() - t0
    assert chunks == [1]
    assert dt >= 0.055                      # held the partial to deadline


# ------------------------------------------------------- publish + loop --

def test_publisher_cadence_and_freshness():
    mesh = make_mesh((1, 1), ("data", "model"))
    mdef = small_fm("split_sgd")
    state, _ = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    pub = SnapshotPublisher(mdef, publish_every=2)
    assert pub.freshness() == {}
    snap = pub.publish(0, state)            # v1 before training starts
    # published snapshots own their slabs (donation safety)
    assert snap.state["emb_w"] is not state["emb"]["hi"]
    for step in range(1, 6):
        pub(step, state)                    # the TrainLoop step_hook shape
    assert pub.publishes == 3               # step 0, 2, 4
    assert pub.registry.current().version == 3
    f = pub.freshness()
    assert f["version"] == 3 and f["steps_behind"] == 1  # head 5, snap 4
    assert 0 <= f["seconds_behind"] < 60
    stats = combined_serve_stats(pub)()
    assert stats["snapshot"]["publishes"] == 3
    assert stats["snapshot"]["versions"] == [2, 3]
    with pytest.raises(ValueError):
        SnapshotPublisher(mdef, publish_every=0)


def test_trainloop_step_hook_and_serve_heartbeat(tmp_path):
    hooks = []

    def step(state, batch):
        return state + 1, float(state)

    hb = tmp_path / "hb.jsonl"
    loop = TrainLoop(TrainLoopConfig(steps=4, log_every=100, prefetch=0,
                                     heartbeat_path=str(hb),
                                     heartbeat_every=2),
                     step, 0, iter(range(100)),
                     step_hook=lambda s, st: hooks.append(s),
                     serve_stats=lambda: {"snapshot": {"version": 7}})
    loop.run()
    assert hooks == [1, 2, 3, 4]            # every completed step, in order
    recs = [json.loads(ln) for ln in hb.read_text().splitlines()]
    assert recs
    assert all(r["serve"] == {"snapshot": {"version": 7}} for r in recs)
