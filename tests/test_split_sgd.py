"""Property tests for Split-SGD-BF16 (paper Sect. VII) — the system's key
numerical invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, split_sgd as S

# magnitudes bounded away from FLT_MIN: XLA flushes subnormal VALUES AND
# PRODUCTS (lr*g) to zero (FTZ) — expected accelerator semantics, not a
# Split-SGD property
_f = st.one_of(st.just(0.0),
               st.floats(1.0000000031710769e-30, 1e6, allow_nan=False, width=32),
               st.floats(-1e6, -1.0000000031710769e-30, allow_nan=False, width=32))
floats = st.lists(_f, min_size=1, max_size=64)


@settings(max_examples=50, deadline=None)
@given(floats)
def test_split_roundtrip_bit_exact(xs):
    """combine(split(x)) == x for every finite fp32 (pure bit partition)."""
    x = jnp.asarray(xs, jnp.float32)
    hi, lo = S.split_fp32(x)
    assert hi.dtype == jnp.bfloat16 and lo.dtype == jnp.uint16
    rc = S.combine_split(hi, lo)
    assert (np.asarray(rc) == np.asarray(x)).all()


@settings(max_examples=50, deadline=None)
@given(floats, floats, st.floats(min_value=1e-4, max_value=1.0))
def test_update_matches_fp32_within_1ulp(ws, gs, lr):
    """The split update IS an fp32 update (paper: 'runs a fully
    FP32-accurate update').  <=1 ulp tolerance covers FMA-contraction
    differences between compilation modes; the storage itself adds ZERO
    error (see test_split_roundtrip_bit_exact)."""
    n = min(len(ws), len(gs))
    w = jnp.asarray(ws[:n], jnp.float32)
    g = jnp.asarray(gs[:n], jnp.float32)
    hi, lo = S.split_fp32(w)
    nh, nl = S.update_leaf(hi, lo, g, lr)
    got = np.asarray(S.combine_split(nh, nl))
    want = np.asarray(w, np.float32) - np.float32(lr) * np.asarray(
        g, np.float32)
    np.testing.assert_array_max_ulp(got, want.astype(np.float32), maxulp=1)


def test_hi_is_truncated_bf16():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    hi, _ = S.split_fp32(x)
    # hi must alias the upper 16 bits exactly
    bits = np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint32))
    hb = np.asarray(jax.lax.bitcast_convert_type(hi, jnp.uint16))
    assert (hb == (bits >> 16).astype(np.uint16)).all()


def test_trajectory_tracks_fp32():
    """Multi-step split-SGD == fp32 SGD when grads are computed from the
    SAME (hi) weights — the optimizer itself adds zero drift."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal(256), jnp.float32)
    state = S.init({"w": w})
    w_ref = w
    for i in range(50):
        g = jnp.asarray(rng.standard_normal(256), jnp.float32)
        state = S.apply_updates(state, {"w": g}, 0.05)
        w_ref = w_ref - 0.05 * g
    got = np.asarray(S.materialize_fp32(state)["w"])
    np.testing.assert_array_equal(got, np.asarray(w_ref))


def test_momentum_variant():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal(64), jnp.float32)
    state = S.init({"w": w}, momentum=0.9)
    m_ref = np.zeros(64, np.float32)
    w_ref = np.asarray(w).copy()
    for _ in range(10):
        g = rng.standard_normal(64).astype(np.float32)
        state = S.apply_updates(state, {"w": jnp.asarray(g)}, 0.1, beta=0.9)
        m_ref = 0.9 * m_ref + g
        w_ref = w_ref - 0.1 * m_ref
    got = np.asarray(S.materialize_fp32(state)["w"])
    np.testing.assert_allclose(got, w_ref, rtol=1e-6)


def test_split_adamw_state_dtypes():
    params = {"a": jnp.ones((8, 4)), "b": jnp.zeros((3,))}
    st_ = adamw.init(params, split=True)
    assert st_.params.hi["a"].dtype == jnp.bfloat16
    assert st_.params.lo["a"].dtype == jnp.uint16
    g = jax.tree.map(jnp.ones_like, params)
    st2 = adamw.apply_updates(st_, g, 1e-3)
    w = S.combine_split(st2.params.hi["a"], st2.params.lo["a"])
    assert np.isfinite(np.asarray(w)).all()
    assert (np.asarray(w) < 1.0).all()   # moved toward smaller values


def test_capacity_overhead_is_zero():
    """hi+lo == exactly 4 bytes/param (the paper's 'implicit master
    weights'), vs 6 for bf16+fp32-master."""
    x = jnp.zeros((1000,), jnp.float32)
    hi, lo = S.split_fp32(x)
    assert hi.nbytes + lo.nbytes == x.nbytes


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(1, 120), st.integers(0, 2**31 - 1))
def test_fused_row_update_bit_exact(vocab, n, seed):
    """Property: the fused Pallas sparse update (kernels/embedding_update)
    == the jitted dedup + combine_split reference, bitwise, for any
    duplicate structure (vocab << n forces heavy duplication)."""
    from repro.kernels import ops
    from repro.optim.row import apply_rows_split_sgd
    rng = np.random.default_rng(seed)
    E = 8
    w = jnp.asarray(rng.standard_normal((64, E)), jnp.float32)
    hi, lo = S.split_fp32(w)
    tgt = jnp.asarray(rng.integers(0, vocab, (n,)), jnp.int32)
    grad = jnp.asarray(rng.standard_normal((n, E)), jnp.float32)
    out = ops.fused_row_update("split_sgd", {"hi": hi, "lo": lo}, tgt,
                               grad, 0.05, interpret=True)
    nh, nl = out["hi"], out["lo"]
    rh, rl = jax.jit(apply_rows_split_sgd)(hi, lo, tgt, grad, 0.05)
    np.testing.assert_array_equal(
        np.asarray(S.combine_split(nh, nl)),
        np.asarray(S.combine_split(rh, rl)))
